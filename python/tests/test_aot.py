"""AOT pipeline: HLO text generation, variant grid and manifest schema."""

import json
import os

import jax
import pytest

from compile import aot, model


def test_to_hlo_text_produces_parseable_module():
    lowered = jax.jit(
        lambda x, y: model.matmul_tiled_entry(x, y, block=16)
    ).lower(aot.spec((32, 32)), aot.spec((32, 32)))
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule"), text[:80]
    # lowered with return_tuple=True: root computation returns a tuple
    assert "ROOT" in text


def test_sig_format():
    assert aot.sig((128, 64)) == "f32[128,64]"
    assert aot.sig((5,)) == "f32[5]"


def test_variant_grid_complete_and_unique():
    variants = list(aot.variant_grid())
    ids = [f'{v["kernel"]}.{v["label"]}.n{v["size"]}' for v in variants]
    assert len(ids) == len(set(ids)), "duplicate variant ids"
    kernels = {v["kernel"] for v in variants}
    assert kernels == {
        "matmul_tiled",
        "matmul_order",
        "saxpy",
        "stencil",
        "mlp_block",
    }
    # Fig 1 axis: every block candidate present for every matmul size
    from compile.kernels import matmul_tiled

    for n in matmul_tiled.SIZES:
        blocks = [
            v["value"]
            for v in variants
            if v["kernel"] == "matmul_tiled" and v["size"] == n
        ]
        assert blocks == matmul_tiled.BLOCK_CANDIDATES
    # Fig 2-5 axis: all three orders for every size
    from compile.kernels import matmul_orders

    for n in matmul_orders.SIZES:
        labels = [
            v["label"]
            for v in variants
            if v["kernel"] == "matmul_order" and v["size"] == n
        ]
        assert labels == matmul_orders.ORDERS


def test_variant_grid_entries_well_formed():
    for v in aot.variant_grid():
        assert v["flops"] > 0
        assert len(v["inputs"]) == len(v["args"])
        assert v["output"].startswith("f32[")
        assert isinstance(v["value"], int)


def test_source_stamp_stable():
    assert aot.source_stamp() == aot.source_stamp()


@pytest.mark.skipif(
    not os.path.exists(
        os.path.join(os.path.dirname(__file__), "..", "..", "artifacts", "manifest.json")
    ),
    reason="artifacts not built (run `make artifacts`)",
)
def test_built_manifest_matches_grid():
    path = os.path.join(
        os.path.dirname(__file__), "..", "..", "artifacts", "manifest.json"
    )
    with open(path) as f:
        manifest = json.load(f)
    assert manifest["schema"] == aot.SCHEMA_VERSION
    ids = {e["id"] for e in manifest["entries"]}
    grid_ids = {
        f'{v["kernel"]}.{v["label"]}.n{v["size"]}' for v in aot.variant_grid()
    }
    assert ids == grid_ids
    art_dir = os.path.dirname(path)
    for e in manifest["entries"]:
        assert os.path.exists(os.path.join(art_dir, e["path"])), e["path"]
