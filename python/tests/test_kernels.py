"""Pallas kernels vs pure-jnp oracles — the core L1 correctness signal.

hypothesis sweeps shapes, block sizes and seeds; every property asserts
allclose against ``kernels.ref``.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import matmul_orders, matmul_tiled, ref, saxpy, stencil

# interpret-mode pallas is slow; keep example counts modest but meaningful.
COMMON = dict(deadline=None, max_examples=20)


def rand(shape, seed):
    return jax.random.normal(jax.random.key(seed), shape, jnp.float32)


# ---------------------------------------------------------------- matmul_tiled
@settings(**COMMON)
@given(
    logm=st.integers(3, 6),
    logk=st.integers(3, 6),
    logn=st.integers(3, 6),
    block=st.sampled_from(matmul_tiled.BLOCK_CANDIDATES),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_tiled_matches_ref(logm, logk, logn, block, seed):
    m, k, n = 2**logm, 2**logk, 2**logn
    x, y = rand((m, k), seed), rand((k, n), seed + 1)
    got = matmul_tiled.matmul_tiled(x, y, block=block)
    want = ref.matmul(x, y)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_matmul_tiled_block_larger_than_matrix_clamps():
    x, y = rand((16, 16), 0), rand((16, 16), 1)
    got = matmul_tiled.matmul_tiled(x, y, block=256)
    np.testing.assert_allclose(got, ref.matmul(x, y), rtol=1e-4, atol=1e-4)


def test_matmul_tiled_rectangular():
    x, y = rand((32, 128), 2), rand((128, 64), 3)
    got = matmul_tiled.matmul_tiled(x, y, block=32)
    np.testing.assert_allclose(got, ref.matmul(x, y), rtol=1e-4, atol=1e-4)


def test_matmul_tiled_rejects_indivisible():
    x, y = rand((48, 48), 4), rand((48, 48), 5)
    with pytest.raises(AssertionError):
        matmul_tiled.matmul_tiled(x, y, block=32)


def test_clamp_block():
    assert matmul_tiled.clamp_block(512, 32, 32, 32) == 32
    assert matmul_tiled.clamp_block(8, 32, 64, 128) == 8


# --------------------------------------------------------------- matmul_orders
@settings(**COMMON)
@given(
    logn=st.integers(5, 7),
    order=st.sampled_from(matmul_orders.ORDERS),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_orders_match_ref(logn, order, seed):
    n = 2**logn
    x, y = rand((n, n), seed), rand((n, n), seed + 1)
    got = matmul_orders.matmul_order(x, y, order=order)
    np.testing.assert_allclose(got, ref.matmul(x, y), rtol=1e-4, atol=1e-4)


def test_all_orders_agree_with_each_other():
    x, y = rand((64, 64), 10), rand((64, 64), 11)
    outs = [
        np.asarray(matmul_orders.matmul_order(x, y, order=o))
        for o in matmul_orders.ORDERS
    ]
    for other in outs[1:]:
        np.testing.assert_allclose(outs[0], other, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------- saxpy
@settings(**COMMON)
@given(
    logn=st.integers(8, 14),
    chunk=st.sampled_from(saxpy.CHUNK_CANDIDATES),
    a=st.floats(-10, 10, allow_nan=False),
    seed=st.integers(0, 2**31 - 1),
)
def test_saxpy_matches_ref(logn, chunk, a, seed):
    n = 2**logn
    if chunk > n:
        chunk = n
    av = jnp.array([a], jnp.float32)
    x, y = rand((n,), seed), rand((n,), seed + 1)
    got = saxpy.saxpy(av, x, y, chunk=chunk)
    np.testing.assert_allclose(got, ref.saxpy(av, x, y), rtol=1e-5, atol=1e-5)


def test_saxpy_zero_scale():
    av = jnp.array([0.0], jnp.float32)
    x, y = rand((1024,), 1), rand((1024,), 2)
    np.testing.assert_allclose(saxpy.saxpy(av, x, y, chunk=256), y, rtol=0, atol=0)


# --------------------------------------------------------------------- stencil
@settings(**COMMON)
@given(
    logn=st.integers(9, 14),
    block=st.sampled_from(stencil.BLOCK_CANDIDATES),
    seed=st.integers(0, 2**31 - 1),
)
def test_stencil_matches_ref(logn, block, seed):
    n = 2**logn
    if block > n:
        block = n
    x = rand((n,), seed)
    got = stencil.stencil3(x, block=block)
    np.testing.assert_allclose(got, ref.stencil3(x), rtol=1e-5, atol=1e-6)


def test_stencil_boundaries_copied():
    x = jnp.arange(512, dtype=jnp.float32)
    out = stencil.stencil3(x, block=256)
    assert out[0] == x[0]
    assert out[-1] == x[-1]
    # interior of a linear ramp is unchanged: (a-1 + a + a+1)/3 = a
    np.testing.assert_allclose(out[1:-1], x[1:-1], rtol=1e-6)


def test_stencil_single_block_whole_array():
    x = rand((256,), 3)
    got = stencil.stencil3(x, block=4096)  # clamps to n
    np.testing.assert_allclose(got, ref.stencil3(x), rtol=1e-5, atol=1e-6)
