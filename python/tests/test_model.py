"""Layer-2 model entry points: shapes, composition, variant equivalence."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import ref


def rand(shape, seed):
    return jax.random.normal(jax.random.key(seed), shape, jnp.float32)


@pytest.fixture(scope="module")
def mlp_inputs():
    g = model.MLP_SHAPE
    return (
        rand((g["batch"], g["d_in"]), 0),
        rand((g["d_in"], g["hidden"]), 1),
        rand((g["hidden"], g["d_out"]), 2),
    )


@pytest.mark.parametrize("block", model.MLP_BLOCKS)
def test_mlp_block_matches_ref(mlp_inputs, block):
    x, w1, w2 = mlp_inputs
    got = model.mlp_block_entry(x, w1, w2, block=block)
    want = ref.mlp_block(x, w1, w2)
    np.testing.assert_allclose(got, want, rtol=5e-4, atol=5e-4)


def test_mlp_variants_agree(mlp_inputs):
    x, w1, w2 = mlp_inputs
    outs = [
        np.asarray(model.mlp_block_entry(x, w1, w2, block=b))
        for b in model.MLP_BLOCKS
    ]
    for other in outs[1:]:
        # different block sizes change the f32 accumulation order; only an
        # absolute tolerance is meaningful near zero
        np.testing.assert_allclose(outs[0], other, rtol=1e-3, atol=2e-3)


def test_mlp_output_shape(mlp_inputs):
    x, w1, w2 = mlp_inputs
    g = model.MLP_SHAPE
    out = model.mlp_block_entry(x, w1, w2, block=32)
    assert out.shape == (g["batch"], g["d_out"])


def test_mlp_relu_nonlinearity(mlp_inputs):
    """The hidden layer must actually clamp: a negated input should not
    simply negate the output (it would for a purely linear block)."""
    x, w1, w2 = mlp_inputs
    out_pos = np.asarray(model.mlp_block_entry(x, w1, w2, block=32))
    out_neg = np.asarray(model.mlp_block_entry(-x, w1, w2, block=32))
    assert not np.allclose(out_neg, -out_pos, rtol=1e-3, atol=1e-3)


def test_mlp_blocks_divide_geometry():
    g = model.MLP_SHAPE
    for b in model.MLP_BLOCKS:
        for dim in g.values():
            assert dim % b == 0, f"block {b} does not divide {dim}"
