"""AOT lowering: sweep the variant grid, emit HLO text + manifest.

This is the only Python that ever runs, and it runs once (``make
artifacts``). For every (kernel, tuning-parameter value, problem size) it
lowers the jitted Layer-2 entry point to **HLO text** and records the
variant in ``artifacts/manifest.json``. The Rust coordinator JIT-compiles
these artifacts at run time via PJRT — the paper's run-time specialization
step, with the template AST replaced by HLO text.

HLO *text*, not ``.serialize()``: jax ≥ 0.5 emits HloModuleProto with
64-bit instruction ids which xla_extension 0.5.1 (the version the `xla`
crate binds) rejects; the text parser reassigns ids and round-trips
cleanly. See /opt/xla-example/README.md.
"""

import argparse
import hashlib
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import matmul_orders, matmul_tiled, saxpy, stencil

SCHEMA_VERSION = 1


def to_hlo_text(lowered) -> str:
    """Convert a jax lowering to XLA HLO text (the interchange format)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def sig(shape, dtype="f32") -> str:
    """Signature string, e.g. ``f32[128,128]`` — shared with the Rust side."""
    return f"{dtype}[{','.join(str(d) for d in shape)}]"


def spec(shape):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.float32)


def variant_grid():
    """Yield every variant to lower.

    Each item: dict with kernel, param, value (int), label, size, the
    callable+example args to lower, input/output signatures and a FLOP
    count for throughput reporting.
    """
    # --- matmul_tiled: Fig 1 / Listing 6 (block-size axis) ---------------
    for n in matmul_tiled.SIZES:
        a = spec((n, n))
        for block in matmul_tiled.BLOCK_CANDIDATES:
            yield dict(
                kernel="matmul_tiled",
                param="block",
                value=block,
                label=f"b{block}",
                size=n,
                fn=lambda x, y, b=block: model.matmul_tiled_entry(x, y, block=b),
                args=(a, a),
                inputs=[sig((n, n)), sig((n, n))],
                output=sig((n, n)),
                flops=2 * n**3,
            )

    # --- matmul_orders: Fig 2-5 / Listing 5 (implementation axis) --------
    for n in matmul_orders.SIZES:
        a = spec((n, n))
        for idx, order in enumerate(matmul_orders.ORDERS):
            yield dict(
                kernel="matmul_order",
                param="order",
                value=idx,
                label=order,
                size=n,
                fn=lambda x, y, o=order: model.matmul_order_entry(x, y, order=o),
                args=(a, a),
                inputs=[sig((n, n)), sig((n, n))],
                output=sig((n, n)),
                flops=2 * n**3,
            )

    # --- saxpy: Listing 1 (chunk/unroll axis) -----------------------------
    for n in saxpy.SIZES:
        for chunk in saxpy.CHUNK_CANDIDATES:
            if chunk > n:
                continue
            yield dict(
                kernel="saxpy",
                param="chunk",
                value=chunk,
                label=f"c{chunk}",
                size=n,
                fn=lambda a_, x, y, c=chunk: model.saxpy_entry(a_, x, y, chunk=c),
                args=(spec((1,)), spec((n,)), spec((n,))),
                inputs=[sig((1,)), sig((n,)), sig((n,))],
                output=sig((n,)),
                flops=2 * n,
            )

    # --- stencil: parameter-reuse kernel ----------------------------------
    for n in stencil.SIZES:
        for block in stencil.BLOCK_CANDIDATES:
            if block > n:
                continue
            yield dict(
                kernel="stencil",
                param="block",
                value=block,
                label=f"b{block}",
                size=n,
                fn=lambda x, b=block: model.stencil_entry(x, block=b),
                args=(spec((n,)),),
                inputs=[sig((n,))],
                output=sig((n,)),
                flops=3 * n,
            )

    # --- mlp_block: end-to-end serving model ------------------------------
    g = model.MLP_SHAPE
    b_, d, h, o = g["batch"], g["d_in"], g["hidden"], g["d_out"]
    for block in model.MLP_BLOCKS:
        yield dict(
            kernel="mlp_block",
            param="block",
            value=block,
            label=f"b{block}",
            size=b_,
            fn=lambda x, w1, w2, bl=block: model.mlp_block_entry(
                x, w1, w2, block=bl
            ),
            args=(spec((b_, d)), spec((d, h)), spec((h, o))),
            inputs=[sig((b_, d)), sig((d, h)), sig((h, o))],
            output=sig((b_, o)),
            flops=2 * b_ * d * h + 2 * b_ * h * o,
        )


def source_stamp() -> str:
    """Content hash of every Python source that feeds the artifacts."""
    here = os.path.dirname(os.path.abspath(__file__))
    digest = hashlib.sha256()
    for root, _dirs, files in sorted(os.walk(here)):
        for name in sorted(files):
            if name.endswith(".py"):
                with open(os.path.join(root, name), "rb") as f:
                    digest.update(name.encode())
                    digest.update(f.read())
    return digest.hexdigest()


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default=None, help="artifacts directory")
    ap.add_argument("--out", default=None, help="(compat) any path inside the artifacts dir")
    ap.add_argument("--only", default=None, help="limit to one kernel family")
    ap.add_argument("--force", action="store_true", help="regenerate even if stamp matches")
    opts = ap.parse_args()

    out_dir = opts.out_dir
    if out_dir is None and opts.out is not None:
        out_dir = os.path.dirname(os.path.abspath(opts.out)) or "."
    if out_dir is None:
        out_dir = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    out_dir = os.path.abspath(out_dir)
    os.makedirs(out_dir, exist_ok=True)

    stamp_path = os.path.join(out_dir, ".stamp")
    manifest_path = os.path.join(out_dir, "manifest.json")
    stamp = source_stamp()
    if (
        not opts.force
        and not opts.only
        and os.path.exists(stamp_path)
        and os.path.exists(manifest_path)
        and open(stamp_path).read().strip() == stamp
    ):
        print(f"artifacts up to date ({out_dir})")
        return 0

    entries = []
    count = 0
    for v in variant_grid():
        if opts.only and v["kernel"] != opts.only:
            continue
        vid = f'{v["kernel"]}.{v["label"]}.n{v["size"]}'
        path = f"{vid}.hlo.txt"
        lowered = jax.jit(v["fn"]).lower(*v["args"])
        text = to_hlo_text(lowered)
        with open(os.path.join(out_dir, path), "w") as f:
            f.write(text)
        entries.append(
            dict(
                id=vid,
                kernel=v["kernel"],
                param=v["param"],
                value=v["value"],
                label=v["label"],
                size=v["size"],
                inputs=v["inputs"],
                output=v["output"],
                path=path,
                flops=v["flops"],
            )
        )
        count += 1
        print(f"[{count:3}] {vid:40} {len(text):8} chars", file=sys.stderr)

    manifest = dict(
        schema=SCHEMA_VERSION,
        generated_by="python/compile/aot.py",
        jax_version=jax.__version__,
        entries=entries,
    )
    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=2)
    if not opts.only:
        with open(stamp_path, "w") as f:
            f.write(stamp)
    print(f"wrote {count} artifacts + manifest to {out_dir}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
