"""Pure-jnp oracles for every Layer-1 kernel.

These definitions are the single source of numerical truth on the Python
side; ``python/tests`` asserts each Pallas kernel against them, and the
Rust side re-implements them independently (``rust/src/tensor/reference.rs``)
for the cross-language check.
"""

import jax.numpy as jnp


def matmul(x, y):
    """C = A @ B with f32 accumulation."""
    return jnp.matmul(x, y, preferred_element_type=jnp.float32)


def saxpy(a, x, y):
    """y' = a * x + y. ``a`` is a shape-(1,) array (scalar broadcast)."""
    return a[0] * x + y


def stencil3(x):
    """3-point Jacobi average with copied boundaries."""
    interior = (x[:-2] + x[1:-1] + x[2:]) / 3.0
    return jnp.concatenate([x[:1], interior, x[-1:]])


def relu(x):
    """max(x, 0)."""
    return jnp.maximum(x, 0.0)


def mlp_block(x, w1, w2):
    """relu(x @ w1) @ w2 — the end-to-end serving example's model."""
    return matmul(relu(matmul(x, w1)), w2)
