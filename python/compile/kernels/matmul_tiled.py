"""Blocked matrix multiplication — the paper's Listing 6 kernel.

The tuning axis is the tile edge ``block`` (the paper's loop-tiling block
size). The Pallas grid iterates over (M/b, N/b, K/b) tiles; each program
instance multiplies one (b, b) tile pair and accumulates into the output
tile. ``BlockSpec`` expresses the HBM↔VMEM schedule that the paper's C
loop nest expressed with blocking.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, y_ref, o_ref):
    # Zero the output tile on its first visit (k == 0), then accumulate
    # one (b, b) @ (b, b) product per contraction step.
    @pl.when(pl.program_id(2) == 0)
    def _():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    )


def clamp_block(block: int, *dims: int) -> int:
    """Tile edge actually used: ``block`` clamped to the smallest dim.

    The paper sweeps block sizes past the matrix size for small matrices
    (Fig 1, N=32 with blocks up to 512); a block larger than the matrix
    degenerates to "no tiling", which we express by clamping.
    """
    return min(block, *dims)


@functools.partial(jax.jit, static_argnames=("block",))
def matmul_tiled(x, y, *, block: int):
    """C[M,N] = A[M,K] @ B[K,N] with square tile edge ``block``.

    M, K, N must be divisible by the (clamped) block — all shipped
    problem sizes are powers of two, as in the paper's benchmark.
    """
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, f"contraction mismatch {k} vs {k2}"
    b = clamp_block(block, m, k, n)
    assert m % b == 0 and k % b == 0 and n % b == 0, (
        f"dims ({m},{k},{n}) not divisible by block {b}"
    )
    grid = (m // b, n // b, k // b)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((b, b), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((b, b), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((b, b), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, y)


#: Tuning-parameter values shipped in the manifest (the paper's Fig 1 axis).
BLOCK_CANDIDATES = [8, 16, 32, 64, 128, 256]

#: Problem sizes exercised by the benchmarks (paper: 32..2048, scaled to
#: CPU-PJRT interpret-mode cost — see DESIGN.md §Substitutions).
SIZES = [32, 64, 128, 256, 512]
