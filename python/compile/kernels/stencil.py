"""1-D 3-point Jacobi stencil, blocked — the parameter-reuse kernel.

``examples/param_reuse.rs`` reproduces the paper's §3.2 scenario: the
block size tuned for the matmul is handed to *another* JIT-compiled
kernel (this one) as a plain parameter instead of re-tuning.

The kernel sees the whole input each step (BlockSpec covers the full
array) and uses dynamic slices for the halo reads, processing ``block``
output elements per grid step. Boundary elements are copied through, as
in the reference.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(block, n, x_ref, o_ref):
    pid = pl.program_id(0)
    start = pid * block

    # Center window plus one halo element on each side. The halo loads are
    # clamped at the array edges; the clamped values only ever reach the
    # two global boundary outputs, which are overwritten by the
    # copy-through below, so the clamping is observationally exact.
    center = pl.load(x_ref, (pl.dslice(start, block),))
    lh = pl.load(x_ref, (pl.dslice(jnp.maximum(start - 1, 0), 1),))
    rh = pl.load(x_ref, (pl.dslice(jnp.minimum(start + block, n - 1), 1),))
    left = jnp.concatenate([lh, center[:-1]])
    right = jnp.concatenate([center[1:], rh])

    avg = (left + center + right) / 3.0

    # Copy the two global boundary elements through unchanged.
    idx = start + jnp.arange(block)
    is_boundary = (idx == 0) | (idx == n - 1)
    out = jnp.where(is_boundary, center, avg)
    pl.store(o_ref, (pl.dslice(start, block),), out)


@functools.partial(jax.jit, static_argnames=("block",))
def stencil3(x, *, block: int):
    """out[i] = (x[i-1] + x[i] + x[i+1]) / 3, boundaries copied."""
    (n,) = x.shape
    b = min(block, n)
    assert n % b == 0
    return pl.pallas_call(
        functools.partial(_kernel, b, n),
        grid=(n // b,),
        in_specs=[pl.BlockSpec((n,), lambda i: (0,))],
        out_specs=pl.BlockSpec((n,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(x)


#: Block candidates (receives the matmul's tuned block in param_reuse).
BLOCK_CANDIDATES = [256, 1024, 4096]

#: Array lengths shipped in the manifest.
SIZES = [16384, 65536]
