"""saxpy (y' = a·x + y) — the paper's Listing 1 motivating kernel.

The tuning axis is the chunk processed per Pallas program instance (the
analog of the unrolling factor the paper tunes for this kernel): larger
chunks mean fewer grid steps with more work each, smaller chunks the
opposite — the classic vector-kernel granularity trade-off.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(a_ref, x_ref, y_ref, o_ref):
    o_ref[...] = a_ref[0] * x_ref[...] + y_ref[...]


@functools.partial(jax.jit, static_argnames=("chunk",))
def saxpy(a, x, y, *, chunk: int):
    """y' = a[0] * x + y over rank-1 arrays, ``chunk`` elements per step.

    ``a`` is a shape-(1,) f32 array (scalars travel as tiny arrays so the
    artifact signature stays uniform: every input is an array).
    """
    (n,) = x.shape
    c = min(chunk, n)
    assert n % c == 0, f"n={n} not divisible by chunk={c}"
    return pl.pallas_call(
        _kernel,
        grid=(n // c,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((c,), lambda i: (i,)),
            pl.BlockSpec((c,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((c,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(a, x, y)


#: Chunk candidates (the tuning-parameter array of Listing 1/4).
CHUNK_CANDIDATES = [256, 1024, 4096, 16384]

#: Vector lengths shipped in the manifest.
SIZES = [16384, 131072]
