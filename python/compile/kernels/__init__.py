"""Layer-1 Pallas kernels.

Each module exposes one kernel family parameterized by the paper's tuning
axis (block size, loop order, chunk/unroll factor). All kernels are built
with ``interpret=True``: the CPU PJRT plugin cannot execute Mosaic
custom-calls, and interpret-mode lowering produces plain HLO that runs on
any backend (see DESIGN.md §Hardware-Adaptation).
"""

from . import matmul_orders, matmul_tiled, ref, saxpy, stencil

__all__ = ["matmul_tiled", "matmul_orders", "saxpy", "stencil", "ref"]
