"""Matmul with permuted loop orders — the paper's Listing 5 benchmark.

The paper's Fig 2–5 choose between three *implementations* of a
straightforward matmul that differ only in loop order (ijk, ikj, jik).
The Pallas analog permutes the **grid iteration order**: the grid is
iterated row-major, so placing a different axis innermost reproduces the
locality differences of the C loop permutations (output-tile reuse for
k-innermost, streaming rank-1-style updates for j-innermost, and a
column-major outer walk for jik). See DESIGN.md §Hardware-Adaptation.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .matmul_tiled import clamp_block

#: Fixed tile edge for the loop-order family (the paper fixes the
#: implementation body and varies only the order).
ORDER_BLOCK = 32

#: The implementation-choice axis (paper's function-pointer array).
ORDERS = ["ijk", "ikj", "jik"]


def _accum_kernel(k_axis):
    def kernel(x_ref, y_ref, o_ref):
        @pl.when(pl.program_id(k_axis) == 0)
        def _():
            o_ref[...] = jnp.zeros_like(o_ref)

        o_ref[...] += jnp.dot(
            x_ref[...], y_ref[...], preferred_element_type=jnp.float32
        )

    return kernel


# Per-order grid layout: grid axes are iterated row-major (last innermost).
#   ijk: (i, j, k)  — contraction innermost: output tile stays hot.
#   ikj: (i, k, j)  — j innermost: x tile reused, output revisited per k.
#   jik: (j, i, k)  — column-major outer walk over the output.
# Each entry maps grid coords -> (x block, y block, o block) index maps and
# tells which grid axis carries the contraction.
_LAYOUTS = {
    "ijk": dict(
        k_axis=2,
        x=lambda i, j, k: (i, k),
        y=lambda i, j, k: (k, j),
        o=lambda i, j, k: (i, j),
    ),
    "ikj": dict(
        k_axis=1,
        x=lambda i, k, j: (i, k),
        y=lambda i, k, j: (k, j),
        o=lambda i, k, j: (i, j),
    ),
    "jik": dict(
        k_axis=2,
        x=lambda j, i, k: (i, k),
        y=lambda j, i, k: (k, j),
        o=lambda j, i, k: (i, j),
    ),
}


@functools.partial(jax.jit, static_argnames=("order",))
def matmul_order(x, y, *, order: str):
    """C = A @ B using the loop order named by ``order`` (ijk|ikj|jik)."""
    layout = _LAYOUTS[order]
    m, k = x.shape
    k2, n = y.shape
    assert k == k2
    b = clamp_block(ORDER_BLOCK, m, k, n)
    assert m % b == 0 and k % b == 0 and n % b == 0
    tiles = {"i": m // b, "j": n // b, "k": k // b}
    grid = tuple(tiles[ax] for ax in order)
    return pl.pallas_call(
        _accum_kernel(layout["k_axis"]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((b, b), layout["x"]),
            pl.BlockSpec((b, b), layout["y"]),
        ],
        out_specs=pl.BlockSpec((b, b), layout["o"]),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, y)


#: Problem sizes for Fig 2–5 (paper: 128/512/2048, scaled).
SIZES = [64, 128, 256, 512]
