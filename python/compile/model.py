"""Layer-2 JAX entry points — one jitted function per (kernel, variant).

Every entry point here is what ``aot.py`` lowers to an HLO artifact. The
entry points call the Layer-1 Pallas kernels so the kernel lowers into the
same HLO module; the Rust coordinator then JIT-compiles whole modules via
PJRT at run time (the paper's ``__clang_jit`` analog).
"""

import functools

import jax
import jax.numpy as jnp

from .kernels import matmul_orders, matmul_tiled, ref, saxpy, stencil


def matmul_tiled_entry(x, y, *, block: int):
    """Tiled matmul entry (Fig 1 / Listing 6 kernel)."""
    return matmul_tiled.matmul_tiled(x, y, block=block)


def matmul_order_entry(x, y, *, order: str):
    """Loop-order matmul entry (Fig 2–5 / Listing 5 kernel)."""
    return matmul_orders.matmul_order(x, y, order=order)


def saxpy_entry(a, x, y, *, chunk: int):
    """saxpy entry (Listing 1 kernel)."""
    return saxpy.saxpy(a, x, y, chunk=chunk)


def stencil_entry(x, *, block: int):
    """Jacobi stencil entry (parameter-reuse kernel)."""
    return stencil.stencil3(x, block=block)


@functools.partial(jax.jit, static_argnames=("block",))
def mlp_block_entry(x, w1, w2, *, block: int):
    """End-to-end model: relu(x @ w1) @ w2, both matmuls through the
    tiled Pallas kernel with the same (tunable) block size.

    This is the serving example's model: the autotuner tunes ``block``
    across the whole two-matmul block at once — the paper's point that
    tuning happens on the real composition, in the real execution
    conditions, not on an isolated kernel.
    """
    h = matmul_tiled.matmul_tiled(x, w1, block=block)
    h = jnp.maximum(h, 0.0)
    return matmul_tiled.matmul_tiled(h, w2, block=block)


#: MLP geometry for the serving example: batch x d_in -> hidden -> d_out.
MLP_SHAPE = {"batch": 64, "d_in": 256, "hidden": 512, "d_out": 256}

#: Block candidates for the MLP (must divide batch/d_in/hidden/d_out).
MLP_BLOCKS = [16, 32, 64]

# Re-exported oracles so tests can reach everything through `model`.
REFS = {
    "matmul_tiled": ref.matmul,
    "matmul_order": ref.matmul,
    "saxpy": ref.saxpy,
    "stencil": ref.stencil3,
    "mlp_block": ref.mlp_block,
}
