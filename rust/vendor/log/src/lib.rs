//! Minimal re-implementation of the `log` crate's facade API.
//!
//! The build environment is fully offline, so crates.io's `log` cannot be
//! fetched; this path crate provides the exact subset jitune uses —
//! `Level`, `LevelFilter`, the `Log` trait with `Record`/`Metadata`,
//! `set_logger`/`set_max_level`/`max_level`, and the five leveled macros.
//! Semantics mirror the real facade: records above `max_level()` are
//! dropped before the logger is consulted, and `set_logger` succeeds only
//! once.

use std::fmt;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Verbosity level of a log record. Smaller = more severe (the real
/// crate's ordering, so `Level <= LevelFilter` filters correctly).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Level {
    /// Unrecoverable or must-see conditions.
    Error = 1,
    /// Recoverable faults (e.g. a variant failing during tuning).
    Warn,
    /// High-level lifecycle events.
    Info,
    /// Per-call diagnostics.
    Debug,
    /// Everything.
    Trace,
}

/// Maximum-verbosity filter, `Level` plus `Off`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum LevelFilter {
    /// Disable all logging.
    Off = 0,
    /// See [`Level::Error`].
    Error,
    /// See [`Level::Warn`].
    Warn,
    /// See [`Level::Info`].
    Info,
    /// See [`Level::Debug`].
    Debug,
    /// See [`Level::Trace`].
    Trace,
}

impl LevelFilter {
    fn from_usize(v: usize) -> LevelFilter {
        match v {
            1 => LevelFilter::Error,
            2 => LevelFilter::Warn,
            3 => LevelFilter::Info,
            4 => LevelFilter::Debug,
            5 => LevelFilter::Trace,
            _ => LevelFilter::Off,
        }
    }
}

impl PartialEq<LevelFilter> for Level {
    fn eq(&self, other: &LevelFilter) -> bool {
        *self as usize == *other as usize
    }
}

impl PartialOrd<LevelFilter> for Level {
    fn partial_cmp(&self, other: &LevelFilter) -> Option<std::cmp::Ordering> {
        (*self as usize).partial_cmp(&(*other as usize))
    }
}

impl PartialEq<Level> for LevelFilter {
    fn eq(&self, other: &Level) -> bool {
        *self as usize == *other as usize
    }
}

impl PartialOrd<Level> for LevelFilter {
    fn partial_cmp(&self, other: &Level) -> Option<std::cmp::Ordering> {
        (*self as usize).partial_cmp(&(*other as usize))
    }
}

impl fmt::Display for Level {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        f.write_str(s)
    }
}

/// Metadata about a log record (level + target module path).
#[derive(Debug, Clone)]
pub struct Metadata<'a> {
    level: Level,
    target: &'a str,
}

impl<'a> Metadata<'a> {
    /// The record's verbosity level.
    pub fn level(&self) -> Level {
        self.level
    }

    /// The record's target (module path by default).
    pub fn target(&self) -> &'a str {
        self.target
    }
}

/// One log record: metadata plus the formatted message arguments.
#[derive(Debug, Clone)]
pub struct Record<'a> {
    metadata: Metadata<'a>,
    args: fmt::Arguments<'a>,
}

impl<'a> Record<'a> {
    /// The record's metadata.
    pub fn metadata(&self) -> &Metadata<'a> {
        &self.metadata
    }

    /// The record's verbosity level.
    pub fn level(&self) -> Level {
        self.metadata.level
    }

    /// The record's target.
    pub fn target(&self) -> &'a str {
        self.metadata.target
    }

    /// The message, ready to render with `{}`.
    pub fn args(&self) -> &fmt::Arguments<'a> {
        &self.args
    }
}

/// A logging backend. Implementations must be thread-safe: records arrive
/// from any thread.
pub trait Log: Send + Sync {
    /// Whether a record with this metadata would be logged.
    fn enabled(&self, metadata: &Metadata<'_>) -> bool;
    /// Log the record.
    fn log(&self, record: &Record<'_>);
    /// Flush buffered output.
    fn flush(&self);
}

/// Error returned when [`set_logger`] is called more than once.
#[derive(Debug)]
pub struct SetLoggerError(());

impl fmt::Display for SetLoggerError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str("a logger is already installed")
    }
}

impl std::error::Error for SetLoggerError {}

static LOGGER: OnceLock<&'static dyn Log> = OnceLock::new();
static MAX_LEVEL: AtomicUsize = AtomicUsize::new(LevelFilter::Off as usize);

/// Install the global logger. Fails if one is already installed.
pub fn set_logger(logger: &'static dyn Log) -> Result<(), SetLoggerError> {
    LOGGER.set(logger).map_err(|_| SetLoggerError(()))
}

/// Set the global maximum verbosity.
pub fn set_max_level(level: LevelFilter) {
    MAX_LEVEL.store(level as usize, Ordering::Relaxed);
}

/// The global maximum verbosity.
pub fn max_level() -> LevelFilter {
    LevelFilter::from_usize(MAX_LEVEL.load(Ordering::Relaxed))
}

/// Macro plumbing: filter against `max_level`, then forward to the
/// installed logger (if any). Public because the exported macros expand to
/// calls of it from other crates; not part of the supported API.
#[doc(hidden)]
pub fn __log(level: Level, target: &str, args: fmt::Arguments<'_>) {
    if level as usize > MAX_LEVEL.load(Ordering::Relaxed) {
        return;
    }
    if let Some(logger) = LOGGER.get() {
        let record = Record { metadata: Metadata { level, target }, args };
        logger.log(&record);
    }
}

/// Log at an explicit level: `log!(Level::Info, "x = {}", x)`.
#[macro_export]
macro_rules! log {
    ($lvl:expr, $($arg:tt)+) => {
        $crate::__log($lvl, ::core::module_path!(), ::core::format_args!($($arg)+))
    };
}

/// Log at [`Level::Error`].
#[macro_export]
macro_rules! error {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Error, $($arg)+) };
}

/// Log at [`Level::Warn`].
#[macro_export]
macro_rules! warn {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Warn, $($arg)+) };
}

/// Log at [`Level::Info`].
#[macro_export]
macro_rules! info {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Info, $($arg)+) };
}

/// Log at [`Level::Debug`].
#[macro_export]
macro_rules! debug {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Debug, $($arg)+) };
}

/// Log at [`Level::Trace`].
#[macro_export]
macro_rules! trace {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Trace, $($arg)+) };
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    struct CountingLogger {
        seen: AtomicU64,
    }

    impl Log for CountingLogger {
        fn enabled(&self, metadata: &Metadata<'_>) -> bool {
            metadata.level() <= max_level()
        }

        fn log(&self, record: &Record<'_>) {
            // exercise the accessors
            let _ = (record.level(), record.target(), format!("{}", record.args()));
            self.seen.fetch_add(1, Ordering::Relaxed);
        }

        fn flush(&self) {}
    }

    static TEST_LOGGER: CountingLogger = CountingLogger { seen: AtomicU64::new(0) };

    #[test]
    fn filtering_and_delivery() {
        let _ = set_logger(&TEST_LOGGER);
        set_max_level(LevelFilter::Info);
        let before = TEST_LOGGER.seen.load(Ordering::Relaxed);
        info!("hello {}", 42);
        debug!("dropped: above max level");
        let after = TEST_LOGGER.seen.load(Ordering::Relaxed);
        assert_eq!(after - before, 1);
        // second installation fails
        assert!(set_logger(&TEST_LOGGER).is_err());
    }

    #[test]
    fn level_vs_filter_ordering() {
        assert!(Level::Error <= LevelFilter::Info);
        assert!(Level::Info <= LevelFilter::Info);
        assert!(Level::Debug > LevelFilter::Info);
        assert!(Level::Trace > LevelFilter::Off);
    }
}
