//! Stub of the `xla` (xla_extension) bindings used by `jitune`'s PJRT
//! engine.
//!
//! The build environment has no network access and no XLA shared library,
//! so the real bindings cannot be compiled. This crate mirrors the exact
//! API surface `jitune::runtime::pjrt` and `benches/perf_probe` consume,
//! with [`PjRtClient::cpu`] returning an error: everything compiles and
//! every non-PJRT code path (mock engine, coordinator, autotuner, all
//! mock-backed tests) runs, while attempts to use the real backend fail
//! fast with an actionable message. Environments that ship the real
//! `xla_extension` bindings replace this directory (the dependency is a
//! plain path crate) and nothing else changes.

use std::fmt;

/// Error type mirroring the bindings' error.
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

/// Result alias mirroring the bindings.
pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>() -> Result<T> {
    Err(Error(
        "PJRT backend unavailable: jitune was built against the stub `xla` crate \
         (rust/vendor/xla). Install the real xla_extension bindings to run on PJRT; \
         the mock engine and all coordinator/autotuner paths work without them."
            .to_string(),
    ))
}

/// A host literal (stub: carries no data).
#[derive(Debug, Clone)]
pub struct Literal;

/// Element dtypes accepted by [`Literal::create_from_shape_and_untyped_data`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    /// 32-bit float.
    F32,
}

impl Literal {
    /// Rank-1 literal from a slice.
    pub fn vec1<T>(_data: &[T]) -> Literal {
        Literal
    }

    /// Reshape to the given dimensions.
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Ok(Literal)
    }

    /// Single-copy construction from raw bytes.
    pub fn create_from_shape_and_untyped_data(
        _ty: ElementType,
        _shape: &[usize],
        _data: &[u8],
    ) -> Result<Literal> {
        Ok(Literal)
    }

    /// Unwrap a 1-tuple literal.
    pub fn to_tuple1(self) -> Result<Literal> {
        unavailable()
    }

    /// Copy out as a typed vector.
    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable()
    }
}

/// Parsed HLO module proto (stub).
#[derive(Debug)]
pub struct HloModuleProto;

impl HloModuleProto {
    /// Parse HLO text without verification.
    pub fn parse_and_return_unverified_module(_text: &[u8]) -> Result<HloModuleProto> {
        unavailable()
    }
}

/// An XLA computation (stub).
#[derive(Debug)]
pub struct XlaComputation;

impl XlaComputation {
    /// Wrap a parsed module proto.
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// A device buffer produced by an execution (stub; never constructed).
#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    /// Copy the buffer back to a host literal.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable()
    }
}

/// A loaded executable (stub; never constructed — `compile` errors first).
#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Execute with the given arguments.
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable()
    }
}

/// A PJRT client (stub: construction always fails).
#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    /// Create a CPU client. Always fails in the stub.
    pub fn cpu() -> Result<PjRtClient> {
        unavailable()
    }

    /// Platform name.
    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    /// Number of devices.
    pub fn device_count(&self) -> usize {
        0
    }

    /// Compile a computation. Always fails in the stub.
    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let err = PjRtClient::cpu().err().expect("stub must fail");
        assert!(err.to_string().contains("stub"), "{err}");
    }

    #[test]
    fn literal_constructors_typecheck() {
        let lit = Literal::vec1(&[1.0f32, 2.0]);
        assert!(lit.reshape(&[2, 1]).is_ok());
        let single = Literal::create_from_shape_and_untyped_data(ElementType::F32, &[2], &[0; 8]);
        assert!(single.is_ok());
        assert!(lit.to_vec::<f32>().is_err());
    }
}
