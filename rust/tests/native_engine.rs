//! End-to-end tests for the CPU-native engine: variant bit-identity
//! through the full engine path, coordinator convergence on real
//! kernels, and a loose ordering sanity check on the tunables.
//!
//! The unit tests inside `runtime/native/` cover the kernel math
//! directly; these tests go through `Engine::compile` + manifest
//! signatures + the coordinator, i.e. the path production traffic takes.

use std::sync::Arc;
use std::time::{Duration, Instant};

use jitune::coordinator::{Coordinator, Dispatcher, KernelRegistry, PoolOptions, ServerOptions};
use jitune::runtime::native::native_manifest;
use jitune::runtime::{Engine, EngineFactory, NativeEngine, NativeEngineFactory};
use jitune::workload::inputs_for;

/// Every tunable variant of every native kernel family must produce
/// bit-identical outputs on seeded inputs when run through the full
/// engine path (manifest signature -> compile -> execute). A
/// wrong-but-fast variant would otherwise win tuning and silently
/// corrupt results.
#[test]
fn all_variants_bit_identical_through_engine_path() {
    let manifest = native_manifest(&[48, 64], &[4096]).expect("native manifest");
    let engine = NativeEngine::new();
    for problem in &manifest.problems {
        let inputs = inputs_for(problem, 0xFEED);
        let baseline = engine
            .compile(&problem.variants[0], "")
            .expect("compile baseline")
            .execute(&inputs)
            .expect("execute baseline");
        for variant in &problem.variants[1..] {
            let out = engine
                .compile(variant, "")
                .expect("compile variant")
                .execute(&inputs)
                .expect("execute variant");
            assert_eq!(
                baseline.data(),
                out.data(),
                "{} disagrees with {} on {}",
                variant.id,
                problem.variants[0].id,
                problem.key()
            );
        }
    }
}

/// The same contract holds across *engines* (fresh scratch pools must
/// not change results) and across repeat executions (pool recycling must
/// not leak state between calls).
#[test]
fn results_stable_across_engines_and_repeats() {
    let manifest = native_manifest(&[48], &[4096]).expect("native manifest");
    let problem = manifest.problem("matmul", 48).expect("matmul problem");
    let inputs = inputs_for(problem, 0xABCD);
    let variant = &problem.variants[1]; // bt — packs B^T via the scratch pool
    let first = NativeEngine::new()
        .compile(variant, "")
        .expect("compile")
        .execute(&inputs)
        .expect("execute");
    let other_engine = NativeEngine::new();
    let kernel = other_engine.compile(variant, "").expect("compile");
    for round in 0..3 {
        let out = kernel.execute(&inputs).expect("execute");
        assert_eq!(first.data(), out.data(), "round {round} diverged");
    }
}

/// A full coordinator over the native engine converges to a tuned
/// winner and keeps serving correct results from it.
#[test]
fn coordinator_converges_on_native_kernels() {
    let factory = Arc::new(NativeEngineFactory::pinned());
    let leader_factory: Arc<dyn EngineFactory> = factory.clone();
    let opts = ServerOptions {
        pool: Some(PoolOptions::new(factory).with_workers(2)),
        ..ServerOptions::default()
    };
    let coord = Coordinator::spawn_with_options(
        move || {
            let manifest = native_manifest(&[48], &[4096])?;
            Ok(Dispatcher::new(KernelRegistry::new(manifest), leader_factory.create()?))
        },
        opts,
    )
    .expect("coordinator");
    let h = coord.handle();
    let manifest = native_manifest(&[48], &[4096]).expect("manifest");
    let problem = manifest.problem("matmul", 48).expect("problem");
    let inputs = inputs_for(problem, 0x5EED);

    let expected = NativeEngine::new()
        .compile(&problem.variants[0], "")
        .expect("oracle compile")
        .execute(&inputs)
        .expect("oracle execute");

    let t0 = Instant::now();
    let mut tuned = None;
    while tuned.is_none() {
        assert!(t0.elapsed() < Duration::from_secs(30), "native tuning never converged");
        let out = h.call("matmul", inputs.clone()).expect("call");
        assert_eq!(expected.data(), out.output.data(), "served result diverged mid-tuning");
        tuned = h.tuned_value("matmul", 48).expect("tuned_value");
    }
    let winner = tuned.expect("winner");
    let catalog: Vec<i64> = problem.variants.iter().map(|v| v.value).collect();
    assert!(catalog.contains(&winner), "winner {winner} not in catalog {catalog:?}");
    // steady state serves the winner, still correct
    for _ in 0..10 {
        let out = h.call("matmul", inputs.clone()).expect("tuned call");
        assert_eq!(expected.data(), out.output.data());
        assert_eq!(out.value, winner);
    }
}

/// Loose perf sanity on the tunables (ordering only — absolute timings
/// are CI-noise): at a cache-unfriendly size, the transposed matmul
/// must not lose to naive by a large factor. This catches a variant
/// whose "tuning axis" stopped doing anything (e.g. the packed-B path
/// accidentally falling back to the naive loop), without flaking on
/// machine speed.
#[test]
fn transposed_matmul_not_dramatically_slower_than_naive() {
    let manifest = native_manifest(&[128], &[]).expect("native manifest");
    let problem = manifest.problem("matmul", 128).expect("problem");
    let inputs = inputs_for(problem, 0xD1CE);
    let engine = NativeEngine::new();
    let time = |label: &str| {
        let v = problem
            .variants
            .iter()
            .find(|v| v.label == label)
            .unwrap_or_else(|| panic!("variant {label} in catalog"));
        let k = engine.compile(v, "").expect("compile");
        k.execute(&inputs).expect("warmup");
        let t0 = Instant::now();
        for _ in 0..10 {
            k.execute(&inputs).expect("execute");
        }
        t0.elapsed()
    };
    let naive = time("naive");
    let transposed = time("bt");
    assert!(
        transposed < naive * 3,
        "transposed matmul should be in naive's ballpark or better: \
         bt {transposed:?} vs naive {naive:?}"
    );
}
