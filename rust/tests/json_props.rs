//! Property-style round-trip coverage for `util::json` string escaping.
//!
//! Hub frames carry arbitrary problem keys (kernel / param / signature
//! strings) over the wire, so serialize → parse must be the identity for
//! *any* string: control characters, quotes, backslashes, and multi-byte
//! UTF-8 up to the last scalar value. Uses the repo's seeded `testutil`
//! property framework — fully deterministic.

use jitune::testutil::{forall, PropConfig};
use jitune::util::json::{parse, Value};
use jitune::util::prng::Rng;

/// Characters chosen to stress every escaping path: the whole
/// backslash-escape table, raw control chars, ASCII, 2/3/4-byte UTF-8,
/// and the scalar-value boundaries around the surrogate range.
const POOL: &[char] = &[
    '\u{00}', '\u{01}', '\u{08}', '\u{09}', '\u{0A}', '\u{0B}', '\u{0C}', '\u{0D}', '\u{1F}',
    '"', '\\', '/', ' ', 'a', 'Z', '0', '~', '\u{7F}', 'é', 'ß', '¿', 'Ω', '\u{7FF}',
    '\u{800}', '中', '日', '\u{D7FF}', '\u{E000}', '\u{FFFD}', '😀', '🦀', '\u{10000}',
    '\u{10FFFF}',
];

fn tricky_string(rng: &mut Rng) -> String {
    let len = rng.below(24);
    (0..len).map(|_| *rng.choose(POOL)).collect()
}

fn roundtrips(v: &Value) -> bool {
    parse(&v.to_json()).is_ok_and(|p| &p == v)
        && parse(&v.to_json_pretty()).is_ok_and(|p| &p == v)
}

#[test]
fn string_values_roundtrip() {
    forall(&PropConfig { cases: 400, ..PropConfig::default() }, tricky_string, |s: &String| {
        roundtrips(&Value::Str(s.clone()))
    });
}

#[test]
fn object_keys_roundtrip() {
    // problem keys travel as object *keys* too (tuning reports) — the
    // key path uses the same escaper but a separate parse site
    forall(&PropConfig { cases: 400, seed: 0xA11CE }, tricky_string, |s: &String| {
        let v = Value::Obj(vec![(s.clone(), Value::Num(1.0))]);
        roundtrips(&v) && parse(&v.to_json()).is_ok_and(|p| p.get(s).is_some())
    });
}

#[test]
fn nested_arrays_of_tricky_strings_roundtrip() {
    forall(&PropConfig { cases: 200, seed: 7 }, tricky_string, |s: &String| {
        let v = Value::Arr(vec![
            Value::Str(s.clone()),
            Value::Obj(vec![("k".into(), Value::Str(s.clone()))]),
            Value::Arr(vec![Value::Str(s.clone()), Value::Null]),
        ]);
        roundtrips(&v)
    });
}

#[test]
fn every_control_char_roundtrips_exhaustively() {
    // the property test samples; this nails each of the 33 escape-worthy
    // code points individually so a regression names the culprit
    for cp in (0u32..0x20).chain([0x7F]) {
        let c = char::from_u32(cp).unwrap();
        let s = format!("a{c}b");
        let v = Value::Str(s.clone());
        let text = v.to_json();
        assert_eq!(parse(&text).unwrap(), v, "code point U+{cp:04X} via {text}");
    }
}

#[test]
fn utf8_boundary_scalars_roundtrip() {
    // first/last scalar of each UTF-8 encoding length + surrogate edges
    for c in ['\u{7F}', '\u{80}', '\u{7FF}', '\u{800}', '\u{D7FF}', '\u{E000}', '\u{FFFF}',
        '\u{10000}', '\u{10FFFF}']
    {
        let v = Value::Str(c.to_string());
        assert_eq!(parse(&v.to_json()).unwrap(), v, "scalar U+{:04X}", c as u32);
    }
}

#[test]
fn escaped_and_raw_forms_parse_to_the_same_string() {
    // the writer emits raw UTF-8 for non-control chars; a peer may send
    // \uXXXX escapes (including surrogate pairs) instead — both must
    // decode to the same string
    assert_eq!(parse(r#""\u00e9""#).unwrap(), Value::Str("é".into()));
    assert_eq!(parse(r#""\u4e2d""#).unwrap(), Value::Str("中".into()));
    assert_eq!(parse(r#""\ud83d\ude00""#).unwrap(), Value::Str("😀".into()));
    assert_eq!(parse(r#""A\n\t\"\\""#).unwrap(), Value::Str("A\n\t\"\\".into()));
}
