//! End-to-end integration: real artifacts, real PJRT engine.
//!
//! These tests load the HLO artifacts produced by `make artifacts`,
//! JIT-compile them through the PJRT CPU client and compare results with
//! the independent pure-Rust references (`jitune::tensor`). They skip
//! (with a notice) when artifacts have not been built.

use jitune::manifest::Manifest;
use jitune::runtime::{CompileCache, PjrtEngine};
use jitune::tensor::{ref_matmul, ref_mlp_block, ref_saxpy, ref_stencil3, HostTensor};

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        None
    }
}

fn setup() -> Option<(Manifest, CompileCache)> {
    let dir = artifacts_dir()?;
    let manifest = Manifest::load(dir).expect("manifest loads");
    let engine = PjrtEngine::cpu().expect("pjrt cpu client");
    Some((manifest, CompileCache::new(Box::new(engine))))
}

#[test]
fn manifest_loads_and_covers_all_kernels() {
    let Some((manifest, _)) = setup() else { return };
    let kernels = manifest.kernels();
    for k in ["matmul_tiled", "matmul_order", "saxpy", "stencil", "mlp_block"] {
        assert!(kernels.iter().any(|n| n == k), "missing kernel {k}");
    }
    // every artifact file exists
    for v in &manifest.variants {
        assert!(manifest.artifact_path(v).exists(), "missing artifact {}", v.path);
    }
}

#[test]
fn matmul_tiled_all_blocks_match_rust_ref() {
    let Some((manifest, mut cache)) = setup() else { return };
    let n = 64usize;
    let a = HostTensor::random(&[n, n], 11);
    let b = HostTensor::random(&[n, n], 12);
    let want = ref_matmul(&a, &b).unwrap();
    let problem = manifest.problem("matmul_tiled", n as i64).unwrap().clone();
    for v in &problem.variants {
        let (exe, compiled) = cache.get_or_compile(&manifest, v).unwrap();
        assert!(compiled);
        let got = exe.execute(&[a.clone(), b.clone()]).unwrap();
        assert!(
            got.allclose(&want, 1e-4, 1e-4),
            "variant {} diverges: max diff {:?}",
            v.id,
            got.max_abs_diff(&want)
        );
    }
}

#[test]
fn matmul_orders_match_rust_ref() {
    let Some((manifest, mut cache)) = setup() else { return };
    let n = 128usize;
    let a = HostTensor::random(&[n, n], 21);
    let b = HostTensor::random(&[n, n], 22);
    let want = ref_matmul(&a, &b).unwrap();
    let problem = manifest.problem("matmul_order", n as i64).unwrap().clone();
    assert_eq!(problem.variants.len(), 3);
    for v in &problem.variants {
        let (exe, _) = cache.get_or_compile(&manifest, v).unwrap();
        let got = exe.execute(&[a.clone(), b.clone()]).unwrap();
        assert!(got.allclose(&want, 1e-4, 1e-4), "order {} diverges", v.label);
    }
}

#[test]
fn saxpy_matches_rust_ref() {
    let Some((manifest, mut cache)) = setup() else { return };
    let n = 16384usize;
    let a = HostTensor::from_vec(&[1], vec![2.5]).unwrap();
    let x = HostTensor::random(&[n], 31);
    let y = HostTensor::random(&[n], 32);
    let want = ref_saxpy(2.5, &x, &y).unwrap();
    let problem = manifest.problem("saxpy", n as i64).unwrap().clone();
    for v in &problem.variants {
        let (exe, _) = cache.get_or_compile(&manifest, v).unwrap();
        let got = exe.execute(&[a.clone(), x.clone(), y.clone()]).unwrap();
        assert!(got.allclose(&want, 1e-5, 1e-5), "chunk {} diverges", v.label);
    }
}

#[test]
fn stencil_matches_rust_ref() {
    let Some((manifest, mut cache)) = setup() else { return };
    let n = 16384usize;
    let x = HostTensor::random(&[n], 41);
    let want = ref_stencil3(&x).unwrap();
    let problem = manifest.problem("stencil", n as i64).unwrap().clone();
    for v in &problem.variants {
        let (exe, _) = cache.get_or_compile(&manifest, v).unwrap();
        let got = exe.execute(&[x.clone()]).unwrap();
        assert!(got.allclose(&want, 1e-5, 1e-5), "block {} diverges", v.label);
    }
}

#[test]
fn mlp_block_matches_rust_ref() {
    let Some((manifest, mut cache)) = setup() else { return };
    let (b, d, h, o) = (64usize, 256usize, 512usize, 256usize);
    let x = HostTensor::random(&[b, d], 51);
    let w1 = HostTensor::random(&[d, h], 52);
    let w2 = HostTensor::random(&[h, o], 53);
    let want = ref_mlp_block(&x, &w1, &w2).unwrap();
    let problem = manifest.problem("mlp_block", b as i64).unwrap().clone();
    for v in &problem.variants {
        let (exe, _) = cache.get_or_compile(&manifest, v).unwrap();
        let got = exe.execute(&[x.clone(), w1.clone(), w2.clone()]).unwrap();
        assert!(got.allclose(&want, 1e-3, 1e-3), "mlp {} diverges", v.label);
    }
}

#[test]
fn compile_cache_hit_skips_recompilation() {
    let Some((manifest, mut cache)) = setup() else { return };
    let v = manifest.problem("matmul_tiled", 64).unwrap().variants[0].clone();
    let (_, first) = cache.get_or_compile(&manifest, &v).unwrap();
    assert!(first);
    let t0 = std::time::Instant::now();
    let (_, second) = cache.get_or_compile(&manifest, &v).unwrap();
    assert!(!second);
    // cache hit must be orders of magnitude cheaper than a compile
    assert!(t0.elapsed().as_micros() < 10_000);
    let s = cache.stats();
    assert_eq!((s.hits, s.misses), (1, 1));
}

#[test]
fn wrong_shape_inputs_rejected() {
    let Some((manifest, mut cache)) = setup() else { return };
    let v = manifest.problem("matmul_tiled", 64).unwrap().variants[0].clone();
    let (exe, _) = cache.get_or_compile(&manifest, &v).unwrap();
    let bad = HostTensor::random(&[32, 32], 1);
    assert!(exe.execute(&[bad.clone(), bad]).is_err());
}
