//! Worker-pool fault injection: replicated-finalization compile failures
//! must fall back to the leader without deadlock, and a panicking worker
//! must be respawned — a call may fail over to the leader, but it must
//! never hang and never be lost.

use std::sync::Arc;
use std::time::{Duration, Instant};

use jitune::coordinator::{
    CallRoute, Coordinator, Dispatcher, KernelRegistry, PoolOptions, ServerOptions,
};
use jitune::runtime::mock::{MockEngine, MockEngineFactory, MockSpec, PinnedEngine};
use jitune::tensor::HostTensor;
use jitune::testutil::{spawn_pooled_mock, synthetic_manifest};

fn spec() -> MockSpec {
    MockSpec::default()
        .with_cost("kern.v0.n8", Duration::from_micros(400))
        .with_cost("kern.v1.n8", Duration::from_micros(40))
}

fn inputs() -> Vec<HostTensor> {
    vec![HostTensor::zeros(&[8, 8])]
}

#[test]
fn worker_compile_failure_falls_back_to_leader_without_deadlock() {
    // The leader's engine is healthy, but every pool worker's engine
    // rejects the winning variant at compile: replicated finalization
    // fails on all workers, so nothing is published and the leader keeps
    // serving — bounded time, no deadlock, no lost call.
    let leader_spec = spec();
    let mut worker_spec = spec();
    worker_spec.fail_compile.insert("kern.v1.n8".into());
    let factory = Arc::new(MockEngineFactory::pinned(worker_spec));
    let coord = Coordinator::spawn_with_options(
        move || {
            let manifest = synthetic_manifest("kern", 2, &[8])?;
            let registry = KernelRegistry::new(manifest);
            let engine = PinnedEngine::new(Box::new(MockEngine::new(leader_spec)));
            Ok(Dispatcher::new(registry, Box::new(engine)))
        },
        ServerOptions {
            pool: Some(PoolOptions::new(factory).with_workers(2)),
            ..ServerOptions::default()
        },
    )
    .unwrap();
    let h = coord.handle();
    for _ in 0..3 {
        h.call("kern", inputs()).unwrap();
    }
    assert_eq!(h.tuned_value("kern", 8).unwrap(), Some(1), "tuning completed on the leader");
    assert_eq!(h.fast_lane_published(), 0, "no worker compiled the winner: nothing published");

    // steady state keeps flowing through the leader, promptly
    let t0 = Instant::now();
    for _ in 0..20 {
        let o = h.call("kern", inputs()).unwrap();
        assert_eq!(o.route, CallRoute::Tuned);
        assert_eq!(o.value, 1);
    }
    assert!(t0.elapsed() < Duration::from_secs(10), "leader fallback must not stall");
    let snap = h.pool_snapshot().expect("pool attached");
    assert_eq!(snap.total_executed(), 0, "workers never served the failed variant");
    assert!(snap.workers.iter().all(|w| w.alive), "compile failure does not kill workers");
}

#[test]
fn panicking_worker_is_respawned_and_no_call_is_lost() {
    let spec = spec();
    let fault = spec.latency_fault.clone();
    let coord = spawn_pooled_mock("kern", 2, &[8], spec, 2, ServerOptions::default()).unwrap();
    let h = coord.handle();
    for _ in 0..3 {
        h.call("kern", inputs()).unwrap();
    }
    assert_eq!(h.fast_lane_published(), 1);
    let o = h.call("kern", inputs()).unwrap();
    assert_eq!(o.route, CallRoute::Tuned, "pool path serving");
    let served_before = h.pool_snapshot().unwrap().total_executed();

    // kill the next execution of the winner: the worker that picks the
    // job up panics mid-call; the caller must get the call served via
    // the leader fallback — an answer, not an error, not a hang
    fault.panic_once("kern.v1.n8");
    let o = h.call("kern", inputs()).unwrap();
    assert_eq!(o.value, 1, "failed-over call still serves the winner");

    // the pool recovers: the entry republishes (lazy self-heal) and the
    // respawned worker serves again — detected via the respawn counter
    // and pool executions resuming past their pre-panic count
    let deadline = Instant::now() + Duration::from_secs(20);
    loop {
        let o = h.call("kern", inputs()).unwrap();
        assert_eq!(o.value, 1);
        let snap = h.pool_snapshot().unwrap();
        if h.fast_lane_published() == 1
            && snap.respawns >= 1
            && snap.total_executed() > served_before
        {
            assert!(snap.workers.iter().all(|w| w.alive), "respawned, not dead: {snap:?}");
            break;
        }
        assert!(
            Instant::now() < deadline,
            "pool did not recover after a worker panic: {snap:?}"
        );
    }
}

#[test]
fn drained_shutdown_leaves_no_hung_callers() {
    // Shut down while worker threads are mid-traffic: every in-flight
    // call either completes or fails over; nothing hangs, and shutdown
    // joins every thread (the test would wedge otherwise).
    let spec = MockSpec::default()
        .with_cost("kern.v0.n8", Duration::from_micros(400))
        .with_cost("kern.v1.n8", Duration::from_micros(100))
        .with_sleep_exec();
    let mut coord = spawn_pooled_mock("kern", 2, &[8], spec, 2, ServerOptions::default()).unwrap();
    let h = coord.handle();
    loop {
        if h.call("kern", inputs()).unwrap().route == CallRoute::Tuned {
            break;
        }
    }
    let mut joins = Vec::new();
    for _ in 0..4 {
        let h = coord.handle();
        joins.push(std::thread::spawn(move || {
            // calls may start failing once the coordinator stops; they
            // must return (Ok or Err), never block forever
            for _ in 0..200 {
                let _ = h.call("kern", inputs());
            }
        }));
    }
    std::thread::sleep(Duration::from_millis(5));
    coord.shutdown();
    for j in joins {
        j.join().unwrap();
    }
}
