//! Property suites over the autotuner — the §5 invariants in DESIGN.md.
//!
//! Driven by the in-crate mini property framework (`jitune::testutil`)
//! with synthetic cost tables, so thousands of schedules run in
//! milliseconds without touching PJRT.

use jitune::autotuner::cost_model::CostModel;
use jitune::autotuner::{
    Autotuner, Decision, History, Phase, ProblemKey, Sweep, TuningState,
};
use jitune::testutil::{f64_range, forall, int_range, vec_of, PropConfig};
use jitune::util::prng::Rng;

/// Drive a sweep-strategy state machine over a synthetic cost table to
/// completion; returns (decisions, state).
fn run_sweep(costs: &[f64]) -> (Vec<Decision>, TuningState) {
    let values: Vec<i64> = (0..costs.len() as i64).collect();
    let mut st = TuningState::new(values, Box::new(Sweep::new(costs.len())));
    let mut decisions = Vec::new();
    for _ in 0..costs.len() + 2 {
        let d = st.decide();
        decisions.push(d);
        match d {
            Decision::Explore(i) => st.report(i, costs[i]),
            Decision::Finalize(i) => st.confirm_finalized(i),
            Decision::Use(_) | Decision::Failed => break,
        }
    }
    (decisions, st)
}

#[test]
fn prop_sweep_visits_each_variant_exactly_once() {
    let cfg = PropConfig { cases: 300, ..PropConfig::default() };
    forall(&cfg, vec_of(f64_range(0.001, 10.0), 1, 12), |costs| {
        let (decisions, _) = run_sweep(costs);
        let mut explored = vec![0usize; costs.len()];
        for d in &decisions {
            if let Decision::Explore(i) = d {
                explored[*i] += 1;
            }
        }
        explored.iter().all(|&c| c == 1)
    });
}

#[test]
fn prop_winner_is_argmin_of_costs() {
    let cfg = PropConfig { cases: 300, ..PropConfig::default() };
    forall(&cfg, vec_of(f64_range(0.001, 10.0), 1, 12), |costs| {
        let (_, st) = run_sweep(costs);
        let argmin = costs
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap();
        st.winner() == Some(argmin) && st.phase() == Phase::Tuned
    });
}

#[test]
fn prop_schedule_is_k_explores_one_finalize_then_use() {
    let cfg = PropConfig { cases: 200, ..PropConfig::default() };
    forall(&cfg, vec_of(f64_range(0.001, 10.0), 1, 10), |costs| {
        let (decisions, _) = run_sweep(costs);
        let k = costs.len();
        decisions.len() == k + 2
            && decisions[..k].iter().all(|d| matches!(d, Decision::Explore(_)))
            && matches!(decisions[k], Decision::Finalize(_))
            && matches!(decisions[k + 1], Decision::Use(_))
    });
}

#[test]
fn prop_random_failures_never_break_convergence() {
    // Inject failures on a random subset (never all) of candidates: the
    // tuner must still converge to the argmin of the surviving ones.
    let cfg = PropConfig { cases: 300, seed: 77 };
    forall(&cfg, vec_of(f64_range(0.001, 10.0), 2, 10), |costs| {
        let n = costs.len();
        let mut rng = Rng::seed(costs.iter().map(|c| c.to_bits()).fold(0, u64::wrapping_add));
        let mut fail: Vec<bool> = (0..n).map(|_| rng.chance(0.3)).collect();
        if fail.iter().all(|&f| f) {
            fail[rng.below(n)] = false; // keep one alive
        }
        let values: Vec<i64> = (0..n as i64).collect();
        let mut st = TuningState::new(values, Box::new(Sweep::new(n)));
        for _ in 0..2 * n + 2 {
            match st.decide() {
                Decision::Explore(i) => {
                    if fail[i] {
                        st.report_failure(i);
                    } else {
                        st.report(i, costs[i]);
                    }
                }
                Decision::Finalize(i) => st.confirm_finalized(i),
                Decision::Use(_) | Decision::Failed => break,
            }
        }
        let alive_argmin = costs
            .iter()
            .enumerate()
            .filter(|(i, _)| !fail[*i])
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i);
        st.phase() == Phase::Tuned && st.winner() == alive_argmin
    });
}

#[test]
fn prop_problem_keys_never_share_state() {
    let cfg = PropConfig { cases: 100, ..PropConfig::default() };
    forall(&cfg, vec_of(int_range(1, 1024), 2, 6), |sizes| {
        let mut tuner = Autotuner::sweep();
        // touch one key per distinct size
        for &s in sizes {
            let key = ProblemKey::new("k", "block", format!("f32[{s},{s}]"));
            tuner.state(&key, &[1, 2, 3]);
        }
        let distinct: std::collections::HashSet<_> = sizes.iter().collect();
        tuner.problems() == distinct.len()
    });
}

#[test]
fn prop_eq1_closed_form_equals_simulation() {
    let cfg = PropConfig { cases: 300, ..PropConfig::default() };
    forall(&cfg, vec_of(f64_range(0.01, 5.0), 1, 10), |exec_times| {
        let model = CostModel::new(0.7, exec_times.to_vec());
        (0..60).all(|n| {
            let sim: f64 = model.simulate_schedule(n).iter().sum();
            (model.e_auto(n) - sim).abs() < 1e-9
        })
    });
}

#[test]
fn prop_eq2_payoff_iff_curves_cross() {
    let cfg = PropConfig { cases: 200, seed: 5 };
    forall(&cfg, vec_of(f64_range(0.01, 5.0), 2, 8), |exec_times| {
        let model = CostModel::new(0.3, exec_times.to_vec());
        (0..exec_times.len()).all(|p| {
            (exec_times.len() + 1..100).all(|n| {
                model.pays_off(p, n) == (model.e_auto(n) <= model.e_fixed(p, n))
            })
        })
    });
}

#[test]
fn prop_crossover_is_minimal() {
    let cfg = PropConfig { cases: 200, seed: 9 };
    forall(&cfg, vec_of(f64_range(0.01, 5.0), 2, 8), |exec_times| {
        let model = CostModel::new(0.2, exec_times.to_vec());
        (0..exec_times.len()).all(|p| match model.crossover(p) {
            Some(n_star) => {
                let n = n_star as usize;
                model.pays_off(p, n) && (n == 0 || !model.pays_off(p, n - 1))
            }
            None => !model.pays_off(p, 1_000_000),
        })
    });
}

#[test]
fn prop_strategies_always_terminate_and_find_something() {
    // every strategy, on every surface, terminates within a generous
    // bound and leaves a best index among the non-failed candidates
    let cfg = PropConfig { cases: 150, seed: 21 };
    forall(&cfg, vec_of(f64_range(0.01, 10.0), 1, 12), |costs| {
        for spec in ["sweep", "random:16", "hillclimb", "anneal:20"] {
            let n = costs.len();
            let mut strategy = jitune::autotuner::search::from_spec(spec, n, 3).unwrap();
            let values: Vec<i64> = (0..n as i64).collect();
            let mut history = History::new(&values);
            let mut iters = 0;
            while let Some(idx) = strategy.next(&history) {
                if idx >= n {
                    return false; // out of bounds = broken strategy
                }
                history.record(idx, costs[idx]);
                iters += 1;
                if iters > 300 {
                    return false; // non-termination
                }
            }
            if history.best_index().is_none() {
                return false;
            }
        }
        true
    });
}

#[test]
fn prop_tuned_value_matches_winner_value() {
    let cfg = PropConfig { cases: 200, seed: 31 };
    forall(&cfg, vec_of(f64_range(0.001, 10.0), 1, 10), |costs| {
        let (_, st) = run_sweep(costs);
        match (st.winner(), st.tuned_value()) {
            (Some(w), Some(v)) => v == st.value_of(w),
            _ => false,
        }
    });
}
