//! End-to-end drift-detection tests: a mock workload whose published
//! winner is degraded mid-run must be retuned automatically — and must
//! NOT be retuned when the policy says the evidence is insufficient
//! (min_samples, cooldown), or when drift monitoring is off entirely.

use std::time::{Duration, Instant};

use jitune::coordinator::{
    CallRoute, Coordinator, Dispatcher, DriftPolicy, KernelRegistry, ServerOptions,
};
use jitune::runtime::mock::{MockEngine, MockSpec};
use jitune::tensor::HostTensor;
use jitune::testutil::{spawn_pooled_mock, synthetic_manifest};

/// v0 at 500us, v1 at 300us: v1 wins tuning; a 3x shift on v1 (900us)
/// makes v0 the rightful winner of a rematch by a wide margin.
fn drifting_spec() -> MockSpec {
    MockSpec::default()
        .with_cost("kern.v0.n8", Duration::from_micros(500))
        .with_cost("kern.v1.n8", Duration::from_micros(300))
}

fn fast_policy() -> DriftPolicy {
    DriftPolicy {
        window: Duration::from_millis(40),
        min_samples: 5,
        ratio_threshold: 2.0,
        cooldown: Duration::ZERO,
        consecutive_windows: 2,
        ewma_alpha: 0.3,
    }
}

fn spawn(spec: MockSpec, drift: Option<DriftPolicy>) -> Coordinator {
    Coordinator::spawn_with_options(
        move || {
            let manifest = synthetic_manifest("kern", 2, &[8])?;
            let registry = KernelRegistry::new(manifest);
            Ok(Dispatcher::new(registry, Box::new(MockEngine::new(spec))))
        },
        ServerOptions { drift, ..ServerOptions::default() },
    )
    .expect("spawn coordinator")
}

fn inputs() -> Vec<HostTensor> {
    vec![HostTensor::zeros(&[8, 8])]
}

/// Drive calls until tuning completes; the winner must be v1 (value 1).
fn tune(coord: &Coordinator) {
    let h = coord.handle();
    loop {
        if h.call("kern", inputs()).unwrap().route == CallRoute::Finalized {
            break;
        }
    }
    assert_eq!(h.tuned_value("kern", 8).unwrap(), Some(1));
}

#[test]
fn injected_latency_shift_triggers_automatic_retune() {
    let spec = drifting_spec();
    let fault = spec.latency_fault.clone();
    let coord = spawn(spec, Some(fast_policy()));
    let h = coord.handle();
    tune(&coord);

    // degrade the published winner 3x: 900us, now far slower than v0's 500us
    fault.set_scale("kern.v1.n8", 3.0);

    // keep calling — NO manual retune(); the drift policy must notice,
    // re-open tuning, and converge to the other variant
    let deadline = Instant::now() + Duration::from_secs(30);
    let mut saw_explore = false;
    loop {
        let o = h.call("kern", inputs()).unwrap();
        if o.route == CallRoute::Explored {
            saw_explore = true;
        }
        if saw_explore && h.tuned_value("kern", 8).unwrap() == Some(0) {
            break;
        }
        assert!(
            Instant::now() < deadline,
            "drift-triggered retune did not converge within 30s"
        );
    }

    // the drift event is visible in machine-readable stats
    let json = h.stats_json().unwrap();
    let events = json.get("drift_events").expect("drift_events exported");
    assert!(!events.as_arr().unwrap().is_empty());
    let kern = json.get("kernels").unwrap().get("kern").unwrap();
    assert!(kern.get("drift_retunes").unwrap().as_i64().unwrap() >= 1);
    // per-entry monitor state rides under fast_lane.drift
    let lane = json.get("fast_lane").unwrap();
    assert!(lane.get("drift").is_some(), "monitor state exported");
    // and the human rendering mentions it
    let (rendered, _) = h.stats().unwrap();
    assert!(rendered.contains("drift retunes:"), "{rendered}");
}

#[test]
fn pool_path_latency_shift_trips_drift_policy() {
    // Same drift story, but the tuned lane is the worker pool (pinned
    // factory: kernels refuse `shared()`): the entry's drift monitor is
    // fed from entry.call on the caller threads, so latency evidence
    // aggregates across every pool worker — the policy must trip exactly
    // as it does on the shared-kernel lane.
    let spec = drifting_spec();
    let fault = spec.latency_fault.clone();
    let coord = spawn_pooled_mock(
        "kern",
        2,
        &[8],
        spec,
        2,
        ServerOptions { drift: Some(fast_policy()), ..ServerOptions::default() },
    )
    .unwrap();
    let h = coord.handle();
    tune(&coord);
    assert_eq!(h.fast_lane_published(), 1, "winner published via the pool route");

    // degrade the winner 3x on every pool worker (the fault handle is
    // shared by all engines the factory created)
    fault.set_scale("kern.v1.n8", 3.0);

    let deadline = Instant::now() + Duration::from_secs(30);
    let mut saw_explore = false;
    loop {
        let o = h.call("kern", inputs()).unwrap();
        if o.route == CallRoute::Explored {
            saw_explore = true;
        }
        if saw_explore && h.tuned_value("kern", 8).unwrap() == Some(0) {
            break;
        }
        assert!(
            Instant::now() < deadline,
            "pool-path drift retune did not converge within 30s"
        );
    }

    let json = h.stats_json().unwrap();
    let kern = json.get("kernels").unwrap().get("kern").unwrap();
    assert!(kern.get("drift_retunes").unwrap().as_i64().unwrap() >= 1);
    let snap = h.pool_snapshot().expect("pool attached");
    assert!(snap.total_executed() > 0, "drift evidence came from pool workers: {snap:?}");
}

#[test]
fn no_retune_below_min_samples() {
    let spec = drifting_spec();
    let fault = spec.latency_fault.clone();
    let mut policy = fast_policy();
    policy.min_samples = 1_000_000; // unreachable: every window is "sparse"
    let coord = spawn(spec, Some(policy));
    let h = coord.handle();
    tune(&coord);

    fault.set_scale("kern.v1.n8", 3.0);
    let t0 = Instant::now();
    while t0.elapsed() < Duration::from_millis(400) {
        let o = h.call("kern", inputs()).unwrap();
        assert_eq!(o.route, CallRoute::Tuned, "degraded winner keeps serving");
    }
    assert_eq!(
        h.tuned_value("kern", 8).unwrap(),
        Some(1),
        "no drift retune below min_samples"
    );
    assert!(h.stats_json().unwrap().get("drift_events").is_none());
}

#[test]
fn no_retune_within_cooldown() {
    let spec = drifting_spec();
    let fault = spec.latency_fault.clone();
    let mut policy = fast_policy();
    policy.cooldown = Duration::from_secs(3600); // never expires in-test
    let coord = spawn(spec, Some(policy));
    let h = coord.handle();
    tune(&coord);

    fault.set_scale("kern.v1.n8", 3.0);
    let t0 = Instant::now();
    while t0.elapsed() < Duration::from_millis(400) {
        let o = h.call("kern", inputs()).unwrap();
        assert_eq!(o.route, CallRoute::Tuned, "cooldown suppresses the retune");
    }
    assert_eq!(h.tuned_value("kern", 8).unwrap(), Some(1));
    assert!(h.stats_json().unwrap().get("drift_events").is_none());
}

#[test]
fn drift_none_preserves_the_manual_flow() {
    let spec = drifting_spec();
    let fault = spec.latency_fault.clone();
    let coord = spawn(spec, None);
    let h = coord.handle();
    tune(&coord);

    fault.set_scale("kern.v1.n8", 3.0);
    let t0 = Instant::now();
    while t0.elapsed() < Duration::from_millis(300) {
        let o = h.call("kern", inputs()).unwrap();
        assert_eq!(o.route, CallRoute::Tuned, "no automatic retune without a policy");
    }
    assert_eq!(h.tuned_value("kern", 8).unwrap(), Some(1));
    let json = h.stats_json().unwrap();
    assert!(json.get("drift_events").is_none());
    assert!(
        json.get("fast_lane").unwrap().get("drift").is_none(),
        "no monitor state without a policy"
    );

    // manual retune still works exactly as before
    assert!(h.retune("kern", 8).unwrap());
    loop {
        let o = h.call("kern", inputs()).unwrap();
        if o.route == CallRoute::Finalized {
            break;
        }
    }
    assert_eq!(
        h.tuned_value("kern", 8).unwrap(),
        Some(0),
        "manual rematch sees the degraded variant and flips the winner"
    );
}
