//! Multi-process fleet integration for the tuned-state hub.
//!
//! The broker runs as a *real spawned process* (`jitune hub serve`), so
//! these tests exercise the actual wire path: Unix socket, length-prefixed
//! frames, version merge. "Process A" / "process B" are in-test
//! dispatchers with their own manifests and engines — each the moral
//! equivalent of one serving process — and `jitune hub dump` is run as a
//! third process to check operator visibility.

use std::path::PathBuf;
use std::process::{Child, Command, Stdio};
use std::time::Duration;

use jitune::autotuner::Phase;
use jitune::coordinator::{CallRoute, Coordinator, Dispatcher, KernelRegistry, ServerOptions};
use jitune::hub::{HubClient, HubOptions};
use jitune::runtime::mock::{MockEngine, MockSpec};
use jitune::tensor::HostTensor;
use jitune::testutil::synthetic_manifest;

fn socket_path(tag: &str) -> PathBuf {
    jitune::testutil::temp_path(&format!("fleet-{tag}"), "sock")
}

/// The broker child process; killed (and its socket removed) on drop so
/// a failing test never leaks it.
struct HubProc {
    child: Child,
    socket: PathBuf,
}

impl HubProc {
    fn spawn(tag: &str) -> HubProc {
        let socket = socket_path(tag);
        let _ = std::fs::remove_file(&socket);
        let child = Command::new(env!("CARGO_BIN_EXE_jitune"))
            .args(["hub", "serve", "--socket"])
            .arg(&socket)
            .stdout(Stdio::null())
            .stderr(Stdio::null())
            .spawn()
            .expect("spawn `jitune hub serve`");
        HubProc { child, socket }
    }

    /// Client options with a generous connect budget (the broker process
    /// may still be starting).
    fn client_opts(&self) -> HubOptions {
        HubOptions { connect_retries: 400, ..HubOptions::at(&self.socket) }
    }
}

impl Drop for HubProc {
    fn drop(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
        let _ = std::fs::remove_file(&self.socket);
    }
}

/// One "serving process": a dispatcher over the shared synthetic
/// manifest layout, connected to the broker.
fn fleet_member(spec: MockSpec, hub: &HubProc) -> Dispatcher {
    let manifest = synthetic_manifest("kern", 2, &[8]).expect("manifest");
    let registry = KernelRegistry::new(manifest);
    let mut d = Dispatcher::new(registry, Box::new(MockEngine::new(spec)));
    d.attach_hub(HubClient::connect(hub.client_opts()).expect("connect hub"));
    d
}

fn inputs() -> Vec<HostTensor> {
    vec![HostTensor::zeros(&[8, 8])]
}

/// v1 wins tuning (60us vs 600us).
fn base_spec() -> MockSpec {
    MockSpec::default()
        .with_cost("kern.v0.n8", Duration::from_micros(600))
        .with_cost("kern.v1.n8", Duration::from_micros(60))
}

#[test]
fn cold_process_warm_starts_with_zero_explores() {
    let hub = HubProc::spawn("warm");

    // process A tunes from scratch; finalization publishes the winner
    let mut a = fleet_member(base_spec(), &hub);
    assert_eq!(a.hub_pull().expect("pull"), (0, 0), "hub starts empty");
    for _ in 0..3 {
        a.call("kern", &inputs()).expect("tune");
    }
    assert_eq!(a.tuned_value("kern", 8), Some(1));
    assert_eq!(a.stats().hub().pushes, 1);

    // process B is cold: one pull reaches Phase::Tuned after the final
    // compile, with zero explore iterations — the acceptance criterion
    let mut b = fleet_member(base_spec(), &hub);
    assert_eq!(b.hub_pull().expect("pull"), (1, 0));
    let first = b.call("kern", &inputs()).expect("warm call");
    assert_eq!(first.route, CallRoute::Finalized, "only the final compile remains");
    assert_eq!(first.value, 1);
    assert_eq!(b.phase("kern", 8), Some(Phase::Tuned));
    assert_eq!(b.stats().kernel("kern").unwrap().explored, 0, "zero explore iterations");
    let second = b.call("kern", &inputs()).expect("steady call");
    assert_eq!(second.route, CallRoute::Tuned);
}

#[test]
fn retuned_winner_is_dumpable_and_adopted_on_next_pull() {
    let hub = HubProc::spawn("retune");
    let spec = base_spec();
    let fault = spec.latency_fault.clone();

    // A tunes (v1 wins) and B adopts it
    let mut a = fleet_member(spec.clone(), &hub);
    for _ in 0..3 {
        a.call("kern", &inputs()).expect("tune");
    }
    assert_eq!(a.tuned_value("kern", 8), Some(1));
    let mut b = fleet_member(spec, &hub);
    assert_eq!(b.hub_pull().expect("pull"), (1, 0));
    b.call("kern", &inputs()).expect("finalize adopted winner");
    assert_eq!(b.tuned_value("kern", 8), Some(1));

    // the winner degrades 20x in A; a retune rematch flips it and the
    // new winner is published at the next version
    fault.set_scale("kern.v1.n8", 20.0);
    assert!(a.retune("kern", 8).expect("retune"));
    for _ in 0..3 {
        a.call("kern", &inputs()).expect("rematch");
    }
    assert_eq!(a.tuned_value("kern", 8), Some(0), "rematch flips the winner");
    assert_eq!(a.stats().hub().pushes, 2);

    // operator visibility: `jitune hub dump` (a third process) shows the
    // retuned winner at version 2
    let out = Command::new(env!("CARGO_BIN_EXE_jitune"))
        .args(["hub", "dump", "--socket"])
        .arg(&hub.socket)
        .output()
        .expect("run `jitune hub dump`");
    assert!(out.status.success(), "dump failed: {}", String::from_utf8_lossy(&out.stderr));
    let dumped = jitune::util::json::parse(&String::from_utf8_lossy(&out.stdout))
        .expect("dump emits JSON");
    let arr = dumped.as_arr().expect("dump is an array");
    assert_eq!(arr.len(), 1);
    assert_eq!(arr[0].get("kernel").unwrap().as_str(), Some("kern"));
    assert_eq!(arr[0].get("winner_value").unwrap().as_i64(), Some(0));
    assert_eq!(arr[0].get("version").unwrap().as_i64(), Some(2));

    // B's next pull adopts the retuned winner
    assert_eq!(b.hub_pull().expect("pull"), (1, 0));
    let o = b.call("kern", &inputs()).expect("refinalize");
    assert_eq!(o.route, CallRoute::Finalized, "adoption refinalizes the new winner");
    assert_eq!(o.value, 0);
    assert_eq!(b.tuned_value("kern", 8), Some(0));
    assert_eq!(b.stats().hub().adopted, 2);
}

#[test]
fn coordinator_warm_starts_through_server_options() {
    let hub = HubProc::spawn("coord");
    let server_opts = |hub: &HubProc| ServerOptions {
        hub: Some(hub.client_opts()),
        ..ServerOptions::default()
    };
    let spawn = |spec: MockSpec, opts: ServerOptions| {
        Coordinator::spawn_with_options(
            move || {
                let manifest = synthetic_manifest("kern", 2, &[8])?;
                let registry = KernelRegistry::new(manifest);
                Ok(Dispatcher::new(registry, Box::new(MockEngine::new(spec))))
            },
            opts,
        )
        .expect("spawn coordinator")
    };

    // fleet member A tunes and publishes
    let a = spawn(base_spec(), server_opts(&hub));
    let ha = a.handle();
    for _ in 0..3 {
        ha.call("kern", inputs()).expect("tune");
    }
    assert_eq!(ha.tuned_value("kern", 8).expect("tuned_value"), Some(1));

    // fleet member B: the warm-start pull completed before spawn
    // returned, so its very first call pays only the final compile
    let b = spawn(base_spec(), server_opts(&hub));
    let hb = b.handle();
    let first = hb.call("kern", inputs()).expect("warm call");
    assert_eq!(first.route, CallRoute::Finalized);
    assert_eq!(first.value, 1);
    let json = hb.stats_json().expect("stats_json");
    let hub_stats = json.get("hub").expect("hub section present when a hub is attached");
    assert_eq!(hub_stats.get("pulls").unwrap().as_i64(), Some(1));
    assert_eq!(hub_stats.get("adopted").unwrap().as_i64(), Some(1));
    assert_eq!(
        json.get("kernels").unwrap().get("kern").unwrap().get("explored").unwrap().as_i64(),
        Some(0),
        "warm-started process never explored"
    );
    // explicit pull through the handle: nothing new to adopt, but the
    // request path works end to end
    assert_eq!(hb.hub_pull().expect("hub_pull"), (0, 0));
}

#[test]
fn hub_free_dispatcher_is_unchanged() {
    // no hub attached: hub_pull is a no-op and nothing is published
    let manifest = synthetic_manifest("kern", 2, &[8]).expect("manifest");
    let mut d = Dispatcher::new(
        KernelRegistry::new(manifest),
        Box::new(MockEngine::new(base_spec())),
    );
    assert_eq!(d.hub_pull().expect("no-op"), (0, 0));
    for _ in 0..3 {
        d.call("kern", &inputs()).expect("tune");
    }
    let h = d.stats().hub();
    assert_eq!((h.pushes, h.pulls, h.adopted, h.conflicts), (0, 0, 0, 0));
}

#[test]
fn dump_against_missing_socket_fails_cleanly() {
    let missing = socket_path("missing");
    let out = Command::new(env!("CARGO_BIN_EXE_jitune"))
        .args(["hub", "dump", "--socket"])
        .arg(&missing)
        .output()
        .expect("run `jitune hub dump`");
    assert!(!out.status.success(), "dump must fail without a broker");
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("hub"), "actionable error, got: {err}");
}
