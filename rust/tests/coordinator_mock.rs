//! Coordinator integration over the mock engine: lifecycle, routing,
//! concurrency, failure injection — no PJRT required, so these run fast
//! and deterministically in any environment.

use std::collections::HashSet;
use std::time::Duration;

use jitune::coordinator::{
    CallRoute, Coordinator, Dispatcher, KernelRegistry,
};
use jitune::manifest::Manifest;
use jitune::runtime::mock::{MockEngine, MockSpec};
use jitune::tensor::HostTensor;
use jitune::util::json;
use jitune::util::prng::Rng;

/// A synthetic manifest with `k` variants of one kernel at sizes 8/16,
/// backed by dummy artifact files on disk.
fn synthetic_manifest(k: usize) -> Manifest {
    static COUNTER: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);
    let dir = std::env::temp_dir().join(format!(
        "jitune-coord-{}-{}",
        std::process::id(),
        COUNTER.fetch_add(1, std::sync::atomic::Ordering::Relaxed)
    ));
    std::fs::create_dir_all(&dir).unwrap();
    let mut entries = Vec::new();
    for size in [8i64, 16] {
        for i in 0..k {
            let id = format!("kern.v{i}.n{size}");
            std::fs::write(dir.join(format!("{id}.hlo.txt")), "HloModule dummy\n").unwrap();
            entries.push(format!(
                r#"{{"id":"{id}","kernel":"kern","param":"p","value":{i},"label":"v{i}",
                    "size":{size},"inputs":["f32[{size},{size}]"],"output":"f32[{size},{size}]",
                    "path":"{id}.hlo.txt","flops":100}}"#
            ));
        }
    }
    let text = format!(
        r#"{{"schema":1,"jax_version":"test","entries":[{}]}}"#,
        entries.join(",")
    );
    Manifest::from_json_str(&text, dir).unwrap()
}

fn spec_with_costs(costs_us: &[u64]) -> MockSpec {
    let mut spec = MockSpec::default();
    for (i, &c) in costs_us.iter().enumerate() {
        for size in [8, 16] {
            spec = spec.with_cost(&format!("kern.v{i}.n{size}"), Duration::from_micros(c));
        }
    }
    spec
}

fn dispatcher(k: usize, spec: MockSpec) -> Dispatcher {
    let registry = KernelRegistry::new(synthetic_manifest(k));
    Dispatcher::new(registry, Box::new(MockEngine::new(spec)))
}

#[test]
fn five_variant_lifecycle_and_winner() {
    // costs: v3 is the clear winner
    let mut d = dispatcher(5, spec_with_costs(&[400, 300, 500, 40, 350]));
    let inputs = [HostTensor::zeros(&[8, 8])];
    let mut routes = Vec::new();
    for _ in 0..8 {
        routes.push(d.call("kern", &inputs).unwrap().route);
    }
    assert_eq!(routes.iter().filter(|r| **r == CallRoute::Explored).count(), 5);
    assert_eq!(routes.iter().filter(|r| **r == CallRoute::Finalized).count(), 1);
    assert_eq!(routes.iter().filter(|r| **r == CallRoute::Tuned).count(), 2);
    assert_eq!(d.tuned_value("kern", 8), Some(3));
    // exactly k+1 JIT compilations happened (k tuning + 1 final)
    assert_eq!(d.cache_stats().misses, 6);
    // only the winner stays resident
    assert_eq!(d.cache_stats().evictions, 5);
}

#[test]
fn outputs_observable_route_the_winner() {
    let mut d = dispatcher(3, spec_with_costs(&[300, 30, 300]));
    let inputs = [HostTensor::zeros(&[8, 8])];
    for _ in 0..5 {
        d.call("kern", &inputs).unwrap();
    }
    // mock kernels fill outputs with their variant value: steady calls
    // must all carry the winner's value
    for _ in 0..3 {
        let out = d.call("kern", &inputs).unwrap();
        assert_eq!(out.route, CallRoute::Tuned);
        assert!(out.output.data().iter().all(|&x| x == 1.0));
    }
}

#[test]
fn execute_failure_mid_tuning_is_survived() {
    let mut spec = spec_with_costs(&[100, 100, 100]);
    spec.fail_execute.insert("kern.v1.n8".into());
    let mut d = dispatcher(3, spec);
    let inputs = [HostTensor::zeros(&[8, 8])];
    for _ in 0..6 {
        d.call("kern", &inputs).unwrap();
    }
    let winner = d.tuned_value("kern", 8).unwrap();
    assert_ne!(winner, 1, "failed variant must not win");
    assert_eq!(d.stats().total_failures(), 1);
}

#[test]
fn tuning_report_json_is_complete() {
    let mut d = dispatcher(2, spec_with_costs(&[100, 50]));
    let inputs = [HostTensor::zeros(&[8, 8])];
    for _ in 0..4 {
        d.call("kern", &inputs).unwrap();
    }
    let report = d.tuning_report();
    let text = report.to_json();
    // parses back and contains the tuned phase + winner
    let parsed = json::parse(&text).unwrap();
    let (_, problem) = &parsed.as_obj().unwrap()[0];
    assert_eq!(problem.get("phase").unwrap().as_str(), Some("tuned"));
    assert_eq!(problem.get("tuned_value").unwrap().as_i64(), Some(1));
    assert_eq!(problem.get("variants").unwrap().as_arr().unwrap().len(), 2);
}

#[test]
fn concurrent_clients_see_consistent_winner() {
    let spec = spec_with_costs(&[500, 50, 400, 300]);
    let coordinator = Coordinator::spawn(move || {
        let registry = KernelRegistry::new(synthetic_manifest(4));
        Ok(Dispatcher::new(registry, Box::new(MockEngine::new(spec))))
    })
    .unwrap();

    let mut joins = Vec::new();
    for seed in 0..6u64 {
        let h = coordinator.handle();
        joins.push(std::thread::spawn(move || {
            let mut rng = Rng::seed(seed);
            let mut steady_values = HashSet::new();
            for _ in 0..10 {
                let size = *rng.choose(&[8usize, 16]);
                let out = h.call("kern", vec![HostTensor::zeros(&[size, size])]).unwrap();
                if out.route == CallRoute::Tuned {
                    steady_values.insert((size, out.value));
                }
            }
            steady_values
        }));
    }
    let mut all: HashSet<(usize, i64)> = HashSet::new();
    for j in joins {
        all.extend(j.join().unwrap());
    }
    // each problem's steady state must be a single consistent winner
    for size in [8usize, 16] {
        let winners: Vec<i64> =
            all.iter().filter(|(s, _)| *s == size).map(|(_, v)| *v).collect();
        assert!(winners.len() <= 1, "size {size} saw multiple steady winners: {winners:?}");
    }
    // and the winner (once tuning is done) is the fast variant
    assert_eq!(coordinator.handle().tuned_value("kern", 8).unwrap(), Some(1));
    assert_eq!(coordinator.handle().tuned_value("kern", 16).unwrap(), Some(1));
}

#[test]
fn jittered_measurements_still_pick_clear_winner() {
    let mut spec = spec_with_costs(&[800, 80, 700]);
    spec.jitter_frac = 0.15;
    let mut d = dispatcher(3, spec);
    let inputs = [HostTensor::zeros(&[8, 8])];
    for _ in 0..5 {
        d.call("kern", &inputs).unwrap();
    }
    // 10x margin: jitter cannot flip the ranking
    assert_eq!(d.tuned_value("kern", 8), Some(1));
}

#[test]
fn stats_latency_histograms_populated() {
    let mut d = dispatcher(2, spec_with_costs(&[100, 50]));
    let inputs = [HostTensor::zeros(&[8, 8])];
    for _ in 0..10 {
        d.call("kern", &inputs).unwrap();
    }
    let ks = d.stats().kernel("kern").unwrap();
    assert_eq!(ks.latency.count(), 10);
    assert_eq!(ks.tuned_latency.count(), 7);
    // tuned calls skip compilation: their latency must be clearly lower
    assert!(ks.tuned_latency.mean() < ks.latency.mean());
}
