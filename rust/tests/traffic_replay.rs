//! Integration tests for the traffic subsystem: the generated trace
//! drives a full coordinator (mock and native engines) and the report
//! reflects what actually happened.

use std::sync::Arc;
use std::time::Duration;

use jitune::coordinator::{
    Coordinator, Dispatcher, DriftPolicy, ExploreOptions, KernelRegistry, PoolOptions,
    ServerOptions,
};
use jitune::runtime::mock::MockSpec;
use jitune::runtime::native::native_manifest;
use jitune::runtime::{EngineFactory, NativeEngineFactory, NativeFault};
use jitune::testutil::{spawn_pooled_mock, synthetic_manifest};
use jitune::traffic::{ReplayOptions, TrafficHarness, TrafficSpec};

/// Replay a churning multi-problem trace on the mock stack and check the
/// report is internally consistent: every arrival accounted for, cold
/// tail at least as heavy as steady, tuned-state series monotone.
#[test]
fn mock_replay_report_is_consistent() {
    let coord = spawn_pooled_mock(
        "kern",
        3,
        &[8, 16, 32],
        MockSpec::default().with_compile_cost(Duration::from_micros(300)),
        2,
        ServerOptions::default(),
    )
    .expect("coordinator");
    let manifest = synthetic_manifest("kern", 3, &[8, 16, 32]).expect("manifest");
    let spec = TrafficSpec {
        calls: 600,
        rps: 5000.0,
        initial: 2,
        churn_every: 150,
        clients: 4,
        seed: 11,
        ..TrafficSpec::default()
    };
    let harness = TrafficHarness::new(&manifest, spec, 99).expect("harness");
    let report = harness.run(&coord, &ReplayOptions::default()).expect("replay");

    assert_eq!(report.calls, 600);
    assert_eq!(report.errors, 0);
    assert_eq!(report.problems.iter().map(|p| p.calls).sum::<usize>(), 600);
    assert_eq!(report.problems.len(), 3, "all three sizes activated by churn");
    // churned-in problems arrive later
    assert!(report.problems[0].first_arrival_ms <= report.problems[2].first_arrival_ms);
    assert!(report.p99_us >= report.p50_us);
    // tuned-state series: starts at zero, never shrinks, ends at the
    // exported-problem count
    assert_eq!(report.tuned_series.first().expect("series").1, 0);
    for w in report.tuned_series.windows(2) {
        assert!(w[1].1 >= w[0].1, "published entries never retract");
    }
    assert_eq!(report.tuned_series.last().expect("series").1, report.tuned_problems);
    assert!(report.tuned_state_bytes > 0);
    // every problem saw enough traffic to tune on the fast mock
    assert_eq!(report.untuned_problems, 0, "report: {report:?}");
}

/// The same spec + seed replays the identical workload — the property
/// every A/B comparison rests on.
#[test]
fn trace_is_reproducible_across_harnesses() {
    let manifest = synthetic_manifest("kern", 2, &[8]).expect("manifest");
    let spec = TrafficSpec { calls: 400, ..TrafficSpec::default() };
    let a = TrafficHarness::new(&manifest, spec.clone(), 5).expect("harness a");
    let b = TrafficHarness::new(&manifest, spec, 5).expect("harness b");
    assert_eq!(a.trace(), b.trace());
    let c = TrafficHarness::new(
        &manifest,
        TrafficSpec { seed: 43, calls: 400, ..TrafficSpec::default() },
        5,
    )
    .expect("harness c");
    assert_ne!(a.trace(), c.trace());
}

/// Mini production run on the native engine: real kernels, background
/// exploration, drift injection through the interference handle. The
/// serving stack must stay error-free and end up tuned.
#[test]
fn native_mini_replay_with_drift_injection() {
    let factory = Arc::new(NativeEngineFactory::pinned());
    let fault: NativeFault = factory.fault();
    let leader_factory: Arc<dyn EngineFactory> = factory.clone();
    let opts = ServerOptions {
        pool: Some(PoolOptions::new(factory).with_workers(2)),
        explore_budget: Some(
            ExploreOptions::percent(30.0).with_window(Duration::from_millis(25)),
        ),
        drift: Some(DriftPolicy {
            window: Duration::from_millis(50),
            min_samples: 8,
            cooldown: Duration::from_millis(250),
            ..DriftPolicy::default()
        }),
        ..ServerOptions::default()
    };
    let coord = Coordinator::spawn_with_options(
        move || {
            let manifest = native_manifest(&[48], &[8192])?;
            Ok(Dispatcher::new(KernelRegistry::new(manifest), leader_factory.create()?))
        },
        opts,
    )
    .expect("coordinator");
    let manifest = native_manifest(&[48], &[8192]).expect("manifest");
    let spec = TrafficSpec {
        calls: 500,
        rps: 2500.0,
        initial: 3,
        churn_every: 0,
        drift_at: 0.5,
        clients: 3,
        ..TrafficSpec::default()
    };
    let harness = TrafficHarness::new(&manifest, spec, 0xCAFE).expect("harness");
    let inject = fault.clone();
    let opts = ReplayOptions {
        drift_inject: Some(Arc::new(move || inject.slow_down("matmul", 2))),
        ..ReplayOptions::default()
    };
    let report = harness.run(&coord, &opts).expect("replay");
    fault.clear();

    assert_eq!(report.calls, 500);
    assert_eq!(report.errors, 0, "native serving must be error-free: {report:?}");
    assert_eq!(report.problems.len(), 3, "matmul + saxpy + reduce all active");
    assert!(report.drift_fired_ms.is_some(), "injection claimed exactly once");
    assert!(
        report.duty_cycle_pct.is_some(),
        "background explore stats present in the report"
    );
    assert!(report.p50_us > 0.0 && report.p99_us.is_finite());
}

/// The CLI spec string round-trips into the harness (the `jitune run
/// --traffic <spec>` path).
#[test]
fn parsed_spec_drives_harness() {
    let manifest = synthetic_manifest("kern", 2, &[8]).expect("manifest");
    let spec = TrafficSpec::parse("calls=80,rps=4000,clients=2,churn=0,initial=1")
        .expect("spec parse");
    let coord = spawn_pooled_mock(
        "kern",
        2,
        &[8],
        MockSpec::default(),
        2,
        ServerOptions::default(),
    )
    .expect("coordinator");
    let harness = TrafficHarness::new(&manifest, spec, 3).expect("harness");
    let report = harness.run(&coord, &ReplayOptions::default()).expect("replay");
    assert_eq!(report.calls, 80);
    assert_eq!(report.errors, 0);
    assert_eq!(report.problems.len(), 1);
}
