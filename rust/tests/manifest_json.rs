//! Manifest + JSON round-trip properties, against both synthetic inputs
//! and the real generated manifest when present.

use jitune::manifest::{Manifest, Variant};
use jitune::testutil::{forall, int_range, vec_of, PropConfig};
use jitune::util::json::{self, Value};

#[test]
fn prop_json_number_roundtrip() {
    forall(&PropConfig { cases: 500, seed: 11 }, int_range(-1_000_000_000, 1_000_000_000), |&x| {
        let v = Value::Num(x as f64);
        json::parse(&v.to_json()).map(|p| p.as_i64() == Some(x)).unwrap_or(false)
    });
}

#[test]
fn prop_json_array_roundtrip() {
    forall(&PropConfig { cases: 300, seed: 13 }, vec_of(int_range(-5000, 5000), 0, 20), |xs| {
        let v = Value::Arr(xs.iter().map(|&x| Value::Num(x as f64)).collect());
        let back = json::parse(&v.to_json()).unwrap();
        back == v && json::parse(&v.to_json_pretty()).unwrap() == v
    });
}

#[test]
fn prop_json_string_roundtrip_with_special_chars() {
    let alphabet: Vec<char> =
        "abc\"\\\n\t\u{e9}\u{4e16}\u{1F600} {}[]:,".chars().collect();
    forall(&PropConfig { cases: 300, seed: 17 }, vec_of(int_range(0, alphabet.len() as i64 - 1), 0, 30), |idxs| {
        let s: String = idxs.iter().map(|&i| alphabet[i as usize]).collect();
        let v = Value::Str(s);
        json::parse(&v.to_json()).map(|p| p == v).unwrap_or(false)
    });
}

#[test]
fn real_manifest_invariants() {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: artifacts not built");
        return;
    }
    let m = Manifest::load(&dir).unwrap();
    // 5 kernel families, every problem has >= 1 variant, consistent sigs
    assert_eq!(m.kernels().len(), 5);
    for p in &m.problems {
        assert!(!p.variants.is_empty());
        for v in &p.variants {
            assert_eq!(v.kernel, p.kernel);
            assert_eq!(v.size, p.size);
            assert!(v.flops > 0);
            // signatures parse and output is well-formed
            v.input_shapes().unwrap();
            assert!(!v.output_shape().unwrap().is_empty());
            assert!(m.artifact_path(v).exists());
        }
        // variant values are unique within a problem
        let mut values: Vec<i64> = p.variants.iter().map(|v| v.value).collect();
        values.sort_unstable();
        values.dedup();
        assert_eq!(values.len(), p.variants.len(), "duplicate values in {}", p.key());
    }
    // the Fig-1 problem set: all blocks present per size
    for &size in &[32i64, 64, 128, 256, 512] {
        let p = m.problem("matmul_tiled", size).unwrap();
        assert_eq!(p.variants.len(), 6, "n={size}");
    }
    // Fig-2 problem set: exactly the three loop orders
    for &size in &[64i64, 128, 256, 512] {
        let p = m.problem("matmul_order", size).unwrap();
        let labels: Vec<&str> = p.variants.iter().map(|v| v.label.as_str()).collect();
        assert_eq!(labels, vec!["ijk", "ikj", "jik"]);
    }
}

#[test]
fn signature_parser_rejects_malformed() {
    for bad in ["f32[", "f32[]", "[8]", "f64[8]", "f32[8,]", "f32[8x8]"] {
        assert!(Variant::parse_sig(bad).is_err(), "`{bad}` should be rejected");
    }
}

#[test]
fn real_manifest_hlo_artifacts_parse_as_hlo_text() {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: artifacts not built");
        return;
    }
    let m = Manifest::load(&dir).unwrap();
    // spot-check one artifact per kernel family
    let mut seen = std::collections::HashSet::new();
    for v in &m.variants {
        if seen.insert(v.kernel.clone()) {
            let text = std::fs::read_to_string(m.artifact_path(v)).unwrap();
            assert!(text.starts_with("HloModule"), "{}: not HLO text", v.id);
            assert!(text.contains("ROOT"), "{}: no ROOT computation", v.id);
        }
    }
    assert_eq!(seen.len(), 5);
}
