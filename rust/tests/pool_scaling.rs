//! Worker-pool scaling + consistency: the mock engine forced onto the
//! pool path (pinned factory — every kernel refuses `shared()`), hammered
//! from many threads. Tuned-call throughput must scale with workers, no
//! call may be lost across a concurrent retune, and the per-worker
//! counters must sum to the lane's global hit count.

use std::sync::Arc;
use std::time::{Duration, Instant};

use jitune::coordinator::{CallRoute, Coordinator, PoolOptions, ServerOptions, WorkerPool};
use jitune::runtime::mock::{CompileFault, MockEngineFactory, MockSpec};
use jitune::tensor::HostTensor;
use jitune::testutil::{spawn_pooled_mock, synthetic_manifest};

/// v1 wins by a wide margin; sleep-based execution models an accelerator
/// offload so throughput is capped by coordination, not host cores.
fn sleepy_spec(exec_us: u64) -> MockSpec {
    MockSpec::default()
        .with_cost("kern.v0.n8", Duration::from_micros(4 * exec_us))
        .with_cost("kern.v1.n8", Duration::from_micros(exec_us))
        .with_sleep_exec()
}

fn spawn(spec: MockSpec, workers: usize) -> Coordinator {
    spawn_pooled_mock("kern", 2, &[8], spec, workers, ServerOptions::default()).unwrap()
}

fn inputs() -> Vec<HostTensor> {
    vec![HostTensor::zeros(&[8, 8])]
}

/// Drive tuning to completion (2 explores + 1 finalize, leader lane).
fn tune(coord: &Coordinator) {
    let h = coord.handle();
    loop {
        if h.call("kern", inputs()).unwrap().route == CallRoute::Tuned {
            break;
        }
    }
    assert_eq!(h.tuned_value("kern", 8).unwrap(), Some(1));
}

fn hammer(coord: &Coordinator, threads: usize, calls: usize) -> usize {
    let mut joins = Vec::new();
    for _ in 0..threads {
        let h = coord.handle();
        joins.push(std::thread::spawn(move || {
            let mut served = 0usize;
            for _ in 0..calls {
                let o = h.call("kern", inputs()).unwrap();
                // outputs always encode the executed variant's value
                assert!(o.output.data().iter().all(|&x| x == o.value as f32));
                served += 1;
            }
            served
        }));
    }
    joins.into_iter().map(|j| j.join().unwrap()).sum()
}

#[test]
fn pool_serves_pinned_engine_and_stats_line_up() {
    let coord = spawn(sleepy_spec(100), 2);
    let h = coord.handle();
    tune(&coord);
    assert_eq!(h.fast_lane_published(), 1, "pool-routed entry published");

    let total = hammer(&coord, 6, 30);
    assert_eq!(total, 180, "no call lost");

    // Per-worker counters sum to the lane's global hit count: every pool
    // execution is exactly one fast-lane hit, nothing double-counted.
    let snap = h.pool_snapshot().expect("pool attached");
    assert_eq!(snap.workers.len(), 2);
    let worker_total = snap.total_executed();
    let lane_hits: u64 = h.fast_lane_stats().iter().map(|(_, hits, _)| *hits).sum();
    assert_eq!(worker_total, lane_hits, "per-worker sums == lane hits: {snap:?}");
    assert!(worker_total >= 180, "steady state runs on the pool: {snap:?}");
    assert!(
        snap.workers.iter().all(|w| w.executed > 0),
        "both workers served: {snap:?}"
    );
    assert_eq!(snap.respawns, 0);

    // machine-readable stats expose all three lanes' counters
    let json = h.stats_json().unwrap();
    assert!(json.get("kernels").is_some());
    assert!(json.get("fast_lane").is_some());
    let pool = json.get("pool").expect("pool stats exported");
    assert_eq!(pool.get("workers").unwrap().as_i64(), Some(2));
    assert_eq!(pool.get("executed").unwrap().as_i64(), Some(worker_total as i64));
}

#[test]
fn tuned_throughput_scales_with_workers() {
    let measure = |workers: usize| {
        let coord = spawn(sleepy_spec(500), workers);
        tune(&coord);
        let t0 = Instant::now();
        let total = hammer(&coord, 8, 40);
        assert_eq!(total, 320);
        total as f64 / t0.elapsed().as_secs_f64()
    };
    let one = measure(1);
    let four = measure(4);
    assert!(
        four > one * 2.0,
        "pool scaling: 1 worker {one:.0} calls/s vs 4 workers {four:.0} calls/s"
    );
}

#[test]
fn idle_worker_steals_from_busy_siblings_shard() {
    // Worker A gets stuck on one long-running job; fast jobs keep
    // round-robining onto A's shard meanwhile. Without stealing they
    // would wait out the long job even though worker B sits idle; with
    // stealing, B drains them — the queue spreads to whoever is free.
    let spec = MockSpec::default()
        .with_cost("kern.v0.n8", Duration::from_millis(300))
        .with_cost("kern.v1.n8", Duration::from_micros(500))
        .with_sleep_exec();
    let manifest = synthetic_manifest("kern", 2, &[8]).unwrap();
    let pool = WorkerPool::spawn(
        PoolOptions::new(Arc::new(MockEngineFactory::new(spec)))
            .with_workers(2)
            .with_queue_depth(16),
    )
    .unwrap();
    let slow = manifest.variant("kern.v0.n8").unwrap().clone();
    let fast = manifest.variant("kern.v1.n8").unwrap().clone();
    assert_eq!(pool.install(slow.clone(), "hlo".into()), 2);
    assert_eq!(pool.install(fast.clone(), "hlo".into()), 2);

    let slow_exe = pool.handle_for(slow.id.clone());
    let slow_join = std::thread::spawn(move || slow_exe.execute(&[HostTensor::zeros(&[8, 8])]));
    std::thread::sleep(Duration::from_millis(50)); // long job popped and running

    let t0 = Instant::now();
    let mut joins = Vec::new();
    for _ in 0..4 {
        let exe = pool.handle_for(fast.id.clone());
        joins.push(std::thread::spawn(move || {
            for _ in 0..25 {
                let out = exe.execute(&[HostTensor::zeros(&[8, 8])]).unwrap();
                assert!(out.data().iter().all(|&x| x == 1.0));
            }
        }));
    }
    for j in joins {
        j.join().unwrap();
    }
    let fast_elapsed = t0.elapsed();
    slow_join.join().unwrap().unwrap();

    let snap = pool.snapshot();
    let steals: u64 = snap.workers.iter().map(|w| w.steals).sum();
    assert!(steals >= 1, "idle worker stole from the busy sibling: {snap:?}");
    // the idle worker absorbed well beyond its round-robin half of the
    // 100 fast jobs (its own ~50 plus most of the busy worker's share)
    let max_executed = snap.workers.iter().map(|w| w.executed).max().unwrap();
    assert!(max_executed >= 60, "stolen jobs ran on the idle worker: {snap:?}");
    // and the fast jobs did not serialize behind the 300ms job
    assert!(
        fast_elapsed < Duration::from_millis(1500),
        "fast jobs finished without waiting out the slow one: {fast_elapsed:?}"
    );
    // stats surface the steals
    let json = pool.to_json();
    let per_worker = json.get("per_worker").unwrap().as_arr().unwrap();
    let steals_json: i64 = per_worker
        .iter()
        .map(|w| w.get("steals").unwrap().as_i64().unwrap())
        .sum();
    assert_eq!(steals_json as u64, steals);
    pool.stop();
}

#[test]
fn partial_install_routes_to_ready_worker_subset() {
    // PR 4 follow-up regression: when only a subset of pool workers
    // manages to compile a finalized winner, tuned traffic must be
    // routed to that ready subset — not degraded to the leader, and
    // never to the failed worker. The CompileFault rule targets the
    // winner on worker 1's deterministically-named thread, so the
    // install broadcast acks on workers 0 and 2 only.
    const THREADS: usize = 4;
    const CALLS: usize = 50;
    let spec = sleepy_spec(100);
    let fault: CompileFault = spec.compile_fault.clone();
    fault.fail_on_thread("kern.v1.n8", "jitune-pool-1");
    let coord = spawn(spec, 3);
    let h = coord.handle();

    tune(&coord);
    assert_eq!(
        h.fast_lane_published(),
        1,
        "a 2-of-3 partial install still publishes a pool route"
    );

    let total = hammer(&coord, THREADS, CALLS);
    assert_eq!(total, THREADS * CALLS, "no call lost");

    let snap = h.pool_snapshot().expect("pool attached");
    assert_eq!(snap.workers.len(), 3);
    assert_eq!(
        snap.workers[1].executed, 0,
        "the worker that failed the compile never serves the winner: {snap:?}"
    );
    assert!(
        snap.workers[0].executed > 0 && snap.workers[2].executed > 0,
        "both ready workers share the tuned traffic: {snap:?}"
    );
    // All hammered calls ran on the pool's ready subset — none fell back
    // to the leader (pool executions and lane hits agree, and cover the
    // hammered volume).
    let lane_hits: u64 = h.fast_lane_stats().iter().map(|(_, hits, _)| *hits).sum();
    assert_eq!(snap.total_executed(), lane_hits, "pool executions == lane hits");
    assert!(
        snap.total_executed() >= (THREADS * CALLS) as u64,
        "steady-state calls stayed on the ready subset: {snap:?}"
    );
    assert_eq!(snap.respawns, 0, "a failed install is not a worker crash");
}

#[test]
fn no_call_lost_during_concurrent_retune() {
    const THREADS: usize = 4;
    const CALLS: usize = 50;
    let coord = spawn(sleepy_spec(50), 3);
    let h = coord.handle();
    tune(&coord);
    assert_eq!(h.fast_lane_published(), 1);

    let mut joins = Vec::new();
    for _ in 0..THREADS {
        let h = coord.handle();
        joins.push(std::thread::spawn(move || {
            for _ in 0..CALLS {
                let o = h.call("kern", inputs()).unwrap();
                // whatever the phase, outputs stay consistent
                assert!(o.output.data().iter().all(|&x| x == o.value as f32));
            }
        }));
    }
    std::thread::sleep(Duration::from_millis(2));
    assert!(h.retune("kern", 8).unwrap());
    for j in joins {
        j.join().unwrap();
    }

    // drive tuning back to steady state; the rematch's winner republishes
    // onto the pool
    let mut tuned = false;
    for _ in 0..10 {
        if h.call("kern", inputs()).unwrap().route == CallRoute::Tuned {
            tuned = true;
            break;
        }
    }
    assert!(tuned, "retuned problem converges back to the pool path");
    assert_eq!(h.tuned_value("kern", 8).unwrap(), Some(1));
    assert_eq!(h.fast_lane_published(), 1);
    // exact accounting: leader calls + lane hits == total submitted
    let json = h.stats_json().unwrap();
    let kern = json.get("kernels").unwrap().get("kern").unwrap();
    let leader_calls: i64 = ["explored", "finalized", "tuned"]
        .into_iter()
        .map(|f| kern.get(f).unwrap().as_i64().unwrap())
        .sum();
    let lane_hits: i64 = h.fast_lane_stats().iter().map(|(_, hits, _)| *hits as i64).sum();
    // tune(): unknown (≤4) warm calls; hammer: THREADS*CALLS; convergence loop counted
    assert!(
        leader_calls + lane_hits >= (THREADS * CALLS) as i64,
        "no call vanished: leader={leader_calls} lane={lane_hits}"
    );
    let snap = h.pool_snapshot().unwrap();
    assert_eq!(snap.total_executed(), lane_hits as u64, "pool executions == lane hits");
}
