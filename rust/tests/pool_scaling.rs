//! Worker-pool scaling + consistency: the mock engine forced onto the
//! pool path (pinned factory — every kernel refuses `shared()`), hammered
//! from many threads. Tuned-call throughput must scale with workers, no
//! call may be lost across a concurrent retune, and the per-worker
//! counters must sum to the lane's global hit count.

use std::time::{Duration, Instant};

use jitune::coordinator::{CallRoute, Coordinator, ServerOptions};
use jitune::runtime::mock::MockSpec;
use jitune::tensor::HostTensor;
use jitune::testutil::spawn_pooled_mock;

/// v1 wins by a wide margin; sleep-based execution models an accelerator
/// offload so throughput is capped by coordination, not host cores.
fn sleepy_spec(exec_us: u64) -> MockSpec {
    MockSpec::default()
        .with_cost("kern.v0.n8", Duration::from_micros(4 * exec_us))
        .with_cost("kern.v1.n8", Duration::from_micros(exec_us))
        .with_sleep_exec()
}

fn spawn(spec: MockSpec, workers: usize) -> Coordinator {
    spawn_pooled_mock("kern", 2, &[8], spec, workers, ServerOptions::default()).unwrap()
}

fn inputs() -> Vec<HostTensor> {
    vec![HostTensor::zeros(&[8, 8])]
}

/// Drive tuning to completion (2 explores + 1 finalize, leader lane).
fn tune(coord: &Coordinator) {
    let h = coord.handle();
    loop {
        if h.call("kern", inputs()).unwrap().route == CallRoute::Tuned {
            break;
        }
    }
    assert_eq!(h.tuned_value("kern", 8).unwrap(), Some(1));
}

fn hammer(coord: &Coordinator, threads: usize, calls: usize) -> usize {
    let mut joins = Vec::new();
    for _ in 0..threads {
        let h = coord.handle();
        joins.push(std::thread::spawn(move || {
            let mut served = 0usize;
            for _ in 0..calls {
                let o = h.call("kern", inputs()).unwrap();
                // outputs always encode the executed variant's value
                assert!(o.output.data().iter().all(|&x| x == o.value as f32));
                served += 1;
            }
            served
        }));
    }
    joins.into_iter().map(|j| j.join().unwrap()).sum()
}

#[test]
fn pool_serves_pinned_engine_and_stats_line_up() {
    let coord = spawn(sleepy_spec(100), 2);
    let h = coord.handle();
    tune(&coord);
    assert_eq!(h.fast_lane_published(), 1, "pool-routed entry published");

    let total = hammer(&coord, 6, 30);
    assert_eq!(total, 180, "no call lost");

    // Per-worker counters sum to the lane's global hit count: every pool
    // execution is exactly one fast-lane hit, nothing double-counted.
    let snap = h.pool_snapshot().expect("pool attached");
    assert_eq!(snap.workers.len(), 2);
    let worker_total = snap.total_executed();
    let lane_hits: u64 = h.fast_lane_stats().iter().map(|(_, hits, _)| *hits).sum();
    assert_eq!(worker_total, lane_hits, "per-worker sums == lane hits: {snap:?}");
    assert!(worker_total >= 180, "steady state runs on the pool: {snap:?}");
    assert!(
        snap.workers.iter().all(|w| w.executed > 0),
        "both workers served: {snap:?}"
    );
    assert_eq!(snap.respawns, 0);

    // machine-readable stats expose all three lanes' counters
    let json = h.stats_json().unwrap();
    assert!(json.get("kernels").is_some());
    assert!(json.get("fast_lane").is_some());
    let pool = json.get("pool").expect("pool stats exported");
    assert_eq!(pool.get("workers").unwrap().as_i64(), Some(2));
    assert_eq!(pool.get("executed").unwrap().as_i64(), Some(worker_total as i64));
}

#[test]
fn tuned_throughput_scales_with_workers() {
    let measure = |workers: usize| {
        let coord = spawn(sleepy_spec(500), workers);
        tune(&coord);
        let t0 = Instant::now();
        let total = hammer(&coord, 8, 40);
        assert_eq!(total, 320);
        total as f64 / t0.elapsed().as_secs_f64()
    };
    let one = measure(1);
    let four = measure(4);
    assert!(
        four > one * 2.0,
        "pool scaling: 1 worker {one:.0} calls/s vs 4 workers {four:.0} calls/s"
    );
}

#[test]
fn no_call_lost_during_concurrent_retune() {
    const THREADS: usize = 4;
    const CALLS: usize = 50;
    let coord = spawn(sleepy_spec(50), 3);
    let h = coord.handle();
    tune(&coord);
    assert_eq!(h.fast_lane_published(), 1);

    let mut joins = Vec::new();
    for _ in 0..THREADS {
        let h = coord.handle();
        joins.push(std::thread::spawn(move || {
            for _ in 0..CALLS {
                let o = h.call("kern", inputs()).unwrap();
                // whatever the phase, outputs stay consistent
                assert!(o.output.data().iter().all(|&x| x == o.value as f32));
            }
        }));
    }
    std::thread::sleep(Duration::from_millis(2));
    assert!(h.retune("kern", 8).unwrap());
    for j in joins {
        j.join().unwrap();
    }

    // drive tuning back to steady state; the rematch's winner republishes
    // onto the pool
    let mut tuned = false;
    for _ in 0..10 {
        if h.call("kern", inputs()).unwrap().route == CallRoute::Tuned {
            tuned = true;
            break;
        }
    }
    assert!(tuned, "retuned problem converges back to the pool path");
    assert_eq!(h.tuned_value("kern", 8).unwrap(), Some(1));
    assert_eq!(h.fast_lane_published(), 1);
    // exact accounting: leader calls + lane hits == total submitted
    let json = h.stats_json().unwrap();
    let kern = json.get("kernels").unwrap().get("kern").unwrap();
    let leader_calls: i64 = ["explored", "finalized", "tuned"]
        .into_iter()
        .map(|f| kern.get(f).unwrap().as_i64().unwrap())
        .sum();
    let lane_hits: i64 = h.fast_lane_stats().iter().map(|(_, hits, _)| *hits as i64).sum();
    // tune(): unknown (≤4) warm calls; hammer: THREADS*CALLS; convergence loop counted
    assert!(
        leader_calls + lane_hits >= (THREADS * CALLS) as i64,
        "no call vanished: leader={leader_calls} lane={lane_hits}"
    );
    let snap = h.pool_snapshot().unwrap();
    assert_eq!(snap.total_executed(), lane_hits as u64, "pool executions == lane hits");
}
