//! Fast-lane stress: N threads × M calls against a tuning kernel from a
//! cold start — no call may be lost across the explore→tuned transition,
//! every output must match the executed variant's reference tensor,
//! tuning calls must stay serialized on the leader, and retune must
//! invalidate the published entry.

use std::time::Duration;

use jitune::coordinator::{CallOutcome, CallRoute, Coordinator, Dispatcher, KernelRegistry};
use jitune::runtime::mock::{MockEngine, MockSpec};
use jitune::tensor::HostTensor;
use jitune::testutil::synthetic_manifest;
use jitune::util::json::Value;

fn spec_with_costs(costs_us: &[u64]) -> MockSpec {
    let mut spec = MockSpec::default();
    for (i, &c) in costs_us.iter().enumerate() {
        for size in [8, 16] {
            spec = spec.with_cost(&format!("kern.v{i}.n{size}"), Duration::from_micros(c));
        }
    }
    spec
}

fn spawn(variants: usize, spec: MockSpec) -> Coordinator {
    Coordinator::spawn(move || {
        let manifest = synthetic_manifest("kern", variants, &[8, 16])?;
        let registry = KernelRegistry::new(manifest);
        Ok(Dispatcher::new(registry, Box::new(MockEngine::new(spec))))
    })
    .unwrap()
}

fn hammer(coord: &Coordinator, threads: usize, calls: usize) -> Vec<CallOutcome> {
    let mut joins = Vec::new();
    for _ in 0..threads {
        let h = coord.handle();
        joins.push(std::thread::spawn(move || {
            let mut outcomes = Vec::new();
            for _ in 0..calls {
                outcomes.push(h.call("kern", vec![HostTensor::zeros(&[8, 8])]).unwrap());
            }
            outcomes
        }));
    }
    let mut all = Vec::new();
    for j in joins {
        all.extend(j.join().unwrap());
    }
    all
}

fn leader_calls(stats: &Value, kernel: &str) -> i64 {
    let k = stats.get("kernels").unwrap().get(kernel).unwrap();
    ["explored", "finalized", "tuned"]
        .into_iter()
        .map(|f| k.get(f).unwrap().as_i64().unwrap())
        .sum()
}

#[test]
fn stress_no_lost_calls_and_reference_outputs() {
    const THREADS: usize = 6;
    const CALLS: usize = 40;
    // v1 is the clear winner (10x margin)
    let coord = spawn(3, spec_with_costs(&[300, 30, 300]));
    let all = hammer(&coord, THREADS, CALLS);
    assert_eq!(all.len(), THREADS * CALLS, "call lost in explore→tuned transition");

    // Every output matches the executed variant's reference tensor (the
    // mock analog of the tensor::reference checks: full(value)).
    for o in &all {
        let want = HostTensor::full(&[8, 8], o.value as f32);
        assert_eq!(o.output, want, "output diverges for {}", o.variant_id);
        if o.route == CallRoute::Tuned {
            assert_eq!(o.value, 1, "steady state must serve the winner");
        }
    }

    // Exploring/finalizing stays serialized through the leader. Fused
    // rounds may run surplus co-scheduled callers as *replicas* of a
    // candidate (their median is what the tuner records), so the
    // explored-call count is >= the candidate count but bounded by the
    // co-scheduled rounds; the tuner itself must still see each
    // candidate, and at most one caller ever observes the finalize (a
    // round that converges finalizes leader-side, with no caller).
    let explored = all.iter().filter(|o| o.route == CallRoute::Explored).count();
    let finalized = all.iter().filter(|o| o.route == CallRoute::Finalized).count();
    assert!(explored >= 3, "every candidate measured (got {explored} explored calls)");
    assert!(
        explored <= 3 * THREADS,
        "explore phase bounded by co-scheduled rounds (got {explored})"
    );
    assert!(finalized <= 1, "winner finalized at most once caller-side");
    // the tuning state saw every candidate, replicas collapsed to medians
    let (_, report) = coord.handle().stats().unwrap();
    let (_, problem) = &report.as_obj().unwrap()[0];
    let variants = problem.get("variants").unwrap().as_arr().unwrap();
    assert_eq!(variants.len(), 3);
    for v in variants {
        assert!(
            v.get("samples").unwrap().as_i64().unwrap() >= 1,
            "candidate measured: {}",
            v.to_json()
        );
    }

    // Exact two-lane accounting: every call either hit the fast lane or
    // was processed by the leader — nothing double-counted, nothing lost.
    let h = coord.handle();
    let stats = h.stats_json().unwrap();
    let lane_hits: i64 = h.fast_lane_stats().iter().map(|(_, hits, _)| *hits as i64).sum();
    assert_eq!(leader_calls(&stats, "kern") + lane_hits, (THREADS * CALLS) as i64);
    assert!(lane_hits > 0, "steady state must use the fast lane");
    assert_eq!(h.fast_lane_published(), 1);
    assert_eq!(h.tuned_value("kern", 8).unwrap(), Some(1));
}

#[test]
fn sizes_publish_independent_entries() {
    let coord = spawn(2, spec_with_costs(&[200, 20]));
    let h = coord.handle();
    for _ in 0..3 {
        h.call("kern", vec![HostTensor::zeros(&[8, 8])]).unwrap();
    }
    assert_eq!(h.fast_lane_published(), 1, "only the n8 problem is tuned");
    for _ in 0..3 {
        h.call("kern", vec![HostTensor::zeros(&[16, 16])]).unwrap();
    }
    assert_eq!(h.fast_lane_published(), 2, "n16 publishes its own entry");
    // each entry serves its own shape with the winner's value
    let o8 = h.call("kern", vec![HostTensor::zeros(&[8, 8])]).unwrap();
    let o16 = h.call("kern", vec![HostTensor::zeros(&[16, 16])]).unwrap();
    assert_eq!(o8.output.shape(), &[8, 8]);
    assert_eq!(o16.output.shape(), &[16, 16]);
    assert_eq!((o8.value, o16.value), (1, 1));
}

#[test]
fn retune_invalidates_published_entry() {
    let coord = spawn(2, spec_with_costs(&[200, 20]));
    let h = coord.handle();
    for _ in 0..4 {
        h.call("kern", vec![HostTensor::zeros(&[8, 8])]).unwrap();
    }
    assert_eq!(h.fast_lane_published(), 1);
    assert!(h.retune("kern", 8).unwrap());
    assert_eq!(h.fast_lane_published(), 0, "retune unpublishes");
    assert_eq!(h.tuned_value("kern", 8).unwrap(), None);
    // next call re-explores through the leader, then tuning completes and
    // the winner is republished
    let o = h.call("kern", vec![HostTensor::zeros(&[8, 8])]).unwrap();
    assert_eq!(o.route, CallRoute::Explored);
    for _ in 0..2 {
        h.call("kern", vec![HostTensor::zeros(&[8, 8])]).unwrap();
    }
    assert_eq!(h.fast_lane_published(), 1);
    assert_eq!(h.tuned_value("kern", 8).unwrap(), Some(1));
}

#[test]
fn retune_under_concurrent_load_is_safe() {
    const THREADS: usize = 4;
    const CALLS: usize = 50;
    let coord = spawn(2, spec_with_costs(&[200, 20]));
    let h = coord.handle();
    for _ in 0..3 {
        h.call("kern", vec![HostTensor::zeros(&[8, 8])]).unwrap();
    }
    assert_eq!(h.fast_lane_published(), 1);

    // hammer from worker threads while the main thread retunes mid-flight
    let mut joins = Vec::new();
    for _ in 0..THREADS {
        let h = coord.handle();
        joins.push(std::thread::spawn(move || {
            for _ in 0..CALLS {
                let o = h.call("kern", vec![HostTensor::zeros(&[8, 8])]).unwrap();
                // whatever the phase, outputs stay consistent
                assert!(o.output.data().iter().all(|&x| x == o.value as f32));
            }
        }));
    }
    std::thread::sleep(Duration::from_millis(2));
    assert!(h.retune("kern", 8).unwrap());
    for j in joins {
        j.join().unwrap();
    }

    // drive tuning back to steady state (bounded; 2 candidates need at
    // most explore+explore+finalize)
    let mut tuned = false;
    for _ in 0..10 {
        if h.call("kern", vec![HostTensor::zeros(&[8, 8])]).unwrap().route == CallRoute::Tuned {
            tuned = true;
            break;
        }
    }
    assert!(tuned, "retuned problem converges back to steady state");
    assert_eq!(h.tuned_value("kern", 8).unwrap(), Some(1));
    assert_eq!(h.fast_lane_published(), 1);
}
