//! Fused-exploration contracts: fused tuning must converge to the same
//! winner as serial tuning for every search strategy, a mid-round
//! candidate failure must only fail that candidate's caller, fused
//! rounds must cut rounds-to-tuned, and cheap control requests must
//! overtake slow explores queued in the same scheduling round.

use std::time::{Duration, Instant};

use jitune::autotuner::{search, Autotuner, BatchDecision, Phase, TuningState, WallClock};
use jitune::coordinator::{
    BatchOptions, CallRoute, Coordinator, Dispatcher, KernelRegistry, ServerOptions,
};
use jitune::runtime::mock::{MockEngine, MockSpec};
use jitune::tensor::HostTensor;
use jitune::testutil::synthetic_manifest;

const KERNEL: &str = "kern";
const SIZE: i64 = 8;

fn inputs() -> Vec<HostTensor> {
    vec![HostTensor::zeros(&[8, 8])]
}

/// Well-separated V-shaped costs over `variants` candidates (winner at
/// the middle index) — ordering robust to spin-timing noise.
fn v_spec(variants: usize) -> MockSpec {
    let mut spec = MockSpec::default().with_compile_cost(Duration::from_micros(150));
    for i in 0..variants {
        let dist = (i as i64 - (variants / 2) as i64).unsigned_abs();
        spec = spec.with_cost(
            &format!("{KERNEL}.v{i}.n{SIZE}"),
            Duration::from_micros(60 + 150 * dist),
        );
    }
    spec
}

fn dispatcher_with_strategy(
    variants: usize,
    strategy: &str,
    seed: u64,
    spec: MockSpec,
) -> Dispatcher {
    let manifest = synthetic_manifest(KERNEL, variants, &[SIZE]).unwrap();
    let strategy = strategy.to_string();
    let tuner = Autotuner::with_factory(Box::new(move |values| {
        search::from_spec(&strategy, values.len(), seed).unwrap()
    }));
    Dispatcher::with(
        KernelRegistry::new(manifest),
        Box::new(MockEngine::new(spec)),
        tuner,
        Box::new(WallClock::new()),
    )
}

fn tune_serial(d: &mut Dispatcher) -> i64 {
    for _ in 0..10_000 {
        d.call(KERNEL, &inputs()).unwrap();
        if let Some(v) = d.tuned_value(KERNEL, SIZE) {
            return v;
        }
    }
    panic!("serial tuning never converged");
}

fn tune_fused(d: &mut Dispatcher, width: usize) -> (i64, usize) {
    for round in 1..=10_000 {
        let batch: Vec<_> = (0..width).map(|_| inputs()).collect();
        for result in d.call_batch(KERNEL, batch) {
            result.unwrap();
        }
        if let Some(v) = d.tuned_value(KERNEL, SIZE) {
            return (v, round);
        }
    }
    panic!("fused tuning never converged");
}

/// State-machine-level equivalence under a *deterministic* cost table:
/// for every strategy and a spread of seeds, driving the tuning state
/// through `decide_batch`/`report_batch` at any width converges to the
/// same winner as the serial `decide`/`report` protocol.
#[test]
fn fused_state_machine_matches_serial_for_every_strategy() {
    let values: Vec<i64> = (0..9).collect();
    let cost = |idx: usize| ((idx as f64) - 6.0).abs() * 10.0 + 1.0; // min at 6
    for strategy in ["sweep", "random:18", "hillclimb", "anneal:32"] {
        for seed in [0u64, 7, 42, 1234] {
            let serial_winner = {
                let mut st = TuningState::new(
                    values.clone(),
                    search::from_spec(strategy, values.len(), seed).unwrap(),
                );
                loop {
                    match st.decide_batch(1) {
                        BatchDecision::Explore(batch) => {
                            let reports: Vec<_> =
                                batch.iter().map(|&i| (i, Some(cost(i)))).collect();
                            st.report_batch(&reports);
                        }
                        BatchDecision::Finalize(i) => {
                            st.confirm_finalized(i);
                            break i;
                        }
                        d => panic!("{strategy}/{seed}: {d:?}"),
                    }
                }
            };
            for width in [2usize, 3, 5] {
                let mut st = TuningState::new(
                    values.clone(),
                    search::from_spec(strategy, values.len(), seed).unwrap(),
                );
                let fused_winner = loop {
                    match st.decide_batch(width) {
                        BatchDecision::Explore(batch) => {
                            let reports: Vec<_> =
                                batch.iter().map(|&i| (i, Some(cost(i)))).collect();
                            st.report_batch(&reports);
                        }
                        BatchDecision::Finalize(i) => {
                            st.confirm_finalized(i);
                            break i;
                        }
                        d => panic!("{strategy}/{seed}/w{width}: {d:?}"),
                    }
                };
                assert_eq!(
                    fused_winner, serial_winner,
                    "{strategy} seed {seed} width {width}: fused diverged from serial"
                );
                assert_eq!(st.phase(), Phase::Tuned);
            }
        }
    }
}

/// Mock-engine end-to-end equivalence: a fused dispatcher converges to
/// the same winner as a serial one on the same engine spec. Covers the
/// strategies whose candidate choice never depends on sub-percent cost
/// deltas (sweep/random cover every candidate; hillclimb compares costs
/// separated 3x+, far beyond spin-timing noise). Annealing's *acceptance
/// draws* consume measurement noise, so its serial-vs-fused equality is
/// asserted under deterministic costs in
/// `fused_state_machine_matches_serial_for_every_strategy`; here it must
/// still converge through the fused path.
#[test]
fn fused_dispatcher_matches_serial_winner_per_strategy() {
    const VARIANTS: usize = 6;
    for (strategy, seed) in [("sweep", 0u64), ("random:12", 42), ("hillclimb", 0)] {
        let mut serial = dispatcher_with_strategy(VARIANTS, strategy, seed, v_spec(VARIANTS));
        let serial_winner = tune_serial(&mut serial);
        for width in [2usize, 4] {
            let mut fused =
                dispatcher_with_strategy(VARIANTS, strategy, seed, v_spec(VARIANTS));
            let (fused_winner, _) = tune_fused(&mut fused, width);
            assert_eq!(
                fused_winner, serial_winner,
                "{strategy} width {width}: fused winner diverged"
            );
        }
    }
    // annealing: fused rounds replicate its single sequential proposal
    // (serial default propose_batch) — it must reach Tuned on a live
    // engine with a sane winner
    let mut anneal = dispatcher_with_strategy(VARIANTS, "anneal:24", 7, v_spec(VARIANTS));
    let (winner, _) = tune_fused(&mut anneal, 3);
    assert!((0..VARIANTS as i64).contains(&winner), "anneal fused converges: {winner}");
}

/// The acceptance ratio: a sweep over 8 variants with 4 co-scheduled
/// callers reaches `Phase::Tuned` in >=2x fewer leader rounds than
/// serial dispatch, and the fused counters account for the saving.
#[test]
fn fused_sweep_cuts_rounds_to_tuned_at_least_2x() {
    const VARIANTS: usize = 8;
    let mut serial = dispatcher_with_strategy(VARIANTS, "sweep", 0, v_spec(VARIANTS));
    let mut serial_rounds = 0usize;
    while serial.phase(KERNEL, SIZE) != Some(Phase::Tuned) {
        serial.call(KERNEL, &inputs()).unwrap();
        serial_rounds += 1;
    }
    assert_eq!(serial_rounds, VARIANTS + 1, "sweep: V explores + 1 finalize");

    let mut fused = dispatcher_with_strategy(VARIANTS, "sweep", 0, v_spec(VARIANTS));
    let (winner, fused_rounds) = tune_fused(&mut fused, 4);
    assert_eq!(winner, (VARIANTS / 2) as i64, "fastest variant wins");
    assert!(
        serial_rounds >= 2 * fused_rounds,
        "fused must be >=2x fewer rounds: serial {serial_rounds} vs fused {fused_rounds}"
    );
    let f = fused.stats().fused();
    assert_eq!(f.fused_rounds as usize, fused_rounds);
    assert_eq!(f.fused_calls, 4 * fused_rounds as u64);
    assert!(
        f.explore_rounds_saved as usize >= serial_rounds - fused_rounds,
        "counters account for the saved rounds: {f:?}"
    );
}

/// Failure isolation end-to-end: in a fused round covering a failing
/// candidate, only the caller(s) assigned to it observe the error;
/// round-mates succeed, the candidate is excluded, and tuning still
/// converges to the correct winner.
#[test]
fn mid_round_candidate_failure_only_fails_its_caller() {
    const VARIANTS: usize = 4;
    let mut spec = v_spec(VARIANTS);
    let winner_id = format!("{KERNEL}.v{}.n{SIZE}", VARIANTS / 2);
    spec.fail_execute.insert(winner_id.clone());
    let mut d = dispatcher_with_strategy(VARIANTS, "sweep", 0, spec);
    // round of 4 over 4 candidates: one call per candidate, the
    // would-be winner fails its own caller only
    let results = d.call_batch(KERNEL, (0..4).map(|_| inputs()).collect());
    let failures: Vec<usize> =
        (0..4).filter(|&i| results[i].is_err()).collect();
    assert_eq!(failures.len(), 1, "exactly the failing candidate's caller errors");
    for (i, r) in results.iter().enumerate() {
        if !failures.contains(&i) {
            let o = r.as_ref().unwrap();
            assert_eq!(o.route, CallRoute::Explored, "round-mates unaffected");
            assert_ne!(o.variant_id, winner_id);
        }
    }
    // the failed candidate is excluded; the runner-up wins in-round
    let tuned = d.tuned_value(KERNEL, SIZE).expect("converged despite the failure");
    assert_ne!(tuned, (VARIANTS / 2) as i64, "failed variant cannot win");
    assert_eq!(d.stats().total_failures(), 1);
}

/// Satellite: cheap control requests reorder ahead of `Call`s within a
/// drained round — a slow explore measurement queued first must not
/// delay a tuned-value probe that entered the queue *behind* it.
#[test]
fn control_requests_overtake_slow_explores_in_a_round() {
    let slow = MockSpec {
        default_exec_cost: Duration::from_millis(300),
        exec_sleep: true,
        ..MockSpec::default()
    };
    let coord = Coordinator::spawn_with_options(
        move || {
            let manifest = synthetic_manifest(KERNEL, 4, &[SIZE])?;
            Ok(Dispatcher::new(KernelRegistry::new(manifest), Box::new(MockEngine::new(slow))))
        },
        ServerOptions { batch: BatchOptions { max_batch: 8 }, ..ServerOptions::default() },
    )
    .unwrap();
    // round 1: one slow explore occupies the leader
    let h1 = coord.handle();
    let first = std::thread::spawn(move || h1.call(KERNEL, inputs()).unwrap());
    std::thread::sleep(Duration::from_millis(30));
    // round 2 queues a second slow call, then the control probe behind it
    let h2 = coord.handle();
    let second = std::thread::spawn(move || h2.call(KERNEL, inputs()).unwrap());
    std::thread::sleep(Duration::from_millis(20));
    let t0 = Instant::now();
    let _ = coord.handle().tuned_value(KERNEL, SIZE).unwrap();
    let control_wait = t0.elapsed();
    // the probe waits out round 1's residue (~250ms) but *not* round 2's
    // 300ms explore that was queued ahead of it (serial order: ~550ms);
    // the ~200ms slack absorbs loaded-CI scheduling noise
    assert!(
        control_wait < Duration::from_millis(450),
        "control reply overtook the queued explore: waited {control_wait:?}"
    );
    first.join().unwrap();
    second.join().unwrap();
}

/// End-to-end through the coordinator: concurrent callers co-scheduled
/// into leader rounds tune correctly and the fused counters surface in
/// `stats_json()`.
#[test]
fn coordinator_fuses_co_scheduled_callers_and_reports_counters() {
    const VARIANTS: usize = 8;
    let mut engine_spec = v_spec(VARIANTS);
    engine_spec.exec_sleep = true; // frees host cores; callers pile up
    let coord = Coordinator::spawn_with_options(
        move || {
            let manifest = synthetic_manifest(KERNEL, VARIANTS, &[SIZE])?;
            Ok(Dispatcher::new(
                KernelRegistry::new(manifest),
                Box::new(MockEngine::new(engine_spec)),
            ))
        },
        ServerOptions { batch: BatchOptions { max_batch: 16 }, ..ServerOptions::default() },
    )
    .unwrap();
    // waves of 4 concurrent callers until tuned
    let mut waves = 0;
    loop {
        waves += 1;
        let joins: Vec<_> = (0..4)
            .map(|_| {
                let h = coord.handle();
                std::thread::spawn(move || h.call(KERNEL, inputs()).unwrap())
            })
            .collect();
        for j in joins {
            j.join().unwrap();
        }
        if coord.handle().tuned_value(KERNEL, SIZE).unwrap().is_some() {
            break;
        }
        assert!(waves < 200, "coordinator tuning never converged");
    }
    assert_eq!(
        coord.handle().tuned_value(KERNEL, SIZE).unwrap(),
        Some((VARIANTS / 2) as i64),
        "co-scheduled tuning converges to the fastest variant"
    );
    // slow sleep-based explores guarantee later waves queue behind the
    // leader: at least one round must have fused
    let json = coord.handle().stats_json().unwrap();
    let fused = json.get("fused").expect("fused counters exported");
    assert!(fused.get("fused_rounds").unwrap().as_i64().unwrap() >= 1, "{}", json.to_json());
    assert!(fused.get("explore_rounds_saved").unwrap().as_i64().unwrap() >= 1);
    let (rendered, _) = coord.handle().stats().unwrap();
    assert!(rendered.contains("fused rounds"), "{rendered}");
}
