//! Background shadow exploration contracts: the duty-cycle budget is
//! respected under sustained traffic, a wedged candidate is hedged off
//! and the round recovers, and a cold-start caller stream never observes
//! explore-inflated latency once a runnable variant exists.

use std::sync::Arc;
use std::time::{Duration, Instant};

use jitune::autotuner::{search, Autotuner, WallClock};
use jitune::coordinator::{
    CallRoute, Coordinator, Dispatcher, ExploreOptions, KernelRegistry, PoolOptions, ServerOptions,
};
use jitune::runtime::mock::{MockEngineFactory, MockSpec};
use jitune::runtime::EngineFactory;
use jitune::tensor::HostTensor;
use jitune::testutil::{spawn_pooled_mock, synthetic_manifest};
use jitune::util::json::Value;

const KERNEL: &str = "kern";
const SIZE: i64 = 8;

fn inputs() -> Vec<HostTensor> {
    vec![HostTensor::zeros(&[8, 8])]
}

fn background_json(stats: &Value) -> &Value {
    stats.get("background").expect("background counters exported")
}

/// Poll `tuned_value` through the handle until the problem reaches
/// `Phase::Tuned`; panics after `timeout`.
fn wait_tuned(coord: &Coordinator, timeout: Duration) -> i64 {
    let h = coord.handle();
    let t0 = Instant::now();
    loop {
        if let Some(v) = h.tuned_value(KERNEL, SIZE).unwrap() {
            return v;
        }
        assert!(t0.elapsed() < timeout, "background tuning never converged");
        std::thread::sleep(Duration::from_millis(2));
    }
}

/// Duty-cycle budget under sustained traffic: with a 20% budget on 2
/// explore workers, exploration busy time stays within the budget (the
/// overshoot is bounded by the in-flight pipeline, about one window) and
/// — the flip side — tuning is genuinely *stretched*: it cannot finish
/// faster than the budget rate allows.
#[test]
fn duty_cycle_budget_respected_under_sustained_traffic() {
    const WORKERS: usize = 2;
    const PCT: f64 = 20.0;
    let window = Duration::from_millis(50);
    // Each explore job costs ~4ms (2ms compile spin + 2ms exec sleep),
    // well under the 20ms per-window capacity, so issuance granularity
    // cannot blow the budget. `random:32` keeps exploring long enough
    // (~128ms of busy work) to span several windows.
    let spec = MockSpec::default()
        .with_compile_cost(Duration::from_millis(2))
        .with_sleep_exec();
    let spec = MockSpec { default_exec_cost: Duration::from_millis(2), ..spec };
    let factory = Arc::new(MockEngineFactory::pinned(spec));
    let leader_factory: Arc<dyn EngineFactory> = factory.clone();
    let opts = ServerOptions {
        pool: Some(PoolOptions::new(factory).with_workers(WORKERS)),
        explore_budget: Some(ExploreOptions::percent(PCT).with_window(window)),
        ..ServerOptions::default()
    };
    let coord = Coordinator::spawn_with_options(
        move || {
            let manifest = synthetic_manifest(KERNEL, 8, &[SIZE])?;
            let tuner = Autotuner::with_factory(Box::new(|values| {
                search::from_spec("random:32", values.len(), 7).unwrap()
            }));
            Ok(Dispatcher::with(
                KernelRegistry::new(manifest),
                leader_factory.create()?,
                tuner,
                Box::new(WallClock::new()),
            ))
        },
        opts,
    )
    .unwrap();

    // Sustained caller traffic while the background tunes.
    let h = coord.handle();
    let t0 = Instant::now();
    let tuned_after = loop {
        h.call(KERNEL, inputs()).unwrap();
        if h.tuned_value(KERNEL, SIZE).unwrap().is_some() {
            break t0.elapsed();
        }
        assert!(t0.elapsed() < Duration::from_secs(30), "never tuned under budget");
    };

    let json = coord.handle().stats_json().unwrap();
    let bg = background_json(&json);
    let jobs = bg.get("jobs_run").unwrap().as_i64().unwrap();
    let busy = bg.get("busy_s").unwrap().as_f64().unwrap();
    assert!(jobs >= 8, "random:32 must run a real sample count, got {jobs}");
    assert!(busy > 0.0);

    // Budget rate in busy-seconds per wall-second across the workers.
    let rate = WORKERS as f64 * PCT / 100.0;
    let elapsed = tuned_after.as_secs_f64();
    // Upper bound: spent busy time never exceeds the budget by more than
    // the per-window issuance granularity (in-flight pipeline of
    // workers+1 jobs, ~1 window of capacity) — allow 2x for CI noise.
    // A broken throttle runs the workers flat out (~100% duty).
    assert!(
        busy <= 2.0 * rate * elapsed + 2.0 * window.as_secs_f64() * rate,
        "duty cycle blown: {busy:.3}s busy in {elapsed:.3}s at {PCT}% x{WORKERS}"
    );
    // Lower bound: the throttle genuinely stretches exploration — the
    // measured busy work cannot have fit in fewer windows than the
    // budget allows (again with 2x overshoot headroom).
    assert!(
        elapsed >= busy / (2.0 * rate),
        "tuned too fast for the budget: {busy:.3}s busy in {elapsed:.3}s"
    );
    // Every window's realized duty cycle was measured and reported.
    assert!(bg.get("windows").unwrap().as_i64().unwrap() >= 2, "{}", json.to_json());
}

/// Hedged cancellation: one candidate whose measurement wedges (100x
/// latency fault) is written off at the hedge deadline, the round moves
/// on without it, and tuning still converges — to some other variant.
#[test]
fn hedge_writes_off_wedged_candidate_and_recovers() {
    let spec = MockSpec::default()
        .with_compile_cost(Duration::from_millis(2))
        .with_sleep_exec();
    let spec = MockSpec { default_exec_cost: Duration::from_millis(3), ..spec };
    let fault = spec.latency_fault.clone();
    // Wedge a middle candidate: the serving default (v0) stays healthy,
    // only v2's background measurement hangs for ~300ms.
    fault.set_scale(&format!("{KERNEL}.v2.n{SIZE}"), 100.0);
    let opts = ServerOptions {
        explore_budget: Some(
            ExploreOptions::percent(50.0)
                .with_window(Duration::from_millis(50))
                .with_hedge(Duration::from_millis(80)),
        ),
        ..ServerOptions::default()
    };
    let coord = spawn_pooled_mock(KERNEL, 4, &[SIZE], spec, 2, opts).unwrap();

    // One call plans the problem and starts background exploration.
    let out = coord.handle().call(KERNEL, inputs()).unwrap();
    assert_eq!(out.route, CallRoute::Default, "cold call serves the default");

    let winner = wait_tuned(&coord, Duration::from_secs(10));
    assert_ne!(winner, 2, "the wedged candidate cannot win");

    let json = coord.handle().stats_json().unwrap();
    let bg = background_json(&json);
    assert!(
        bg.get("hedges_fired").unwrap().as_i64().unwrap() >= 1,
        "the wedged job must have been hedged: {}",
        json.to_json()
    );
}

/// Cold-start serving latency: while the background explores, callers
/// are routed to the current-best/default variant and never pay a
/// candidate's compile+measure. Only the call that compiles the default
/// itself (and at most a couple queued behind the leader-side finalize
/// compile) may exceed the serving cost; under inline exploration every
/// early call would pay the ~40ms candidate compile.
#[test]
fn cold_start_callers_never_pay_exploration() {
    let compile = Duration::from_millis(40);
    let spec = MockSpec::default().with_compile_cost(compile).with_sleep_exec();
    let spec = MockSpec { default_exec_cost: Duration::from_millis(2), ..spec };
    let opts = ServerOptions {
        explore_budget: Some(
            ExploreOptions::percent(80.0).with_window(Duration::from_millis(20)),
        ),
        ..ServerOptions::default()
    };
    let coord = spawn_pooled_mock(KERNEL, 8, &[SIZE], spec, 2, opts).unwrap();

    let h = coord.handle();
    let mut outcomes = Vec::new();
    let t0 = Instant::now();
    loop {
        outcomes.push(h.call(KERNEL, inputs()).unwrap());
        if h.tuned_value(KERNEL, SIZE).unwrap().is_some() {
            break;
        }
        assert!(t0.elapsed() < Duration::from_secs(30), "background tuning never converged");
    }

    // No caller ever ran an exploration round.
    for o in &outcomes {
        assert!(
            matches!(o.route, CallRoute::Default | CallRoute::Tuned),
            "caller must never explore in background mode, got {:?}",
            o.route
        );
    }
    // At most the default-compile call plus a couple of calls queued
    // behind the leader's finalize compile may exceed half the compile
    // cost; a caller paying a full explore round would be ~40ms+ and
    // inline mode would put *every* early call there.
    let slow = outcomes.iter().filter(|o| o.total > compile / 2).count();
    assert!(
        slow <= 3,
        "{slow} of {} cold-start calls saw explore-inflated latency",
        outcomes.len()
    );

    let json = coord.handle().stats_json().unwrap();
    let bg = background_json(&json);
    assert!(
        bg.get("serve_while_exploring").unwrap().as_i64().unwrap() >= 1,
        "{}",
        json.to_json()
    );
    assert!(bg.get("jobs_run").unwrap().as_i64().unwrap() >= 8, "{}", json.to_json());
    // The rendered stats surface the background block too.
    let (rendered, _) = coord.handle().stats().unwrap();
    assert!(rendered.contains("background:"), "{rendered}");
}
