//! Lock-doctor integration suite, run with
//! `cargo test --features lock-doctor --test lock_doctor`.
//!
//! Three properties of the detector: a seeded ABBA inversion is reported
//! with both site labels even though the run never deadlocks; a guard
//! held past the threshold is reported; and a real multi-threaded
//! coordinator workload produces **no** cycles — the detector has teeth
//! without crying wolf. The registry is process-global, so the seeded
//! tests use `lockdoc.test.*` labels and the clean-suite assertion
//! filters them out.

#![cfg(feature = "lock-doctor")]

use std::sync::Arc;
use std::time::Duration;

use jitune::coordinator::{CallRoute, ServerOptions};
use jitune::runtime::mock::MockSpec;
use jitune::sync::{doctor, TrackedMutex};
use jitune::tensor::HostTensor;
use jitune::testutil::spawn_pooled_mock;

/// On a fresh named thread: take `first`, then `second`, release both.
fn lock_pair_in_order(first: &Arc<TrackedMutex<()>>, second: &Arc<TrackedMutex<()>>, name: &str) {
    let (a, b) = (Arc::clone(first), Arc::clone(second));
    std::thread::Builder::new()
        .name(name.to_string())
        .spawn(move || {
            let _g1 = a.lock();
            let _g2 = b.lock();
        })
        .expect("spawn lock-order thread")
        .join()
        .expect("join lock-order thread");
}

#[test]
fn seeded_abba_inversion_is_detected() {
    let a = Arc::new(TrackedMutex::new("lockdoc.test.abba_a", ()));
    let b = Arc::new(TrackedMutex::new("lockdoc.test.abba_b", ()));
    // Sequentially joined threads: the inversion exists in the order
    // graph even though this run can never actually deadlock.
    lock_pair_in_order(&a, &b, "lockdoc-ab");
    lock_pair_in_order(&b, &a, "lockdoc-ba");

    let cycles = doctor::cycles();
    let cycle = cycles
        .iter()
        .find(|c| {
            c.path.iter().any(|s| s == "lockdoc.test.abba_a")
                && c.path.iter().any(|s| s == "lockdoc.test.abba_b")
        })
        .unwrap_or_else(|| panic!("ABBA inversion not reported; cycles: {cycles:?}"));
    assert_eq!(cycle.path.first(), cycle.path.last(), "cycle path is closed");
    assert_eq!(cycle.path.len(), 3, "two-site cycle renders as a -> b -> a");
}

#[test]
fn slow_hold_is_reported() {
    doctor::set_hold_threshold(Duration::from_millis(1));
    let m = TrackedMutex::new("lockdoc.test.slow", ());
    {
        let _g = m.lock();
        std::thread::sleep(Duration::from_millis(20));
    }
    let v = doctor::hold_violations()
        .into_iter()
        .find(|v| v.site == "lockdoc.test.slow")
        .expect("a 20ms hold against a 1ms threshold must be recorded");
    assert!(v.held_for >= Duration::from_millis(1), "{:?}", v.held_for);
}

#[test]
fn coordinator_workload_has_no_lock_order_cycles() {
    let coord =
        spawn_pooled_mock("kern", 2, &[8], MockSpec::default(), 2, ServerOptions::default())
            .expect("spawn pooled coordinator");
    let h = coord.handle();
    // Tune to completion on the leader, then hammer the tuned path from
    // several threads so pool shards, routes, the fast lane and drift
    // trackers all interleave.
    loop {
        if h.call("kern", vec![HostTensor::zeros(&[8, 8])]).expect("tuning call").route
            == CallRoute::Tuned
        {
            break;
        }
    }
    let mut joins = Vec::new();
    for t in 0..4 {
        let h = coord.handle();
        joins.push(
            std::thread::Builder::new()
                .name(format!("lockdoc-hammer-{t}"))
                .spawn(move || {
                    for _ in 0..50 {
                        h.call("kern", vec![HostTensor::zeros(&[8, 8])]).expect("tuned call");
                    }
                })
                .expect("spawn hammer thread"),
        );
    }
    for j in joins {
        j.join().expect("join hammer thread");
    }
    drop(coord);

    let production: Vec<_> = doctor::cycles()
        .into_iter()
        .filter(|c| !c.path.iter().any(|s| s.starts_with("lockdoc.test")))
        .collect();
    assert!(production.is_empty(), "lock-order cycles in the coordinator stack: {production:?}");
}
