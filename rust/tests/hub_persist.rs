//! Durable, replicated hub integration: broker restarts, TCP fleets,
//! push-notify propagation, spawn-time prewarm, and shipping the tuned
//! cache as a deployable artifact.
//!
//! Brokers run in-process (bound with [`HubServer::bind_with`], stopped
//! via [`HubStopHandle`]) so a "restart" is a real stop → rebind over
//! the same persist directory; the export/import cookbook runs the
//! actual `jitune` binary.

use std::path::PathBuf;
use std::process::Command;
use std::time::{Duration, Instant};

use jitune::coordinator::{CallRoute, Coordinator, Dispatcher, KernelRegistry, ServerOptions};
use jitune::hub::{
    BrokerOptions, HubClient, HubEntry, HubOptions, HubServer, HubStopHandle, PersistOptions,
};
use jitune::runtime::mock::{MockEngine, MockSpec};
use jitune::tensor::HostTensor;
use jitune::testutil::{synthetic_manifest, temp_path};

/// An in-process broker serving on a background thread; joined on
/// shutdown so listeners and the socket file are fully released before
/// a rebind.
struct Broker {
    stop: HubStopHandle,
    join: Option<std::thread::JoinHandle<()>>,
    tcp: Option<std::net::SocketAddr>,
}

impl Broker {
    /// Bind (retrying briefly — a just-stopped predecessor may still be
    /// releasing the port) and serve on a background thread.
    fn start(opts: BrokerOptions) -> Broker {
        let deadline = Instant::now() + Duration::from_secs(5);
        let server = loop {
            match HubServer::bind_with(opts.clone()) {
                Ok(s) => break s,
                Err(e) => {
                    assert!(Instant::now() < deadline, "bind broker: {e}");
                    std::thread::sleep(Duration::from_millis(20));
                }
            }
        };
        let stop = server.stop_handle();
        let tcp = server.tcp_addr();
        Broker { stop, join: Some(server.spawn()), tcp }
    }

    fn shutdown(mut self) {
        self.stop.stop();
        if let Some(j) = self.join.take() {
            j.join().expect("join broker thread");
        }
    }
}

impl Drop for Broker {
    fn drop(&mut self) {
        self.stop.stop();
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

/// An entry matching the synthetic manifest (`kern`, param `p`,
/// 8×8 inputs, candidate values [0, 1]) so dispatchers can adopt it.
fn entry(kernel: &str, winner: i64, version: u64) -> HubEntry {
    HubEntry {
        kernel: kernel.into(),
        param: "p".into(),
        signature: "f32[8,8]".into(),
        values: vec![0, 1],
        winner_value: winner,
        version,
    }
}

/// v1 wins tuning (60us vs 600us).
fn base_spec() -> MockSpec {
    MockSpec::default()
        .with_cost("kern.v0.n8", Duration::from_micros(600))
        .with_cost("kern.v1.n8", Duration::from_micros(60))
}

fn inputs() -> Vec<HostTensor> {
    vec![HostTensor::zeros(&[8, 8])]
}

/// One "serving process": a dispatcher over the shared synthetic
/// manifest layout, hub-attached with the given client options.
fn member(opts: HubOptions) -> Dispatcher {
    let manifest = synthetic_manifest("kern", 2, &[8]).expect("manifest");
    let mut d =
        Dispatcher::new(KernelRegistry::new(manifest), Box::new(MockEngine::new(base_spec())));
    d.attach_hub(HubClient::connect(opts).expect("connect hub"));
    d
}

fn sorted(mut entries: Vec<HubEntry>) -> Vec<HubEntry> {
    entries.sort_by(|a, b| a.kernel.cmp(&b.kernel));
    entries
}

#[test]
fn broker_restart_loses_zero_published_entries_over_unix() {
    let dir = temp_path("persist-unix", "d");
    let sock = temp_path("persist-unix", "sock");
    let opts = BrokerOptions::unix(&sock).with_persist(PersistOptions::at(&dir));

    let broker = Broker::start(opts.clone());
    {
        let mut c = HubClient::connect(HubOptions::at(&sock)).expect("connect");
        c.publish(&entry("kern", 1, 1)).expect("publish");
        c.publish(&entry("other", 0, 3)).expect("publish");
        // a newer version replacing an older one must survive as the
        // *newer* one
        c.publish(&entry("kern", 0, 2)).expect("publish");
    }
    broker.shutdown();

    let restarted = Broker::start(opts);
    let mut c = HubClient::connect(HubOptions::at(&sock)).expect("reconnect");
    let got = sorted(c.pull_all().expect("pull"));
    assert_eq!(
        got,
        vec![entry("kern", 0, 2), entry("other", 0, 3)],
        "every acked publish must come back, at its exact version"
    );
    drop(c);
    restarted.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn broker_restart_loses_zero_published_entries_over_tcp() {
    let dir = temp_path("persist-tcp", "d");
    let opts = BrokerOptions::default()
        .with_tcp("127.0.0.1:0")
        .with_persist(PersistOptions::at(&dir));

    let broker = Broker::start(opts.clone());
    let addr = broker.tcp.expect("tcp addr").to_string();
    {
        let mut c = HubClient::connect(HubOptions::tcp(&addr)).expect("connect tcp");
        c.publish(&entry("kern", 1, 1)).expect("publish");
        c.publish(&entry("kern", 0, 2)).expect("publish");
    } // client closes first: the restarted listener can rebind the port
    broker.shutdown();

    // restart on the *same* port so clients redial transparently
    let restarted = Broker::start(
        BrokerOptions::default().with_tcp(addr.clone()).with_persist(PersistOptions::at(&dir)),
    );
    let mut c = HubClient::connect(HubOptions::tcp(&addr)).expect("reconnect tcp");
    assert_eq!(c.pull_all().expect("pull"), vec![entry("kern", 0, 2)]);
    drop(c);
    restarted.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn fleet_reconverges_through_a_restarted_broker() {
    let dir = temp_path("reconverge", "d");
    let sock = temp_path("reconverge", "sock");
    let opts = BrokerOptions::unix(&sock).with_persist(PersistOptions::at(&dir));

    // A tunes from scratch; finalization publishes the winner (v1)
    let broker = Broker::start(opts.clone());
    let mut a = member(HubOptions::at(&sock));
    for _ in 0..3 {
        a.call("kern", &inputs()).expect("tune");
    }
    assert_eq!(a.tuned_value("kern", 8), Some(1));
    assert_eq!(a.stats().hub().pushes, 1);
    broker.shutdown();

    // the broker restarts from its log; a cold process B warm-starts
    // off it with zero explore iterations
    let restarted = Broker::start(opts);
    let mut b = member(HubOptions::at(&sock));
    assert_eq!(b.hub_pull().expect("pull"), (1, 0));
    let first = b.call("kern", &inputs()).expect("warm call");
    assert_eq!(first.route, CallRoute::Finalized, "only the final compile remains");
    assert_eq!(first.value, 1);
    assert_eq!(b.stats().kernel("kern").unwrap().explored, 0, "zero explores after restart");

    // A's live client redials transparently: the connection generation
    // bumps, hub_resync drops stale per-entry knowledge, and the pull
    // reconverges on broker truth without re-tuning
    assert_eq!(a.hub_pull().expect("resync pull"), (0, 0), "same winner: nothing to adopt");
    assert_eq!(a.tuned_value("kern", 8), Some(1));
    restarted.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn push_notify_propagates_between_coordinators_without_pulls() {
    let sock = temp_path("push", "sock");
    let _broker = Broker::start(BrokerOptions::unix(&sock));
    let spawn = |sock: PathBuf| {
        Coordinator::spawn_with_options(
            move || {
                let manifest = synthetic_manifest("kern", 2, &[8])?;
                Ok(Dispatcher::new(
                    KernelRegistry::new(manifest),
                    Box::new(MockEngine::new(base_spec())),
                ))
            },
            ServerOptions {
                // push channel only: no pull_interval — propagation must
                // come from the broker's notify, not polling
                hub: Some(HubOptions { subscribe: true, ..HubOptions::at(&sock) }),
                ..ServerOptions::default()
            },
        )
        .expect("spawn coordinator")
    };

    let b = spawn(sock.clone());
    let hb = b.handle();
    let a = spawn(sock.clone());
    let ha = a.handle();
    for _ in 0..3 {
        ha.call("kern", inputs()).expect("tune");
    }
    assert_eq!(ha.tuned_value("kern", 8).expect("tuned_value"), Some(1));

    // B adopts A's winner with no caller traffic and no periodic pull:
    // the broker pushed the publish, B's notifier triggered the pull
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let json = hb.stats_json().expect("stats_json");
        let adopted = json
            .get("hub")
            .and_then(|h| h.get("adopted"))
            .and_then(jitune::util::json::Value::as_i64)
            .unwrap_or(0);
        if adopted >= 1 {
            break;
        }
        assert!(Instant::now() < deadline, "push-notified adoption never happened");
        std::thread::sleep(Duration::from_millis(20));
    }
    let first = hb.call("kern", inputs()).expect("adopted call");
    assert_eq!(first.value, 1, "B serves A's winner without ever exploring");
    assert_eq!(
        hb.stats_json()
            .expect("stats_json")
            .get("kernels")
            .and_then(|k| k.get("kern"))
            .and_then(|k| k.get("explored"))
            .and_then(jitune::util::json::Value::as_i64),
        Some(0)
    );
}

#[test]
fn prewarm_serves_the_first_call_from_the_cache() {
    let sock = temp_path("prewarm", "sock");
    let _broker = Broker::start(BrokerOptions::unix(&sock));
    {
        let mut c = HubClient::connect(HubOptions::at(&sock)).expect("connect");
        c.publish(&entry("kern", 1, 1)).expect("seed winner");
    }

    let coord = Coordinator::spawn_with_options(
        move || {
            let manifest = synthetic_manifest("kern", 2, &[8])?;
            Ok(Dispatcher::new(
                KernelRegistry::new(manifest),
                Box::new(MockEngine::new(base_spec())),
            ))
        },
        ServerOptions {
            hub: Some(HubOptions::at(&sock)),
            prewarm: true,
            ..ServerOptions::default()
        },
    )
    .expect("spawn coordinator");
    let h = coord.handle();

    // without prewarm the first warm-started call is CallRoute::Finalized
    // (it pays the winner's compile); with prewarm the compile happened
    // at spawn, so the very first call is already steady-state
    let first = h.call("kern", inputs()).expect("first call");
    assert_eq!(first.route, CallRoute::Tuned, "prewarm already paid the winner's compile");
    assert_eq!(first.value, 1);
    let json = h.stats_json().expect("stats_json");
    assert_eq!(
        json.get("kernels")
            .and_then(|k| k.get("kern"))
            .and_then(|k| k.get("explored"))
            .and_then(jitune::util::json::Value::as_i64),
        Some(0),
        "prewarmed process never explored"
    );
}

#[test]
fn exported_cache_artifact_ships_between_brokers_and_cold_boots() {
    let sock_a = temp_path("ship-a", "sock");
    let sock_b = temp_path("ship-b", "sock");
    let _a = Broker::start(BrokerOptions::unix(&sock_a));
    let _b = Broker::start(BrokerOptions::unix(&sock_b));
    {
        let mut c = HubClient::connect(HubOptions::at(&sock_a)).expect("connect");
        c.publish(&entry("kern", 1, 2)).expect("publish");
    }

    // export broker A's map as one deployable artifact
    let artifact = temp_path("ship", "json");
    let out = Command::new(env!("CARGO_BIN_EXE_jitune"))
        .args(["state", "export"])
        .arg(&artifact)
        .arg("--hub")
        .arg(format!("unix:{}", sock_a.display()))
        .output()
        .expect("run `jitune state export`");
    assert!(out.status.success(), "export failed: {}", String::from_utf8_lossy(&out.stderr));
    let text = std::fs::read_to_string(&artifact).expect("artifact written");
    assert!(text.contains("jitune-tuned-cache"), "artifact is typed: {text}");

    // import it into broker B (a different fleet)
    let out = Command::new(env!("CARGO_BIN_EXE_jitune"))
        .args(["state", "import"])
        .arg(&artifact)
        .arg("--hub")
        .arg(format!("unix:{}", sock_b.display()))
        .output()
        .expect("run `jitune state import`");
    assert!(out.status.success(), "import failed: {}", String::from_utf8_lossy(&out.stderr));
    let mut cb = HubClient::connect(HubOptions::at(&sock_b)).expect("connect B");
    assert_eq!(cb.pull_all().expect("pull"), vec![entry("kern", 1, 2)]);
    drop(cb);

    // and a hub-less process cold-boots straight off the artifact file
    let manifest = synthetic_manifest("kern", 2, &[8]).expect("manifest");
    let mut d =
        Dispatcher::new(KernelRegistry::new(manifest), Box::new(MockEngine::new(base_spec())));
    assert_eq!(d.load_state(&artifact).expect("load artifact"), (1, 0));
    let first = d.call("kern", &inputs()).expect("cold boot");
    assert_eq!(first.route, CallRoute::Finalized);
    assert_eq!(first.value, 1);
    assert_eq!(d.stats().kernel("kern").unwrap().explored, 0, "zero explores off the artifact");
    let _ = std::fs::remove_file(&artifact);
}
