//! Serving-path resilience, end to end: an erroring winner is
//! quarantined and demoted to the fallback with zero hung callers,
//! wedged calls return within deadline + slack, and an overload burst
//! sheds fast instead of queueing unboundedly.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use jitune::coordinator::{
    CallRoute, Coordinator, Dispatcher, KernelRegistry, QuarantinePolicy, ServerOptions, ShedPolicy,
};
use jitune::runtime::mock::{MockEngine, MockSpec};
use jitune::tensor::HostTensor;
use jitune::testutil::{spawn_pooled_mock, synthetic_manifest};
use jitune::Error;

/// v0 slowest, v1 the clear winner, v2 the next-best fallback — so a
/// quarantine demotion is observable from tuned values alone.
fn resilience_spec() -> MockSpec {
    MockSpec::default()
        .with_cost("kern.v0.n8", Duration::from_micros(1500))
        .with_cost("kern.v1.n8", Duration::from_micros(200))
        .with_cost("kern.v2.n8", Duration::from_micros(600))
        .with_sleep_exec()
}

/// A breaker that trips on one bad window: tests run in milliseconds,
/// not the production defaults.
fn fast_breaker() -> QuarantinePolicy {
    QuarantinePolicy {
        window: Duration::from_millis(30),
        min_samples: 4,
        error_threshold: 0.4,
        consecutive_windows: 1,
        cooldown: Duration::ZERO,
        quarantine_for: Duration::from_secs(60),
    }
}

/// Shared-fast-lane coordinator (no pool): tuned calls execute on the
/// caller thread, where the failure breaker records outcomes.
fn spawn_lane(spec: MockSpec, opts: ServerOptions) -> Coordinator {
    Coordinator::spawn_with_options(
        move || {
            let manifest = synthetic_manifest("kern", 3, &[8])?;
            Ok(Dispatcher::new(KernelRegistry::new(manifest), Box::new(MockEngine::new(spec))))
        },
        opts,
    )
    .expect("spawn coordinator")
}

fn inputs() -> Vec<HostTensor> {
    vec![HostTensor::zeros(&[8, 8])]
}

/// Drive calls until tuning finalizes on v1.
fn tune(coord: &Coordinator) {
    let h = coord.handle();
    loop {
        if h.call("kern", inputs()).unwrap().route == CallRoute::Finalized {
            break;
        }
    }
    assert_eq!(h.tuned_value("kern", 8).unwrap(), Some(1));
}

/// Erroring winner: once the published winner starts failing, the
/// breaker must demote it and serve the fallback — and every caller
/// thread that rode through the fault must return (no hangs).
#[test]
fn erroring_winner_demotes_to_fallback_without_hanging_callers() {
    let spec = resilience_spec();
    let fault = spec.latency_fault.clone();
    let coord = spawn_lane(spec, ServerOptions { quarantine: Some(fast_breaker()), ..Default::default() });
    tune(&coord);

    fault.fail_execute("kern.v1.n8");

    // four caller threads hammer through the fault window; each call
    // either succeeds (fallback) or errors (breaker still sampling) —
    // none may hang.
    let t0 = Instant::now();
    let errors = Arc::new(AtomicUsize::new(0));
    let fallbacks = Arc::new(AtomicUsize::new(0));
    let joins: Vec<_> = (0..4)
        .map(|_| {
            let h = coord.handle();
            let errors = Arc::clone(&errors);
            let fallbacks = Arc::clone(&fallbacks);
            std::thread::spawn(move || {
                for _ in 0..120 {
                    match h.call("kern", inputs()) {
                        Ok(out) => {
                            if out.value == 2 {
                                fallbacks.fetch_add(1, Ordering::Relaxed);
                            }
                        }
                        Err(_) => {
                            errors.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                    std::thread::sleep(Duration::from_millis(2));
                }
            })
        })
        .collect();
    for j in joins {
        j.join().expect("caller thread must return");
    }
    assert!(
        t0.elapsed() < Duration::from_secs(30),
        "callers took {:?} — something hung",
        t0.elapsed()
    );

    // the breaker window bounds the error burst: 4 threads for ~1s at
    // one bad window (~30ms) cannot approach the total call count
    let errs = errors.load(Ordering::Relaxed);
    assert!(errs < 240, "breaker must bound the burst, got {errs}/480 errors");
    assert!(
        fallbacks.load(Ordering::Relaxed) > 0,
        "fallback variant must have served during the fault"
    );

    // demotion settles on the next-best variant and is reported
    let h = coord.handle();
    let deadline = Instant::now() + Duration::from_secs(10);
    while h.tuned_value("kern", 8).unwrap() != Some(2) {
        assert!(Instant::now() < deadline, "winner never demoted to the fallback");
        std::thread::sleep(Duration::from_millis(10));
    }
    assert_eq!(h.call("kern", inputs()).unwrap().value, 2);
    let json = h.stats_json().unwrap();
    let events = json.get("quarantine_events").expect("quarantine_events exported");
    assert!(!events.as_arr().unwrap().is_empty());
}

/// Wedged winner: every call must come back within deadline + slack,
/// as `DeadlineExceeded` — the caller is released while the straggler
/// finishes (and is discarded) behind the scenes.
#[test]
fn wedged_winner_calls_return_within_deadline_plus_slack() {
    let spec = resilience_spec();
    let fault = spec.latency_fault.clone();
    let coord = spawn_pooled_mock(
        "kern",
        3,
        &[8],
        spec,
        1,
        ServerOptions { call_deadline: Some(Duration::from_millis(20)), ..Default::default() },
    )
    .expect("spawn coordinator");
    tune(&coord);

    // wedge the winner: 200us -> 40ms, well past the 20ms deadline
    fault.set_scale("kern.v1.n8", 200.0);

    let joins: Vec<_> = (0..4)
        .map(|_| {
            let h = coord.handle();
            std::thread::spawn(move || {
                for _ in 0..5 {
                    let t0 = Instant::now();
                    let err = h.call("kern", inputs()).unwrap_err();
                    let took = t0.elapsed();
                    assert!(
                        matches!(err, Error::DeadlineExceeded { .. }),
                        "wedged call must miss its deadline, got {err}"
                    );
                    // slack covers pool queueing behind earlier wedged
                    // jobs plus scheduler jitter
                    assert!(
                        took < Duration::from_millis(20) + Duration::from_millis(500),
                        "call took {took:?}, deadline is 20ms"
                    );
                }
            })
        })
        .collect();
    for j in joins {
        j.join().expect("caller thread must return");
    }

    // clearing the wedge restores tuned serving — retry while the
    // worker drains discarded stragglers left over from the wedge
    fault.clear();
    let h = coord.handle();
    let deadline = Instant::now() + Duration::from_secs(10);
    let out = loop {
        match h.call("kern", inputs()) {
            Ok(out) => break out,
            Err(_) => {
                assert!(Instant::now() < deadline, "serving never recovered after the wedge");
                std::thread::sleep(Duration::from_millis(20));
            }
        }
    };
    assert_eq!(out.value, 1);
    let json = h.stats_json().unwrap();
    let res = json.get("resilience").expect("resilience counters exported");
    assert!(res.get("deadline_exceeded").unwrap().as_i64().unwrap() >= 20);
}

/// Overload burst: with the admission gate at 2 in-flight calls, a
/// burst of 8 concurrent callers must shed the excess fast with
/// `Overloaded` — and the gate must reopen once the burst drains.
#[test]
fn overload_burst_sheds_instead_of_queueing_unboundedly() {
    let spec = MockSpec::default()
        .with_cost("kern.v0.n8", Duration::from_millis(25))
        .with_cost("kern.v1.n8", Duration::from_millis(20))
        .with_cost("kern.v2.n8", Duration::from_millis(22))
        .with_sleep_exec();
    let coord = spawn_pooled_mock(
        "kern",
        3,
        &[8],
        spec,
        1,
        ServerOptions {
            shed: Some(ShedPolicy { max_inflight: 2, max_queue_wait: Duration::from_secs(5) }),
            ..Default::default()
        },
    )
    .expect("spawn coordinator");
    tune(&coord);

    let shed = Arc::new(AtomicUsize::new(0));
    let served = Arc::new(AtomicUsize::new(0));
    let t0 = Instant::now();
    let joins: Vec<_> = (0..8)
        .map(|_| {
            let h = coord.handle();
            let shed = Arc::clone(&shed);
            let served = Arc::clone(&served);
            std::thread::spawn(move || match h.call("kern", inputs()) {
                Ok(_) => {
                    served.fetch_add(1, Ordering::Relaxed);
                }
                Err(Error::Overloaded(_)) => {
                    // shed calls fail fast, not after queueing
                    shed.fetch_add(1, Ordering::Relaxed);
                }
                Err(other) => panic!("unexpected error class under overload: {other}"),
            })
        })
        .collect();
    for j in joins {
        j.join().expect("caller thread must return");
    }
    // 8 calls at 20ms each through one worker would serialize to 160ms+
    // without the gate; shedding keeps the burst well under that
    assert!(t0.elapsed() < Duration::from_secs(5), "burst took {:?}", t0.elapsed());
    assert!(shed.load(Ordering::Relaxed) > 0, "the gate must shed part of the burst");
    assert!(served.load(Ordering::Relaxed) > 0, "admitted calls must still serve");

    // the gate reopens once in-flight calls drain
    let h = coord.handle();
    let out = h.call("kern", inputs()).expect("recovery call after the burst");
    assert_eq!(out.value, 1);
    let json = h.stats_json().unwrap();
    let res = json.get("resilience").expect("resilience counters exported");
    assert!(res.get("shed").unwrap().as_i64().unwrap() >= 1);
}
