//! Descriptive statistics for measurement samples (criterion is
//! unavailable offline; the bench harness builds on this module).

/// Online mean/variance accumulator (Welford's algorithm) — numerically
/// stable for long-running measurement streams.
#[derive(Debug, Clone, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Welford {
    /// Fresh accumulator.
    pub fn new() -> Self {
        Welford { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    /// Fold one sample in.
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    /// Number of samples.
    pub fn count(&self) -> u64 {
        self.n
    }

    /// Arithmetic mean (0 when empty).
    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Sample variance (n-1 denominator; 0 for fewer than 2 samples).
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    /// Sample standard deviation.
    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }

    /// Smallest sample seen.
    pub fn min(&self) -> f64 {
        self.min
    }

    /// Largest sample seen.
    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Summary of a sample set: mean/stddev/min/median/p95/max.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    /// Sample count.
    pub n: usize,
    /// Arithmetic mean.
    pub mean: f64,
    /// Sample standard deviation.
    pub stddev: f64,
    /// Minimum.
    pub min: f64,
    /// Median (p50).
    pub median: f64,
    /// 95th percentile.
    pub p95: f64,
    /// 99th percentile.
    pub p99: f64,
    /// Maximum.
    pub max: f64,
}

impl Summary {
    /// Compute a summary over the samples. Empty input yields all-zero.
    pub fn of(samples: &[f64]) -> Summary {
        if samples.is_empty() {
            return Summary {
                n: 0,
                mean: 0.0,
                stddev: 0.0,
                min: 0.0,
                median: 0.0,
                p95: 0.0,
                p99: 0.0,
                max: 0.0,
            };
        }
        let mut sorted = samples.to_vec();
        // total_cmp: NaN samples sort to the top instead of panicking the
        // stats path (they surface in max/p99 rather than killing a run).
        sorted.sort_by(|a, b| a.total_cmp(b));
        let mut w = Welford::new();
        for &x in samples {
            w.push(x);
        }
        Summary {
            n: samples.len(),
            mean: w.mean(),
            stddev: w.stddev(),
            min: sorted[0],
            median: percentile_sorted(&sorted, 50.0),
            p95: percentile_sorted(&sorted, 95.0),
            p99: percentile_sorted(&sorted, 99.0),
            max: *sorted.last().unwrap(),
        }
    }

    /// One-line human-readable rendering with a unit suffix.
    pub fn render(&self, unit: &str) -> String {
        format!(
            "n={} mean={:.3}{u} sd={:.3}{u} min={:.3}{u} p50={:.3}{u} p95={:.3}{u} \
             p99={:.3}{u} max={:.3}{u}",
            self.n, self.mean, self.stddev, self.min, self.median, self.p95, self.p99, self.max,
            u = unit
        )
    }
}

/// Linear-interpolation percentile over a pre-sorted slice, `p` in [0,100].
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = (p / 100.0) * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] + (sorted[hi] - sorted[lo]) * frac
}

/// Percentile over an unsorted slice. NaN-tolerant: NaN samples sort to
/// the top via `total_cmp` instead of panicking.
pub fn percentile(samples: &[f64], p: f64) -> f64 {
    let mut sorted = samples.to_vec();
    sorted.sort_by(|a, b| a.total_cmp(b));
    percentile_sorted(&sorted, p)
}

/// Median absolute deviation — robust spread estimate used by the bench
/// harness to flag noisy runs.
pub fn mad(samples: &[f64]) -> f64 {
    if samples.is_empty() {
        return 0.0;
    }
    let med = percentile(samples, 50.0);
    let devs: Vec<f64> = samples.iter().map(|x| (x - med).abs()).collect();
    percentile(&devs, 50.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_naive() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        assert!((w.mean() - 5.0).abs() < 1e-12);
        // naive sample variance
        let mean = 5.0;
        let var: f64 = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / (xs.len() - 1) as f64;
        assert!((w.variance() - var).abs() < 1e-12);
        assert_eq!(w.min(), 2.0);
        assert_eq!(w.max(), 9.0);
        assert_eq!(w.count(), 8);
    }

    #[test]
    fn percentiles_interpolate() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn summary_of_constant_series() {
        let s = Summary::of(&[5.0; 10]);
        assert_eq!(s.mean, 5.0);
        assert_eq!(s.stddev, 0.0);
        assert_eq!(s.median, 5.0);
        assert_eq!(s.p95, 5.0);
    }

    #[test]
    fn summary_empty_is_zero() {
        let s = Summary::of(&[]);
        assert_eq!(s.n, 0);
        assert_eq!(s.mean, 0.0);
    }

    #[test]
    fn mad_robust_to_outlier() {
        let xs = [1.0, 1.0, 1.0, 1.0, 100.0];
        assert_eq!(mad(&xs), 0.0); // median dev from median(1.0) is 0
        let ys = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert!((mad(&ys) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn render_contains_fields() {
        let s = Summary::of(&[1.0, 2.0, 3.0]);
        let r = s.render("ms");
        assert!(r.contains("n=3"));
        assert!(r.contains("mean=2.000ms"));
        assert!(r.contains("p99="), "p99 must be rendered: {r}");
    }

    #[test]
    fn nan_samples_do_not_panic() {
        let xs = [1.0, f64::NAN, 2.0, 3.0];
        let s = Summary::of(&xs);
        assert_eq!(s.n, 4);
        assert_eq!(s.min, 1.0, "NaN sorts to the top, not the bottom");
        assert!(s.max.is_nan(), "NaN surfaces in max instead of killing the run");
        assert!(percentile(&xs, 50.0).is_finite());
        let _ = mad(&xs); // must not panic either
    }
}
