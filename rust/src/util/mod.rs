//! Infrastructure substrates.
//!
//! The build container has no crates.io access beyond the `xla` dependency
//! tree, so the usual ecosystem crates (serde_json, rand, criterion's
//! statistics, env_logger) are re-implemented here as small, fully tested
//! modules.

pub mod chart;
pub mod hist;
pub mod json;
pub mod logging;
pub mod prng;
pub mod stats;

/// Crash-safe file write: the contents land in a per-write `.tmp`
/// sibling first (concurrent writers — across processes or within one
/// — cannot interleave into one scratch file), are `fsync`ed so
/// journaled filesystems cannot
/// surface an empty renamed file after power loss, and are then
/// `rename`d into place — readers (and `load_state`/the hub) can never
/// observe a torn file. The rename is atomic because the sibling lives
/// in the same directory.
pub fn atomic_write(path: &std::path::Path, contents: &str) -> crate::Result<()> {
    use std::io::Write as _;
    let file_name = path
        .file_name()
        .ok_or_else(|| {
            crate::Error::io(
                path.display().to_string(),
                std::io::Error::new(std::io::ErrorKind::InvalidInput, "path has no file name"),
            )
        })?
        .to_string_lossy();
    // pid + process-wide counter: concurrent writers in other processes
    // *and* in this one each get their own scratch file
    let seq = {
        use std::sync::atomic::{AtomicU64, Ordering};
        // relaxed-counter: unique-suffix sequence, never synchronizes
        static COUNTER: AtomicU64 = AtomicU64::new(0);
        COUNTER.fetch_add(1, Ordering::Relaxed)
    };
    let tmp = path.with_file_name(format!("{file_name}.{}.{seq}.tmp", std::process::id()));
    let write = |tmp: &std::path::Path| -> std::io::Result<()> {
        let mut f = std::fs::File::create(tmp)?;
        f.write_all(contents.as_bytes())?;
        f.sync_all()
    };
    write(&tmp).map_err(|e| {
        let _ = std::fs::remove_file(&tmp);
        crate::Error::io(tmp.display().to_string(), e)
    })?;
    std::fs::rename(&tmp, path).map_err(|e| {
        let _ = std::fs::remove_file(&tmp);
        crate::Error::io(path.display().to_string(), e)
    })?;
    // The rename only becomes crash-durable once the *directory* entry is
    // on disk: fsync the parent, or a power loss after this call returns
    // can still surface the old file (or none) on reboot.
    let parent = match path.parent() {
        Some(p) if !p.as_os_str().is_empty() => p.to_path_buf(),
        _ => std::path::PathBuf::from("."),
    };
    let dir = std::fs::File::open(&parent)
        .map_err(|e| crate::Error::io(parent.display().to_string(), e))?;
    dir.sync_all()
        .map_err(|e| crate::Error::io(parent.display().to_string(), e))
}

#[cfg(test)]
mod tests {
    #[test]
    fn atomic_write_replaces_and_leaves_no_tmp() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!("jitune-atomic-{}.json", std::process::id()));
        super::atomic_write(&path, "first").unwrap();
        assert_eq!(std::fs::read_to_string(&path).unwrap(), "first");
        super::atomic_write(&path, "second").unwrap();
        assert_eq!(std::fs::read_to_string(&path).unwrap(), "second");
        let prefix = format!("jitune-atomic-{}.json.", std::process::id());
        let leftovers: Vec<String> = std::fs::read_dir(&dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .map(|e| e.file_name().to_string_lossy().into_owned())
            .filter(|n| n.starts_with(&prefix) && n.ends_with(".tmp"))
            .collect();
        assert!(leftovers.is_empty(), "tmp siblings must not survive: {leftovers:?}");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn atomic_write_rejects_pathless_target() {
        assert!(super::atomic_write(std::path::Path::new("/"), "x").is_err());
    }

    #[test]
    fn atomic_write_fsyncs_parent_directory() {
        // The durability half (dir entry on disk before return) needs a
        // crash to observe directly; what a unit test *can* pin down is
        // that the parent-fsync path executes and succeeds for both
        // nested and bare relative paths.
        let dir = std::env::temp_dir().join(format!("jitune-dirsync-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("entry.json");
        super::atomic_write(&path, "payload").unwrap();
        assert_eq!(std::fs::read_to_string(&path).unwrap(), "payload");
        // Overwrite takes the same rename+dir-fsync path.
        super::atomic_write(&path, "payload2").unwrap();
        assert_eq!(std::fs::read_to_string(&path).unwrap(), "payload2");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
