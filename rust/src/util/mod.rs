//! Infrastructure substrates.
//!
//! The build container has no crates.io access beyond the `xla` dependency
//! tree, so the usual ecosystem crates (serde_json, rand, criterion's
//! statistics, env_logger) are re-implemented here as small, fully tested
//! modules.

pub mod chart;
pub mod hist;
pub mod json;
pub mod logging;
pub mod prng;
pub mod stats;
