//! Minimal JSON parser and writer (serde_json is unavailable offline).
//!
//! Supports the complete JSON grammar (RFC 8259): objects, arrays, strings
//! with escapes (including `\uXXXX` and surrogate pairs), numbers, booleans
//! and null. Object key order is preserved (entries are a `Vec`), which
//! keeps manifest round-trips and golden tests deterministic.

use std::collections::BTreeMap;
use std::fmt;

use crate::error::{Error, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    /// `null`
    Null,
    /// `true` / `false`
    Bool(bool),
    /// Any JSON number (stored as f64; integers up to 2^53 round-trip).
    Num(f64),
    /// String (unescaped).
    Str(String),
    /// Array.
    Arr(Vec<Value>),
    /// Object with insertion-ordered keys.
    Obj(Vec<(String, Value)>),
}

impl Value {
    /// Borrow as `&str` if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Borrow as f64 if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Borrow as i64 if this is a number representing an integer exactly.
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Num(n) if n.fract() == 0.0 && n.abs() <= 9007199254740992.0 => Some(*n as i64),
            _ => None,
        }
    }

    /// Borrow as bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Borrow as array slice.
    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Borrow the object entries.
    pub fn as_obj(&self) -> Option<&[(String, Value)]> {
        match self {
            Value::Obj(v) => Some(v),
            _ => None,
        }
    }

    /// Object field lookup (first match).
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(entries) => entries.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Typed field accessors with contextual errors — used by the manifest
    /// loader so a broken manifest produces an actionable message.
    pub fn req_str(&self, key: &str) -> Result<&str> {
        self.get(key)
            .and_then(Value::as_str)
            .ok_or_else(|| Error::Manifest(format!("missing/invalid string field `{key}`")))
    }

    /// Required integer field.
    pub fn req_i64(&self, key: &str) -> Result<i64> {
        self.get(key)
            .and_then(Value::as_i64)
            .ok_or_else(|| Error::Manifest(format!("missing/invalid integer field `{key}`")))
    }

    /// Required float field.
    pub fn req_f64(&self, key: &str) -> Result<f64> {
        self.get(key)
            .and_then(Value::as_f64)
            .ok_or_else(|| Error::Manifest(format!("missing/invalid number field `{key}`")))
    }

    /// Required array field.
    pub fn req_arr(&self, key: &str) -> Result<&[Value]> {
        self.get(key)
            .and_then(Value::as_arr)
            .ok_or_else(|| Error::Manifest(format!("missing/invalid array field `{key}`")))
    }

    /// Serialize to a compact JSON string.
    pub fn to_json(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Serialize with 2-space indentation.
    pub fn to_json_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out.push('\n');
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Value::Num(n) => out.push_str(&fmt_num(*n)),
            Value::Str(s) => write_escaped(out, s),
            Value::Arr(items) => {
                if items.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push(']');
            }
            Value::Obj(entries) => {
                if entries.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in entries.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

/// Format a number the way JSON expects: integers without a trailing `.0`.
fn fmt_num(n: f64) -> String {
    if n.fract() == 0.0 && n.abs() < 9.0e15 {
        format!("{}", n as i64)
    } else {
        // `{}` on f64 produces the shortest representation that round-trips.
        format!("{n}")
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            '\u{08}' => out.push_str("\\b"),
            '\u{0C}' => out.push_str("\\f"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a JSON document. The whole input must be consumed (trailing
/// whitespace allowed).
pub fn parse(input: &str) -> Result<Value> {
    let mut p = Parser { bytes: input.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing characters after top-level value"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: impl Into<String>) -> Error {
        Error::Json { offset: self.pos, msg: msg.into() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(format!("expected `{}`", b as char)))
        }
    }

    fn value(&mut self) -> Result<Value> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.lit("true", Value::Bool(true)),
            Some(b'f') => self.lit("false", Value::Bool(false)),
            Some(b'n') => self.lit("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => Err(self.err(format!("unexpected character `{}`", c as char))),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn lit(&mut self, word: &str, v: Value) -> Result<Value> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(format!("invalid literal, expected `{word}`")))
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.expect(b'{')?;
        let mut entries = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(entries));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            entries.push((key, val));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Value::Obj(entries));
                }
                _ => return Err(self.err("expected `,` or `}` in object")),
            }
        }
    }

    fn array(&mut self) -> Result<Value> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Value::Arr(items));
                }
                _ => return Err(self.err("expected `,` or `]` in array")),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{08}'),
                        Some(b'f') => s.push('\u{0C}'),
                        Some(b'u') => {
                            self.pos += 1;
                            let hi = self.hex4()?;
                            let c = if (0xD800..0xDC00).contains(&hi) {
                                // surrogate pair
                                if self.bytes[self.pos..].starts_with(b"\\u") {
                                    self.pos += 2;
                                    let lo = self.hex4()?;
                                    if !(0xDC00..0xE000).contains(&lo) {
                                        return Err(self.err("invalid low surrogate"));
                                    }
                                    let cp =
                                        0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00);
                                    char::from_u32(cp)
                                        .ok_or_else(|| self.err("invalid surrogate pair"))?
                                } else {
                                    return Err(self.err("unpaired high surrogate"));
                                }
                            } else if (0xDC00..0xE000).contains(&hi) {
                                return Err(self.err("unpaired low surrogate"));
                            } else {
                                char::from_u32(hi).ok_or_else(|| self.err("invalid \\u escape"))?
                            };
                            s.push(c);
                            continue; // hex4 already advanced pos
                        }
                        _ => return Err(self.err("invalid escape sequence")),
                    }
                    self.pos += 1;
                }
                Some(c) if c < 0x20 => return Err(self.err("control character in string")),
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = &self.bytes[self.pos..];
                    let len = utf8_len(rest[0]);
                    let chunk = rest
                        .get(..len)
                        .and_then(|c| std::str::from_utf8(c).ok())
                        .ok_or_else(|| self.err("invalid utf-8 in string"))?;
                    s.push_str(chunk);
                    self.pos += len;
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32> {
        let chunk = self
            .bytes
            .get(self.pos..self.pos + 4)
            .ok_or_else(|| self.err("truncated \\u escape"))?;
        let s = std::str::from_utf8(chunk).map_err(|_| self.err("invalid \\u escape"))?;
        let v = u32::from_str_radix(s, 16).map_err(|_| self.err("invalid \\u escape"))?;
        self.pos += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Value::Num)
            .map_err(|_| self.err(format!("invalid number `{text}`")))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

/// Convenience: build a `Value::Obj` from pairs.
pub fn obj(entries: Vec<(&str, Value)>) -> Value {
    Value::Obj(entries.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

/// Convenience: string value.
pub fn s(v: impl Into<String>) -> Value {
    Value::Str(v.into())
}

/// Convenience: number value.
pub fn n(v: impl Into<f64>) -> Value {
    Value::Num(v.into())
}

/// Convenience: sorted map → object (deterministic output for reports).
pub fn from_map(map: &BTreeMap<String, f64>) -> Value {
    Value::Obj(map.iter().map(|(k, v)| (k.clone(), Value::Num(*v))).collect())
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_json())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse(" false ").unwrap(), Value::Bool(false));
        assert_eq!(parse("42").unwrap(), Value::Num(42.0));
        assert_eq!(parse("-3.5e2").unwrap(), Value::Num(-350.0));
        assert_eq!(parse("\"hi\"").unwrap(), Value::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[2].get("b"), Some(&Value::Null));
    }

    #[test]
    fn escapes_roundtrip() {
        let original = Value::Str("line\n\t\"q\" \\ \u{1F600} é".into());
        let text = original.to_json();
        assert_eq!(parse(&text).unwrap(), original);
    }

    #[test]
    fn unicode_escape_parsing() {
        assert_eq!(parse(r#""é""#).unwrap(), Value::Str("é".into()));
        // surrogate pair for 😀
        assert_eq!(parse(r#""😀""#).unwrap(), Value::Str("\u{1F600}".into()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("tru").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse(r#""\ud83d""#).is_err()); // unpaired surrogate
        assert!(parse("\"\u{01}\"").is_err()); // raw control char
    }

    #[test]
    fn object_order_preserved() {
        let v = parse(r#"{"z":1,"a":2,"m":3}"#).unwrap();
        let keys: Vec<_> = v.as_obj().unwrap().iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(keys, vec!["z", "a", "m"]);
        assert_eq!(v.to_json(), r#"{"z":1,"a":2,"m":3}"#);
    }

    #[test]
    fn integers_have_no_decimal_point() {
        assert_eq!(Value::Num(128.0).to_json(), "128");
        assert_eq!(Value::Num(0.5).to_json(), "0.5");
    }

    #[test]
    fn pretty_print_parses_back() {
        let v = parse(r#"{"a":[1,2],"b":{"c":true}}"#).unwrap();
        assert_eq!(parse(&v.to_json_pretty()).unwrap(), v);
    }

    #[test]
    fn req_accessors_error_messages() {
        let v = parse(r#"{"a": 1}"#).unwrap();
        assert_eq!(v.req_i64("a").unwrap(), 1);
        let err = v.req_str("missing").unwrap_err();
        assert!(err.to_string().contains("missing"));
    }

    #[test]
    fn large_integer_roundtrip() {
        let v = parse("9007199254740991").unwrap(); // 2^53 - 1
        assert_eq!(v.as_i64(), Some(9007199254740991));
    }
}
