//! Deterministic pseudo-random numbers (the `rand` crate is unavailable
//! offline). SplitMix64 for seeding, xoshiro256++ for the main stream —
//! the same construction rand's SmallRng family uses.
//!
//! Every stochastic component in the repo (workload generation, random /
//! annealing search, property tests) takes an explicit seed so all
//! experiments are reproducible.

/// SplitMix64 step — used to expand a single u64 seed into a full state.
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256++ generator.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a seed. Equal seeds yield equal streams.
    pub fn seed(seed: u64) -> Self {
        let mut sm = seed;
        let s = [splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm)];
        Rng { s }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0].wrapping_add(s[3]).rotate_left(23).wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        // 53 top bits → [0,1)
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [lo, hi).
    pub fn f32_range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (self.f64() as f32) * (hi - lo)
    }

    /// Uniform integer in [0, n). Panics if n == 0.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "below(0)");
        // Lemire-style rejection-free enough for our n ≪ 2^64 use.
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        lo + (self.next_u64() % ((hi - lo) as u64 + 1)) as i64
    }

    /// Bernoulli trial with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Pick a uniformly random element.
    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.below(items.len())]
    }

    /// Fisher–Yates shuffle in place.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.below(i + 1);
            items.swap(i, j);
        }
    }

    /// Standard normal via Box–Muller (used by the jittered mock engine).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Derive an independent child generator (stable split).
    pub fn split(&mut self) -> Rng {
        Rng::seed(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::seed(42);
        let mut b = Rng::seed(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::seed(1);
        let mut b = Rng::seed(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::seed(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::seed(3);
        let mut seen = [false; 8];
        for _ in 0..1_000 {
            seen[r.below(8)] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::seed(9);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>()); // astronomically unlikely to be identity
    }

    #[test]
    fn normal_roughly_standard() {
        let mut r = Rng::seed(11);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean={mean}");
        assert!((var - 1.0).abs() < 0.1, "var={var}");
    }

    #[test]
    fn chance_extremes() {
        let mut r = Rng::seed(5);
        assert!(!(0..100).any(|_| r.chance(0.0)));
        assert!((0..100).all(|_| r.chance(1.0)));
    }
}
