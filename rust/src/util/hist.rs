//! Fixed-boundary latency histogram (HdrHistogram-lite) used by the
//! coordinator's stats and by the serving example's latency report.

/// Histogram with exponentially spaced bucket boundaries, tracking counts
/// plus exact min/max/sum so means stay exact even though percentiles are
/// bucket-resolution.
#[derive(Debug, Clone)]
pub struct Histogram {
    bounds: Vec<f64>,
    counts: Vec<u64>,
    total: u64,
    sum: f64,
    min: f64,
    max: f64,
}

impl Histogram {
    /// Exponential buckets from `lo` to `hi` (both > 0), `per_decade`
    /// buckets per factor of 10.
    pub fn exponential(lo: f64, hi: f64, per_decade: usize) -> Self {
        assert!(lo > 0.0 && hi > lo && per_decade > 0);
        let mut bounds = Vec::new();
        let ratio = 10f64.powf(1.0 / per_decade as f64);
        let mut b = lo;
        while b < hi {
            bounds.push(b);
            b *= ratio;
        }
        bounds.push(hi);
        let n = bounds.len() + 1;
        Histogram {
            bounds,
            counts: vec![0; n],
            total: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    /// Default latency histogram: 1µs .. 100s in seconds.
    pub fn latency() -> Self {
        Histogram::exponential(1e-6, 100.0, 10)
    }

    /// Record one observation.
    pub fn record(&mut self, x: f64) {
        let idx = self.bounds.partition_point(|&b| b <= x);
        self.counts[idx] += 1;
        self.total += 1;
        self.sum += x;
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    /// Number of observations.
    pub fn count(&self) -> u64 {
        self.total
    }

    /// Exact mean of all observations.
    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum / self.total as f64
        }
    }

    /// Exact minimum.
    pub fn min(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.min
        }
    }

    /// Exact maximum.
    pub fn max(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.max
        }
    }

    /// Approximate percentile (upper bound of the bucket containing the
    /// p-th observation), `p` in [0,100].
    pub fn percentile(&self, p: f64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let target = ((p / 100.0) * self.total as f64).ceil().max(1.0) as u64;
        let mut seen = 0;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= target {
                return if i == 0 {
                    self.min
                } else if i == self.counts.len() - 1 {
                    // overflow bucket: everything here is above the top bound
                    self.max
                } else {
                    self.bounds[i - 1].min(self.max).max(self.min)
                };
            }
        }
        self.max
    }

    /// Merge another histogram with identical bounds.
    pub fn merge(&mut self, other: &Histogram) {
        assert_eq!(self.bounds.len(), other.bounds.len(), "histogram bounds mismatch");
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.total += other.total;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    /// Compact one-line report (seconds → ms for readability).
    pub fn render_ms(&self) -> String {
        format!(
            "n={} mean={:.3}ms p50={:.3}ms p95={:.3}ms p99={:.3}ms max={:.3}ms",
            self.total,
            self.mean() * 1e3,
            self.percentile(50.0) * 1e3,
            self.percentile(95.0) * 1e3,
            self.percentile(99.0) * 1e3,
            self.max() * 1e3,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_counts() {
        let mut h = Histogram::latency();
        for i in 1..=100 {
            h.record(i as f64 * 1e-3); // 1..100 ms
        }
        assert_eq!(h.count(), 100);
        assert!((h.mean() - 0.0505).abs() < 1e-9);
        assert_eq!(h.min(), 1e-3);
        assert_eq!(h.max(), 0.1);
    }

    #[test]
    fn percentile_monotone_and_bounded() {
        let mut h = Histogram::latency();
        for i in 1..=1000 {
            h.record(i as f64 * 1e-4);
        }
        let p50 = h.percentile(50.0);
        let p95 = h.percentile(95.0);
        let p99 = h.percentile(99.0);
        assert!(p50 <= p95 && p95 <= p99);
        assert!(p50 >= h.min() && p99 <= h.max());
        // bucket resolution: p50 should be within ~30% of true median 0.05
        assert!((p50 - 0.05).abs() / 0.05 < 0.3, "p50={p50}");
    }

    #[test]
    fn merge_adds_up() {
        let mut a = Histogram::latency();
        let mut b = Histogram::latency();
        a.record(0.001);
        b.record(0.010);
        b.record(0.100);
        a.merge(&b);
        assert_eq!(a.count(), 3);
        assert_eq!(a.max(), 0.1);
        assert_eq!(a.min(), 0.001);
    }

    #[test]
    fn out_of_range_clamped_to_edge_buckets() {
        let mut h = Histogram::exponential(1e-3, 1.0, 5);
        h.record(1e-9);
        h.record(50.0);
        assert_eq!(h.count(), 2);
        assert_eq!(h.percentile(0.0), 1e-9);
        assert_eq!(h.percentile(100.0), 50.0);
    }

    #[test]
    fn empty_histogram_is_zeroes() {
        let h = Histogram::latency();
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.percentile(99.0), 0.0);
    }
}
