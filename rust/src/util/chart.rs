//! ASCII chart rendering for the figure benches — every paper figure is
//! regenerated both as a CSV (machine-readable) and an ASCII chart
//! (eyeball-checkable in the bench output).

/// A named series of (x, y) points.
#[derive(Debug, Clone)]
pub struct Series {
    /// Legend label.
    pub name: String,
    /// Data points.
    pub points: Vec<(f64, f64)>,
}

impl Series {
    /// Construct from a label and points.
    pub fn new(name: impl Into<String>, points: Vec<(f64, f64)>) -> Self {
        Series { name: name.into(), points }
    }
}

const MARKS: &[char] = &['*', '+', 'o', 'x', '#', '@', '%', '&'];

/// Render a multi-series scatter/line chart into a text block.
///
/// `log_y` applies a log10 transform to the y axis (Fig 2 in the paper is
/// log-scale). Width/height are the plot area in characters.
pub fn render(title: &str, series: &[Series], width: usize, height: usize, log_y: bool) -> String {
    let mut out = String::new();
    out.push_str(&format!("## {title}\n"));
    let all: Vec<(f64, f64)> = series.iter().flat_map(|s| s.points.iter().copied()).collect();
    if all.is_empty() {
        out.push_str("(no data)\n");
        return out;
    }
    let ty = |y: f64| if log_y { y.max(1e-300).log10() } else { y };
    let (mut xmin, mut xmax) = (f64::INFINITY, f64::NEG_INFINITY);
    let (mut ymin, mut ymax) = (f64::INFINITY, f64::NEG_INFINITY);
    for &(x, y) in &all {
        xmin = xmin.min(x);
        xmax = xmax.max(x);
        ymin = ymin.min(ty(y));
        ymax = ymax.max(ty(y));
    }
    if (xmax - xmin).abs() < 1e-300 {
        xmax = xmin + 1.0;
    }
    if (ymax - ymin).abs() < 1e-300 {
        ymax = ymin + 1.0;
    }
    let mut grid = vec![vec![' '; width]; height];
    for (si, s) in series.iter().enumerate() {
        let mark = MARKS[si % MARKS.len()];
        for &(x, y) in &s.points {
            let cx = ((x - xmin) / (xmax - xmin) * (width - 1) as f64).round() as usize;
            let cy = ((ty(y) - ymin) / (ymax - ymin) * (height - 1) as f64).round() as usize;
            let row = height - 1 - cy.min(height - 1);
            let col = cx.min(width - 1);
            // Later series overwrite; collisions get '?'.
            grid[row][col] = if grid[row][col] == ' ' || grid[row][col] == mark { mark } else { '?' };
        }
    }
    let ylab = |v: f64| if log_y { format!("{:.3e}", 10f64.powf(v)) } else { format!("{v:.4}") };
    for (i, row) in grid.iter().enumerate() {
        let yv = ymax - (ymax - ymin) * i as f64 / (height - 1) as f64;
        let label = if i == 0 || i == height - 1 || i == height / 2 {
            format!("{:>11} |", ylab(yv))
        } else {
            format!("{:>11} |", "")
        };
        out.push_str(&label);
        out.push_str(&row.iter().collect::<String>());
        out.push('\n');
    }
    out.push_str(&format!("{:>12}+{}\n", "", "-".repeat(width)));
    out.push_str(&format!("{:>13}{:<w$.4}{:>8.4}\n", "", xmin, xmax, w = width - 7));
    for (si, s) in series.iter().enumerate() {
        out.push_str(&format!("  {} {}\n", MARKS[si % MARKS.len()], s.name));
    }
    out
}

/// Render a labelled horizontal bar chart (used for the Fig 1 histogram).
pub fn bars(title: &str, rows: &[(String, f64)], width: usize) -> String {
    let mut out = format!("## {title}\n");
    let max = rows.iter().map(|(_, v)| *v).fold(0.0, f64::max).max(1e-300);
    let label_w = rows.iter().map(|(l, _)| l.len()).max().unwrap_or(0);
    for (label, v) in rows {
        let n = ((v / max) * width as f64).round() as usize;
        out.push_str(&format!("{label:>label_w$} | {}{} {v:.2}\n", "█".repeat(n), " ".repeat(width - n)));
    }
    out
}

/// Write rows as CSV. First row is the header.
pub fn csv(header: &[&str], rows: &[Vec<String>]) -> String {
    let mut out = header.join(",");
    out.push('\n');
    for row in rows {
        out.push_str(&row.join(","));
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_contains_legend_and_axes() {
        let s = vec![
            Series::new("fast", vec![(0.0, 1.0), (1.0, 2.0), (2.0, 3.0)]),
            Series::new("slow", vec![(0.0, 3.0), (1.0, 6.0), (2.0, 9.0)]),
        ];
        let text = render("test chart", &s, 40, 10, false);
        assert!(text.contains("## test chart"));
        assert!(text.contains("* fast"));
        assert!(text.contains("+ slow"));
        assert!(text.lines().count() > 10);
    }

    #[test]
    fn log_scale_handles_wide_range() {
        let s = vec![Series::new("x", vec![(0.0, 1e-6), (1.0, 1e2)])];
        let text = render("log", &s, 20, 5, true);
        assert!(text.contains("## log"));
    }

    #[test]
    fn empty_series_no_panic() {
        let text = render("empty", &[], 10, 5, false);
        assert!(text.contains("(no data)"));
    }

    #[test]
    fn single_point_no_panic() {
        let s = vec![Series::new("p", vec![(1.0, 1.0)])];
        let _ = render("single", &s, 10, 5, false);
        let _ = render("single-log", &s, 10, 5, true);
    }

    #[test]
    fn bars_scale_to_max() {
        let rows = vec![("a".to_string(), 10.0), ("bb".to_string(), 5.0)];
        let text = bars("hist", &rows, 20);
        let a_blocks = text.lines().nth(1).unwrap().matches('█').count();
        let b_blocks = text.lines().nth(2).unwrap().matches('█').count();
        assert_eq!(a_blocks, 20);
        assert_eq!(b_blocks, 10);
    }

    #[test]
    fn csv_shape() {
        let text = csv(&["a", "b"], &[vec!["1".into(), "2".into()]]);
        assert_eq!(text, "a,b\n1,2\n");
    }
}
