//! Tiny `log` facade backend (env_logger is unavailable offline).
//!
//! Level comes from `JITUNE_LOG` (error|warn|info|debug|trace), default
//! `info`. Output goes to stderr with elapsed-time stamps so tuning-phase
//! transitions are easy to correlate with bench output.

use std::sync::OnceLock;
use std::time::Instant;

use log::{Level, LevelFilter, Log, Metadata, Record};

struct StderrLogger {
    start: Instant,
}

impl Log for StderrLogger {
    fn enabled(&self, metadata: &Metadata<'_>) -> bool {
        metadata.level() <= log::max_level()
    }

    fn log(&self, record: &Record<'_>) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let t = self.start.elapsed().as_secs_f64();
        let lvl = match record.level() {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        eprintln!("[{t:10.4}s {lvl} {}] {}", record.target(), record.args());
    }

    fn flush(&self) {}
}

static LOGGER: OnceLock<StderrLogger> = OnceLock::new();

/// Install the logger (idempotent). Reads `JITUNE_LOG` for the level.
pub fn init() {
    let logger = LOGGER.get_or_init(|| StderrLogger { start: Instant::now() });
    let level = match std::env::var("JITUNE_LOG").as_deref() {
        Ok("error") => LevelFilter::Error,
        Ok("warn") => LevelFilter::Warn,
        Ok("debug") => LevelFilter::Debug,
        Ok("trace") => LevelFilter::Trace,
        Ok("off") => LevelFilter::Off,
        _ => LevelFilter::Info,
    };
    // set_logger fails if already set (tests call init repeatedly) — fine.
    let _ = log::set_logger(logger);
    log::set_max_level(level);
}

#[cfg(test)]
mod tests {
    #[test]
    fn init_is_idempotent() {
        super::init();
        super::init();
        log::info!("logging smoke test");
    }
}
