//! Run configuration: TOML-lite file + environment overrides.
//!
//! The launcher reads an optional config file (a flat TOML subset:
//! `key = value` lines, `#` comments, optional `[section]` headers that
//! prefix keys as `section.key`), then applies `JITUNE_*` environment
//! overrides, then CLI flags (highest precedence, applied by the caller).

use std::collections::BTreeMap;
use std::path::Path;

use crate::error::{Error, Result};

/// A parsed configuration: flat map of dotted keys to raw string values,
/// with typed accessors.
#[derive(Debug, Clone, Default)]
pub struct Config {
    values: BTreeMap<String, String>,
}

impl Config {
    /// Empty config.
    pub fn new() -> Config {
        Config::default()
    }

    /// Parse the TOML-lite text.
    pub fn parse(text: &str) -> Result<Config> {
        let mut values = BTreeMap::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
                section = name.trim().to_string();
                continue;
            }
            let (key, value) = line.split_once('=').ok_or_else(|| {
                Error::Config(format!("line {}: expected `key = value`", lineno + 1))
            })?;
            let key = key.trim();
            if key.is_empty() {
                return Err(Error::Config(format!("line {}: empty key", lineno + 1)));
            }
            let full_key =
                if section.is_empty() { key.to_string() } else { format!("{section}.{key}") };
            let value = value.trim().trim_matches('"').to_string();
            values.insert(full_key, value);
        }
        Ok(Config { values })
    }

    /// Load from a file.
    pub fn load(path: impl AsRef<Path>) -> Result<Config> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::io(path.display().to_string(), e))?;
        Config::parse(&text)
    }

    /// Apply `JITUNE_<KEY>` environment overrides (dots become
    /// underscores, case-insensitive): `JITUNE_TUNE_STRATEGY=random:8`
    /// overrides `tune.strategy`.
    pub fn apply_env(&mut self) {
        for (k, v) in std::env::vars() {
            if let Some(rest) = k.strip_prefix("JITUNE_") {
                if rest == "LOG" {
                    continue; // belongs to the logger
                }
                let key = rest.to_lowercase().replace("__", ".").replace('_', ".");
                self.values.insert(key, v);
            }
        }
    }

    /// Set a value programmatically (CLI flags).
    pub fn set(&mut self, key: &str, value: impl Into<String>) {
        self.values.insert(key.to_string(), value.into());
    }

    /// Raw string lookup.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(String::as_str)
    }

    /// String with default.
    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    /// Integer with default.
    pub fn i64_or(&self, key: &str, default: i64) -> Result<i64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse::<i64>()
                .map_err(|_| Error::Config(format!("`{key}` = `{v}` is not an integer"))),
        }
    }

    /// Float with default.
    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse::<f64>()
                .map_err(|_| Error::Config(format!("`{key}` = `{v}` is not a number"))),
        }
    }

    /// Boolean with default (`true/false/1/0/yes/no`).
    pub fn bool_or(&self, key: &str, default: bool) -> Result<bool> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => match v.to_lowercase().as_str() {
                "true" | "1" | "yes" => Ok(true),
                "false" | "0" | "no" => Ok(false),
                other => Err(Error::Config(format!("`{key}` = `{other}` is not a boolean"))),
            },
        }
    }

    /// All keys (for `--help` / debugging).
    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.values.keys().map(String::as_str)
    }
}

/// The resolved runtime settings used by the launcher and examples.
#[derive(Debug, Clone)]
pub struct RunSettings {
    /// Artifacts directory.
    pub artifacts: String,
    /// Search strategy spec (`sweep`, `random:K`, `hillclimb`, `anneal:K`).
    pub strategy: String,
    /// Metric name (`wall_clock`, `rdtsc`, `energy`).
    pub metric: String,
    /// Global workload seed.
    pub seed: u64,
}

impl RunSettings {
    /// Resolve from a config.
    pub fn from_config(cfg: &Config) -> Result<RunSettings> {
        Ok(RunSettings {
            artifacts: cfg.str_or("artifacts", "artifacts"),
            strategy: cfg.str_or("tune.strategy", "sweep"),
            metric: cfg.str_or("tune.metric", "wall_clock"),
            seed: cfg.i64_or("seed", 42)? as u64,
        })
    }

    /// Build the metric object named by `metric`.
    pub fn build_metric(&self) -> Result<Box<dyn crate::autotuner::Metric>> {
        match self.metric.as_str() {
            "wall_clock" => Ok(Box::new(crate::autotuner::WallClock::new())),
            "rdtsc" => Ok(Box::new(crate::autotuner::Rdtsc)),
            "energy" => Ok(Box::new(crate::autotuner::EnergyModel::new(65.0))),
            other => Err(Error::Config(format!("unknown metric `{other}`"))),
        }
    }

    /// Build the strategy factory named by `strategy`.
    pub fn build_strategy_factory(&self) -> Result<crate::autotuner::StrategyFactory> {
        // validate the spec eagerly against a dummy candidate count
        crate::autotuner::search::from_spec(&self.strategy, 4, self.seed)?;
        let spec = self.strategy.clone();
        let seed = self.seed;
        Ok(Box::new(move |values| {
            crate::autotuner::search::from_spec(&spec, values.len(), seed)
                .expect("spec validated at startup")
        }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_flat_and_sections() {
        let cfg = Config::parse(
            "artifacts = \"artifacts\"\nseed = 7\n# comment\n[tune]\nstrategy = random:8\nmetric = rdtsc\n",
        )
        .unwrap();
        assert_eq!(cfg.get("artifacts"), Some("artifacts"));
        assert_eq!(cfg.i64_or("seed", 0).unwrap(), 7);
        assert_eq!(cfg.get("tune.strategy"), Some("random:8"));
    }

    #[test]
    fn rejects_malformed_lines() {
        assert!(Config::parse("just a line").is_err());
        assert!(Config::parse("= value").is_err());
    }

    #[test]
    fn typed_accessors_and_defaults() {
        let cfg = Config::parse("a = 3\nb = 2.5\nc = yes\nd = nope\n").unwrap();
        assert_eq!(cfg.i64_or("a", 0).unwrap(), 3);
        assert_eq!(cfg.f64_or("b", 0.0).unwrap(), 2.5);
        assert!(cfg.bool_or("c", false).unwrap());
        assert!(cfg.bool_or("d", false).is_err());
        assert_eq!(cfg.i64_or("missing", 9).unwrap(), 9);
        assert!(cfg.i64_or("b", 0).is_err());
    }

    #[test]
    fn run_settings_resolve_and_build() {
        let mut cfg = Config::new();
        cfg.set("tune.strategy", "hillclimb");
        cfg.set("tune.metric", "energy");
        let rs = RunSettings::from_config(&cfg).unwrap();
        assert_eq!(rs.strategy, "hillclimb");
        assert!(rs.build_metric().is_ok());
        let factory = rs.build_strategy_factory().unwrap();
        assert_eq!(factory(&[1, 2, 3]).name(), "hillclimb");
    }

    #[test]
    fn bad_strategy_and_metric_rejected() {
        let mut cfg = Config::new();
        cfg.set("tune.metric", "nope");
        let rs = RunSettings::from_config(&cfg).unwrap();
        assert!(rs.build_metric().is_err());
        let mut cfg2 = Config::new();
        cfg2.set("tune.strategy", "nope");
        let rs2 = RunSettings::from_config(&cfg2).unwrap();
        assert!(rs2.build_strategy_factory().is_err());
    }
}
