//! `jitune` launcher: inspect artifacts, tune kernels, replay traces,
//! run the serving demo — all through the public library API.

use std::sync::Arc;

use jitune::autotuner::Autotuner;
use jitune::cli::{self, FlagSpec};
use jitune::config::{Config, RunSettings};
use jitune::coordinator::{
    BatchOptions, CallRoute, Coordinator, Dispatcher, ExploreOptions, KernelRegistry, PoolOptions,
    ServerOptions,
};
use jitune::hub::{
    artifact_json, merge_entry, state_entry_values, BrokerOptions, HubAddr, HubClient, HubEntry,
    HubOptions, HubServer, Merge, PersistOptions,
};
use jitune::manifest::Manifest;
use jitune::runtime::native::default_native_manifest;
use jitune::runtime::{
    Engine, EngineFactory, NativeEngine, NativeEngineFactory, PjrtEngine, PjrtEngineFactory,
};
use jitune::traffic::{ReplayOptions, TrafficHarness, TrafficSpec};
use jitune::util::json::Value;
use jitune::workload::{inputs_for, CallTrace};
use jitune::{Error, Result};

const COMMANDS: &[(&str, &str)] = &[
    ("inspect", "list kernels, problems and variants in the manifest"),
    ("tune", "tune one kernel at one size and print the tuning report"),
    ("run", "replay a call trace (--trace kernel:size:iters[,...]) or a generated production-shaped trace (--traffic k=v,...) through the dispatcher"),
    ("stats", "tune then print coordinator + cache statistics"),
    ("hub", "tuned-state hub broker: `hub serve --socket <p> [--listen host:port] [--persist <dir>]` | `hub dump --hub <addr>`"),
    ("state", "tuning-state files: `state show <file>` | `state merge <out> <in>...` | `state export <out> --hub <addr>` | `state import <file> --hub <addr>`"),
    ("help", "show this message"),
];

fn flag_specs() -> Vec<FlagSpec> {
    vec![
        FlagSpec { name: "config", takes_value: true, help: "config file (TOML-lite)" },
        FlagSpec { name: "artifacts", takes_value: true, help: "artifacts directory" },
        FlagSpec { name: "kernel", takes_value: true, help: "kernel family (default matmul_tiled)" },
        FlagSpec { name: "size", takes_value: true, help: "problem size (default 128)" },
        FlagSpec { name: "iters", takes_value: true, help: "call count (default 20)" },
        FlagSpec { name: "trace", takes_value: true, help: "trace spec kernel:size:iters[,...]" },
        FlagSpec { name: "strategy", takes_value: true, help: "sweep|random:K|hillclimb|anneal:K" },
        FlagSpec { name: "metric", takes_value: true, help: "wall_clock|rdtsc|energy" },
        FlagSpec { name: "seed", takes_value: true, help: "workload seed (default 42)" },
        FlagSpec { name: "json", takes_value: false, help: "emit JSON reports" },
        FlagSpec {
            name: "state-file",
            takes_value: true,
            help: "persisted tuning state: warm-start from it, save back after",
        },
        FlagSpec {
            name: "socket",
            takes_value: true,
            help: "hub broker Unix socket path (hub serve / hub dump)",
        },
        FlagSpec {
            name: "listen",
            takes_value: true,
            help: "hub serve: also listen on TCP host:port (cross-host fleets; \
                   port 0 picks a free port)",
        },
        FlagSpec {
            name: "persist",
            takes_value: true,
            help: "hub serve: durable broker state directory (append-only entry \
                   log + snapshot, replayed on restart)",
        },
        FlagSpec {
            name: "compact-every",
            takes_value: true,
            help: "hub serve: snapshot-compact the log every N appended records \
                   (default 256; 0 never compacts)",
        },
        FlagSpec {
            name: "hub",
            takes_value: true,
            help: "hub broker address `unix:<path>` | `tcp:host:port` | bare \
                   socket path (hub dump, state export/import, run warm-start)",
        },
        FlagSpec {
            name: "prewarm",
            takes_value: false,
            help: "run: compile warm-started winners (hub- or state-file-adopted) \
                   at spawn, so the very first call of each problem is served \
                   from the cache",
        },
        FlagSpec {
            name: "pool",
            takes_value: true,
            help: "run: serve the trace through a worker pool of N PJRT engines \
                   (thread-pinned fast lane)",
        },
        FlagSpec {
            name: "max-batch",
            takes_value: true,
            help: "run: serve the trace through a coordinator whose leader drains \
                   up to N requests per scheduling round (co-scheduled same-problem \
                   calls fuse into one exploration round)",
        },
        FlagSpec {
            name: "engine",
            takes_value: true,
            help: "execution backend: `pjrt` (default; needs artifacts) or `native` \
                   (built-in CPU kernels with a generated manifest — no artifacts)",
        },
        FlagSpec {
            name: "traffic",
            takes_value: true,
            help: "run: replay a seeded production-shaped trace (Zipf popularity, \
                   shape churn, bursts) instead of --trace; comma-separated k=v over \
                   calls/rps/zipf/initial/churn/burst/burstlen/drift/seed/clients, \
                   empty string for defaults",
        },
        FlagSpec {
            name: "deadline",
            takes_value: true,
            help: "run: per-call deadline in milliseconds — calls that exceed it \
                   (queue wait included) return `deadline exceeded` instead of \
                   hanging; stragglers are discarded on arrival, not killed",
        },
        FlagSpec {
            name: "explore-budget",
            takes_value: true,
            help: "run: background shadow exploration — callers always execute the \
                   current-best (or default) variant while candidates compile+measure \
                   in the background, capped at this % of explore-worker time \
                   (0 = serve the default variant only, never tune)",
        },
    ]
}

fn main() {
    jitune::util::logging::init();
    let args: Vec<String> = std::env::args().skip(1).collect();
    match run(&args) {
        Ok(()) => {}
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(1);
        }
    }
}

fn run(args: &[String]) -> Result<()> {
    let specs = flag_specs();
    let parsed = cli::parse(args, &specs)?;

    let mut cfg = match parsed.get("config") {
        Some(path) => Config::load(path)?,
        None => Config::new(),
    };
    cfg.apply_env();
    for key in ["artifacts", "seed"] {
        if let Some(v) = parsed.get(key) {
            cfg.set(key, v);
        }
    }
    if let Some(v) = parsed.get("strategy") {
        cfg.set("tune.strategy", v);
    }
    if let Some(v) = parsed.get("metric") {
        cfg.set("tune.metric", v);
    }
    let settings = RunSettings::from_config(&cfg)?;

    match parsed.command.as_str() {
        "inspect" => inspect(&settings, engine_kind(&parsed)?, parsed.has("json")),
        "tune" => tune_with_state(
            &settings,
            engine_kind(&parsed)?,
            &parsed.str_or("kernel", "matmul_tiled"),
            parsed.i64_or("size", 128)?,
            parsed.i64_or("iters", 20)? as usize,
            parsed.has("json"),
            parsed.get("state-file"),
        ),
        "run" => {
            let kind = engine_kind(&parsed)?;
            let max_batch = match parsed.i64_or("max-batch", 0)? {
                0 => None,
                n if n > 0 => Some(n as usize),
                bad => return Err(Error::Config(format!("--max-batch `{bad}` must be positive"))),
            };
            let explore_budget = match parsed.get("explore-budget") {
                None => None,
                Some(raw) => {
                    let pct: f64 = raw.parse().map_err(|_| {
                        Error::Config(format!("--explore-budget `{raw}` must be a number"))
                    })?;
                    if !(0.0..=100.0).contains(&pct) {
                        return Err(Error::Config(format!(
                            "--explore-budget `{raw}` must be between 0 and 100"
                        )));
                    }
                    Some(pct)
                }
            };
            let pool = match parsed.i64_or("pool", 0)? {
                n if n >= 0 => n as usize,
                bad => return Err(Error::Config(format!("--pool `{bad}` must be positive"))),
            };
            let deadline = match parsed.i64_or("deadline", 0)? {
                0 => None,
                ms if ms > 0 => Some(std::time::Duration::from_millis(ms as u64)),
                bad => {
                    return Err(Error::Config(format!("--deadline `{bad}` must be positive")))
                }
            };
            // --hub attaches the fleet's tuned-state broker: warm-start
            // at spawn, publish every finalization, and subscribe the
            // push channel so retunes elsewhere propagate immediately.
            let hub = match parsed.get("hub") {
                None => None,
                Some(spec) => {
                    let mut opts = HubOptions::for_addr(HubAddr::parse(spec)?);
                    opts.subscribe = true;
                    Some(opts)
                }
            };
            let prewarm = parsed.has("prewarm");
            if let Some(traffic) = parsed.get("traffic") {
                return run_traffic(
                    &settings,
                    kind,
                    traffic,
                    pool,
                    max_batch,
                    explore_budget,
                    deadline,
                    hub,
                    prewarm,
                    parsed.has("json"),
                );
            }
            let spec = parsed
                .get("trace")
                .ok_or_else(|| Error::Config("run requires --trace or --traffic".into()))?
                .to_string();
            match pool {
                // no pool, no batching, no budget, no hub: plain
                // single-lane replay without a coordinator
                0 if max_batch.is_none()
                    && explore_budget.is_none()
                    && deadline.is_none()
                    && hub.is_none()
                    && !prewarm =>
                {
                    run_trace(&settings, kind, &spec, parsed.get("state-file"))
                }
                workers => run_trace_served(
                    &settings,
                    kind,
                    &spec,
                    workers,
                    max_batch,
                    explore_budget,
                    deadline,
                    hub,
                    prewarm,
                    parsed.get("state-file"),
                ),
            }
        }
        "stats" => tune_with_stats(
            &settings,
            engine_kind(&parsed)?,
            &parsed.str_or("kernel", "matmul_tiled"),
            parsed.i64_or("size", 128)?,
            parsed.i64_or("iters", 20)? as usize,
        ),
        "hub" => hub_cmd(&parsed),
        "state" => state_cmd(&parsed),
        "help" | "" => {
            println!("{}", cli::usage("jitune", COMMANDS, &specs));
            Ok(())
        }
        other => Err(Error::Config(format!("unknown command `{other}` (try `help`)"))),
    }
}

/// Which execution backend `--engine` selected.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum EngineKind {
    /// PJRT over compiled HLO artifacts (the default).
    Pjrt,
    /// Built-in CPU kernels with a generated manifest ([`jitune::runtime::native`]).
    Native,
}

fn engine_kind(parsed: &cli::Parsed) -> Result<EngineKind> {
    match parsed.str_or("engine", "pjrt").as_str() {
        "pjrt" => Ok(EngineKind::Pjrt),
        "native" => Ok(EngineKind::Native),
        other => Err(Error::Config(format!("--engine `{other}` must be `pjrt` or `native`"))),
    }
}

/// The manifest for a backend: PJRT reads the artifacts directory,
/// native generates its own (stub HLO, real kernel configs).
fn load_manifest(kind: EngineKind, settings: &RunSettings) -> Result<Manifest> {
    match kind {
        EngineKind::Pjrt => Manifest::load(&settings.artifacts),
        EngineKind::Native => default_native_manifest(),
    }
}

/// Per-worker engine factory for pools and shadow exploration. Native is
/// pinned for parity with PJRT: tuned traffic exercises the same
/// replicate-onto-workers path.
fn engine_factory(kind: EngineKind) -> Arc<dyn EngineFactory> {
    match kind {
        EngineKind::Pjrt => Arc::new(PjrtEngineFactory),
        EngineKind::Native => Arc::new(NativeEngineFactory::pinned()),
    }
}

fn build_dispatcher(settings: &RunSettings, kind: EngineKind) -> Result<Dispatcher> {
    let manifest = load_manifest(kind, settings)?;
    let registry = KernelRegistry::new(manifest);
    let engine: Box<dyn Engine> = match kind {
        EngineKind::Pjrt => Box::new(PjrtEngine::cpu()?),
        EngineKind::Native => Box::new(NativeEngine::new()),
    };
    let tuner = Autotuner::with_factory(settings.build_strategy_factory()?);
    let metric = settings.build_metric()?;
    Ok(Dispatcher::with(registry, engine, tuner, metric))
}

fn inspect(settings: &RunSettings, kind: EngineKind, json: bool) -> Result<()> {
    let manifest = load_manifest(kind, settings)?;
    if json {
        println!(
            "{}",
            jitune::util::json::Value::Obj(vec![
                ("jax_version".into(), jitune::util::json::s(manifest.jax_version.clone())),
                (
                    "kernels".into(),
                    jitune::util::json::Value::Arr(
                        manifest.kernels().into_iter().map(jitune::util::json::s).collect()
                    )
                ),
                ("variants".into(), jitune::util::json::n(manifest.variants.len() as f64)),
                ("problems".into(), jitune::util::json::n(manifest.problems.len() as f64)),
            ])
            .to_json_pretty()
        );
        return Ok(());
    }
    println!("manifest: {} (jax {})", settings.artifacts, manifest.jax_version);
    println!("{} variants across {} problems\n", manifest.variants.len(), manifest.problems.len());
    for p in &manifest.problems {
        let labels: Vec<&str> = p.variants.iter().map(|v| v.label.as_str()).collect();
        println!("{:<44} param={:<6} candidates: {}", p.key(), p.param, labels.join(" "));
    }
    Ok(())
}

/// Warm-start from `--state-file` if present; returns the path for the
/// save-back after the run.
fn load_state_flag(
    dispatcher: &mut Dispatcher,
    state_file: Option<&str>,
) -> Result<Option<std::path::PathBuf>> {
    let Some(path) = state_file else { return Ok(None) };
    let path = std::path::PathBuf::from(path);
    if path.exists() {
        let (imported, skipped) = dispatcher.load_state(&path)?;
        println!("state: warm-started {imported} problem(s), skipped {skipped} stale");
    }
    Ok(Some(path))
}

fn save_state_flag(dispatcher: &Dispatcher, path: &Option<std::path::PathBuf>) -> Result<()> {
    if let Some(path) = path {
        let n = dispatcher.save_state(path)?;
        println!("state: saved {n} tuned problem(s) to {}", path.display());
    }
    Ok(())
}

fn tune_with_state(
    settings: &RunSettings,
    kind: EngineKind,
    kernel: &str,
    size: i64,
    iters: usize,
    json: bool,
    state_file: Option<&str>,
) -> Result<()> {
    let mut dispatcher = build_dispatcher(settings, kind)?;
    let state_path = load_state_flag(&mut dispatcher, state_file)?;
    let problem = dispatcher.registry().problem(kernel, size)?.clone();
    let inputs = inputs_for(&problem, settings.seed);
    println!(
        "tuning {kernel} at n={size} over {} candidates ({} calls)...",
        problem.variants.len(),
        iters
    );
    for i in 0..iters {
        let out = dispatcher.call(kernel, &inputs)?;
        let route = match out.route {
            CallRoute::Explored => "explore",
            CallRoute::Finalized => "finalize",
            CallRoute::Tuned => "tuned",
            CallRoute::Default => "default",
        };
        println!(
            "call {i:3}: {route:<8} variant={:<28} value={:<6} compile={} total={:.3}ms",
            out.variant_id,
            out.value,
            out.compiled,
            out.total.as_secs_f64() * 1e3
        );
    }
    if json {
        println!("{}", dispatcher.tuning_report().to_json_pretty());
    } else if let Some(v) = dispatcher.tuned_value(kernel, size) {
        println!("\ntuned value for {kernel}/n{size}: {v}");
    } else {
        println!("\ntuning not finished after {iters} calls");
    }
    save_state_flag(&dispatcher, &state_path)?;
    Ok(())
}

/// Parse a `kernel:size:iters[,...]` trace spec.
fn parse_trace(spec: &str) -> Result<CallTrace> {
    let mut trace = CallTrace::default();
    for part in spec.split(',') {
        let fields: Vec<&str> = part.split(':').collect();
        if fields.len() != 3 {
            return Err(Error::Config(format!(
                "bad trace part `{part}` (want kernel:size:iters)"
            )));
        }
        let size: i64 =
            fields[1].parse().map_err(|_| Error::Config(format!("bad size in `{part}`")))?;
        let iters: usize =
            fields[2].parse().map_err(|_| Error::Config(format!("bad iters in `{part}`")))?;
        trace.calls.extend(CallTrace::uniform(fields[0], size, iters).calls);
    }
    Ok(trace)
}

fn run_trace(
    settings: &RunSettings,
    kind: EngineKind,
    spec: &str,
    state_file: Option<&str>,
) -> Result<()> {
    let mut dispatcher = build_dispatcher(settings, kind)?;
    let state_path = load_state_flag(&mut dispatcher, state_file)?;
    let trace = parse_trace(spec)?;
    println!("replaying {} calls...", trace.len());
    let t0 = std::time::Instant::now();
    for call in &trace.calls {
        let problem = dispatcher.registry().problem(&call.kernel, call.size)?.clone();
        let inputs = inputs_for(&problem, settings.seed);
        dispatcher.call(&call.kernel, &inputs)?;
    }
    let dt = t0.elapsed();
    println!(
        "done in {:.3}s ({:.1} calls/s)\n",
        dt.as_secs_f64(),
        trace.len() as f64 / dt.as_secs_f64()
    );
    print!("{}", dispatcher.stats().render());
    println!("cache: {:?}", dispatcher.cache_stats());
    save_state_flag(&dispatcher, &state_path)?;
    Ok(())
}

/// Spawn the serving coordinator all served `run` paths share: optional
/// worker pool and background-explore budget over the `--engine`
/// backend's factory, optional warm start from `--state-file`.
#[allow(clippy::too_many_arguments)]
fn spawn_coordinator(
    settings: &RunSettings,
    kind: EngineKind,
    workers: usize,
    max_batch: Option<usize>,
    explore_budget: Option<f64>,
    deadline: Option<std::time::Duration>,
    hub: Option<HubOptions>,
    prewarm: bool,
    warm_start: Option<std::path::PathBuf>,
) -> Result<Coordinator> {
    let leader_settings = settings.clone();
    let mut opts = ServerOptions {
        pool: (workers > 0).then(|| PoolOptions::new(engine_factory(kind)).with_workers(workers)),
        hub,
        prewarm,
        call_deadline: deadline,
        ..ServerOptions::default()
    };
    if let Some(max_batch) = max_batch {
        opts.batch = BatchOptions { max_batch };
    }
    if let Some(pct) = explore_budget {
        let mut eo = ExploreOptions::percent(pct);
        if workers == 0 {
            // no serving pool: background jobs get their own engine
            eo = eo.with_shadow_factory(engine_factory(kind));
        }
        opts.explore_budget = Some(eo);
    }
    Coordinator::spawn_with_options(
        move || {
            let mut dispatcher = build_dispatcher(&leader_settings, kind)?;
            if let Some(path) = warm_start.filter(|p| p.exists()) {
                let (imported, skipped) = dispatcher.load_state(&path)?;
                println!("state: warm-started {imported} problem(s), skipped {skipped} stale");
            }
            Ok(dispatcher)
        },
        opts,
    )
}

/// `jitune run --traffic <spec> [--engine native] [--pool N]
/// [--explore-budget P]`: generate the seeded production-shaped trace
/// (Zipf popularity over the manifest's problems, shape churn, open-loop
/// bursts) and replay it open-loop against a live coordinator from the
/// spec's client threads. Prints the traffic report — p50/p99 serve
/// latency (overall/cold/steady), per-problem time-to-good, explore duty
/// cycle, tuned-state size — or its JSON with `--json`. Runs with a
/// 2-worker pool unless `--pool` says otherwise, so the full serving
/// stack is exercised by default.
#[allow(clippy::too_many_arguments)]
fn run_traffic(
    settings: &RunSettings,
    kind: EngineKind,
    traffic: &str,
    pool: usize,
    max_batch: Option<usize>,
    explore_budget: Option<f64>,
    deadline: Option<std::time::Duration>,
    hub: Option<HubOptions>,
    prewarm: bool,
    json: bool,
) -> Result<()> {
    let spec = TrafficSpec::parse(traffic)?;
    let manifest = load_manifest(kind, settings)?;
    let workers = if pool == 0 { 2 } else { pool };
    let coordinator = spawn_coordinator(
        settings,
        kind,
        workers,
        max_batch,
        explore_budget,
        deadline,
        hub,
        prewarm,
        None,
    )?;
    let harness = TrafficHarness::new(&manifest, spec.clone(), settings.seed)?;
    println!(
        "replaying {} generated arrivals ({} problems, {} clients, {} worker(s))...",
        harness.trace().len(),
        harness.trace().problems().len(),
        spec.clients,
        workers
    );
    let report = harness.run(&coordinator, &ReplayOptions::default())?;
    if json {
        println!("{}", report.to_json().to_json_pretty());
    } else {
        print!("{}", report.render());
    }
    Ok(())
}

/// `jitune run --trace .. [--pool N] [--max-batch B] [--explore-budget P]`:
/// replay the trace through a live coordinator. `--pool N` serves
/// steady-state calls on a worker pool of N PJRT engines (finalized
/// winners replicated onto every worker — thread-pinned executables
/// scale off-leader); `--max-batch B` sizes the leader's scheduling
/// rounds, so co-scheduled same-problem calls fuse into one exploration
/// round; `--explore-budget P` moves exploration off the serving path
/// entirely — callers execute the current-best (or default) variant
/// while candidates compile+measure in the background, capped at P% of
/// explore-worker time (`0` serves the default forever and never
/// tunes). Without a pool the budget runs on a dedicated shadow engine.
/// The printed stats include the per-worker pool, fused-round and
/// background counters.
#[allow(clippy::too_many_arguments)]
fn run_trace_served(
    settings: &RunSettings,
    kind: EngineKind,
    spec: &str,
    workers: usize,
    max_batch: Option<usize>,
    explore_budget: Option<f64>,
    deadline: Option<std::time::Duration>,
    hub: Option<HubOptions>,
    prewarm: bool,
    state_file: Option<&str>,
) -> Result<()> {
    let trace = parse_trace(spec)?;
    let state_path = state_file.map(std::path::PathBuf::from);
    let coordinator = spawn_coordinator(
        settings,
        kind,
        workers,
        max_batch,
        explore_budget,
        deadline,
        hub,
        prewarm,
        state_path.clone(),
    )?;
    let h = coordinator.handle();
    let manifest = load_manifest(kind, settings)?;
    println!(
        "replaying {} calls through the coordinator ({} pool worker(s), max_batch {})...",
        trace.len(),
        workers,
        max_batch.unwrap_or_else(|| BatchOptions::default().max_batch)
    );
    let t0 = std::time::Instant::now();
    for call in &trace.calls {
        // inputs resolved per problem, exactly like the single-lane path
        let problem = manifest.problem(&call.kernel, call.size)?;
        let inputs = inputs_for(problem, settings.seed);
        h.call(&call.kernel, inputs)?;
    }
    let dt = t0.elapsed();
    println!(
        "done in {:.3}s ({:.1} calls/s)\n",
        dt.as_secs_f64(),
        trace.len() as f64 / dt.as_secs_f64()
    );
    let (rendered, _) = h.stats()?;
    print!("{rendered}");
    if let Some(path) = state_path {
        let saved = h.save_state(&path)?;
        println!("state: saved {saved} tuned problem(s) to {}", path.display());
    }
    Ok(())
}

/// Broker address for client-side subcommands: `--hub <addr>`
/// (`unix:<path>` | `tcp:host:port` | bare path) or the original
/// `--socket <path>`.
fn hub_flag_addr(parsed: &cli::Parsed) -> Result<HubAddr> {
    if let Some(spec) = parsed.get("hub") {
        return HubAddr::parse(spec);
    }
    match parsed.get("socket") {
        Some(path) => Ok(HubAddr::Unix(std::path::PathBuf::from(path))),
        None => Err(Error::Config("need --hub <addr> (or --socket <path>)".into())),
    }
}

/// `jitune hub serve --socket <p> [--listen host:port] [--persist <d>]`
/// / `jitune hub dump --hub <addr>`: run the fleet's tuned-state broker
/// (durable when `--persist` names a directory), or print its map.
fn hub_cmd(parsed: &cli::Parsed) -> Result<()> {
    match parsed.positionals.first().map(String::as_str) {
        Some("serve") => {
            let persist = match parsed.get("persist") {
                None => None,
                Some(dir) => {
                    let mut p = PersistOptions::at(dir);
                    match parsed.i64_or("compact-every", p.compact_every as i64)? {
                        n if n >= 0 => p.compact_every = n as u64,
                        bad => {
                            return Err(Error::Config(format!(
                                "--compact-every `{bad}` must be >= 0"
                            )))
                        }
                    }
                    Some(p)
                }
            };
            let opts = BrokerOptions {
                socket: parsed.get("socket").map(std::path::PathBuf::from),
                tcp: parsed.get("listen").map(str::to_string),
                persist,
            };
            if opts.socket.is_none() && opts.tcp.is_none() {
                return Err(Error::Config(
                    "hub serve requires --socket <path> and/or --listen <host:port>".into(),
                ));
            }
            let server = HubServer::bind_with(opts)?;
            if let Some(path) = server.socket_path() {
                println!("hub: listening on unix:{}", path.display());
            }
            if let Some(addr) = server.tcp_addr() {
                println!("hub: listening on tcp:{addr}");
            }
            let replay = server.replay_report();
            if replay.snapshot_entries + replay.log_records > 0 || replay.truncated_bytes > 0 {
                println!(
                    "hub: restored {} snapshot entr(ies) + {} log record(s) \
                     ({} torn byte(s) discarded)",
                    replay.snapshot_entries, replay.log_records, replay.truncated_bytes
                );
            }
            server.serve_forever()
        }
        Some("dump") => {
            let addr = hub_flag_addr(parsed)?;
            let mut client = HubClient::connect(HubOptions::for_addr(addr))?;
            let entries = client.pull_all()?;
            let arr = Value::Arr(entries.iter().map(HubEntry::to_json).collect());
            println!("{}", arr.to_json_pretty());
            Ok(())
        }
        other => Err(Error::Config(format!(
            "hub requires a subcommand `serve` or `dump`, got `{}`",
            other.unwrap_or("")
        ))),
    }
}

/// `jitune state show <file>` / `jitune state merge <out> <in>...` /
/// `jitune state export <out> --hub <addr>` / `jitune state import
/// <file> --hub <addr>`: operator tooling for tuning-state files and
/// shipping the tuned cache between brokers.
fn state_cmd(parsed: &cli::Parsed) -> Result<()> {
    match parsed.positionals.split_first() {
        Some((sub, rest)) if sub == "show" => match rest {
            [file] => state_show(std::path::Path::new(file)),
            _ => Err(Error::Config("state show requires exactly one <file>".into())),
        },
        Some((sub, rest)) if sub == "merge" => match rest.split_first() {
            Some((out, inputs)) if !inputs.is_empty() => {
                state_merge(std::path::Path::new(out), inputs)
            }
            _ => Err(Error::Config("state merge requires <out> and at least one <in>".into())),
        },
        Some((sub, rest)) if sub == "export" => match rest {
            [out] => state_export(std::path::Path::new(out), parsed),
            _ => Err(Error::Config(
                "state export requires exactly one <out> (plus --hub <addr>)".into(),
            )),
        },
        Some((sub, rest)) if sub == "import" => match rest {
            [file] => state_import(std::path::Path::new(file), parsed),
            _ => Err(Error::Config(
                "state import requires exactly one <file> (plus --hub <addr>)".into(),
            )),
        },
        _ => Err(Error::Config(
            "state requires a subcommand: `show <file>`, `merge <out> <in>...`, \
             `export <out> --hub <addr>` or `import <file> --hub <addr>`"
                .into(),
        )),
    }
}

/// Parse a tuning-state document: a bare array of tuned entries
/// (`save_state` output; `version` optional) or a `state export` cache
/// artifact.
fn load_state_entries(path: &std::path::Path) -> Result<Vec<HubEntry>> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| Error::io(path.display().to_string(), e))?;
    let parsed = jitune::util::json::parse(&text)?;
    let arr = state_entry_values(&parsed)
        .map_err(|e| Error::Autotune(format!("{}: {e}", path.display())))?;
    arr.iter().map(HubEntry::from_json).collect()
}

/// `jitune state export <out> --hub <addr>`: capture the broker's full
/// tuned map as one deployable cache artifact.
fn state_export(out: &std::path::Path, parsed: &cli::Parsed) -> Result<()> {
    let addr = hub_flag_addr(parsed)?;
    let mut client = HubClient::connect(HubOptions::for_addr(addr.clone()))?;
    let entries = client.pull_all()?;
    jitune::util::atomic_write(out, &artifact_json(&entries).to_json_pretty())?;
    println!(
        "state: exported {} tuned problem(s) from {addr} -> {}",
        entries.len(),
        out.display()
    );
    Ok(())
}

/// `jitune state import <file> --hub <addr>`: publish a cache artifact
/// (or plain state file) into a broker — every entry LWW-merges, so the
/// import is safe against a broker that already holds newer winners.
fn state_import(file: &std::path::Path, parsed: &cli::Parsed) -> Result<()> {
    let addr = hub_flag_addr(parsed)?;
    let entries = load_state_entries(file)?;
    let mut client = HubClient::connect(HubOptions::for_addr(addr.clone()))?;
    let (mut merged, mut conflicts) = (0usize, 0usize);
    for entry in &entries {
        if client.publish(entry)?.conflict {
            conflicts += 1;
        } else {
            merged += 1;
        }
    }
    println!(
        "state: imported {} entr(ies) from {} into {addr} \
         ({merged} merged, {conflicts} version conflict(s) broker-resolved)",
        entries.len(),
        file.display()
    );
    Ok(())
}

fn state_show(path: &std::path::Path) -> Result<()> {
    let entries = load_state_entries(path)?;
    println!("{}: {} tuned problem(s)", path.display(), entries.len());
    for e in &entries {
        let candidates: Vec<String> = e.values.iter().map(i64::to_string).collect();
        // pad the key as a string: width flags don't reach a custom Display
        let key = e.problem_key().to_string();
        println!(
            "  {key:<48} winner={:<8} v{:<4} candidates=[{}]",
            e.winner_value,
            e.version,
            candidates.join(" ")
        );
    }
    Ok(())
}

fn state_merge(out: &std::path::Path, inputs: &[String]) -> Result<()> {
    let mut map = std::collections::BTreeMap::new();
    let (mut total, mut conflicts, mut outdated) = (0usize, 0usize, 0usize);
    for input in inputs {
        let entries = load_state_entries(std::path::Path::new(input))?;
        total += entries.len();
        for entry in entries {
            match merge_entry(&mut map, entry) {
                // same version, different winner: the later file wins
                Merge::Conflict { .. } => conflicts += 1,
                // strictly older version, different winner: dropped —
                // the already-merged newer entry stands
                Merge::Outdated => outdated += 1,
                Merge::Inserted | Merge::Replaced | Merge::Stale => {}
            }
        }
    }
    let merged = Value::Arr(map.values().map(HubEntry::to_json).collect());
    jitune::util::atomic_write(out, &merged.to_json_pretty())?;
    println!(
        "state: merged {total} entr(ies) from {} file(s) into {} problem(s) \
         ({conflicts} same-version conflict(s) resolved later-file-wins, \
         {outdated} older-version entr(ies) dropped) -> {}",
        inputs.len(),
        map.len(),
        out.display()
    );
    Ok(())
}

fn tune_with_stats(
    settings: &RunSettings,
    kind: EngineKind,
    kernel: &str,
    size: i64,
    iters: usize,
) -> Result<()> {
    let mut dispatcher = build_dispatcher(settings, kind)?;
    let problem = dispatcher.registry().problem(kernel, size)?.clone();
    let inputs = inputs_for(&problem, settings.seed);
    for _ in 0..iters {
        dispatcher.call(kernel, &inputs)?;
    }
    print!("{}", dispatcher.stats().render());
    println!("cache: {:?}", dispatcher.cache_stats());
    println!("{}", dispatcher.tuning_report().to_json_pretty());
    Ok(())
}
