//! Baseline execution policies the paper compares against (Fig 3–5).
//!
//! * [`FixedVariant`] — "the programmer would have picked an
//!   implementation p": one variant, compiled once ahead of the timed
//!   region (AOT-style), every call runs it.
//! * [`Oracle`] — the best variant with perfect knowledge and no tuning
//!   cost on the timed path (lower bound; the paper's "very skilled
//!   programmer").
//! * [`AotAll`] — the alternative the paper's introduction discusses and
//!   rejects: generate/compile *all* variants ahead of time, select the
//!   best at run time by measuring each once without JIT compilation on
//!   the request path. Start-up pays k compilations; `ablation_aot.rs`
//!   quantifies the trade.

use std::time::{Duration, Instant};

use crate::error::{Error, Result};
use crate::manifest::{Manifest, Problem};
use crate::runtime::CompileCache;
use crate::tensor::HostTensor;

/// Per-call wall times of a baseline run, plus its setup cost.
#[derive(Debug, Clone)]
pub struct BaselineRun {
    /// Policy label, e.g. `fixed:ijk`.
    pub label: String,
    /// One-off setup cost (compilations outside the call loop).
    pub setup: Duration,
    /// Wall time of each timed call.
    pub per_call: Vec<Duration>,
}

impl BaselineRun {
    /// Cumulative times (the paper's Fig 3–5 y-axis), **excluding** setup
    /// — the paper's fixed baselines are AOT-compiled, their compile cost
    /// is not on the execution path.
    pub fn cumulative(&self) -> Vec<f64> {
        let mut acc = 0.0;
        self.per_call
            .iter()
            .map(|d| {
                acc += d.as_secs_f64();
                acc
            })
            .collect()
    }

    /// Total time of the call loop.
    pub fn total(&self) -> f64 {
        self.per_call.iter().map(Duration::as_secs_f64).sum()
    }
}

/// Run `iters` calls of one fixed variant (compiled outside the timed
/// loop).
pub struct FixedVariant;

impl FixedVariant {
    /// Execute the baseline.
    pub fn run(
        manifest: &Manifest,
        cache: &mut CompileCache,
        problem: &Problem,
        variant_idx: usize,
        inputs: &[HostTensor],
        iters: usize,
    ) -> Result<BaselineRun> {
        let variant = &problem.variants[variant_idx];
        let t0 = Instant::now();
        cache.get_or_compile(manifest, variant)?;
        let setup = t0.elapsed();
        let mut per_call = Vec::with_capacity(iters);
        for _ in 0..iters {
            let (exe, compiled) = cache.get_or_compile(manifest, variant)?;
            debug_assert!(!compiled);
            let t = Instant::now();
            exe.execute(inputs)?;
            per_call.push(t.elapsed());
        }
        Ok(BaselineRun { label: format!("fixed:{}", variant.label), setup, per_call })
    }
}

/// Oracle: measure every variant once (setup), then run the best.
pub struct Oracle;

impl Oracle {
    /// Execute the baseline. Setup includes the measurement pass.
    pub fn run(
        manifest: &Manifest,
        cache: &mut CompileCache,
        problem: &Problem,
        inputs: &[HostTensor],
        iters: usize,
    ) -> Result<BaselineRun> {
        let t0 = Instant::now();
        let mut best: Option<(usize, Duration)> = None;
        for (i, v) in problem.variants.iter().enumerate() {
            let (exe, _) = cache.get_or_compile(manifest, v)?;
            let t = Instant::now();
            exe.execute(inputs)?;
            let dt = t.elapsed();
            if best.map(|(_, b)| dt < b).unwrap_or(true) {
                best = Some((i, dt));
            }
        }
        let (best_idx, _) =
            best.ok_or_else(|| Error::Autotune("oracle: no variants".into()))?;
        let setup = t0.elapsed();
        let mut run = FixedVariant::run(manifest, cache, problem, best_idx, inputs, iters)?;
        run.label = format!("oracle:{}", problem.variants[best_idx].label);
        run.setup = setup;
        Ok(run)
    }
}

/// AOT-all-variants: compile the full variant set up front, pick the best
/// by one measured call each, then serve.
pub struct AotAll;

impl AotAll {
    /// Execute the baseline: setup = k compilations + k measurements.
    pub fn run(
        manifest: &Manifest,
        cache: &mut CompileCache,
        problem: &Problem,
        inputs: &[HostTensor],
        iters: usize,
    ) -> Result<BaselineRun> {
        let t0 = Instant::now();
        for v in &problem.variants {
            cache.get_or_compile(manifest, v)?;
        }
        let mut run = Oracle::run(manifest, cache, problem, inputs, iters)?;
        run.label = "aot-all".to_string();
        run.setup = t0.elapsed();
        Ok(run)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::mock::{MockEngine, MockSpec};

    fn setup(spec: MockSpec) -> (Manifest, CompileCache) {
        let manifest = crate::manifest::tests::sample_manifest().unwrap();
        (manifest, CompileCache::new(Box::new(MockEngine::new(spec))))
    }

    fn spec_fast_b() -> MockSpec {
        MockSpec::default()
            .with_cost("k.a.n8", Duration::from_micros(500))
            .with_cost("k.b.n8", Duration::from_micros(50))
    }

    #[test]
    fn fixed_variant_runs_requested_variant() {
        let (m, mut cache) = setup(spec_fast_b());
        let p = m.problem("k", 8).unwrap().clone();
        let inputs = [HostTensor::zeros(&[8, 8])];
        let run = FixedVariant::run(&m, &mut cache, &p, 0, &inputs, 5).unwrap();
        assert_eq!(run.label, "fixed:a");
        assert_eq!(run.per_call.len(), 5);
        assert!(run.setup > Duration::ZERO);
        // cumulative is monotone with the right length
        let cum = run.cumulative();
        assert_eq!(cum.len(), 5);
        assert!(cum.windows(2).all(|w| w[1] >= w[0]));
        assert!((cum[4] - run.total()).abs() < 1e-12);
    }

    #[test]
    fn oracle_picks_fast_variant() {
        let (m, mut cache) = setup(spec_fast_b());
        let p = m.problem("k", 8).unwrap().clone();
        let inputs = [HostTensor::zeros(&[8, 8])];
        let run = Oracle::run(&m, &mut cache, &p, &inputs, 3).unwrap();
        assert_eq!(run.label, "oracle:b");
        // steady calls at the fast variant's cost
        assert!(run.total() < 3.0 * 500e-6, "total={}", run.total());
    }

    #[test]
    fn aot_all_setup_covers_all_compiles() {
        let (m, mut cache) = setup(spec_fast_b());
        let p = m.problem("k", 8).unwrap().clone();
        let inputs = [HostTensor::zeros(&[8, 8])];
        let run = AotAll::run(&m, &mut cache, &p, &inputs, 3).unwrap();
        assert_eq!(run.label, "aot-all");
        // setup ≥ 2 compiles (200µs each by default)
        assert!(run.setup >= Duration::from_micros(400), "setup={:?}", run.setup);
        assert_eq!(cache.stats().misses, 2);
    }
}
