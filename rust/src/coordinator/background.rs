//! Background shadow exploration: the scheduler that moves candidate
//! compile+measure off the serving path.
//!
//! With `ServerOptions { explore_budget: Some(opts) }` the dispatcher
//! stops running `Decision::Explore` on callers. Callers always execute
//! the current-best variant (or the first runnable default while nothing
//! is measured yet) and candidate exploration runs as background jobs on
//! pool workers — or on a dedicated shadow worker when no pool is
//! configured — under a strict duty-cycle budget.
//!
//! The scheduler is leader-owned bookkeeping, not a thread:
//!
//! * **Duty cycle** — each window of `ExploreOptions::window` may spend
//!   at most `pct`% of the explore workers' combined time on candidate
//!   compile+measure. Actual busy time is debited when results arrive;
//!   issuance stops once the window's capacity is spent and resumes when
//!   the window rolls. Because job cost is only known after the fact,
//!   the overshoot is bounded by the in-flight cap (≈ one window).
//! * **Pipelining** — up to `workers + 1` jobs may be in flight at once,
//!   across problems: candidate N+1 compiles while candidate N is still
//!   measuring, and a multi-problem workload keeps every explore worker
//!   fed without waiting for round barriers.
//! * **Adaptive rounds** — [`crate::autotuner::TuningState::decide_background`]
//!   is asked for exactly as many fresh candidates as the budget allows
//!   right now, so rounds widen while the budget is underspent and
//!   shrink to nothing when it is exhausted.
//! * **Hedging** — a job that misses `ExploreOptions::hedge` is written
//!   off: the candidate is reported failed and its in-flight slot is
//!   freed, so one wedged candidate cannot stall the round. A late
//!   result for a hedged (or forgotten) job is dropped, but its busy
//!   time is still debited — the duty cycle stays honest.

use std::collections::HashMap;
use std::fmt;
use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::autotuner::ProblemKey;
use crate::coordinator::pool::WorkerPool;
use crate::manifest::Variant;
use crate::runtime::EngineFactory;
use crate::tensor::HostTensor;

/// Budget knobs for background exploration
/// (`ServerOptions::explore_budget`).
#[derive(Clone)]
pub struct ExploreOptions {
    /// Share of each explore worker's time that candidate compile+measure
    /// may consume, in percent (`5.0` = 5%, the default). `0.0` disables
    /// exploration entirely: callers are served the default variant
    /// forever and no problem ever reaches `Phase::Tuned`.
    pub pct: f64,
    /// Duty-cycle enforcement window (default 100ms). Spending is
    /// reconciled and the budget refilled once per window.
    pub window: Duration,
    /// Hedge deadline for one background job (default 2s): a candidate
    /// whose compile+measure has not reported back within this long is
    /// marked failed and its in-flight slot is handed to the next
    /// candidate.
    pub hedge: Duration,
    /// Engine factory for the dedicated shadow worker used when no
    /// worker pool is configured. Ignored when a pool is attached (its
    /// workers run the explore jobs). With neither a pool nor a factory,
    /// background mode is disabled with a warning and exploration stays
    /// inline.
    pub shadow_factory: Option<Arc<dyn EngineFactory>>,
}

impl ExploreOptions {
    /// Options with the given duty-cycle percentage and default window
    /// and hedge.
    pub fn percent(pct: f64) -> ExploreOptions {
        ExploreOptions {
            pct,
            window: Duration::from_millis(100),
            hedge: Duration::from_secs(2),
            shadow_factory: None,
        }
    }

    /// Set the duty-cycle window.
    pub fn with_window(mut self, window: Duration) -> ExploreOptions {
        self.window = window;
        self
    }

    /// Set the per-job hedge deadline.
    pub fn with_hedge(mut self, hedge: Duration) -> ExploreOptions {
        self.hedge = hedge;
        self
    }

    /// Set the shadow-worker engine factory (used only when no pool is
    /// configured).
    pub fn with_shadow_factory(mut self, factory: Arc<dyn EngineFactory>) -> ExploreOptions {
        self.shadow_factory = Some(factory);
        self
    }
}

impl fmt::Debug for ExploreOptions {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("ExploreOptions")
            .field("pct", &self.pct)
            .field("window", &self.window)
            .field("hedge", &self.hedge)
            .field("shadow_factory", &self.shadow_factory.as_ref().map(|sf| sf.name()))
            .finish()
    }
}

/// One background compile+measure outcome, reported by an explore worker
/// back to the leader.
#[derive(Debug)]
pub(crate) struct ExploreResult {
    /// Problem the candidate belongs to.
    pub key: ProblemKey,
    /// Candidate index within the problem's parameter-value array.
    pub candidate: usize,
    /// Issuance sequence number — a result whose seq does not match the
    /// in-flight entry is stale (hedged, retuned, or reloaded) and must
    /// not report into tuner state.
    pub seq: u64,
    /// Measured execution cost in seconds, or the compile/execute error.
    pub cost: crate::Result<f64>,
    /// Worker time the job consumed (compile + measure), debited against
    /// the duty-cycle window.
    pub busy: Duration,
}

/// In-flight bookkeeping for one issued job.
struct Inflight {
    seq: u64,
    issued_at: Instant,
    /// Plan coordinates (`Dispatcher::plans` hash + slot) so hedge expiry
    /// can reach the owning tuning state without guessing.
    hash: u64,
    slot: usize,
}

/// Leader-owned scheduler state for background exploration: duty-cycle
/// window accounting, the in-flight job map, and the submission side of
/// the explore job channel.
pub(crate) struct BackgroundScheduler {
    opts: ExploreOptions,
    pool: Arc<WorkerPool>,
    explore_workers: usize,
    reply: mpsc::Sender<ExploreResult>,
    seq: u64,
    inflight: HashMap<(ProblemKey, usize), Inflight>,
    window_start: Instant,
    spent: Duration,
}

impl BackgroundScheduler {
    /// Scheduler submitting explore jobs to `pool` (`explore_workers` of
    /// its workers share the duty-cycle budget) and tagging them with the
    /// reply sender.
    pub fn new(
        opts: ExploreOptions,
        pool: Arc<WorkerPool>,
        explore_workers: usize,
        reply: mpsc::Sender<ExploreResult>,
    ) -> BackgroundScheduler {
        let opts = ExploreOptions {
            window: opts.window.max(Duration::from_millis(1)),
            hedge: opts.hedge.max(Duration::from_millis(1)),
            ..opts
        };
        BackgroundScheduler {
            opts,
            pool,
            explore_workers: explore_workers.max(1),
            reply,
            seq: 0,
            inflight: HashMap::new(),
            window_start: Instant::now(),
            spent: Duration::ZERO,
        }
    }

    /// Configured duty-cycle percentage.
    pub fn pct(&self) -> f64 {
        self.opts.pct
    }

    /// Busy-time capacity of one window across the explore workers.
    fn capacity(&self) -> Duration {
        self.opts.window.mul_f64((self.opts.pct / 100.0).max(0.0) * self.explore_workers as f64)
    }

    /// In-flight job cap: one job per explore worker plus one queued, so
    /// the next candidate's compile overlaps the current measurement.
    fn pipeline_cap(&self) -> usize {
        self.explore_workers + 1
    }

    /// How many fresh jobs may be issued right now — 0 when the budget
    /// is disabled, the window's capacity is spent, or the pipeline is
    /// full.
    pub fn issue_capacity(&self) -> usize {
        if self.opts.pct <= 0.0 || self.spent >= self.capacity() {
            return 0;
        }
        self.pipeline_cap().saturating_sub(self.inflight.len())
    }

    /// Roll the duty-cycle window if it elapsed; returns the finished
    /// window's realized duty-cycle percentage (per explore worker).
    pub fn roll_window(&mut self, now: Instant) -> Option<f64> {
        let elapsed = now.saturating_duration_since(self.window_start);
        if elapsed < self.opts.window {
            return None;
        }
        let denom = elapsed.as_secs_f64() * self.explore_workers as f64;
        let pct = if denom > 0.0 { self.spent.as_secs_f64() / denom * 100.0 } else { 0.0 };
        self.spent = Duration::ZERO;
        self.window_start = now;
        Some(pct)
    }

    /// Issue one candidate's compile+measure as a background job.
    /// Bookkeeping is only committed when the submission is accepted.
    /// `inputs` are synthesized by the dispatcher (workers have no caller
    /// tensors): zero-filled tensors of the problem's input shapes.
    #[allow(clippy::too_many_arguments)]
    pub fn submit(
        &mut self,
        variant: Variant,
        hlo_text: String,
        inputs: Vec<HostTensor>,
        key: ProblemKey,
        candidate: usize,
        hash: u64,
        slot: usize,
        now: Instant,
    ) -> crate::Result<()> {
        let seq = self.seq + 1;
        self.pool.submit_explore(
            variant,
            hlo_text,
            inputs,
            key.clone(),
            candidate,
            seq,
            self.reply.clone(),
        )?;
        self.seq = seq;
        self.inflight.insert((key, candidate), Inflight { seq, issued_at: now, hash, slot });
        Ok(())
    }

    /// Absorb a result: debit its busy time against the current window
    /// and, when it matches the in-flight entry, clear the entry and
    /// return the owning plan's `(hash, slot)`. A stale result (hedged,
    /// forgotten, or reissued) returns `None` — its measurement must be
    /// dropped, but the worker time it consumed still counts.
    pub fn absorb(&mut self, result: &ExploreResult) -> Option<(u64, usize)> {
        self.spent += result.busy;
        let lookup = (result.key.clone(), result.candidate);
        match self.inflight.get(&lookup) {
            Some(inf) if inf.seq == result.seq => {
                // jitune-lint: allow(L005): the match arm above just observed this key
                let inf = self.inflight.remove(&lookup).expect("entry just observed");
                Some((inf.hash, inf.slot))
            }
            _ => None,
        }
    }

    /// Remove and return every in-flight job past its hedge deadline as
    /// `(key, candidate, hash, slot)` — the caller reports each candidate
    /// failed so the round can move on without it.
    pub fn expire_hedges(&mut self, now: Instant) -> Vec<(ProblemKey, usize, u64, usize)> {
        let hedge = self.opts.hedge;
        let expired: Vec<(ProblemKey, usize)> = self
            .inflight
            .iter()
            .filter(|(_, inf)| now.saturating_duration_since(inf.issued_at) >= hedge)
            .map(|(k, _)| k.clone())
            .collect();
        expired
            .into_iter()
            .map(|k| {
                // jitune-lint: allow(L005): key came from scanning this same map
                let inf = self.inflight.remove(&k).expect("expired entry present");
                (k.0, k.1, inf.hash, inf.slot)
            })
            .collect()
    }

    /// Earliest hedge deadline among in-flight jobs.
    pub fn earliest_hedge(&self) -> Option<Instant> {
        self.inflight.values().map(|inf| inf.issued_at + self.opts.hedge).min()
    }

    /// When the current duty-cycle window rolls (budget refill).
    pub fn window_end(&self) -> Instant {
        self.window_start + self.opts.window
    }

    /// Number of jobs in flight.
    pub fn inflight_len(&self) -> usize {
        self.inflight.len()
    }

    /// Drop in-flight bookkeeping for one candidate — called when the
    /// candidate is reported failed through another path while its job
    /// is still running, so the late result cannot report into the
    /// tuner.
    pub fn forget_candidate(&mut self, key: &ProblemKey, candidate: usize) {
        self.inflight.remove(&(key.clone(), candidate));
    }

    /// Drop in-flight bookkeeping for one problem — called when its
    /// tuning state is replaced (retune, hub adoption), so late results
    /// cannot report into the fresh state.
    pub fn forget_key(&mut self, key: &ProblemKey) {
        self.inflight.retain(|(k, _), _| k != key);
    }

    /// Drop all in-flight bookkeeping (tuning-state import).
    pub fn forget_all(&mut self) {
        self.inflight.clear();
    }
}
