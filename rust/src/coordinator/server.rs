//! Threaded coordinator: leader thread owning the dispatcher, serving
//! requests from any number of application threads — plus the tuned-path
//! fast lane that lets steady-state calls skip the leader entirely.
//!
//! PJRT clients are thread-pinned (`Rc` internally), so the dispatcher
//! lives on one leader thread. Application threads hold cloneable
//! [`CoordinatorHandle`]s. A call first consults the shared
//! [`FastLane`]: problems whose tuning already finished (and whose
//! engine hands out `Send + Sync` executables) run right on the calling
//! thread. Everything else — tuning iterations, finalizations, retunes,
//! thread-pinned backends — is submitted over an mpsc channel and
//! serialized by the single leader, which preserves the paper's
//! "compilation is protected by a mutex" guarantee and keeps the tuner
//! observing executions under real cross-request contention.

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::coordinator::background::{BackgroundScheduler, ExploreOptions, ExploreResult};
use crate::coordinator::dispatcher::{CallOutcome, Dispatcher};
use crate::coordinator::drift::{DriftPolicy, QuarantinePolicy};
use crate::coordinator::fastlane::FastLane;
use crate::coordinator::pool::{PoolOptions, PoolSnapshot, WorkerPool};
use crate::error::{Error, Result};
use crate::hub::{HubClient, HubOptions, HubSubscriber};
use crate::tensor::HostTensor;
use crate::util::json::Value;

enum Request {
    Call {
        kernel: String,
        inputs: Vec<HostTensor>,
        /// Absolute call deadline (`ServerOptions::call_deadline` applied
        /// at call entry); the leader sheds the call unexecuted when it
        /// dequeues after this instant.
        deadline: Option<Instant>,
        /// When the handle enqueued the call — queue wait is measured
        /// against [`ShedPolicy::max_queue_wait`] at dequeue.
        enqueued: Instant,
        reply: mpsc::SyncSender<Result<CallOutcome>>,
    },
    TunedValue {
        kernel: String,
        size: i64,
        reply: mpsc::SyncSender<Option<i64>>,
    },
    Retune {
        kernel: String,
        size: i64,
        reply: mpsc::SyncSender<Result<bool>>,
    },
    Stats {
        reply: mpsc::SyncSender<(String, Value)>,
    },
    StatsJson {
        reply: mpsc::SyncSender<Value>,
    },
    HubPull {
        reply: mpsc::SyncSender<Result<(usize, usize)>>,
    },
    SaveState {
        path: std::path::PathBuf,
        reply: mpsc::SyncSender<Result<usize>>,
    },
    /// Internal: one background explore job's outcome, forwarded from
    /// the explore-worker reply channel onto the leader queue.
    ExploreDone(ExploreResult),
    /// Internal: the hub notifier thread saw a pushed update — pull the
    /// broker's map now instead of waiting for the next pull tick.
    /// Coalesced per scheduling round (N queued notifies → one pull).
    HubNotify,
    Shutdown,
}

/// One deferred kernel call awaiting fused dispatch: (kernel, inputs,
/// reply).
type CallItem = (String, Vec<HostTensor>, mpsc::SyncSender<Result<CallOutcome>>);

/// Round requests that must keep their arrival order relative to each
/// other: kernel calls, and retunes (which mutate tuner state, so they
/// must not overtake a call queued before them — unlike the cheap
/// control requests, which answer first).
enum Deferred {
    Call(String, Vec<HostTensor>, mpsc::SyncSender<Result<CallOutcome>>),
    Retune {
        kernel: String,
        size: i64,
        reply: mpsc::SyncSender<Result<bool>>,
    },
}

/// Dispatch a run of deferred calls as fused same-kernel batches and
/// route each reply to its caller; clears the run.
fn flush_call_run(dispatcher: &mut Dispatcher, depth: usize, run: &mut Vec<CallItem>) {
    if run.is_empty() {
        return;
    }
    let mut groups: Vec<(
        String,
        Vec<(Vec<HostTensor>, mpsc::SyncSender<Result<CallOutcome>>)>,
    )> = Vec::new();
    for (kernel, inputs, reply) in run.drain(..) {
        match groups.iter_mut().find(|(k, _)| *k == kernel) {
            Some((_, members)) => members.push((inputs, reply)),
            None => groups.push((kernel, vec![(inputs, reply)])),
        }
    }
    for (kernel, members) in groups {
        let (inputs, replies): (Vec<_>, Vec<_>) = members.into_iter().unzip();
        for _ in 0..inputs.len() {
            dispatcher.stats_mut().enqueue_round(depth);
        }
        let results = dispatcher.call_batch(&kernel, inputs);
        for (result, reply) in results.into_iter().zip(replies) {
            let _ = reply.send(result);
        }
    }
}

/// Lock-free resilience counters shared by every handle and the leader:
/// the admission gate's in-flight count plus shed / deadline-exceeded
/// totals. Handles record here without any leader round-trip; the
/// leader syncs the totals into [`super::stats::CoordStats`] before
/// answering a stats request.
#[derive(Debug, Default)]
struct ResilienceGauge {
    /// Leader-lane calls admitted but not yet answered.
    inflight: AtomicUsize,
    /// Calls refused by the admission gate or shed by the leader for
    /// exceeding [`ShedPolicy::max_queue_wait`].
    shed: AtomicU64,
    /// Calls that returned [`Error::DeadlineExceeded`] on any lane.
    deadline_exceeded: AtomicU64,
}

/// RAII in-flight slot: decrements the gauge however the call exits
/// (reply, deadline timeout, panic unwind).
struct InflightPermit<'a>(&'a ResilienceGauge);

impl Drop for InflightPermit<'_> {
    fn drop(&mut self) {
        self.0.inflight.fetch_sub(1, Ordering::Relaxed);
    }
}

/// Cloneable, `Send` handle for submitting kernel calls to the leader —
/// or executing them directly when the tuned fast lane has a published
/// winner for the problem.
#[derive(Clone)]
pub struct CoordinatorHandle {
    tx: mpsc::Sender<Request>,
    fast_lane: Option<Arc<FastLane>>,
    pool: Option<Arc<WorkerPool>>,
    gauge: Arc<ResilienceGauge>,
    call_deadline: Option<Duration>,
    shed: Option<ShedPolicy>,
}

impl CoordinatorHandle {
    /// Dispatch a kernel call and wait for its result.
    ///
    /// Steady state: a fast-lane hit executes the published winner on
    /// *this* thread — no channel, no leader, no serialization against
    /// other callers. Misses (still tuning, retuned, thread-pinned
    /// engine) fall back to the leader exactly as before. A published
    /// winner that fails at execution is unpublished and the call retries
    /// through the leader, so callers never observe a lost call — unless
    /// a quarantine policy armed a failure breaker on the entry, in which
    /// case the error returns to the caller and the *breaker* owns
    /// demotion (sliding-window rate, next-best fallback) instead of one
    /// error evicting a healthy winner.
    ///
    /// With [`ServerOptions::call_deadline`] the whole call is bounded:
    /// fast-lane execution is budget-checked, the leader sheds the call
    /// if it dequeues past the deadline, and the reply wait itself times
    /// out — a wedged winner costs the caller the deadline, never a hang.
    /// The straggler's eventual reply lands in a dropped channel and is
    /// discarded. With [`ServerOptions::shed`] admission is bounded too:
    /// beyond `max_inflight` concurrent leader-lane calls the handle
    /// fails fast with [`Error::Overloaded`] instead of queueing.
    pub fn call(&self, kernel: &str, inputs: Vec<HostTensor>) -> Result<CallOutcome> {
        let t0 = Instant::now();
        let deadline = self.call_deadline.map(|d| t0 + d);
        if let Some(lane) = &self.fast_lane {
            if let Some(entry) = lane.lookup(kernel, &inputs) {
                match entry.call_deadline(&inputs, t0, deadline) {
                    Ok(outcome) => return Ok(outcome),
                    Err(e @ Error::DeadlineExceeded { .. }) => {
                        // Not a winner failure and not retryable — the
                        // budget is gone either way.
                        self.gauge.deadline_exceeded.fetch_add(1, Ordering::Relaxed);
                        return Err(e);
                    }
                    Err(e) if entry.failure_breaker().is_some() => {
                        // The breaker recorded the error; the leader's
                        // quarantine scan demotes once the windowed rate
                        // trips. One error must not evict the entry.
                        return Err(e);
                    }
                    Err(e) => {
                        log::warn!(
                            "fast lane: {} failed ({e}); demoting to leader lane",
                            entry.variant_id()
                        );
                        // By identity, not by key: a newer entry the
                        // leader republished meanwhile must survive.
                        lane.invalidate_entry(&entry);
                    }
                }
            }
        }
        let _permit = if let Some(shed) = &self.shed {
            let admitted = self.gauge.inflight.fetch_add(1, Ordering::Relaxed);
            let permit = InflightPermit(&self.gauge);
            if admitted >= shed.max_inflight {
                // permit drops here, releasing the slot we just took
                self.gauge.shed.fetch_add(1, Ordering::Relaxed);
                return Err(Error::Overloaded(format!(
                    "{kernel}: {admitted} leader-lane calls in flight (max {})",
                    shed.max_inflight
                )));
            }
            Some(permit)
        } else {
            None
        };
        let (reply, rx) = mpsc::sync_channel(1);
        self.tx
            .send(Request::Call {
                kernel: kernel.to_string(),
                inputs,
                deadline,
                enqueued: Instant::now(),
                reply,
            })
            .map_err(|_| Error::Coordinator("coordinator stopped".into()))?;
        let result = match deadline {
            Some(d) => match rx.recv_timeout(d.saturating_duration_since(Instant::now())) {
                Ok(result) => result,
                Err(mpsc::RecvTimeoutError::Timeout) => {
                    // Dropping `rx` makes the leader's eventual reply a
                    // failed send — the result is discarded on arrival,
                    // nothing blocks on us.
                    Err(Error::DeadlineExceeded {
                        kernel: kernel.to_string(),
                        deadline: d.saturating_duration_since(t0),
                    })
                }
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    return Err(Error::Coordinator("coordinator dropped reply".into()))
                }
            },
            // jitune-lint: allow(L006): no deadline configured; leader
            // shutdown drops the reply sender, so this recv disconnects
            // instead of hanging
            None => rx.recv().map_err(|_| Error::Coordinator("coordinator dropped reply".into()))?,
        };
        match &result {
            Err(Error::DeadlineExceeded { .. }) => {
                self.gauge.deadline_exceeded.fetch_add(1, Ordering::Relaxed);
            }
            Err(Error::Overloaded(_)) => {
                // leader-side shed (queue wait exceeded the policy)
                self.gauge.shed.fetch_add(1, Ordering::Relaxed);
            }
            _ => {}
        }
        result
    }

    /// Tuned parameter value for a problem, if tuning completed.
    pub fn tuned_value(&self, kernel: &str, size: i64) -> Result<Option<i64>> {
        let (reply, rx) = mpsc::sync_channel(1);
        self.tx
            .send(Request::TunedValue { kernel: kernel.to_string(), size, reply })
            .map_err(|_| Error::Coordinator("coordinator stopped".into()))?;
        // jitune-lint: allow(L006): control-plane query — leader shutdown drops
        // the reply sender, so this recv disconnects instead of hanging
        rx.recv().map_err(|_| Error::Coordinator("coordinator dropped reply".into()))
    }

    /// Restart tuning for a problem. The leader resets the tuner state,
    /// evicts resident executables and invalidates the published
    /// fast-lane entry; subsequent calls re-explore. Returns whether
    /// tuner state existed.
    pub fn retune(&self, kernel: &str, size: i64) -> Result<bool> {
        let (reply, rx) = mpsc::sync_channel(1);
        self.tx
            .send(Request::Retune { kernel: kernel.to_string(), size, reply })
            .map_err(|_| Error::Coordinator("coordinator stopped".into()))?;
        // jitune-lint: allow(L006): control-plane query — leader shutdown drops
        // the reply sender, so this recv disconnects instead of hanging
        rx.recv().map_err(|_| Error::Coordinator("coordinator dropped reply".into()))?
    }

    /// Rendered stats + JSON tuning report.
    pub fn stats(&self) -> Result<(String, Value)> {
        let (reply, rx) = mpsc::sync_channel(1);
        self.tx
            .send(Request::Stats { reply })
            .map_err(|_| Error::Coordinator("coordinator stopped".into()))?;
        // jitune-lint: allow(L006): control-plane query — leader shutdown drops
        // the reply sender, so this recv disconnects instead of hanging
        rx.recv().map_err(|_| Error::Coordinator("coordinator dropped reply".into()))
    }

    /// Machine-readable statistics: per-kernel leader-lane counters under
    /// `"kernels"` plus (when enabled) the fast lane's counters under
    /// `"fast_lane"`.
    pub fn stats_json(&self) -> Result<Value> {
        let (reply, rx) = mpsc::sync_channel(1);
        self.tx
            .send(Request::StatsJson { reply })
            .map_err(|_| Error::Coordinator("coordinator stopped".into()))?;
        // jitune-lint: allow(L006): control-plane query — leader shutdown drops
        // the reply sender, so this recv disconnects instead of hanging
        rx.recv().map_err(|_| Error::Coordinator("coordinator dropped reply".into()))
    }

    /// Pull the tuned-state hub's full map now and adopt newer winners
    /// (see [`Dispatcher::hub_pull`]). Returns (adopted, skipped);
    /// (0, 0) when no hub is attached. Periodic pulls happen on their
    /// own when `HubOptions::pull_interval` is set — this is the
    /// explicit, deterministic variant for operators and tests.
    pub fn hub_pull(&self) -> Result<(usize, usize)> {
        let (reply, rx) = mpsc::sync_channel(1);
        self.tx
            .send(Request::HubPull { reply })
            .map_err(|_| Error::Coordinator("coordinator stopped".into()))?;
        // jitune-lint: allow(L006): control-plane query — leader shutdown drops
        // the reply sender, so this recv disconnects instead of hanging
        rx.recv().map_err(|_| Error::Coordinator("coordinator dropped reply".into()))?
    }

    /// Persist tuned results to a JSON file (the leader runs
    /// [`Dispatcher::save_state`]). Returns the number of tuned
    /// problems written.
    pub fn save_state(&self, path: &std::path::Path) -> Result<usize> {
        let (reply, rx) = mpsc::sync_channel(1);
        self.tx
            .send(Request::SaveState { path: path.to_path_buf(), reply })
            .map_err(|_| Error::Coordinator("coordinator stopped".into()))?;
        // jitune-lint: allow(L006): control-plane query — leader shutdown drops
        // the reply sender, so this recv disconnects instead of hanging
        rx.recv().map_err(|_| Error::Coordinator("coordinator dropped reply".into()))?
    }

    /// Number of published fast-lane entries (0 when the lane is
    /// disabled). Reads the shared map directly — no leader round-trip.
    pub fn fast_lane_published(&self) -> usize {
        self.fast_lane.as_ref().map_or(0, |l| l.published())
    }

    /// Fast-lane per-kernel `(kernel, hits, mean latency seconds)`
    /// snapshot. Empty when the lane is disabled.
    pub fn fast_lane_stats(&self) -> Vec<(String, u64, f64)> {
        self.fast_lane.as_ref().map(|l| l.snapshot()).unwrap_or_default()
    }

    /// Worker-pool counter snapshot (per-worker executed/errors/compiles,
    /// respawns). `None` when no pool is attached. Reads the shared pool
    /// state directly — no leader round-trip.
    pub fn pool_snapshot(&self) -> Option<PoolSnapshot> {
        self.pool.as_ref().map(|p| p.snapshot())
    }
}

/// Batching policy for the leader loop.
#[derive(Debug, Clone, Copy)]
pub struct BatchOptions {
    /// Maximum requests drained from the queue per scheduling round.
    /// Draining lets the leader observe queue depth (admission stats),
    /// keeps reply latency fair under burst load, and — since rounds
    /// dispatch as fused batches — bounds how many co-scheduled
    /// exploration candidates one round can measure: with B callers
    /// co-scheduled (`max_batch ≥ B`), a sweep over V variants reaches
    /// `Phase::Tuned` in ~V/B leader rounds instead of V.
    pub max_batch: usize,
}

impl Default for BatchOptions {
    fn default() -> Self {
        BatchOptions { max_batch: 16 }
    }
}

/// Bounded admission ahead of the leader queue: when the server is
/// saturated, fail fast with [`Error::Overloaded`] instead of letting
/// the queue (and every caller's latency) grow without bound.
///
/// Two independent bounds: `max_inflight` refuses work at the door,
/// `max_queue_wait` sheds work that got in but sat queued so long that
/// executing it late helps nobody. Fast-lane hits bypass both — they
/// never queue.
#[derive(Debug, Clone, Copy)]
pub struct ShedPolicy {
    /// Maximum leader-lane calls in flight (admitted, not yet answered)
    /// across all handles. The next admission fails fast.
    pub max_inflight: usize,
    /// Maximum time a call may sit on the leader queue; the leader sheds
    /// staler calls unexecuted at dequeue.
    pub max_queue_wait: Duration,
}

impl Default for ShedPolicy {
    fn default() -> Self {
        ShedPolicy { max_inflight: 1024, max_queue_wait: Duration::from_secs(1) }
    }
}

/// Full server configuration.
#[derive(Debug, Clone)]
pub struct ServerOptions {
    /// Leader-loop batching.
    pub batch: BatchOptions,
    /// Publish tuned winners for lock-free execution on caller threads.
    /// Disable to force every call through the leader (the pre-fast-lane
    /// behaviour — the baseline the throughput-scaling bench compares
    /// against).
    pub fast_lane: bool,
    /// Worker pool of thread-pinned engines. `Some(opts)` spawns
    /// `opts.workers` threads, each creating its own engine via
    /// `opts.factory` on its own thread; finalized winners that cannot
    /// provide a shared executable are replicated onto the pool
    /// (compiled once per worker) and published as pool-routed fast-lane
    /// entries, so steady-state throughput scales with workers even when
    /// kernels are `!Send` (PJRT). Requires `fast_lane` (ignored with a
    /// warning otherwise). `None` keeps thread-pinned winners on the
    /// leader exactly as before.
    pub pool: Option<PoolOptions>,
    /// Drift-detection retune policy. `Some(policy)` makes the leader
    /// periodically compare each published winner's windowed fast-lane
    /// latency against its tuning-time baseline and retune automatically
    /// when the policy trips (requires `fast_lane`; ignored with a
    /// warning otherwise). `None` preserves the manual-retune-only
    /// behaviour exactly.
    pub drift: Option<DriftPolicy>,
    /// Tuned-state hub connection. `Some(opts)` makes the leader connect
    /// at spawn, pull the fleet's tuned map for a warm start, publish
    /// every finalized winner back, and (with
    /// [`HubOptions::pull_interval`]) keep adopting newer winners while
    /// serving. An unreachable broker degrades to a warning — serving
    /// never depends on hub liveness — and, when `pull_interval` is
    /// set, the connection is re-attempted on pull ticks so a broker
    /// that starts late still gets joined. With
    /// [`HubOptions::subscribe`] a notifier thread receives broker
    /// pushes and triggers an immediate pull — push-first propagation,
    /// with `pull_interval` as the fallback. `None` keeps the
    /// process-local behaviour exactly.
    pub hub: Option<HubOptions>,
    /// Compile hub-adopted (and state-file-imported) winners at spawn:
    /// after the hub warm start, every problem sitting in `Finalizing`
    /// with a pending winner is finalized immediately — compiled on the
    /// leader, replicated across the worker pool when one is attached,
    /// and published to the fast lane — so a freshly booted replica
    /// serves tuned traffic from its *first* call instead of paying the
    /// winner's compile on it. `false` (the default) defers that
    /// compile to first use, exactly as before.
    pub prewarm: bool,
    /// Background shadow exploration (the serve/explore split — see
    /// [`crate::coordinator::background`]). `Some(opts)` means callers
    /// never pay exploration: anything not yet tuned serves the
    /// current-best (or default) variant while candidate compile+measure
    /// runs as background jobs on the worker pool — or on a dedicated
    /// shadow worker built from `ExploreOptions::shadow_factory` when no
    /// pool is configured — capped at `opts.pct`% of explore-worker time
    /// per window. `pct = 0` serves the default forever and never tunes
    /// (documented escape hatch: `jitune run --explore-budget 0`).
    /// `None` keeps inline exploration exactly as before.
    pub explore_budget: Option<ExploreOptions>,
    /// Per-call deadline. `Some(d)` bounds every [`CoordinatorHandle::
    /// call`] end to end — fast-lane execution, leader queue wait, and
    /// the reply wait itself — returning [`Error::DeadlineExceeded`]
    /// when the budget elapses. A straggling execution's result is
    /// discarded on arrival; the worker that produced it lives on.
    /// `None` (the default) keeps calls unbounded exactly as before.
    pub call_deadline: Option<Duration>,
    /// Load shedding. `Some(policy)` arms a bounded admission gate ahead
    /// of the leader queue (see [`ShedPolicy`]); shed calls fail fast
    /// with [`Error::Overloaded`] and are counted in stats. `None` (the
    /// default) admits everything exactly as before.
    pub shed: Option<ShedPolicy>,
    /// Winner quarantine. `Some(policy)` arms a per-entry failure-rate
    /// breaker on every published fast-lane winner: when a winner's
    /// windowed runtime error rate trips the policy, the leader demotes
    /// it everywhere (lane, cache, pool), quarantines the variant so an
    /// immediate retune cannot re-pick it, and serves the next-best
    /// variant from tuning history as fallback (requires `fast_lane`;
    /// ignored with a warning otherwise). `None` (the default) keeps the
    /// invalidate-on-first-error behaviour exactly.
    pub quarantine: Option<QuarantinePolicy>,
}

impl Default for ServerOptions {
    fn default() -> Self {
        ServerOptions {
            batch: BatchOptions::default(),
            fast_lane: true,
            pool: None,
            drift: None,
            hub: None,
            prewarm: false,
            explore_budget: None,
            call_deadline: None,
            shed: None,
            quarantine: None,
        }
    }
}

/// The running coordinator (leader thread + handle factory).
pub struct Coordinator {
    tx: mpsc::Sender<Request>,
    join: Option<JoinHandle<()>>,
    fast_lane: Option<Arc<FastLane>>,
    pool: Option<Arc<WorkerPool>>,
    /// Dedicated explore worker when background mode runs without a
    /// serving pool; stopped at shutdown.
    shadow_pool: Option<Arc<WorkerPool>>,
    /// Explore-result forwarder thread; exits once every reply sender
    /// (the leader's scheduler + drained jobs) has dropped, joined at
    /// shutdown.
    forwarder: Option<JoinHandle<()>>,
    /// Hub push-notify subscriber thread (see `HubOptions::subscribe`);
    /// stopped via `notifier_stop` and joined at shutdown.
    notifier: Option<JoinHandle<()>>,
    notifier_stop: Arc<AtomicBool>,
    /// Shared resilience counters; every handle gets a clone.
    gauge: Arc<ResilienceGauge>,
    /// Per-call deadline handed to every handle.
    call_deadline: Option<Duration>,
    /// Admission-gate policy handed to every handle.
    shed: Option<ShedPolicy>,
}

impl Coordinator {
    /// Spawn with default options (fast lane enabled).
    pub fn spawn<F>(factory: F) -> Result<Coordinator>
    where
        F: FnOnce() -> Result<Dispatcher> + Send + 'static,
    {
        Coordinator::spawn_with_options(factory, ServerOptions::default())
    }

    /// Spawn with custom batching (fast lane enabled).
    pub fn spawn_with<F>(factory: F, batch: BatchOptions) -> Result<Coordinator>
    where
        F: FnOnce() -> Result<Dispatcher> + Send + 'static,
    {
        Coordinator::spawn_with_options(
            factory,
            ServerOptions { batch, ..ServerOptions::default() },
        )
    }

    /// Spawn the leader thread around a dispatcher factory.
    ///
    /// The factory runs *on the leader thread* because PJRT clients must
    /// be created on the thread that uses them. When the fast lane is
    /// enabled, the leader gets the publishing side and every handle gets
    /// the reading side of the shared map.
    pub fn spawn_with_options<F>(factory: F, opts: ServerOptions) -> Result<Coordinator>
    where
        F: FnOnce() -> Result<Dispatcher> + Send + 'static,
    {
        let max_batch = opts.batch.max_batch.max(1);
        let lane = if opts.fast_lane {
            Some(Arc::new(FastLane::with_policies(opts.drift, opts.quarantine)))
        } else {
            if opts.drift.is_some() {
                log::warn!(
                    "drift policy ignored: the fast lane is disabled, so there \
                     are no lane latency windows to monitor"
                );
            }
            if opts.quarantine.is_some() {
                log::warn!(
                    "quarantine policy ignored: the fast lane is disabled, so \
                     there are no published winners to arm breakers on"
                );
            }
            None
        };
        // The pool publishes through the fast lane, so it needs one.
        let pool = match &opts.pool {
            Some(pool_opts) if lane.is_some() => Some(WorkerPool::spawn(pool_opts.clone())?),
            Some(_) => {
                log::warn!(
                    "worker pool ignored: the fast lane is disabled, so pooled \
                     winners have nowhere to publish"
                );
                None
            }
            None => None,
        };
        // Leader wake-up cadences; None for all keeps the plain
        // blocking recv loop (no behaviour change without
        // drift/hub/quarantine).
        let drift_every = if opts.fast_lane {
            opts.drift.map(|p| p.window.max(Duration::from_millis(1)))
        } else {
            None
        };
        let quarantine_every = if opts.fast_lane {
            opts.quarantine.map(|p| p.window.max(Duration::from_millis(1)))
        } else {
            None
        };
        let shed_policy = opts.shed;
        let gauge = Arc::new(ResilienceGauge::default());
        let leader_gauge = Arc::clone(&gauge);
        let hub_opts = opts.hub.clone();
        let notify_opts = opts.hub.clone().filter(|h| h.subscribe);
        let prewarm = opts.prewarm;
        let pull_every = hub_opts
            .as_ref()
            .and_then(|h| h.pull_interval)
            .map(|every| every.max(Duration::from_millis(1)));
        let leader_lane = lane.clone();
        let leader_pool = pool.clone();
        let (tx, rx) = mpsc::channel::<Request>();
        // Background explore substrate: jobs run on the serving pool's
        // background lane when one exists, else on a dedicated one-worker
        // shadow pool built from `ExploreOptions::shadow_factory`. With
        // neither, background mode is disabled and exploration stays
        // inline. Results come back over a private channel; a tiny
        // forwarder thread moves them onto the leader queue so the leader
        // keeps a single receive loop.
        let mut shadow_pool: Option<Arc<WorkerPool>> = None;
        let mut scheduler: Option<BackgroundScheduler> = None;
        let mut forwarder: Option<JoinHandle<()>> = None;
        if let Some(eo) = &opts.explore_budget {
            let substrate = if let Some(pool) = &pool {
                Some((pool.clone(), pool.worker_count()))
            } else if let Some(factory) = &eo.shadow_factory {
                let spawned = match WorkerPool::spawn(PoolOptions {
                    workers: 1,
                    queue_depth: 8,
                    factory: factory.clone(),
                }) {
                    Ok(p) => p,
                    Err(e) => {
                        if let Some(pool) = &pool {
                            pool.stop();
                        }
                        return Err(e);
                    }
                };
                shadow_pool = Some(spawned.clone());
                Some((spawned, 1))
            } else {
                log::warn!(
                    "explore budget ignored: no worker pool and no shadow \
                     factory, so background jobs have nowhere to run; \
                     exploring inline"
                );
                None
            };
            if let Some((explore_pool, explore_workers)) = substrate {
                let (bg_tx, bg_rx) = mpsc::channel::<ExploreResult>();
                scheduler = Some(BackgroundScheduler::new(
                    eo.clone(),
                    explore_pool,
                    explore_workers,
                    bg_tx,
                ));
                let main_tx = tx.clone();
                let fwd = std::thread::Builder::new()
                    .name("jitune-explore-fwd".into())
                    .spawn(move || {
                        // Exits once every result sender (the leader's
                        // scheduler plus any drained jobs) has dropped,
                        // or when the leader queue itself is gone.
                        for result in bg_rx {
                            if main_tx.send(Request::ExploreDone(result)).is_err() {
                                break;
                            }
                        }
                    });
                match fwd {
                    Ok(handle) => forwarder = Some(handle),
                    Err(e) => {
                        if let Some(pool) = &pool {
                            pool.stop();
                        }
                        if let Some(sp) = &shadow_pool {
                            sp.stop();
                        }
                        return Err(Error::Coordinator(format!("spawn: {e}")));
                    }
                }
            }
        }
        let (ready_tx, ready_rx) = mpsc::sync_channel::<Result<()>>(1);
        let join = std::thread::Builder::new()
            .name("jitune-leader".into())
            .spawn(move || {
                // Set when the initial hub connect failed: periodic pull
                // ticks re-attempt the connection (single try, no sleep
                // loop) so a broker that starts late still gets joined.
                let mut hub_retry: Option<HubOptions> = None;
                let mut dispatcher = match factory() {
                    Ok(mut d) => {
                        if let Some(lane) = leader_lane {
                            d.set_fast_lane(lane);
                        }
                        if let Some(pool) = leader_pool {
                            d.attach_pool(pool);
                        }
                        if let Some(scheduler) = scheduler {
                            d.set_background(scheduler);
                        }
                        // Hub warm-start happens before readiness is
                        // signalled: when spawn() returns, the tuned map
                        // has already been adopted (deterministic for
                        // callers). An unreachable broker only warns.
                        if let Some(hub_opts) = hub_opts {
                            match HubClient::connect(hub_opts.clone()) {
                                Ok(client) => {
                                    d.attach_hub(client);
                                    match d.hub_pull() {
                                        Ok((adopted, skipped)) => log::info!(
                                            "hub: warm-started {adopted} problem(s), \
                                             skipped {skipped} stale"
                                        ),
                                        Err(e) => {
                                            log::warn!("hub: initial pull failed: {e}")
                                        }
                                    }
                                }
                                Err(e) => {
                                    log::warn!(
                                        "hub: unreachable ({e}); serving without warm-start"
                                    );
                                    hub_retry = Some(hub_opts);
                                }
                            }
                        }
                        // Pre-replication: compile adopted winners (hub
                        // warm start and/or a state file loaded by the
                        // factory) before the first call arrives. Runs
                        // before readiness so spawn() returning means
                        // "tuned traffic serves tuned from call one".
                        if prewarm {
                            let (compiled, failed) = d.prewarm_tuned();
                            if compiled + failed > 0 {
                                log::info!(
                                    "prewarm: compiled {compiled} adopted winner(s) at \
                                     spawn ({failed} failed)"
                                );
                            }
                        }
                        let _ = ready_tx.send(Ok(()));
                        d
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                let mut next_drift = drift_every.map(|every| Instant::now() + every);
                let mut next_pull = pull_every.map(|every| Instant::now() + every);
                let mut next_quarantine =
                    quarantine_every.map(|every| Instant::now() + every);
                'serve: loop {
                    // Advance the background explore scheduler first:
                    // expire hedges, roll the duty-cycle window, issue
                    // whatever jobs the budget allows, and learn when it
                    // next needs the loop awake (hedge deadline or window
                    // roll). No-op (`None`) when background mode is off.
                    let next_bg = dispatcher.background_tick(Instant::now());
                    // Block for the head request — with a deadline when a
                    // drift policy, a periodic hub pull, or the background
                    // scheduler needs the loop to wake even while the
                    // queue is idle. All timers coalesce into a single
                    // earliest-next-event `recv_timeout` deadline, so a
                    // saturated round queue cannot starve drift ticks and
                    // explore wakes never busy-spin the leader.
                    let next_tick = [next_drift, next_pull, next_quarantine, next_bg]
                        .into_iter()
                        .flatten()
                        .min();
                    let first = match next_tick {
                        Some(deadline) => {
                            let timeout = deadline.saturating_duration_since(Instant::now());
                            match rx.recv_timeout(timeout) {
                                Ok(req) => Some(req),
                                Err(mpsc::RecvTimeoutError::Timeout) => None,
                                Err(mpsc::RecvTimeoutError::Disconnected) => break 'serve,
                            }
                        }
                        // jitune-lint: allow(L006): idle leader wait — every handle holds a
                        // sender clone, so this recv disconnects when the last handle drops
                        None => match rx.recv() {
                            Ok(req) => Some(req),
                            Err(_) => break 'serve,
                        },
                    };
                    let now = Instant::now();
                    if let (Some(deadline), Some(every)) = (next_drift, drift_every) {
                        if now >= deadline {
                            dispatcher.drift_tick();
                            next_drift = Some(now + every);
                        }
                    }
                    if let (Some(deadline), Some(every)) = (next_quarantine, quarantine_every)
                    {
                        if now >= deadline {
                            dispatcher.quarantine_tick(now);
                            next_quarantine = Some(now + every);
                        }
                    }
                    if let (Some(deadline), Some(every)) = (next_pull, pull_every) {
                        if now >= deadline {
                            if let Some(opts) = hub_retry.as_ref() {
                                // one immediate attempt — a still-down
                                // broker must not stall queued calls
                                let once =
                                    HubOptions { connect_retries: 0, ..opts.clone() };
                                match HubClient::connect(once) {
                                    Ok(client) => {
                                        dispatcher.attach_hub(client);
                                        hub_retry = None;
                                        log::info!("hub: connected after retry");
                                    }
                                    Err(e) => log::debug!("hub: still unreachable: {e}"),
                                }
                            }
                            if dispatcher.hub_active() {
                                if let Err(e) = dispatcher.hub_pull() {
                                    log::warn!("hub: periodic pull failed: {e}");
                                }
                            }
                            next_pull = Some(now + every);
                        }
                    }
                    let Some(first) = first else { continue 'serve };
                    // Drain a scheduling round: the blocking head request
                    // plus whatever queued behind it, up to max_batch.
                    let mut round = vec![first];
                    while round.len() < max_batch {
                        match rx.try_recv() {
                            Ok(req) => round.push(req),
                            Err(_) => break,
                        }
                    }
                    let depth = round.len();
                    // Reorder within the round: cheap read-ish control
                    // requests (tuned-value probes, stats, hub pulls,
                    // state saves) answer *before* any kernel call, so a
                    // slow explore measurement queued ahead of them never
                    // delays introspection replies. Calls — and Retunes,
                    // which mutate tuner state and must not overtake a
                    // call queued before them — keep their arrival order:
                    // runs of same-kernel calls dispatch as fused
                    // batches, flushed around each Retune.
                    let mut calls: Vec<Deferred> = Vec::new();
                    let mut shutdown = false;
                    let mut hub_notified = false;
                    let dequeued = Instant::now();
                    for req in round {
                        match req {
                            Request::Call { kernel, inputs, deadline, enqueued, reply } => {
                                // Shed before execute: a call whose
                                // budget died in the queue (or that
                                // outsat the shed policy's queue-wait
                                // bound) must not burn leader time — the
                                // caller has given up (or will, the
                                // instant this reply lands).
                                if let Some(d) = deadline {
                                    if dequeued >= d {
                                        let _ = reply.send(Err(Error::DeadlineExceeded {
                                            kernel,
                                            deadline: d.saturating_duration_since(enqueued),
                                        }));
                                        continue;
                                    }
                                }
                                if let Some(shed) = shed_policy {
                                    let waited = dequeued.saturating_duration_since(enqueued);
                                    if waited > shed.max_queue_wait {
                                        let _ = reply.send(Err(Error::Overloaded(format!(
                                            "{kernel}: queued {}ms (max {}ms)",
                                            waited.as_millis(),
                                            shed.max_queue_wait.as_millis()
                                        ))));
                                        continue;
                                    }
                                }
                                calls.push(Deferred::Call(kernel, inputs, reply));
                            }
                            Request::TunedValue { kernel, size, reply } => {
                                let _ = reply.send(dispatcher.tuned_value(&kernel, size));
                            }
                            Request::Retune { kernel, size, reply } => {
                                calls.push(Deferred::Retune { kernel, size, reply });
                            }
                            Request::Stats { reply } => {
                                dispatcher.stats_mut().set_resilience(
                                    leader_gauge.shed.load(Ordering::Relaxed),
                                    leader_gauge.deadline_exceeded.load(Ordering::Relaxed),
                                );
                                let lane_render =
                                    dispatcher.fast_lane().map(|l| l.render()).unwrap_or_default();
                                let pool_render =
                                    dispatcher.pool().map(|p| p.render()).unwrap_or_default();
                                let rendered = format!(
                                    "{}cache: {:?}\n{}{}",
                                    dispatcher.stats().render(),
                                    dispatcher.cache_stats(),
                                    lane_render,
                                    pool_render
                                );
                                let _ = reply.send((rendered, dispatcher.tuning_report()));
                            }
                            Request::StatsJson { reply } => {
                                dispatcher.stats_mut().set_resilience(
                                    leader_gauge.shed.load(Ordering::Relaxed),
                                    leader_gauge.deadline_exceeded.load(Ordering::Relaxed),
                                );
                                let mut obj =
                                    vec![("kernels".to_string(), dispatcher.stats().to_json())];
                                if let Some(lane) = dispatcher.fast_lane() {
                                    obj.push(("fast_lane".to_string(), lane.to_json()));
                                }
                                if let Some(pool) = dispatcher.pool() {
                                    obj.push(("pool".to_string(), pool.to_json()));
                                }
                                if !dispatcher.stats().drift_events().is_empty() {
                                    obj.push((
                                        "drift_events".to_string(),
                                        dispatcher.stats().drift_events_json(),
                                    ));
                                }
                                if !dispatcher.stats().quarantine_events().is_empty() {
                                    obj.push((
                                        "quarantine_events".to_string(),
                                        dispatcher.stats().quarantine_events_json(),
                                    ));
                                }
                                let res = dispatcher.stats().resilience();
                                if res.shed + res.deadline_exceeded > 0 {
                                    obj.push((
                                        "resilience".to_string(),
                                        dispatcher.stats().resilience_json(),
                                    ));
                                }
                                if dispatcher.hub_active() {
                                    obj.push(("hub".to_string(), dispatcher.stats().hub_json()));
                                }
                                if dispatcher.stats().fused().fused_rounds > 0 {
                                    obj.push((
                                        "fused".to_string(),
                                        dispatcher.stats().fused_json(),
                                    ));
                                }
                                if dispatcher.background_active() {
                                    obj.push((
                                        "background".to_string(),
                                        dispatcher.stats().background_json(),
                                    ));
                                }
                                let _ = reply.send(Value::Obj(obj));
                            }
                            Request::HubPull { reply } => {
                                let _ = reply.send(dispatcher.hub_pull());
                            }
                            Request::SaveState { path, reply } => {
                                let _ = reply.send(dispatcher.save_state(&path));
                            }
                            Request::ExploreDone(result) => {
                                dispatcher.background_report(result);
                            }
                            Request::HubNotify => hub_notified = true,
                            Request::Shutdown => shutdown = true,
                        }
                    }
                    // Push-notified pull: one pull per round no matter
                    // how many notifies queued, and *before* the fused
                    // call dispatch so calls in this round already see
                    // freshly adopted winners.
                    if hub_notified && dispatcher.hub_active() {
                        match dispatcher.hub_pull() {
                            Ok((adopted, _)) if adopted > 0 => {
                                log::debug!("hub: push-notified pull adopted {adopted}")
                            }
                            Ok(_) => {}
                            Err(e) => log::warn!("hub: push-notified pull failed: {e}"),
                        }
                    }
                    // Fused dispatch: runs of same-kernel calls go down
                    // as single batches — co-scheduled exploration
                    // candidates execute back-to-back and report together
                    // (see `Dispatcher::call_batch`). Reply routing stays
                    // per caller; a Retune flushes the calls queued
                    // before it, then applies.
                    let mut run: Vec<CallItem> = Vec::new();
                    for item in calls {
                        match item {
                            Deferred::Call(kernel, inputs, reply) => {
                                run.push((kernel, inputs, reply));
                            }
                            Deferred::Retune { kernel, size, reply } => {
                                flush_call_run(&mut dispatcher, depth, &mut run);
                                let _ = reply.send(dispatcher.retune(&kernel, size));
                            }
                        }
                    }
                    flush_call_run(&mut dispatcher, depth, &mut run);
                    if shutdown {
                        break 'serve;
                    }
                }
            })
            .map_err(|e| {
                if let Some(pool) = &pool {
                    pool.stop();
                }
                if let Some(sp) = &shadow_pool {
                    sp.stop();
                }
                Error::Coordinator(format!("spawn: {e}"))
            })?;
        let ready = ready_rx
            // jitune-lint: allow(L006): init handshake — the leader sends exactly once
            // before its loop and its thread death drops the sender, disconnecting this
            .recv()
            .map_err(|_| Error::Coordinator("leader died during init".into()))
            .and_then(|r| r);
        if let Err(e) = ready {
            // the leader is exiting (or gone); reap it and the workers
            // jitune-lint: allow(L006): init-failure reap — the leader already reported
            // its error over the ready channel, so its loop has exited and the join returns
            let _ = join.join();
            if let Some(pool) = &pool {
                pool.stop();
            }
            if let Some(sp) = &shadow_pool {
                sp.stop();
            }
            if let Some(fwd) = forwarder.take() {
                // jitune-lint: allow(L006): init-failure reap — the dead leader and
                // stopped pools dropped the forwarder's senders, so its loop has exited
                let _ = fwd.join();
            }
            return Err(e);
        }
        // Hub push-notify: a dedicated thread holds the subscribed
        // connection and nudges the leader (Request::HubNotify) on every
        // broker push. Reconnects with bounded backoff; checks its stop
        // flag between waits so shutdown stays prompt. Its failure to
        // spawn degrades propagation to the pull fallback — never the
        // coordinator.
        let notifier_stop = Arc::new(AtomicBool::new(false));
        let notifier = match notify_opts {
            None => None,
            Some(sub_opts) => {
                let stop = Arc::clone(&notifier_stop);
                let notify_tx = tx.clone();
                let spawned = std::thread::Builder::new().name("jitune-hub-notify".into()).spawn(
                    move || {
                        // single connect attempt per cycle: the backoff
                        // loop below owns the retry cadence (and the
                        // stop checks)
                        let once = HubOptions { connect_retries: 0, ..sub_opts };
                        loop {
                            if stop.load(Ordering::Acquire) {
                                return;
                            }
                            if let Ok(mut sub) = HubSubscriber::connect(&once) {
                                // the snapshot itself is adopted through
                                // the leader's validated pull; one nudge
                                // covers pushes missed while disconnected
                                let _ = sub.take_initial();
                                if notify_tx.send(Request::HubNotify).is_err() {
                                    return;
                                }
                                loop {
                                    if stop.load(Ordering::Acquire) {
                                        return;
                                    }
                                    match sub.next(Duration::from_millis(200)) {
                                        Ok(None) => continue,
                                        Ok(Some(_)) => {
                                            if notify_tx.send(Request::HubNotify).is_err() {
                                                return;
                                            }
                                        }
                                        Err(e) => {
                                            log::debug!(
                                                "hub: push channel lost ({e}); resubscribing"
                                            );
                                            break;
                                        }
                                    }
                                }
                            }
                            for _ in 0..10 {
                                if stop.load(Ordering::Acquire) {
                                    return;
                                }
                                std::thread::sleep(Duration::from_millis(50));
                            }
                        }
                    },
                );
                match spawned {
                    Ok(handle) => Some(handle),
                    Err(e) => {
                        log::warn!("hub: notifier spawn failed ({e}); falling back to pulls");
                        None
                    }
                }
            }
        };
        Ok(Coordinator {
            tx,
            join: Some(join),
            fast_lane: lane,
            pool,
            shadow_pool,
            forwarder,
            notifier,
            notifier_stop,
            gauge,
            call_deadline: opts.call_deadline,
            shed: opts.shed,
        })
    }

    /// A new handle for this coordinator.
    pub fn handle(&self) -> CoordinatorHandle {
        CoordinatorHandle {
            tx: self.tx.clone(),
            fast_lane: self.fast_lane.clone(),
            pool: self.pool.clone(),
            gauge: Arc::clone(&self.gauge),
            call_deadline: self.call_deadline,
            shed: self.shed,
        }
    }

    /// Graceful shutdown (also triggered by Drop): stop the leader, then
    /// the worker pool — queued pool jobs drain before the threads join.
    /// The explore-result forwarder joins last: once the leader (holding
    /// the scheduler's reply sender) is gone and the pools have dropped
    /// their queued jobs, its channel disconnects and it exits.
    pub fn shutdown(&mut self) {
        // flag the notifier first so it winds down while the leader
        // drains; it is joined after the leader below
        self.notifier_stop.store(true, Ordering::Release);
        let _ = self.tx.send(Request::Shutdown);
        if let Some(join) = self.join.take() {
            // jitune-lint: allow(L006): shutdown join — Request::Shutdown (or the
            // disconnect when this last handle drops) makes the leader loop exit
            let _ = join.join();
        }
        if let Some(notifier) = self.notifier.take() {
            // jitune-lint: allow(L006): shutdown join — the stop flag stored above is
            // checked between the notifier's bounded waits, so its loop exits promptly
            let _ = notifier.join();
        }
        if let Some(pool) = &self.pool {
            pool.stop();
        }
        if let Some(pool) = &self.shadow_pool {
            pool.stop();
        }
        if let Some(fwd) = self.forwarder.take() {
            // jitune-lint: allow(L006): shutdown join — the joined leader and stopped
            // pools dropped the forwarder's senders, so its channel disconnected
            let _ = fwd.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::registry::KernelRegistry;
    use crate::coordinator::CallRoute;
    use crate::runtime::mock::{MockEngine, MockSpec};
    use std::time::Duration;

    fn spawn_mock(spec: MockSpec) -> Coordinator {
        Coordinator::spawn(move || {
            let manifest = crate::manifest::tests::sample_manifest()?;
            let registry = KernelRegistry::new(manifest);
            Ok(Dispatcher::new(registry, Box::new(MockEngine::new(spec))))
        })
        .unwrap()
    }

    fn spawn_mock_with(spec: MockSpec, opts: ServerOptions) -> Coordinator {
        Coordinator::spawn_with_options(
            move || {
                let manifest = crate::manifest::tests::sample_manifest()?;
                let registry = KernelRegistry::new(manifest);
                Ok(Dispatcher::new(registry, Box::new(MockEngine::new(spec))))
            },
            opts,
        )
        .unwrap()
    }

    #[test]
    fn serves_calls_from_multiple_threads() {
        let spec = MockSpec::default()
            .with_cost("k.a.n8", Duration::from_micros(400))
            .with_cost("k.b.n8", Duration::from_micros(40));
        let coord = spawn_mock(spec);
        let mut joins = Vec::new();
        for t in 0..4 {
            let h = coord.handle();
            joins.push(std::thread::spawn(move || {
                let mut values = Vec::new();
                for _ in 0..5 {
                    let out = h.call("k", vec![HostTensor::zeros(&[8, 8])]).unwrap();
                    values.push(out.value);
                }
                (t, values)
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        // after 20 calls tuning is long done; winner is the fast variant
        let tuned = coord.handle().tuned_value("k", 8).unwrap();
        assert_eq!(tuned, Some(2));
    }

    #[test]
    fn stats_reachable_through_handle() {
        let coord = spawn_mock(MockSpec::default());
        let h = coord.handle();
        for _ in 0..4 {
            h.call("k", vec![HostTensor::zeros(&[8, 8])]).unwrap();
        }
        let (rendered, report) = h.stats().unwrap();
        assert!(rendered.contains("k:"), "{rendered}");
        assert!(rendered.contains("fast lane:"), "{rendered}");
        assert!(report.as_obj().is_some());
    }

    #[test]
    fn save_state_through_handle() {
        let coord = spawn_mock(MockSpec::default());
        let h = coord.handle();
        for _ in 0..4 {
            h.call("k", vec![HostTensor::zeros(&[8, 8])]).unwrap();
        }
        let path = crate::testutil::temp_path("srv-state", "json");
        assert_eq!(h.save_state(&path).unwrap(), 1, "tuned problem persisted");
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn factory_failure_propagates() {
        let result = Coordinator::spawn(|| Err(Error::Coordinator("nope".into())));
        assert!(result.is_err());
    }

    #[test]
    fn shutdown_then_call_errors() {
        let mut coord = spawn_mock(MockSpec::default());
        let h = coord.handle();
        coord.shutdown();
        assert!(h.call("k", vec![HostTensor::zeros(&[8, 8])]).is_err());
    }

    #[test]
    fn errors_propagate_to_caller() {
        let coord = spawn_mock(MockSpec::default());
        let h = coord.handle();
        assert!(h.call("unknown", vec![]).is_err());
    }

    #[test]
    fn burst_load_records_scheduling_rounds() {
        let spec = MockSpec::default();
        let coord = Coordinator::spawn_with(
            move || {
                let manifest = crate::manifest::tests::sample_manifest()?;
                let registry = KernelRegistry::new(manifest);
                Ok(Dispatcher::new(registry, Box::new(MockEngine::new(spec))))
            },
            BatchOptions { max_batch: 8 },
        )
        .unwrap();
        // burst: many threads firing concurrently builds queue depth > 1
        let mut joins = Vec::new();
        for _ in 0..8 {
            let h = coord.handle();
            joins.push(std::thread::spawn(move || {
                for _ in 0..10 {
                    h.call("k", vec![HostTensor::zeros(&[8, 8])]).unwrap();
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        let (rendered, _) = coord.handle().stats().unwrap();
        assert!(rendered.contains("scheduling rounds"), "{rendered}");
    }

    #[test]
    fn fast_lane_absorbs_steady_state_calls() {
        let spec = MockSpec::default()
            .with_cost("k.a.n8", Duration::from_micros(400))
            .with_cost("k.b.n8", Duration::from_micros(40));
        let coord = spawn_mock(spec);
        let h = coord.handle();
        assert_eq!(h.fast_lane_published(), 0);
        // 2 explores + 1 finalize completes tuning and publishes
        for _ in 0..3 {
            h.call("k", vec![HostTensor::zeros(&[8, 8])]).unwrap();
        }
        assert_eq!(h.fast_lane_published(), 1);
        let out = h.call("k", vec![HostTensor::zeros(&[8, 8])]).unwrap();
        assert_eq!(out.route, CallRoute::Tuned);
        assert_eq!(out.value, 2);
        let stats = h.fast_lane_stats();
        assert_eq!(stats.len(), 1);
        assert_eq!(stats[0].0, "k");
        assert!(stats[0].1 >= 1, "fast-lane hit recorded: {stats:?}");
        // machine-readable stats expose both lanes
        let json = h.stats_json().unwrap();
        assert!(json.get("kernels").is_some());
        let lane = json.get("fast_lane").unwrap();
        assert_eq!(lane.get("published").unwrap().as_i64(), Some(1));
    }

    #[test]
    fn single_lane_option_disables_fast_lane() {
        let opts = ServerOptions { fast_lane: false, ..ServerOptions::default() };
        let coord = spawn_mock_with(MockSpec::default(), opts);
        let h = coord.handle();
        for _ in 0..5 {
            h.call("k", vec![HostTensor::zeros(&[8, 8])]).unwrap();
        }
        assert_eq!(h.fast_lane_published(), 0);
        assert!(h.fast_lane_stats().is_empty());
        let json = h.stats_json().unwrap();
        assert!(json.get("fast_lane").is_none());
        // steady state still works, just through the leader
        let out = h.call("k", vec![HostTensor::zeros(&[8, 8])]).unwrap();
        assert_eq!(out.route, CallRoute::Tuned);
    }

    #[test]
    fn drift_without_fast_lane_is_ignored() {
        // the drift signal comes from fast-lane windows; without a lane
        // the policy is inert and serving is unchanged
        let opts = ServerOptions {
            fast_lane: false,
            drift: Some(DriftPolicy {
                window: Duration::from_millis(20),
                ..DriftPolicy::default()
            }),
            ..ServerOptions::default()
        };
        let coord = spawn_mock_with(MockSpec::default(), opts);
        let h = coord.handle();
        for _ in 0..5 {
            h.call("k", vec![HostTensor::zeros(&[8, 8])]).unwrap();
        }
        std::thread::sleep(Duration::from_millis(60)); // a few idle ticks
        let json = h.stats_json().unwrap();
        assert!(json.get("fast_lane").is_none());
        assert!(json.get("drift_events").is_none());
        let out = h.call("k", vec![HostTensor::zeros(&[8, 8])]).unwrap();
        assert_eq!(out.route, CallRoute::Tuned);
    }

    #[test]
    fn idle_leader_with_drift_policy_stays_responsive() {
        // drift enabled: the leader uses recv_timeout wake-ups; requests
        // arriving between ticks must still be served promptly and
        // shutdown must still terminate the thread
        let opts = ServerOptions {
            drift: Some(DriftPolicy {
                window: Duration::from_millis(10),
                ..DriftPolicy::default()
            }),
            ..ServerOptions::default()
        };
        let mut coord = spawn_mock_with(MockSpec::default(), opts);
        let h = coord.handle();
        for _ in 0..4 {
            h.call("k", vec![HostTensor::zeros(&[8, 8])]).unwrap();
        }
        std::thread::sleep(Duration::from_millis(50)); // leader ticks while idle
        let out = h.call("k", vec![HostTensor::zeros(&[8, 8])]).unwrap();
        assert_eq!(out.route, CallRoute::Tuned);
        coord.shutdown();
        // leader-lane operations fail once the loop exited (fast-lane
        // hits intentionally keep serving off the published entry)
        assert!(h.stats().is_err());
    }

    #[test]
    fn pool_without_fast_lane_is_ignored() {
        use crate::coordinator::pool::PoolOptions;
        use crate::runtime::mock::MockEngineFactory;
        let spec = MockSpec::default();
        let factory = Arc::new(MockEngineFactory::pinned(spec.clone()));
        let opts = ServerOptions {
            fast_lane: false,
            pool: Some(PoolOptions::new(factory).with_workers(2)),
            ..ServerOptions::default()
        };
        let coord = spawn_mock_with(spec, opts);
        let h = coord.handle();
        for _ in 0..5 {
            h.call("k", vec![HostTensor::zeros(&[8, 8])]).unwrap();
        }
        assert!(h.pool_snapshot().is_none(), "pool not spawned without a lane");
        assert!(h.stats_json().unwrap().get("pool").is_none());
        let out = h.call("k", vec![HostTensor::zeros(&[8, 8])]).unwrap();
        assert_eq!(out.route, CallRoute::Tuned, "leader keeps serving");
    }

    #[test]
    fn pooled_spawn_serves_thread_pinned_engines_off_leader() {
        use crate::coordinator::pool::PoolOptions;
        use crate::runtime::mock::MockEngineFactory;
        use crate::runtime::EngineFactory;
        let spec = MockSpec::default()
            .with_cost("k.a.n8", Duration::from_micros(400))
            .with_cost("k.b.n8", Duration::from_micros(40));
        let factory = Arc::new(MockEngineFactory::pinned(spec));
        let leader_factory: Arc<dyn EngineFactory> = factory.clone();
        let mut coord = Coordinator::spawn_with_options(
            move || {
                let manifest = crate::manifest::tests::sample_manifest()?;
                let registry = KernelRegistry::new(manifest);
                Ok(Dispatcher::new(registry, leader_factory.create()?))
            },
            ServerOptions {
                pool: Some(PoolOptions::new(factory).with_workers(2)),
                ..ServerOptions::default()
            },
        )
        .unwrap();
        let h = coord.handle();
        for _ in 0..3 {
            h.call("k", vec![HostTensor::zeros(&[8, 8])]).unwrap();
        }
        assert_eq!(h.fast_lane_published(), 1, "pool-routed entry published");
        let out = h.call("k", vec![HostTensor::zeros(&[8, 8])]).unwrap();
        assert_eq!(out.route, CallRoute::Tuned);
        assert_eq!(out.value, 2);
        let snap = h.pool_snapshot().expect("pool attached");
        assert_eq!(snap.workers.len(), 2);
        assert_eq!(snap.total_executed(), 1, "the tuned call ran on a worker");
        let json = h.stats_json().unwrap();
        assert_eq!(json.get("pool").unwrap().get("workers").unwrap().as_i64(), Some(2));
        let (rendered, _) = h.stats().unwrap();
        assert!(rendered.contains("worker pool"), "{rendered}");
        coord.shutdown(); // joins leader + workers; no leaked threads
    }

    #[test]
    fn retune_through_handle_invalidates_and_reexplores() {
        let spec = MockSpec::default()
            .with_cost("k.a.n8", Duration::from_micros(400))
            .with_cost("k.b.n8", Duration::from_micros(40));
        let coord = spawn_mock(spec);
        let h = coord.handle();
        for _ in 0..4 {
            h.call("k", vec![HostTensor::zeros(&[8, 8])]).unwrap();
        }
        assert_eq!(h.fast_lane_published(), 1);
        assert!(h.retune("k", 8).unwrap());
        assert_eq!(h.fast_lane_published(), 0);
        assert_eq!(h.tuned_value("k", 8).unwrap(), None);
        let out = h.call("k", vec![HostTensor::zeros(&[8, 8])]).unwrap();
        assert_eq!(out.route, CallRoute::Explored, "retuned problem re-explores");
        // finish retuning: winner republished
        for _ in 0..2 {
            h.call("k", vec![HostTensor::zeros(&[8, 8])]).unwrap();
        }
        assert_eq!(h.fast_lane_published(), 1);
        assert_eq!(h.tuned_value("k", 8).unwrap(), Some(2));
    }

    #[test]
    fn call_deadline_bounds_wedged_calls() {
        // every execution sleeps 50ms; a 10ms deadline must release the
        // caller early with DeadlineExceeded instead of making it wait
        let spec = MockSpec {
            default_exec_cost: Duration::from_millis(50),
            ..MockSpec::default()
        }
        .with_sleep_exec();
        let opts = ServerOptions {
            call_deadline: Some(Duration::from_millis(10)),
            ..ServerOptions::default()
        };
        let coord = spawn_mock_with(spec, opts);
        let h = coord.handle();
        let t0 = Instant::now();
        let err = h.call("k", vec![HostTensor::zeros(&[8, 8])]).unwrap_err();
        assert!(
            matches!(err, Error::DeadlineExceeded { .. }),
            "expected deadline error, got: {err}"
        );
        assert!(
            t0.elapsed() < Duration::from_millis(45),
            "caller released well before the 50ms execution finished"
        );
        // the straggler's reply lands in a dropped channel; the leader
        // stays healthy and the miss is counted
        let json = h.stats_json().unwrap();
        let res = json.get("resilience").expect("resilience counters exported");
        assert_eq!(res.get("deadline_exceeded").unwrap().as_i64(), Some(1));
        assert_eq!(res.get("shed").unwrap().as_i64(), Some(0));
    }

    #[test]
    fn overload_burst_sheds_instead_of_queueing() {
        let spec = MockSpec {
            default_exec_cost: Duration::from_millis(60),
            ..MockSpec::default()
        }
        .with_sleep_exec();
        let opts = ServerOptions {
            shed: Some(ShedPolicy {
                max_inflight: 1,
                max_queue_wait: Duration::from_secs(5),
            }),
            ..ServerOptions::default()
        };
        let coord = spawn_mock_with(spec, opts);
        let h = coord.handle();
        let wedger = coord.handle();
        let t = std::thread::spawn(move || {
            // occupies the single in-flight slot for ~60ms
            let _ = wedger.call("k", vec![HostTensor::zeros(&[8, 8])]);
        });
        std::thread::sleep(Duration::from_millis(20)); // wedger admitted
        let err = h.call("k", vec![HostTensor::zeros(&[8, 8])]).unwrap_err();
        assert!(matches!(err, Error::Overloaded(_)), "expected shed, got: {err}");
        t.join().unwrap();
        // the slot freed once the wedger finished: calls admit again
        h.call("k", vec![HostTensor::zeros(&[8, 8])]).unwrap();
        let json = h.stats_json().unwrap();
        let res = json.get("resilience").expect("resilience counters exported");
        assert_eq!(res.get("shed").unwrap().as_i64(), Some(1));
    }

    #[test]
    fn quarantine_demotes_erroring_winner_and_serves_fallback() {
        let spec = MockSpec::default()
            .with_cost("k.a.n8", Duration::from_micros(400))
            .with_cost("k.b.n8", Duration::from_micros(40));
        let fault = spec.latency_fault.clone();
        let opts = ServerOptions {
            quarantine: Some(QuarantinePolicy {
                window: Duration::from_millis(20),
                min_samples: 4,
                error_threshold: 0.5,
                consecutive_windows: 1,
                cooldown: Duration::ZERO,
                ..QuarantinePolicy::default()
            }),
            ..ServerOptions::default()
        };
        let coord = spawn_mock_with(spec, opts);
        let h = coord.handle();
        for _ in 0..3 {
            h.call("k", vec![HostTensor::zeros(&[8, 8])]).unwrap();
        }
        assert_eq!(h.tuned_value("k", 8).unwrap(), Some(2), "fast variant wins");
        // the published winner starts erroring at runtime; with a breaker
        // armed the errors return to callers (no one-strike eviction)
        // while the sliding window accumulates
        fault.fail_execute("k.b.n8");
        for _ in 0..6 {
            h.call("k", vec![HostTensor::zeros(&[8, 8])]).unwrap_err();
        }
        // within a couple of breaker windows the leader's scan trips,
        // demotes the winner and republishes the next-best variant
        let mut demoted = false;
        for _ in 0..50 {
            std::thread::sleep(Duration::from_millis(10));
            if h.tuned_value("k", 8).unwrap() == Some(1) {
                demoted = true;
                break;
            }
        }
        assert!(demoted, "winner demoted to fallback within the breaker window");
        let out = h.call("k", vec![HostTensor::zeros(&[8, 8])]).unwrap();
        assert_eq!(out.value, 1, "fallback variant serves");
        let json = h.stats_json().unwrap();
        let events = json.get("quarantine_events").expect("quarantine event exported");
        let list = events.as_arr().unwrap();
        assert_eq!(list.len(), 1);
        assert_eq!(list[0].get("variant_id").unwrap().as_str(), Some("k.b.n8"));
    }
}
