//! Threaded coordinator: leader thread owning the dispatcher, serving
//! requests from any number of application threads.
//!
//! PJRT clients are thread-pinned (`Rc` internally), so the dispatcher
//! lives on one leader thread. Application threads hold cloneable
//! [`CoordinatorHandle`]s and submit calls over an mpsc channel; replies
//! come back on per-request rendezvous channels. The single consumer
//! serializes JIT compilations, providing the paper's "compilation is
//! protected by a mutex" guarantee at the channel boundary — and the
//! tuner observes executions under real cross-request contention, which
//! is exactly the paper's argument for *online* tuning.

use std::sync::mpsc;
use std::thread::JoinHandle;

use crate::coordinator::dispatcher::{CallOutcome, Dispatcher};
use crate::error::{Error, Result};
use crate::tensor::HostTensor;
use crate::util::json::Value;

enum Request {
    Call {
        kernel: String,
        inputs: Vec<HostTensor>,
        reply: mpsc::SyncSender<Result<CallOutcome>>,
    },
    TunedValue {
        kernel: String,
        size: i64,
        reply: mpsc::SyncSender<Option<i64>>,
    },
    Stats {
        reply: mpsc::SyncSender<(String, Value)>,
    },
    Shutdown,
}

/// Cloneable, `Send` handle for submitting kernel calls to the leader.
#[derive(Clone)]
pub struct CoordinatorHandle {
    tx: mpsc::Sender<Request>,
}

impl CoordinatorHandle {
    /// Dispatch a kernel call and wait for its result.
    pub fn call(&self, kernel: &str, inputs: Vec<HostTensor>) -> Result<CallOutcome> {
        let (reply, rx) = mpsc::sync_channel(1);
        self.tx
            .send(Request::Call { kernel: kernel.to_string(), inputs, reply })
            .map_err(|_| Error::Coordinator("coordinator stopped".into()))?;
        rx.recv().map_err(|_| Error::Coordinator("coordinator dropped reply".into()))?
    }

    /// Tuned parameter value for a problem, if tuning completed.
    pub fn tuned_value(&self, kernel: &str, size: i64) -> Result<Option<i64>> {
        let (reply, rx) = mpsc::sync_channel(1);
        self.tx
            .send(Request::TunedValue { kernel: kernel.to_string(), size, reply })
            .map_err(|_| Error::Coordinator("coordinator stopped".into()))?;
        rx.recv().map_err(|_| Error::Coordinator("coordinator dropped reply".into()))
    }

    /// Rendered stats + JSON tuning report.
    pub fn stats(&self) -> Result<(String, Value)> {
        let (reply, rx) = mpsc::sync_channel(1);
        self.tx
            .send(Request::Stats { reply })
            .map_err(|_| Error::Coordinator("coordinator stopped".into()))?;
        rx.recv().map_err(|_| Error::Coordinator("coordinator dropped reply".into()))
    }
}

/// Batching policy for the leader loop.
#[derive(Debug, Clone, Copy)]
pub struct BatchOptions {
    /// Maximum requests drained from the queue per scheduling round.
    /// Draining lets the leader observe queue depth (admission stats)
    /// and keeps reply latency fair under burst load.
    pub max_batch: usize,
}

impl Default for BatchOptions {
    fn default() -> Self {
        BatchOptions { max_batch: 16 }
    }
}

/// The running coordinator (leader thread + handle factory).
pub struct Coordinator {
    tx: mpsc::Sender<Request>,
    join: Option<JoinHandle<()>>,
}

impl Coordinator {
    /// Spawn with default batching.
    pub fn spawn<F>(factory: F) -> Result<Coordinator>
    where
        F: FnOnce() -> Result<Dispatcher> + Send + 'static,
    {
        Coordinator::spawn_with(factory, BatchOptions::default())
    }

    /// Spawn the leader thread around a dispatcher factory.
    ///
    /// The factory runs *on the leader thread* because PJRT clients must
    /// be created on the thread that uses them.
    pub fn spawn_with<F>(factory: F, batch: BatchOptions) -> Result<Coordinator>
    where
        F: FnOnce() -> Result<Dispatcher> + Send + 'static,
    {
        let max_batch = batch.max_batch.max(1);
        let (tx, rx) = mpsc::channel::<Request>();
        let (ready_tx, ready_rx) = mpsc::sync_channel::<Result<()>>(1);
        let join = std::thread::Builder::new()
            .name("jitune-leader".into())
            .spawn(move || {
                let mut dispatcher = match factory() {
                    Ok(d) => {
                        let _ = ready_tx.send(Ok(()));
                        d
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                'serve: while let Ok(first) = rx.recv() {
                    // Drain a scheduling round: the blocking head request
                    // plus whatever queued behind it, up to max_batch.
                    let mut round = vec![first];
                    while round.len() < max_batch {
                        match rx.try_recv() {
                            Ok(req) => round.push(req),
                            Err(_) => break,
                        }
                    }
                    let depth = round.len();
                    for req in round {
                        match req {
                            Request::Call { kernel, inputs, reply } => {
                                dispatcher.stats_mut().enqueue_round(depth);
                                let result = dispatcher.call(&kernel, &inputs);
                                let _ = reply.send(result);
                            }
                            Request::TunedValue { kernel, size, reply } => {
                                let _ = reply.send(dispatcher.tuned_value(&kernel, size));
                            }
                            Request::Stats { reply } => {
                                let rendered = format!(
                                    "{}cache: {:?}\n",
                                    dispatcher.stats().render(),
                                    dispatcher.cache_stats()
                                );
                                let _ = reply.send((rendered, dispatcher.tuning_report()));
                            }
                            Request::Shutdown => break 'serve,
                        }
                    }
                }
            })
            .map_err(|e| Error::Coordinator(format!("spawn: {e}")))?;
        ready_rx
            .recv()
            .map_err(|_| Error::Coordinator("leader died during init".into()))??;
        Ok(Coordinator { tx, join: Some(join) })
    }

    /// A new handle for this coordinator.
    pub fn handle(&self) -> CoordinatorHandle {
        CoordinatorHandle { tx: self.tx.clone() }
    }

    /// Graceful shutdown (also triggered by Drop).
    pub fn shutdown(&mut self) {
        let _ = self.tx.send(Request::Shutdown);
        if let Some(join) = self.join.take() {
            let _ = join.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::registry::KernelRegistry;
    use crate::runtime::mock::{MockEngine, MockSpec};
    use std::time::Duration;

    fn spawn_mock(spec: MockSpec) -> Coordinator {
        Coordinator::spawn(move || {
            let manifest = crate::manifest::tests::sample_manifest()?;
            let registry = KernelRegistry::new(manifest);
            Ok(Dispatcher::new(registry, Box::new(MockEngine::new(spec))))
        })
        .unwrap()
    }

    #[test]
    fn serves_calls_from_multiple_threads() {
        let spec = MockSpec::default()
            .with_cost("k.a.n8", Duration::from_micros(400))
            .with_cost("k.b.n8", Duration::from_micros(40));
        let coord = spawn_mock(spec);
        let mut joins = Vec::new();
        for t in 0..4 {
            let h = coord.handle();
            joins.push(std::thread::spawn(move || {
                let mut values = Vec::new();
                for _ in 0..5 {
                    let out = h.call("k", vec![HostTensor::zeros(&[8, 8])]).unwrap();
                    values.push(out.value);
                }
                (t, values)
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        // after 20 calls tuning is long done; winner is the fast variant
        let tuned = coord.handle().tuned_value("k", 8).unwrap();
        assert_eq!(tuned, Some(2));
    }

    #[test]
    fn stats_reachable_through_handle() {
        let coord = spawn_mock(MockSpec::default());
        let h = coord.handle();
        for _ in 0..4 {
            h.call("k", vec![HostTensor::zeros(&[8, 8])]).unwrap();
        }
        let (rendered, report) = h.stats().unwrap();
        assert!(rendered.contains("k:"), "{rendered}");
        assert!(report.as_obj().is_some());
    }

    #[test]
    fn factory_failure_propagates() {
        let result = Coordinator::spawn(|| Err(Error::Coordinator("nope".into())));
        assert!(result.is_err());
    }

    #[test]
    fn shutdown_then_call_errors() {
        let mut coord = spawn_mock(MockSpec::default());
        let h = coord.handle();
        coord.shutdown();
        assert!(h.call("k", vec![HostTensor::zeros(&[8, 8])]).is_err());
    }

    #[test]
    fn errors_propagate_to_caller() {
        let coord = spawn_mock(MockSpec::default());
        let h = coord.handle();
        assert!(h.call("unknown", vec![]).is_err());
    }

    #[test]
    fn burst_load_records_scheduling_rounds() {
        let spec = MockSpec::default();
        let coord = Coordinator::spawn_with(
            move || {
                let manifest = crate::manifest::tests::sample_manifest()?;
                let registry = KernelRegistry::new(manifest);
                Ok(Dispatcher::new(registry, Box::new(MockEngine::new(spec))))
            },
            BatchOptions { max_batch: 8 },
        )
        .unwrap();
        // burst: many threads firing concurrently builds queue depth > 1
        let mut joins = Vec::new();
        for _ in 0..8 {
            let h = coord.handle();
            joins.push(std::thread::spawn(move || {
                for _ in 0..10 {
                    h.call("k", vec![HostTensor::zeros(&[8, 8])]).unwrap();
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        let (rendered, _) = coord.handle().stats().unwrap();
        assert!(rendered.contains("scheduling rounds"), "{rendered}");
    }
}
