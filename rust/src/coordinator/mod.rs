//! The run-time coordinator: registry, dispatcher, threaded server, and
//! the tuned-path fast lane.
//!
//! The [`Dispatcher`] is the heart of the system — the piece that plays
//! ClangJIT's `__clang_jit` role with autotuning folded in (paper §3.2):
//! every kernel call consults the [`crate::autotuner::TuningState`] for
//! its problem, JIT-compiles whatever variant the tuner asks for,
//! measures tuning iterations, finalizes the winner into the
//! instantiation cache, and routes steady-state calls to it.
//!
//! # Two-lane architecture
//!
//! [`server::Coordinator`] serves application threads through two lanes:
//!
//! * **Leader lane** — a dedicated leader thread owns the dispatcher
//!   (PJRT clients are thread-pinned) and drains an mpsc request queue.
//!   Every call that *tunes* — exploration, the winner's final
//!   compilation, retuned problems — takes this lane, so compilation and
//!   measurement stay serialized: the paper's "compilation is protected
//!   by a mutex" guarantee, enforced at the channel boundary, with the
//!   tuner observing executions under real cross-request contention.
//!
//! * **Tuned fast lane** — when a problem reaches `Phase::Tuned`, the
//!   leader publishes an immutable [`fastlane::TunedEntry`] (winning
//!   variant + an `Arc`'d `Send + Sync` executable handle) into the
//!   shared [`FastLane`] map. [`server::CoordinatorHandle::call`]
//!   consults that map *before* touching the channel; hits execute right
//!   on the calling thread and record latency into sharded atomic
//!   counters, so steady-state throughput scales with application
//!   threads instead of being capped at one leader-serialized call at a
//!   time.
//!
//! **Publication protocol.** Publish happens on `confirm_finalized`
//! (plus a lazy self-heal on leader-lane tuned calls, covering warm
//! starts and lanes attached late). Invalidation happens on retune, on a
//! candidate failure that demotes the winner, on tuning-state import,
//! and on a fast-lane execution failure (the failing call then retries
//! through the leader, so no call is ever lost). Backends whose
//! executables cannot leave the leader thread (PJRT) simply never
//! publish — their steady-state calls keep flowing through the leader,
//! preserving exact pre-fast-lane behaviour.

pub mod fastlane;

mod dispatcher;
mod registry;
pub mod server;
mod stats;

pub use dispatcher::{CallOutcome, CallRoute, Dispatcher};
pub use fastlane::FastLane;
pub use registry::KernelRegistry;
pub use server::{BatchOptions, Coordinator, CoordinatorHandle, ServerOptions};
pub use stats::{CoordStats, KernelStats};
