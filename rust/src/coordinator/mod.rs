//! The run-time coordinator: registry, dispatcher, threaded server, and
//! the tuned-path fast lane.
//!
//! The [`Dispatcher`] is the heart of the system — the piece that plays
//! ClangJIT's `__clang_jit` role with autotuning folded in (paper §3.2):
//! every kernel call consults the [`crate::autotuner::TuningState`] for
//! its problem, JIT-compiles whatever variant the tuner asks for,
//! measures tuning iterations, finalizes the winner into the
//! instantiation cache, and routes steady-state calls to it.
//!
//! # Three-lane architecture
//!
//! [`server::Coordinator`] serves application threads through three
//! lanes, selected per problem by what the backend can offer:
//!
//! * **Leader lane** — a dedicated leader thread owns the dispatcher
//!   (PJRT clients are thread-pinned) and drains an mpsc request queue.
//!   Every call that *tunes* — exploration, the winner's final
//!   compilation, retuned problems — takes this lane, so compilation and
//!   measurement stay serialized: the paper's "compilation is protected
//!   by a mutex" guarantee, enforced at the channel boundary, with the
//!   tuner observing executions under real cross-request contention.
//!
//! * **Shared fast lane** — when a problem reaches `Phase::Tuned` *and*
//!   the engine hands out a `Send + Sync` executable handle, the leader
//!   publishes an immutable [`fastlane::TunedEntry`] (winning variant +
//!   the `Arc`'d handle) into the shared [`FastLane`] map.
//!   [`server::CoordinatorHandle::call`] consults that map *before*
//!   touching the channel; hits execute right on the calling thread and
//!   record latency into sharded atomic counters, so steady-state
//!   throughput scales with application threads instead of being capped
//!   at one leader-serialized call at a time.
//!
//! * **Worker pool** — when the engine's executables are thread-pinned
//!   (`shared()` is `None`, the PJRT shape) and `ServerOptions { pool:
//!   Some(opts) }` is set, finalized winners take the [`pool::WorkerPool`]
//!   instead: N worker threads each own a *private* engine (built by an
//!   [`crate::runtime::EngineFactory`] on the worker's own thread) and a
//!   private compiled copy of every winner (**replicated finalization**:
//!   the leader broadcasts the variant + HLO at publish; each worker
//!   compiles it once). The published entry's executable handle routes
//!   through a sharded MPMC queue to a ready worker, so tuned throughput
//!   scales with workers even though no executable ever crosses a
//!   thread. Lane selection is per entry: shared handle if the engine
//!   offers one, pool route otherwise, leader if neither.
//!
//! # Fused exploration rounds
//!
//! The leader drains its queue in *scheduling rounds* of up to
//! [`server::BatchOptions::max_batch`] requests. Rounds used to be
//! merely observed (queue-depth stats); now they are exploited:
//!
//! * Cheap control requests (tuned-value probes, stats, hub pulls,
//!   state saves) are answered **before** any kernel call in the round,
//!   so a slow explore measurement never delays introspection replies
//!   queued behind it.
//! * Same-problem calls dispatch as one batch
//!   ([`Dispatcher::call_batch`]). For a problem still in
//!   `Phase::Exploring`, the search strategy proposes *multiple*
//!   pending candidates in one shot
//!   (`SearchStrategy::propose_batch` — the paper's in-order sweep and
//!   random search fill the round; sequential heuristics like hill
//!   climbing and annealing keep proposing one), the candidates execute
//!   back-to-back on the warmed engine (compiled once each), and the
//!   whole round reports to the tuning state as a single batch. When
//!   the strategy converges mid-round, the winner is finalized *within
//!   the round* — the next caller already hits the fast lane.
//! * **Replicate-median denoising:** surplus co-scheduled calls (more
//!   callers than pending candidates) re-run a round-mate's candidate,
//!   and the tuner records the replicas' *median* — repeated
//!   observations amortize measurement noise exactly where the
//!   measurement matters, at selection time.
//! * **Failure isolation:** a candidate failing mid-round is excluded
//!   from tuning (as in serial mode) and only its assigned caller(s)
//!   observe the error; round-mates' calls succeed. Lone calls keep the
//!   serial retry-next-candidate contract unchanged.
//!
//! With B co-scheduled callers a sweep over V variants reaches
//! `Phase::Tuned` in ~V/B leader rounds instead of V, so `max_batch`
//! directly bounds time-to-tuned under concurrency — the
//! `benches/time_to_tuned.rs` headline. The saving is accounted in
//! [`CoordStats`] (`fused_rounds`, `fused_calls`,
//! `replicated_measurements`, `explore_rounds_saved`, exported under
//! `"fused"` in `stats_json()`).
//!
//! # Serve/explore split (background shadow exploration)
//!
//! Fused rounds shrink how many rounds tuning takes, but callers in
//! those rounds still *pay* for it: an exploring problem runs compile +
//! measure inline on the caller's critical path, which lands as cold-
//! start p99 spikes under a serving load. With `ServerOptions {
//! explore_budget: Some(ExploreOptions), .. }` the dispatcher splits
//! serving from exploring instead:
//!
//! * **Callers never explore.** Any call to a problem that is not yet
//!   `Phase::Tuned` executes the problem's *current best* — the pending
//!   winner while finalizing, the best measured candidate so far, or
//!   the first runnable variant (the "safe default") when nothing has
//!   been measured yet. The default's one-time bootstrap compile is the
//!   only JIT work a caller can ever observe; such calls are routed
//!   [`CallRoute::Default`] and counted as `serve_while_exploring`.
//! * **Exploration runs as background jobs** on the worker pool's
//!   background job lane (stolen like any job, but always behind
//!   caller-facing work), or on a dedicated one-worker shadow pool
//!   (`ExploreOptions::shadow_factory`) when no pool is configured.
//!   Inputs are synthesized from the problem's declared shapes. Each
//!   result reports asynchronously into the tuning state; the winner's
//!   finalization also happens on the leader with no caller attached.
//! * **A duty-cycle budget** caps explore work at `pct`% of the explore
//!   workers' time per `window` (default 5% / 100ms). Budget interacts
//!   with pool sizing multiplicatively: a 4-worker pool at 5% yields
//!   20ms of explore time per 100ms window, so time-to-tuned shrinks as
//!   the pool grows while the per-worker tax stays fixed. `pct = 0`
//!   means serve-default-only: tuning never advances, by design.
//! * **Adaptive rounds + pipelining.** The scheduler asks
//!   [`crate::autotuner::TuningState::decide_background`] for exactly as
//!   many fresh candidates as the remaining budget and in-flight cap
//!   (`workers + 1`) allow — rounds widen while the budget is underspent
//!   — and keeps candidate N+1 queued while N measures, across
//!   problems.
//! * **Hedging.** A job that misses `ExploreOptions::hedge` is written
//!   off (candidate reported failed, slot freed) so one wedged candidate
//!   cannot stall tuning; a late result is dropped but its worker time
//!   is still debited.
//!
//! `explore_rounds_saved` semantics carry over from fused rounds: both
//! count explore work that callers would have paid serially but did
//! not. In background mode *every* explore job is such a saving, so the
//! accounting moves wholesale into the `background` stats block
//! (`jobs_run`, `busy_s`, `hedges_fired`, `serve_while_exploring`,
//! realized `duty_cycle_pct`) rather than inflating per-kernel
//! `explored`/`finalized` counters, which stay one-tick == one-served-
//! call. See `rust/tests/background_explore.rs` for the contract and
//! `benches/cold_start_p99.rs` for the cold-start p99 headline.
//!
//! **Publication protocol.** Publish happens on `confirm_finalized`
//! (plus a lazy self-heal on leader-lane tuned calls, covering warm
//! starts and lanes attached late). Invalidation happens on retune, on a
//! candidate failure that demotes the winner, on tuning-state import,
//! and on a fast-lane execution failure (the failing call then retries
//! through the leader, so no call is ever lost — this also covers a pool
//! worker dying mid-call). Thread-pinned backends without a pool simply
//! never publish — their steady-state calls keep flowing through the
//! leader, preserving exact pre-fast-lane behaviour. With a pool, a
//! winner no worker could compile stays on the leader too (the failed
//! install is memoized until the next retune).
//!
//! # Drift monitoring
//!
//! A published winner is a bet that past measurements predict future
//! latency; thermal throttling, co-tenancy, or input-distribution shift
//! can silently invalidate it. With `ServerOptions { drift: Some(policy) }`
//! the lanes close that loop:
//!
//! * On publication the entry captures a **baseline** (the winner's
//!   *mean* tuning-time execution cost; warm starts self-calibrate from
//!   the first full window).
//! * Fast-lane hits additionally feed their execution latency — the same
//!   quantity the baseline measured — into a [`drift::DriftMonitor`]:
//!   sharded atomic window counters (count, summed nanos, log₂ buckets
//!   for an approximate p95), still contention-free on the hot path.
//!   Pool-routed entries record through the same monitor, so drift
//!   evidence aggregates across every worker, not just the shared lane.
//! * The leader loop wakes at least every [`drift::DriftPolicy::window`]
//!   (an idle-capable `recv_timeout` instead of the plain blocking
//!   `recv`) and runs [`Dispatcher::drift_tick`]: windows with enough
//!   samples whose mean exceeds `ratio_threshold` × baseline build a
//!   streak, and `consecutive_windows` bad windows after the `cooldown`
//!   trigger the existing [`Dispatcher::retune`] path — the entry is
//!   invalidated, callers fall back to the leader, tuning re-explores,
//!   and the new winner republishes with a fresh baseline and cooldown.
//!   Hysteresis (streak + cooldown) keeps one noisy window from
//!   flapping.
//! * Every automatic retune is recorded in [`CoordStats`]
//!   (`drift_retunes` per kernel, a capped `drift_events` log) and the
//!   per-entry monitor state is exported under `fast_lane.drift` in
//!   `stats_json()`.
//!
//! With `drift: None` (the default) none of this machinery is even
//! allocated: the leader loop blocks exactly as before and published
//! entries carry no monitor.
//!
//! # Fleet warm-start (the tuned-state hub)
//!
//! Tuning knowledge normally dies with the process. With
//! `ServerOptions { hub: Some(HubOptions::at(socket)) }` the coordinator
//! joins a fleet around a [`crate::hub::HubServer`] broker
//! (`jitune hub serve --socket <path>`):
//!
//! * **At spawn** the leader connects (with retry), pulls the broker's
//!   full tuned map and warm-starts every entry that matches the local
//!   manifest — the problem lands in `Phase::Finalizing`, so its first
//!   call pays one JIT compile and *zero* explore iterations, exactly
//!   like a `load_state` import. Warm-start completes before `spawn`
//!   returns.
//! * **At finalize** — first tune, manual retune or drift-triggered
//!   retune — the leader publishes the confirmed winner back to the
//!   broker with a per-problem monotonic version. The broker merges
//!   last-writer-wins-by-version and reports conflicts (two processes
//!   tuning the same problem concurrently).
//! * **While serving**, `HubOptions::pull_interval` makes the leader
//!   periodically re-pull and adopt strictly-newer winners (their
//!   fast-lane entries are invalidated so callers switch); a retune in
//!   one process therefore propagates to the whole fleet. Explicit
//!   pulls are available via `CoordinatorHandle::hub_pull`.
//!
//! The hub is strictly an accelerant: an unreachable broker degrades to
//! a log warning and local-only behaviour, never a serving failure.
//! Traffic is accounted in [`CoordStats`] and exported under `"hub"` in
//! `stats_json()` (pushes / pulls / adopted / conflicts). See
//! `rust/tests/hub_fleet.rs` for the multi-process contract and
//! `examples/hub_fleet.rs` + `benches/hub_warm_start.rs` for the
//! fleet-scale amortization story.
//!
//! # Grounding the claims: native engine + traffic replay
//!
//! Everything above is measurable against mocks, but mocks only prove
//! scheduling, not that tuning *finds* anything. Two subsystems close
//! the loop:
//!
//! * [`crate::runtime::native`] is a real CPU backend whose manifest
//!   parameters select genuinely different machine behaviour — matmul
//!   loop scheduling (naive / packed-transpose / tiled+unrolled), saxpy
//!   access patterns (strided / chunked), reduce accumulator-lane
//!   counts — with bit-identical results across every variant of a
//!   problem, and a size-classed aligned [`crate::runtime::native::BufferPool`]
//!   so pool workers stop paying per-call allocation. It slots into the
//!   fast lane, worker pool and background exploration through the same
//!   [`crate::runtime::EngineFactory`] seam as PJRT
//!   (`NativeEngineFactory::pinned()` for the thread-pinned shape).
//! * [`crate::traffic`] replays a seeded production-shaped trace —
//!   Zipfian kernel popularity, shape churn, bursty open-loop arrivals,
//!   mid-run interference injection — against a live coordinator from N
//!   client threads, and reports what callers actually observed:
//!   p50/p99 by phase, per-problem time-to-good, explore duty cycle,
//!   and a tuned-state-size series.
//!
//! `benches/traffic_replay.rs` combines them: an exhaustive sweep
//! establishes the real variant spread (>= 1.3x gate), the replay shows
//! the coordinator converging to the sweep's best under churn and drift,
//! and `BENCH_TRAFFIC.json` at the repo root records the trajectory
//! (refreshed by CI on pushes to main; see the README for how to read
//! it).
//!
//! # Failure model (serving-path resilience)
//!
//! Tuning picks winners from *measurements*; production then feeds those
//! winners inputs, co-tenants and hardware the measurements never saw. A
//! winner can start erroring (a variant miscompiled for a rare shape), a
//! worker can wedge mid-call, and a burst can outrun the leader. Each
//! failure has a bounded, explicit answer — opt-in via
//! [`ServerOptions`], all off by default:
//!
//! * **Call deadlines** (`call_deadline: Some(d)`): every
//!   [`server::CoordinatorHandle::call`] is bounded end to end.
//!   Fast-lane execution is budget-checked before it starts; pool
//!   round-trips bound backpressure, queue wait *and* the reply wait
//!   ([`pool::WorkerPool::submit_deadline`]); leader-lane calls are shed
//!   unexecuted if they dequeue past their deadline, and the caller's
//!   reply wait itself times out. The caller gets
//!   [`crate::error::Error::DeadlineExceeded`] no later than the budget
//!   (plus scheduling slack) — never a hang. A straggling execution's
//!   result lands in a dropped reply channel and is discarded on
//!   arrival; the worker that produced it is *not* killed.
//! * **Winner quarantine + fallback** (`quarantine: Some(policy)`):
//!   every published entry carries a [`drift::FailureMonitor`] — the
//!   failure-rate sibling of the drift monitor's latency windows
//!   (sharded atomic ok/err counters, leader-only scan, streak + cooldown
//!   hysteresis). When a winner's windowed runtime error rate trips
//!   [`drift::QuarantinePolicy`], the leader demotes it everywhere (lane
//!   entry, instantiation cache, pool replicas, background candidacy),
//!   marks the variant failed in tuning history, republishes the
//!   *next-best measured variant* as fallback — callers degrade to the
//!   runner-up instead of erroring — and quarantines the variant so an
//!   immediate retune cannot re-pick it until `quarantine_for` passes.
//!   Deadline/overload errors never count toward the breaker: they say
//!   nothing about the variant. Demotions emit [`QuarantineEvent`]s
//!   (`"quarantine_events"` in `stats_json()`) and hub-publish so the
//!   fleet learns the fallback too.
//! * **Load shedding** (`shed: Some(policy)`): a bounded admission gate
//!   ahead of the leader queue. Beyond [`ShedPolicy::max_inflight`]
//!   concurrent leader-lane calls the handle fails fast with
//!   [`crate::error::Error::Overloaded`]; calls that sat queued longer
//!   than `max_queue_wait` are shed at dequeue instead of executing
//!   late. Fast-lane hits never queue, so they bypass the gate. Shed and
//!   deadline counts are kept lock-free ([`ResilienceStats`],
//!   `"resilience"` in `stats_json()`).
//! * **Transient vs permanent candidate failures**: an exploration
//!   candidate that *times out* (hedge expiry) is released for one retry
//!   before being marked failed — a compile or execution *error* stays
//!   immediately permanent — so one slow measurement does not
//!   permanently exclude a potentially-best variant.
//!
//! The chaos-replay harness (`benches/chaos_replay.rs`, gated in CI)
//! injects exactly these faults — wedged variants, erroring winners,
//! worker death, broker outage, overload bursts — mid-replay via
//! [`crate::traffic::FaultPlan`] and asserts the contract: callers never
//! hang, error rates stay bounded, and p99 recovers once the fault
//! clears. `rust/tests/chaos_resilience.rs` pins the per-mechanism
//! behaviour deterministically.
//!
//! # Correctness tooling
//!
//! Three lanes, a worker pool, background exploration and a drift
//! monitor add up to a lot of locks. The coordinator leans on three
//! layers of tooling to keep them honest:
//!
//! * **Tracked locks** — every lock in this module tree is a
//!   [`crate::sync::TrackedMutex`] / [`crate::sync::TrackedRwLock`] /
//!   [`crate::sync::TrackedCondvar`] with a dotted site label
//!   (`"coordinator.pool.routes"`). Acquisition is poison-tolerant
//!   (a panicking worker never wedges the serving path), and under the
//!   `lock-doctor` feature every acquisition feeds a global lock-order
//!   graph that reports ABBA cycles and held-too-long guards the moment
//!   they become *possible*, not when they finally deadlock. With the
//!   feature off the wrappers are zero-overhead transparent newtypes.
//!   `rust/tests/lock_doctor.rs` seeds an inversion to prove detection
//!   and hammers the full pooled stack to prove no false positives.
//! * **`jitune-lint`** (`rust/lint/`, `cargo run -p jitune-lint --
//!   rust/src`) — a std-only static pass gating CI: no raw `std::sync`
//!   locks outside `sync/` (L001), no `.lock().unwrap()` (L002),
//!   `Ordering::Relaxed` only on atomics annotated as pure counters
//!   (L003), named-`thread::Builder` threads only (L004), and no
//!   `unwrap`/`expect` on non-test coordinator/hub paths without an
//!   inline justification (L005).
//! * **Sanitizer CI** — ThreadSanitizer runs the pool, fast-lane and
//!   background-explore suites on nightly, and a time-boxed Miri pass
//!   covers the engine-free unit tests (`util::`, the pool's
//!   single-threaded queue tests).

pub mod background;
pub mod drift;
pub mod fastlane;
pub mod pool;

mod dispatcher;
mod registry;
pub mod server;
mod stats;

pub use background::ExploreOptions;
pub use dispatcher::{CallOutcome, CallRoute, Dispatcher};
pub use drift::{
    DriftHit, DriftMonitor, DriftPolicy, FailureMonitor, FailureWindow, QuarantineHit,
    QuarantinePolicy, WindowSummary,
};
pub use fastlane::{FastLane, Publication};
pub use pool::{PoolOptions, PoolSnapshot, WorkerPool, WorkerSnapshot};
pub use registry::KernelRegistry;
pub use server::{BatchOptions, Coordinator, CoordinatorHandle, ServerOptions, ShedPolicy};
pub use stats::{
    BackgroundStats, CoordStats, DriftEvent, FusedStats, HubStats, KernelStats, QuarantineEvent,
    ResilienceStats,
};
