//! The run-time coordinator: registry, dispatcher, threaded server.
//!
//! The [`Dispatcher`] is the heart of the system — the piece that plays
//! ClangJIT's `__clang_jit` role with autotuning folded in (paper §3.2):
//! every kernel call consults the [`crate::autotuner::TuningState`] for
//! its problem, JIT-compiles whatever variant the tuner asks for,
//! measures tuning iterations, finalizes the winner into the
//! instantiation cache, and routes steady-state calls to it.
//!
//! [`server::Coordinator`] wraps the dispatcher in a leader thread
//! (PJRT clients are thread-pinned) with a channel-based request
//! protocol, so any number of application threads can call kernels
//! concurrently — the analog of the paper's multi-threaded execution
//! conditions, and the mutex-protected compilation protocol.

mod dispatcher;
mod registry;
pub mod server;
mod stats;

pub use dispatcher::{CallOutcome, CallRoute, Dispatcher};
pub use registry::KernelRegistry;
pub use server::{BatchOptions, Coordinator, CoordinatorHandle};
pub use stats::{CoordStats, KernelStats};
