//! The call dispatcher — `__clang_jit` with autotuning (paper §3.2).

use std::collections::HashMap;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::autotuner::{
    Autotuner, BatchDecision, Decision, Metric, Phase, ProblemKey, WallClock,
};
use crate::error::{Error, Result};
use crate::hub::{HubClient, HubEntry};
use crate::manifest::Variant;
use crate::runtime::{CacheStats, CompileCache, Engine, SharedKernel};
use crate::tensor::HostTensor;

use super::background::{BackgroundScheduler, ExploreResult};
use super::drift::QuarantinePolicy;
use super::fastlane::{self, FastLane};
use super::pool::WorkerPool;
use super::registry::KernelRegistry;
use super::stats::CoordStats;

/// How a call was routed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CallRoute {
    /// Tuning iteration: variant JIT-compiled, run, measured, discarded.
    Explored,
    /// The winner's final compilation into the instantiation cache.
    Finalized,
    /// Steady state: cached winner.
    Tuned,
    /// Background-explore mode: the call executed the current-best (or
    /// safe default) variant while candidate tuning runs off the serving
    /// path (see [`super::background`]).
    Default,
}

/// Everything observable about one dispatched call (benches consume this
/// to regenerate the paper's figures).
#[derive(Debug, Clone)]
pub struct CallOutcome {
    /// Kernel output.
    pub output: HostTensor,
    /// Variant that actually ran.
    pub variant_id: String,
    /// Parameter value of that variant.
    pub value: i64,
    /// Routing phase of this call.
    pub route: CallRoute,
    /// Whether this call paid a JIT compilation.
    pub compiled: bool,
    /// Measured execution cost in metric units (tuning iterations) or
    /// wall seconds (steady state).
    pub exec_cost: f64,
    /// End-to-end call duration including any compilation.
    pub total: Duration,
}

/// Cached per-problem call metadata — built on a problem's first call so
/// the steady-state path performs no manifest walks and no allocations
/// beyond the reply itself (§Perf). Keyed by [`fastlane::plan_hash`] so
/// the hot-path lookup needs neither a signature-string join nor a
/// `(String, String)` key clone; the plan verifies kernel + shapes on
/// hit, so a hash collision degrades to a bucket scan, never a wrong
/// plan.
struct CallPlan {
    kernel: String,
    input_shapes: Vec<Vec<usize>>,
    problem_idx: usize,
    key: ProblemKey,
    values: Vec<i64>,
    /// Set when a publication attempt found the engine's executables
    /// thread-pinned (PJRT). Shareability never changes at run time, so
    /// once set the steady-state leader path stops re-attempting the
    /// fast-lane self-heal — keeping the hot path allocation-free for
    /// non-shareable backends too.
    unshareable: bool,
}

impl CallPlan {
    fn matches(&self, kernel: &str, inputs: &[HostTensor]) -> bool {
        fastlane::shapes_match(&self.kernel, &self.input_shapes, kernel, inputs)
    }
}

/// The dispatcher: owns the registry, the JIT compile cache, the
/// autotuner and the measurement metric. Single-threaded by design (PJRT
/// pinning); the [`super::server::Coordinator`] provides the
/// multi-threaded facade, and publishes tuned winners into the attached
/// [`FastLane`] (when the engine's executables are shareable) so
/// steady-state calls can bypass the leader entirely.
pub struct Dispatcher {
    registry: KernelRegistry,
    cache: CompileCache,
    tuner: Autotuner,
    metric: Box<dyn Metric>,
    stats: CoordStats,
    plans: HashMap<u64, Vec<CallPlan>>,
    fast_lane: Option<Arc<FastLane>>,
    /// Worker pool of thread-pinned engines: when the leader's engine
    /// cannot hand out a shared executable, finalized winners are
    /// replicated onto the pool and published as pool-routed entries.
    pool: Option<Arc<WorkerPool>>,
    /// Background explore scheduler (leader-owned). `Some` switches the
    /// dispatcher into serve/explore split mode: callers never run
    /// `Decision::Explore` — candidates compile+measure as background
    /// jobs instead (see [`super::background`]).
    background: Option<BackgroundScheduler>,
    hub: Option<HubClient>,
    /// Per-problem hub knowledge: the last version this process pulled
    /// or had acknowledged, plus that version's winner. Gates publishes
    /// (a warm-started winner is not re-published) and pulls (only
    /// strictly newer versions are adopted).
    hub_known: HashMap<ProblemKey, HubSeen>,
    /// Client connection generation this knowledge was built against; a
    /// bump means the client redialed and the (in-memory) broker may
    /// have restarted empty — `hub_known` is dropped and resynced.
    hub_generation: u64,
    /// Highest version warned about per unadoptable hub entry, so
    /// periodic pulls in a heterogeneous fleet warn once per version
    /// instead of forever.
    hub_skipped: HashMap<ProblemKey, u64>,
    /// Variants demoted by the failure breaker, with their quarantine
    /// expiry: a retune that fires inside the window re-applies the
    /// marks, so the rematch cannot immediately re-pick a winner that
    /// just erred its way off the lane.
    quarantined: HashMap<ProblemKey, Vec<(usize, Instant)>>,
    /// Transient-timeout strikes per candidate: a first hedge releases
    /// the candidate back to the strategy (a once-wedged compile may
    /// succeed on retry), a second escalates to the permanent
    /// [`Dispatcher::candidate_failed`] path.
    timeout_strikes: HashMap<(ProblemKey, usize), u32>,
}

/// What this process last knew the hub to hold for one problem.
#[derive(Debug, Clone, Copy)]
struct HubSeen {
    version: u64,
    /// The winner stored at that version — `None` right after a publish
    /// conflict, where the broker kept *some* entry at `version` but
    /// the ack does not say whose. Unknown winners keep the version
    /// usable for publishing while letting the next pull re-adopt
    /// broker truth.
    winner_value: Option<i64>,
}

impl Dispatcher {
    /// Dispatcher with the paper's defaults: sweep strategy + wall-clock
    /// metric.
    pub fn new(registry: KernelRegistry, engine: Box<dyn Engine>) -> Dispatcher {
        Dispatcher::with(registry, engine, Autotuner::sweep(), Box::new(WallClock::new()))
    }

    /// Fully parameterized constructor.
    pub fn with(
        registry: KernelRegistry,
        engine: Box<dyn Engine>,
        tuner: Autotuner,
        metric: Box<dyn Metric>,
    ) -> Dispatcher {
        Dispatcher {
            registry,
            cache: CompileCache::new(engine),
            tuner,
            metric,
            stats: CoordStats::new(),
            plans: HashMap::new(),
            fast_lane: None,
            pool: None,
            background: None,
            hub: None,
            hub_known: HashMap::new(),
            hub_generation: 0,
            hub_skipped: HashMap::new(),
            quarantined: HashMap::new(),
            timeout_strikes: HashMap::new(),
        }
    }

    /// Attach the published-winner fast lane (the coordinator does this
    /// when it spawns the leader). Problems tuned before attachment are
    /// re-published lazily on their next leader-lane call.
    pub fn set_fast_lane(&mut self, lane: Arc<FastLane>) {
        self.fast_lane = Some(lane);
    }

    /// The attached fast lane, if any.
    pub fn fast_lane(&self) -> Option<&Arc<FastLane>> {
        self.fast_lane.as_ref()
    }

    /// Attach a worker pool of thread-pinned engines. With both a fast
    /// lane and a pool attached, finalized winners that cannot provide a
    /// shared executable are replicated onto the pool and published as
    /// pool-routed fast-lane entries instead of staying leader-pinned.
    pub fn attach_pool(&mut self, pool: Arc<WorkerPool>) {
        self.pool = Some(pool);
    }

    /// The attached worker pool, if any.
    pub fn pool(&self) -> Option<&Arc<WorkerPool>> {
        self.pool.as_ref()
    }

    /// Attach a tuned-state hub connection. Call [`Dispatcher::hub_pull`]
    /// afterwards for the initial warm-start (the coordinator does both
    /// at spawn).
    pub fn attach_hub(&mut self, client: HubClient) {
        self.hub = Some(client);
    }

    /// Whether a hub connection is attached.
    pub fn hub_active(&self) -> bool {
        self.hub.is_some()
    }

    /// Pull the hub's full tuned map and adopt every entry that is newer
    /// than what this process already knows. Adopted problems warm-start
    /// in `Finalizing` (zero explore iterations; the winner pays one JIT
    /// compile on first use) and their published fast-lane entries are
    /// invalidated so callers pick up the new winner. Entries that no
    /// longer match the live manifest are skipped, exactly like
    /// [`Dispatcher::load_state`]. Returns (adopted, skipped).
    pub fn hub_pull(&mut self) -> Result<(usize, usize)> {
        let Some(hub) = self.hub.as_mut() else { return Ok((0, 0)) };
        let entries = hub.pull_all()?;
        let generation = hub.generation();
        self.hub_resync(generation);
        let mut skipped = 0;
        // Stage adoptions first: registry lookups and version gating
        // borrow immutably, the tuner import below borrows mutably.
        // Items are (entry, winner_idx, kernel, input shapes).
        let mut staged = Vec::new();
        for entry in entries {
            let key = entry.problem_key();
            if let Some(seen) = self.hub_known.get(&key) {
                // skip what we already know; an unknown winner at the
                // same version (post-conflict) must fall through so the
                // pull resolves it to broker truth
                if entry.version < seen.version
                    || (entry.version == seen.version && seen.winner_value.is_some())
                {
                    continue;
                }
            }
            // resolve into owned data eagerly so the registry borrow
            // never overlaps the skip-log bookkeeping below
            let resolved = self
                .matching_problem(&entry.kernel, &entry.param, &entry.signature, &entry.values)
                .map(|p| (p.kernel.clone(), p.variants[0].input_shapes()));
            let Some((kernel, shapes)) = resolved else {
                self.hub_skip_warn(&key, entry.version, "manifest mismatch");
                skipped += 1;
                continue;
            };
            // a locally-unparsable signature skips this entry only —
            // it must not abort adoption of every other kernel's winner
            let Ok(shapes) = shapes else {
                self.hub_skip_warn(&key, entry.version, "unparsable input signature");
                skipped += 1;
                continue;
            };
            let Some(winner_idx) = entry.values.iter().position(|&v| v == entry.winner_value)
            else {
                self.hub_skip_warn(&key, entry.version, "winner not a candidate");
                skipped += 1;
                continue;
            };
            staged.push((entry, winner_idx, kernel, shapes));
        }
        let mut adopted = 0;
        for (entry, winner_idx, kernel, shapes) in staged {
            let key = entry.problem_key();
            self.hub_known.insert(
                key.clone(),
                HubSeen { version: entry.version, winner_value: Some(entry.winner_value) },
            );
            // Already tuned to the same winner locally: record the
            // version but keep serving — no refinalization needed.
            let local_same = self
                .tuner
                .peek(&key)
                .is_some_and(|s| s.tuned_value() == Some(entry.winner_value));
            if local_same {
                continue;
            }
            self.tuner.warm_start(key.clone(), entry.values.clone(), winner_idx)?;
            // the adopted state replaces local tuning wholesale; pending
            // background results for the old state are now stale
            if let Some(bg) = self.background.as_mut() {
                bg.forget_key(&key);
            }
            if let Some(lane) = &self.fast_lane {
                lane.invalidate(&kernel, &shapes);
            }
            log::info!("hub: adopted {key} = {} (v{})", entry.winner_value, entry.version);
            adopted += 1;
        }
        self.stats.hub_pull(adopted as u64);
        Ok((adopted, skipped))
    }

    /// Compile every adopted-but-unfinalized winner right now, so the
    /// first call of each warm-started problem is served from the
    /// instantiation cache instead of paying the winner's one JIT
    /// compilation. Pool-aware: finalization flows through the same
    /// `publish_winner` path as a caller finalize, so with thread-pinned
    /// engines the winner is replicated onto the worker pool and the
    /// fast-lane entry is live before the first request arrives.
    /// Returns (compiled, failed).
    pub fn prewarm_tuned(&mut self) -> (usize, usize) {
        // Stage (kernel, input shapes) first: the registry borrow must
        // not overlap the mutable plan/tuner calls below.
        let mut pending: Vec<(String, Vec<Vec<usize>>)> = Vec::new();
        let mut failed = 0;
        for problem in &self.registry.manifest().problems {
            let key = ProblemKey::for_problem(problem);
            let Some(state) = self.tuner.peek(&key) else { continue };
            if state.pending_winner().is_none() {
                continue;
            }
            match problem.variants[0].input_shapes() {
                Ok(shapes) => pending.push((problem.kernel.clone(), shapes)),
                Err(e) => {
                    log::warn!("prewarm: cannot derive input shapes for {key}: {e}");
                    failed += 1;
                }
            }
        }
        let mut ok = 0;
        for (kernel, shapes) in pending {
            let inputs: Vec<HostTensor> = shapes.iter().map(|s| HostTensor::zeros(s)).collect();
            let (hash, slot) = match self.plan_slot(&kernel, &inputs) {
                Ok(id) => id,
                Err(e) => {
                    log::warn!("prewarm: cannot plan {kernel}: {e}");
                    failed += 1;
                    continue;
                }
            };
            // Re-read the winner through the registered plan: plan_slot
            // may have raced nothing (leader-only), but the state could
            // have been confirmed by an earlier iteration of this loop
            // if two manifest problems share a key.
            let winner = {
                let plan = &self.plans[&hash][slot];
                self.tuner.peek(&plan.key).and_then(|s| s.pending_winner())
            };
            let Some(winner) = winner else { continue };
            if self.finalize_pending(hash, slot, winner, "at prewarm") {
                ok += 1;
            } else {
                failed += 1;
            }
        }
        (ok, failed)
    }

    /// Publish the problem's confirmed winner to the hub. A winner the
    /// hub already holds is *re-asserted at its known version* rather
    /// than skipped: on a healthy broker that merges as `Stale` (no
    /// version burn), and on a broker that restarted empty it re-seeds
    /// the map — skipping would leave the fleet's warm-start silently
    /// dead with no request ever detecting the restart. Hub failures
    /// degrade to a warning: serving must not depend on broker
    /// liveness.
    fn hub_publish(&mut self, hash: u64, slot: usize) {
        let Some(hub) = self.hub.as_ref() else { return };
        let generation = hub.generation();
        self.hub_resync(generation);
        let (key, values, winner_value) = {
            let plan = &self.plans[&hash][slot];
            let Some(state) = self.tuner.peek(&plan.key) else { return };
            let Some(win) = state.winner_snapshot() else { return };
            (plan.key.clone(), plan.values.clone(), win.value)
        };
        let version = match self.hub_known.get(&key) {
            Some(seen) if seen.winner_value == Some(winner_value) => seen.version,
            Some(seen) => seen.version + 1,
            None => 1,
        };
        let entry = HubEntry {
            kernel: key.kernel.clone(),
            param: key.param.clone(),
            signature: key.signature.clone(),
            values,
            winner_value,
            version,
        };
        // jitune-lint: allow(L005): guarded by the early return above
        let result = self.hub.as_mut().expect("checked above").publish(&entry);
        match result {
            Ok(ack) if ack.conflict => {
                // The broker resolved a race (or rejected our publish as
                // outdated): an entry exists at ack.version but the ack
                // does not say whose. Record the version with the winner
                // unknown — the next pull adopts broker truth, whichever
                // writer it favoured.
                self.stats.hub_push(true);
                self.hub_known.insert(key, HubSeen { version: ack.version, winner_value: None });
            }
            Ok(ack) => {
                self.stats.hub_push(false);
                let seen = HubSeen { version: ack.version, winner_value: Some(winner_value) };
                self.hub_known.insert(key, seen);
            }
            Err(e) => log::warn!("hub: publish of {key} failed: {e}"),
        }
    }

    /// Drop per-entry hub knowledge when the client's connection
    /// generation changed: the in-memory broker may have restarted
    /// empty, so cached versions (and skip-warn history) are no longer
    /// grounded — the next pull/publish rebuilds them from broker truth.
    fn hub_resync(&mut self, generation: u64) {
        if generation != self.hub_generation {
            log::info!("hub: reconnected (generation {generation}); resyncing entry versions");
            self.hub_generation = generation;
            self.hub_known.clear();
            self.hub_skipped.clear();
        }
    }

    /// Warn once per (problem, version) about a hub entry this process
    /// cannot adopt — a heterogeneous fleet with periodic pulls must
    /// not repeat the same warning every interval.
    fn hub_skip_warn(&mut self, key: &ProblemKey, version: u64, why: &str) {
        let seen = self.hub_skipped.get(key).copied().unwrap_or(0);
        if version > seen {
            log::warn!("hub: skipping entry {key} v{version} ({why})");
            self.hub_skipped.insert(key.clone(), version);
        }
    }

    /// The manifest problem matching (kernel, param, signature,
    /// candidate values) exactly — the shared trust test for imported
    /// tuning state (`load_state`) and hub adoption: an entry whose
    /// candidates changed since it was recorded must not be trusted.
    fn matching_problem(
        &self,
        kernel: &str,
        param: &str,
        signature: &str,
        values: &[i64],
    ) -> Option<&crate::manifest::Problem> {
        self.registry.manifest().problems.iter().find(|p| {
            p.kernel == kernel
                && p.param == param
                && p.variants[0].inputs.join(",") == signature
                && p.variants.iter().map(|v| v.value).eq(values.iter().copied())
        })
    }

    /// Resolve the cached call plan for (kernel, inputs), building it on
    /// the problem's first call. Hit path: one hash + bucket scan, no
    /// allocation.
    fn plan_slot(&mut self, kernel: &str, inputs: &[HostTensor]) -> Result<(u64, usize)> {
        let hash = fastlane::plan_hash(kernel, inputs);
        if let Some(bucket) = self.plans.get(&hash) {
            if let Some(slot) = bucket.iter().position(|p| p.matches(kernel, inputs)) {
                return Ok((hash, slot));
            }
        }
        // First call of this problem: resolve against the manifest. The
        // allocations below happen once per problem, not per call (§Perf).
        let (problem_idx, key, values) = {
            let problem = self.registry.problem_for_inputs(kernel, inputs)?;
            let idx = self
                .registry
                .manifest()
                .problems
                .iter()
                .position(|q| std::ptr::eq(q, problem))
                // jitune-lint: allow(L005): `problem` is a reference into this same vec
                .expect("problem from this manifest");
            let values: Vec<i64> = problem.variants.iter().map(|v| v.value).collect();
            (idx, ProblemKey::for_problem(problem), values)
        };
        let plan = CallPlan {
            kernel: kernel.to_string(),
            input_shapes: inputs.iter().map(|t| t.shape().to_vec()).collect(),
            problem_idx,
            key,
            values,
            unshareable: false,
        };
        let bucket = self.plans.entry(hash).or_default();
        bucket.push(plan);
        Ok((hash, bucket.len() - 1))
    }

    /// Dispatch one kernel call: the `__clang_jit` entry point.
    ///
    /// The problem is identified by the kernel name plus the *actual*
    /// argument signature (paper: a different argument set is a different
    /// autotuning problem).
    pub fn call(&mut self, kernel: &str, inputs: &[HostTensor]) -> Result<CallOutcome> {
        let t0 = Instant::now();
        let (hash, slot) = self.plan_slot(kernel, inputs)?;

        // Serve/explore split: with a background scheduler attached,
        // callers never run `Decision::Explore`. Anything not yet tuned
        // is served the current-best (or default) variant while the
        // scheduler advances tuning off the serving path.
        if self.background.is_some() {
            let phase = {
                let plan = &self.plans[&hash][slot];
                self.tuner.state(&plan.key, &plan.values).phase()
            };
            match phase {
                Phase::Exploring | Phase::Finalizing => {
                    return self.serve_default(kernel, hash, slot, inputs, t0);
                }
                Phase::Failed => {
                    let plan = &self.plans[&hash][slot];
                    return Err(Error::Autotune(format!(
                        "every variant of {} failed; cannot execute",
                        plan.key
                    )));
                }
                Phase::Tuned => {}
            }
        }

        // Failure-retry loop: a failing variant is excluded and the next
        // decision is consulted, until the call succeeds or every
        // candidate is dead.
        loop {
            let decision = {
                let plan = &self.plans[&hash][slot];
                self.tuner.state(&plan.key, &plan.values).decide()
            };
            match decision {
                Decision::Failed => {
                    let plan = &self.plans[&hash][slot];
                    return Err(Error::Autotune(format!(
                        "every variant of {} failed; cannot execute",
                        plan.key
                    )));
                }
                Decision::Explore(i) => {
                    let (key, variant) = {
                        let plan = &self.plans[&hash][slot];
                        let manifest = self.registry.manifest();
                        (plan.key.clone(), manifest.problems[plan.problem_idx].variants[i].clone())
                    };
                    match self.explore(&key, &variant, i, inputs, t0) {
                        Ok(outcome) => return Ok(outcome),
                        Err(e) => {
                            log::warn!("variant {} failed during tuning: {e}", variant.id);
                            self.stats.failure(kernel);
                            self.candidate_failed(hash, slot, i);
                            continue;
                        }
                    }
                }
                Decision::Finalize(i) => {
                    let (variant, all_ids) = {
                        let plan = &self.plans[&hash][slot];
                        let problem = &self.registry.manifest().problems[plan.problem_idx];
                        let all_ids: Vec<String> =
                            problem.variants.iter().map(|v| v.id.clone()).collect();
                        (problem.variants[i].clone(), all_ids)
                    };
                    match self.finalize(&variant, &all_ids, inputs, t0) {
                        Ok(mut outcome) => {
                            {
                                let plan = &self.plans[&hash][slot];
                                self.tuner.state(&plan.key, &plan.values).confirm_finalized(i);
                            }
                            // The winner is compiled and confirmed: hand a
                            // shareable executable to caller threads and
                            // share it with the fleet. Every finalization
                            // flows through here — first tune, manual
                            // retune, drift-triggered retune — so the hub
                            // sees every new winner.
                            self.publish_winner(hash, slot);
                            self.hub_publish(hash, slot);
                            self.stats.finalized(kernel, outcome.total);
                            outcome.route = CallRoute::Finalized;
                            log::info!(
                                "{} tuned: value={} ({})",
                                self.plans[&hash][slot].key,
                                outcome.value,
                                outcome.variant_id
                            );
                            return Ok(outcome);
                        }
                        Err(e) => {
                            log::warn!("winner {} failed finalization: {e}", variant.id);
                            self.stats.failure(kernel);
                            self.candidate_failed(hash, slot, i);
                            continue;
                        }
                    }
                }
                Decision::Use(i) => {
                    // §Perf fast path: no allocation before the reply —
                    // the hashed plan lookup replaced the signature join,
                    // and disjoint field borrows let the executable run
                    // straight off the cache while the registry stays
                    // immutably borrowed.
                    let pidx = self.plans[&hash][slot].problem_idx;
                    let manifest = self.registry.manifest();
                    let variant = &manifest.problems[pidx].variants[i];
                    let (exe, compiled) = self.cache.get_or_compile(manifest, variant)?;
                    let begin = self.metric.begin();
                    let output = exe.execute(inputs)?;
                    let cost = self.metric.end(begin);
                    debug_assert!(!compiled, "steady-state call should hit the cache");
                    let outcome = CallOutcome {
                        output,
                        variant_id: variant.id.clone(),
                        value: variant.value,
                        route: CallRoute::Tuned,
                        compiled,
                        exec_cost: cost,
                        total: t0.elapsed(),
                    };
                    self.stats.tuned_call(kernel, outcome.total);
                    // Self-heal the published entry: republish when the
                    // lane lost it (attached late, warm start, or a
                    // transient fast-lane failure) — unless the engine
                    // already proved unshareable for this problem.
                    let needs_publish = match &self.fast_lane {
                        Some(lane) => {
                            !self.plans[&hash][slot].unshareable
                                && !lane.contains(kernel, inputs)
                        }
                        None => false,
                    };
                    if needs_publish {
                        self.publish_winner(hash, slot);
                    }
                    return Ok(outcome);
                }
            }
        }
    }

    /// Dispatch one scheduling round of co-scheduled calls for `kernel`
    /// in a single batch, returning one result per call in input order.
    ///
    /// Calls are partitioned by tuning problem (same kernel name, but the
    /// argument signature still separates problems). For a problem in
    /// `Phase::Exploring`, the group becomes a **fused exploration
    /// round**: multiple pending candidates are drawn from the search
    /// strategy in one shot (`propose_batch`), the group's calls execute
    /// back-to-back on the warmed engine — one call per candidate, each
    /// candidate compiled once; surplus calls replicate a candidate and
    /// the replicas' *median* is what the tuner records, denoising the
    /// measurement — and the whole round reports to the tuning state as
    /// one batch. When the strategy converges mid-round, the winner is
    /// finalized *within the round*, so the next caller already hits the
    /// fast lane. With B co-scheduled callers, a sweep over V variants
    /// therefore reaches `Phase::Tuned` in ~V/B leader rounds instead of
    /// V (see `benches/time_to_tuned.rs`).
    ///
    /// **Failure isolation.** A candidate that fails mid-round is
    /// excluded from tuning (exactly like the serial path) and only the
    /// call(s) assigned to it observe the error — round-mates' calls
    /// succeed untouched. Serial single-call groups keep the serial
    /// retry-next-candidate contract byte-for-byte: they route through
    /// [`Dispatcher::call`].
    pub fn call_batch(
        &mut self,
        kernel: &str,
        batch: Vec<Vec<HostTensor>>,
    ) -> Vec<Result<CallOutcome>> {
        let mut results: Vec<Option<Result<CallOutcome>>> =
            (0..batch.len()).map(|_| None).collect();
        // Partition by tuning problem (plan identity): same-kernel calls
        // with different signatures are different problems.
        let mut groups: Vec<((u64, usize), Vec<usize>)> = Vec::new();
        for (i, inputs) in batch.iter().enumerate() {
            match self.plan_slot(kernel, inputs) {
                Ok(id) => match groups.iter_mut().find(|(g, _)| *g == id) {
                    Some((_, members)) => members.push(i),
                    None => groups.push((id, vec![i])),
                },
                Err(e) => results[i] = Some(Err(e)),
            }
        }
        for ((hash, slot), members) in groups {
            if members.len() == 1 || self.background.is_some() {
                // Lone call — or background-explore mode, where fused
                // inline rounds are disabled: each call takes the serial
                // path (incl. its retry-on-candidate-failure loop; under
                // a background scheduler it serves the current best).
                for i in members {
                    results[i] = Some(self.call(kernel, &batch[i]));
                }
                continue;
            }
            let decision = {
                let plan = &self.plans[&hash][slot];
                self.tuner.state(&plan.key, &plan.values).decide_batch(members.len())
            };
            match decision {
                BatchDecision::Explore(candidates) => {
                    self.fused_explore(
                        kernel,
                        hash,
                        slot,
                        &members,
                        &candidates,
                        &batch,
                        &mut results,
                    );
                }
                // Finalize/Use/Failed: each call takes the serial path —
                // finalization happens once, the rest ride the cache.
                _ => {
                    for i in members {
                        results[i] = Some(self.call(kernel, &batch[i]));
                    }
                }
            }
        }
        results
            .into_iter()
            // jitune-lint: allow(L005): the loop above filled every slot before this drain
            .map(|r| r.expect("every call in the round resolved"))
            .collect()
    }

    /// One fused exploration round: execute `candidates` across the
    /// group's calls (candidate-major, compile once per candidate, evict
    /// after — tuning iterations never populate the instantiation
    /// cache), then report every measurement to the tuning state in a
    /// single batch and finalize in-round if the strategy converged.
    #[allow(clippy::too_many_arguments)]
    fn fused_explore(
        &mut self,
        kernel: &str,
        hash: u64,
        slot: usize,
        members: &[usize],
        candidates: &[usize],
        batch: &[Vec<HostTensor>],
        results: &mut [Option<Result<CallOutcome>>],
    ) {
        let (key, problem_idx) = {
            let plan = &self.plans[&hash][slot];
            (plan.key.clone(), plan.problem_idx)
        };
        let group = members.len();
        // More proposals than calls: the tail stays outstanding and is
        // re-issued next round (report_batch never hears about it).
        let active = candidates.len().min(group);
        let mut reports: Vec<(usize, Option<f64>)> = Vec::with_capacity(active);
        let mut failed_ids: Vec<String> = Vec::new();
        for (pos, &cand) in candidates[..active].iter().enumerate() {
            let variant =
                self.registry.manifest().problems[problem_idx].variants[cand].clone();
            // Calls assigned to this candidate: one "owner" plus any
            // surplus replicas (round-robin by position in the group).
            let assigned: Vec<usize> = members
                .iter()
                .enumerate()
                .filter(|(j, _)| j % active == pos)
                .map(|(_, &i)| i)
                .collect();
            let mut samples: Vec<f64> = Vec::with_capacity(assigned.len());
            let mut fail: Option<String> = None;
            for &i in &assigned {
                let call_t0 = Instant::now();
                if let Some(msg) = &fail {
                    // The candidate already failed this round: its
                    // replicas fail fast with the same cause instead of
                    // re-running a known-dead variant.
                    results[i] = Some(Err(Error::Autotune(format!(
                        "fused round: candidate {} failed: {msg}",
                        variant.id
                    ))));
                    continue;
                }
                let executed = {
                    let manifest = self.registry.manifest();
                    match self.cache.get_or_compile(manifest, &variant) {
                        Ok((exe, compiled)) => {
                            let begin = self.metric.begin();
                            match exe.execute(&batch[i]) {
                                Ok(output) => {
                                    let cost = self.metric.end(begin);
                                    Ok((output, cost, compiled))
                                }
                                Err(e) => Err(e),
                            }
                        }
                        Err(e) => Err(e),
                    }
                };
                match executed {
                    Ok((output, cost, compiled)) => {
                        samples.push(cost);
                        self.stats.explored(kernel, call_t0.elapsed());
                        results[i] = Some(Ok(CallOutcome {
                            output,
                            variant_id: variant.id.clone(),
                            value: variant.value,
                            route: CallRoute::Explored,
                            compiled,
                            exec_cost: cost,
                            total: call_t0.elapsed(),
                        }));
                    }
                    Err(e) => {
                        log::warn!(
                            "variant {} failed during fused tuning: {e}",
                            variant.id
                        );
                        self.stats.failure(kernel);
                        fail = Some(e.to_string());
                        results[i] = Some(Err(e));
                    }
                }
            }
            self.cache.evict(&variant.id);
            // Any execution failure excludes the candidate — exactly the
            // serial contract, and independent of whether a successful
            // replica happened to run before the failing one.
            if fail.is_some() || samples.is_empty() {
                failed_ids.push(variant.id.clone());
                reports.push((cand, None));
            } else {
                // Replicas collapse to their median (NaN-safe linear-
                // interpolation percentile, shared with the bench stats).
                reports.push((cand, Some(crate::util::stats::percentile(&samples, 50.0))));
            }
        }
        // One batch report for the whole round.
        self.tuner.state(&key, &[]).report_batch(&reports);
        if !failed_ids.is_empty() {
            // Parity with the serial candidate-failure path: unpublish
            // anything the dead variants might still be serving.
            let plan = &self.plans[&hash][slot];
            if let Some(lane) = &self.fast_lane {
                lane.invalidate(&plan.kernel, &plan.input_shapes);
            }
            if let Some(pool) = &self.pool {
                pool.evict(&failed_ids);
            }
        }
        // Rounds saved vs serial dispatch: `active` distinct candidates
        // measured in one round instead of `active` serial explore
        // rounds. Replicas save nothing (serially they would have been
        // steady-state calls, not extra explores); the in-round finalize
        // below accounts for its own saved round.
        self.stats.fused_round(
            group as u64,
            group.saturating_sub(active) as u64,
            active.saturating_sub(1) as u64,
        );
        // In-round finalization: the batch report may have exhausted the
        // strategy — finish tuning now so the *next* caller already hits
        // the published winner instead of paying a finalize round. The
        // probe is batch-width: when the strategy has candidates left it
        // pre-draws the next round's full batch (marked outstanding and
        // re-issued wholesale), never throttling the next round to one
        // candidate.
        let probe = self.tuner.state(&key, &[]).decide_batch(group);
        if let BatchDecision::Finalize(winner) = probe {
            let (variant, all_ids) = {
                let problem = &self.registry.manifest().problems[problem_idx];
                let all_ids: Vec<String> =
                    problem.variants.iter().map(|v| v.id.clone()).collect();
                (problem.variants[winner].clone(), all_ids)
            };
            // jitune-lint: allow(L005): groups are built non-empty by the partition above
            let inputs = &batch[*members.last().expect("non-empty group")];
            match self.finalize(&variant, &all_ids, inputs, Instant::now()) {
                Ok(outcome) => {
                    self.tuner.state(&key, &[]).confirm_finalized(winner);
                    self.publish_winner(hash, slot);
                    self.hub_publish(hash, slot);
                    // Accounted in the fused counters only: per-kernel
                    // explored/finalized/tuned counters stay one-tick ==
                    // one-served-call, so lane accounting (leader calls +
                    // lane hits == calls submitted) keeps holding.
                    self.stats.fused_inround_finalize();
                    log::info!(
                        "{key} tuned in-round: value={} ({})",
                        outcome.value,
                        outcome.variant_id
                    );
                }
                Err(e) => {
                    // Demote and let the next caller drive the rematch —
                    // exactly the serial finalize-failure contract.
                    log::warn!("winner {} failed in-round finalization: {e}", variant.id);
                    self.stats.failure(kernel);
                    self.candidate_failed(hash, slot, winner);
                }
            }
        }
    }

    /// Report a candidate failure to the tuner and unpublish any fast-lane
    /// entry for the problem (a demoted winner must not keep serving);
    /// the worker pool drops its replicated copies too.
    fn candidate_failed(&mut self, hash: u64, slot: usize, idx: usize) {
        let plan = &self.plans[&hash][slot];
        self.tuner.state(&plan.key, &plan.values).report_failure(idx);
        // The candidate may still have a background job in flight: drop
        // its bookkeeping so a late result cannot report into the tuner
        // (its busy time is still debited when it arrives).
        if let Some(bg) = self.background.as_mut() {
            bg.forget_candidate(&plan.key, idx);
        }
        if let Some(lane) = &self.fast_lane {
            lane.invalidate(&plan.kernel, &plan.input_shapes);
        }
        if let Some(pool) = &self.pool {
            let plan = &self.plans[&hash][slot];
            let failed_id = self.registry.manifest().problems[plan.problem_idx].variants[idx]
                .id
                .clone();
            pool.evict(std::slice::from_ref(&failed_id));
        }
    }

    /// The *transient*-failure sibling of [`Dispatcher::candidate_failed`]:
    /// a candidate that timed out (a hedged background measurement, a
    /// wedged worker) rather than erroring. A timeout says nothing about
    /// the candidate itself — the worker may have been descheduled, the
    /// queue backed up — so the first strike only releases the candidate
    /// back to the strategy (its history stays untouched and it remains
    /// proposable). A second strike for the same candidate escalates to
    /// the permanent failure path: twice-wedged is evidence.
    pub(crate) fn candidate_timed_out(&mut self, hash: u64, slot: usize, idx: usize) {
        let (key, values) = {
            let plan = &self.plans[&hash][slot];
            (plan.key.clone(), plan.values.clone())
        };
        let strikes = self.timeout_strikes.entry((key.clone(), idx)).or_insert(0);
        *strikes += 1;
        if *strikes >= 2 {
            self.timeout_strikes.remove(&(key, idx));
            self.candidate_failed(hash, slot, idx);
            return;
        }
        log::info!("{key}: candidate {idx} timed out once; released for retry");
        if let Some(bg) = self.background.as_mut() {
            bg.forget_candidate(&key, idx);
        }
        self.tuner.state(&key, &values).release_outstanding(idx);
    }

    /// Attach a background explore scheduler, switching the dispatcher
    /// into serve/explore split mode (see [`super::background`]).
    pub(crate) fn set_background(&mut self, scheduler: BackgroundScheduler) {
        self.background = Some(scheduler);
    }

    /// Whether background exploration is active.
    pub fn background_active(&self) -> bool {
        self.background.is_some()
    }

    /// Serve one call without touching tuning decisions: execute the
    /// problem's current best — the pending winner while finalizing, the
    /// best measured candidate so far, or the first runnable variant
    /// when nothing is measured yet (the "safe default"). That variant's
    /// one-time bootstrap compile is the only JIT work a caller can
    /// observe in background mode; tuning compiles happen on explore
    /// workers.
    fn serve_default(
        &mut self,
        kernel: &str,
        hash: u64,
        slot: usize,
        inputs: &[HostTensor],
        t0: Instant,
    ) -> Result<CallOutcome> {
        // Failure-retry loop, like `call`: a default that dies at compile
        // or execute is excluded and the next-best candidate serves.
        loop {
            let (idx, pidx) = {
                let plan = &self.plans[&hash][slot];
                // jitune-lint: allow(L005): serve() registers the tuner state before issuing
                let state = self.tuner.peek(&plan.key).expect("serve gate created the state");
                let history = state.history();
                let idx = state
                    .pending_winner()
                    .or_else(|| history.best_index())
                    .or_else(|| history.records.iter().position(|r| !r.failed));
                let Some(idx) = idx else {
                    return Err(Error::Autotune(format!(
                        "every variant of {} failed; cannot execute",
                        plan.key
                    )));
                };
                (idx, plan.problem_idx)
            };
            let executed = {
                let manifest = self.registry.manifest();
                let variant = &manifest.problems[pidx].variants[idx];
                match self.cache.get_or_compile(manifest, variant) {
                    Ok((exe, compiled)) => {
                        let begin = self.metric.begin();
                        match exe.execute(inputs) {
                            Ok(output) => {
                                let cost = self.metric.end(begin);
                                Ok((output, cost, compiled, variant.id.clone(), variant.value))
                            }
                            Err(e) => Err((e, variant.id.clone())),
                        }
                    }
                    Err(e) => Err((e, variant.id.clone())),
                }
            };
            match executed {
                Ok((output, cost, compiled, variant_id, value)) => {
                    self.stats.background_serve();
                    return Ok(CallOutcome {
                        output,
                        variant_id,
                        value,
                        route: CallRoute::Default,
                        compiled,
                        exec_cost: cost,
                        total: t0.elapsed(),
                    });
                }
                Err((e, variant_id)) => {
                    log::warn!("default variant {variant_id} failed while serving: {e}");
                    self.stats.failure(kernel);
                    self.cache.evict(&variant_id);
                    self.candidate_failed(hash, slot, idx);
                    continue;
                }
            }
        }
    }

    /// One background-scheduler maintenance pass, run by the leader loop
    /// every iteration (and after every explore result): expire hedges,
    /// roll the duty-cycle window, then issue as many fresh candidate
    /// jobs as budget and pipeline allow across all known problems.
    /// Returns the next instant the scheduler needs waking — `None` when
    /// nothing is in flight and no problem can make progress.
    pub(crate) fn background_tick(&mut self, now: Instant) -> Option<Instant> {
        self.background.as_ref()?;
        // jitune-lint: allow(L005): guarded by the `?` early return above
        let expired = self.background.as_mut().expect("checked above").expire_hedges(now);
        for (key, candidate, hash, slot) in expired {
            log::warn!("background: hedging wedged candidate {candidate} of {key}");
            self.stats.background_hedge();
            let kernel = self.plans[&hash][slot].kernel.clone();
            self.stats.failure(&kernel);
            // A hedge expiry is a *timeout*, not a candidate error: the
            // first strike releases the candidate for a retry, only a
            // repeat offender is failed permanently.
            self.candidate_timed_out(hash, slot, candidate);
        }
        // jitune-lint: allow(L005): guarded by the `?` early return above
        if let Some(pct) = self.background.as_mut().expect("checked above").roll_window(now) {
            self.stats.background_window(pct);
        }
        let plans: Vec<(u64, usize)> = self
            .plans
            .iter()
            .flat_map(|(&hash, bucket)| (0..bucket.len()).map(move |slot| (hash, slot)))
            .collect();
        let mut exploring = false;
        for (hash, slot) in plans {
            exploring |= self.background_advance(hash, slot, now);
        }
        // jitune-lint: allow(L005): guarded by the `?` early return above
        let bg = self.background.as_ref().expect("checked above");
        let mut wake = bg.earliest_hedge();
        if exploring && bg.pct() > 0.0 {
            let refill = bg.window_end();
            wake = Some(wake.map_or(refill, |w| w.min(refill)));
        }
        wake
    }

    /// Advance one problem's background tuning: issue fresh candidates
    /// while the budget allows, or run the caller-less finalization once
    /// the strategy converged. Returns whether the problem is still
    /// exploring (and thus needs a budget-refill wake-up).
    fn background_advance(&mut self, hash: u64, slot: usize, now: Instant) -> bool {
        let (key, values, pidx) = {
            let plan = &self.plans[&hash][slot];
            (plan.key.clone(), plan.values.clone(), plan.problem_idx)
        };
        loop {
            match self.tuner.state(&key, &values).phase() {
                Phase::Tuned | Phase::Failed => return false,
                Phase::Finalizing => {
                    let decision = self.tuner.state(&key, &values).decide_background(1);
                    let BatchDecision::Finalize(winner) = decision else { return false };
                    self.background_finalize(hash, slot, winner);
                    // A failed finalize demotes back to Exploring — loop
                    // so the rematch starts this tick, not next window.
                    if self.tuner.state(&key, &values).phase() != Phase::Exploring {
                        return false;
                    }
                }
                Phase::Exploring => {
                    let cap =
                        // jitune-lint: allow(L005): Phase::Exploring only exists with background on
                        self.background.as_ref().expect("background active").issue_capacity();
                    if cap == 0 {
                        // Budget spent or pipeline full. Never consult
                        // `decide_background(0)` here: an empty proposal
                        // must mean "strategy exhausted", not "no budget".
                        return true;
                    }
                    match self.tuner.state(&key, &values).decide_background(cap) {
                        BatchDecision::Explore(fresh) => {
                            // May be empty: in-flight results are still
                            // outstanding and the strategy waits on them.
                            for cand in fresh {
                                self.background_issue(hash, slot, &key, pidx, cand, now);
                            }
                            return true;
                        }
                        BatchDecision::Finalize(winner) => {
                            self.background_finalize(hash, slot, winner);
                            if self.tuner.state(&key, &values).phase() != Phase::Exploring {
                                return false;
                            }
                        }
                        BatchDecision::Failed => return false,
                        BatchDecision::Use(_) => return false,
                    }
                }
            }
        }
    }

    /// Issue one candidate's compile+measure as a background job, with
    /// inputs synthesized from the problem's declared shapes (explore
    /// workers have no caller tensors; engines only need shape-correct
    /// data for timing).
    fn background_issue(
        &mut self,
        hash: u64,
        slot: usize,
        key: &ProblemKey,
        pidx: usize,
        cand: usize,
        now: Instant,
    ) {
        let variant = self.registry.manifest().problems[pidx].variants[cand].clone();
        let hlo = match self.cache.hlo_for(self.registry.manifest(), &variant) {
            Ok(text) => text,
            Err(e) => {
                log::warn!("background: cannot read HLO for {}: {e}", variant.id);
                self.stats.failure(&variant.kernel);
                self.candidate_failed(hash, slot, cand);
                return;
            }
        };
        let inputs: Vec<HostTensor> =
            self.plans[&hash][slot].input_shapes.iter().map(|s| HostTensor::zeros(s)).collect();
        // jitune-lint: allow(L005): callers reach here only from the background tick
        let submitted = self.background.as_mut().expect("background active").submit(
            variant.clone(),
            hlo,
            inputs,
            key.clone(),
            cand,
            hash,
            slot,
            now,
        );
        if let Err(e) = submitted {
            log::warn!("background: cannot submit {}: {e}", variant.id);
            self.stats.failure(&variant.kernel);
            self.candidate_failed(hash, slot, cand);
        }
    }

    /// The caller-less finalization of a background-tuned winner: losers
    /// evicted, the winner compiled into the instantiation cache, state
    /// confirmed, fast-lane + hub publication — no caller ever pays the
    /// finalize compile. Per-kernel `finalized` stays call-aligned (like
    /// the fused in-round finalize, and for the same reason: lane
    /// accounting must keep holding); the work shows up in the
    /// `background` stats block instead.
    fn background_finalize(&mut self, hash: u64, slot: usize, winner: usize) {
        self.finalize_pending(hash, slot, winner, "in background");
    }

    /// Caller-less finalization shared by the background scheduler and
    /// the spawn-time prewarm: losers evicted, the winner compiled into
    /// the instantiation cache, state confirmed, fast-lane + hub
    /// publication. A winner that fails to compile is demoted via
    /// `candidate_failed`, exactly like the caller-path finalize.
    /// Returns whether the winner compiled.
    fn finalize_pending(&mut self, hash: u64, slot: usize, winner: usize, how: &str) -> bool {
        let (key, variant, all_ids) = {
            let plan = &self.plans[&hash][slot];
            let problem = &self.registry.manifest().problems[plan.problem_idx];
            let all_ids: Vec<String> = problem.variants.iter().map(|v| v.id.clone()).collect();
            (plan.key.clone(), problem.variants[winner].clone(), all_ids)
        };
        self.cache.evict_losers(&all_ids, &variant.id);
        let compiled = {
            let manifest = self.registry.manifest();
            self.cache.get_or_compile(manifest, &variant).map(|_| ())
        };
        match compiled {
            Ok(()) => {
                self.tuner.state(&key, &[]).confirm_finalized(winner);
                self.publish_winner(hash, slot);
                self.hub_publish(hash, slot);
                log::info!("{key} tuned {how}: value={} ({})", variant.value, variant.id);
                true
            }
            Err(e) => {
                log::warn!("winner {} failed finalization ({how}): {e}", variant.id);
                self.stats.failure(&variant.kernel);
                self.candidate_failed(hash, slot, winner);
                false
            }
        }
    }

    /// Absorb one explore-worker result into scheduler accounting and
    /// tuner state. Stale results (hedged, retuned, reloaded) only debit
    /// the duty cycle.
    pub(crate) fn background_report(&mut self, result: ExploreResult) {
        let Some(bg) = self.background.as_mut() else { return };
        let matched = bg.absorb(&result);
        self.stats.background_job(result.busy);
        let Some((hash, slot)) = matched else {
            log::debug!(
                "background: dropped stale result for candidate {} of {}",
                result.candidate,
                result.key
            );
            return;
        };
        match result.cost {
            Ok(cost) => {
                let (key, values) = {
                    let plan = &self.plans[&hash][slot];
                    (plan.key.clone(), plan.values.clone())
                };
                self.tuner.state(&key, &values).report(result.candidate, cost);
            }
            Err(e) => {
                log::warn!(
                    "background: candidate {} of {} failed: {e}",
                    result.candidate,
                    result.key
                );
                let kernel = self.plans[&hash][slot].kernel.clone();
                self.stats.failure(&kernel);
                self.candidate_failed(hash, slot, result.candidate);
            }
        }
    }

    /// Publish the tuned winner into the fast lane: directly (the
    /// engine hands out a shared executable), or routed through the
    /// worker pool (thread-pinned engines with a pool attached —
    /// replicated finalization compiles the winner on every worker
    /// first). No-op when no lane is attached, the problem is not
    /// `Tuned`, or the winner has no off-leader execution path.
    ///
    /// The winner's *mean* measured tuning cost rides along as the
    /// entry's drift baseline (steadier than the selection-time minimum
    /// when a strategy sampled the winner more than once); a warm-started
    /// winner with an empty history publishes baseline 0, which the
    /// monitor self-calibrates from its first full window. A residually
    /// anomalous single-sample baseline can cause at most one spurious
    /// retune per cooldown — the rematch re-measures and republishes a
    /// fresh baseline, which self-corrects.
    fn publish_winner(&mut self, hash: u64, slot: usize) {
        let Some(lane) = self.fast_lane.clone() else { return };
        let (kernel, shapes, variant, size, baseline) = {
            let plan = &self.plans[&hash][slot];
            let Some(state) = self.tuner.peek(&plan.key) else { return };
            let Some(win) = state.winner_snapshot() else { return };
            let problem = &self.registry.manifest().problems[plan.problem_idx];
            let winner = &problem.variants[win.index];
            debug_assert_eq!(winner.value, win.value);
            // Cheap gate for the steady-state self-heal: with a pool
            // attached, `unshareable` is never set (a retune may succeed
            // where the last install failed), so an uncompilable winner
            // re-enters here on every tuned leader call. Bail before
            // the clones — a dead install must cost lookups, not
            // allocations.
            if let Some(pool) = &self.pool {
                if pool.install_failed(&winner.id)
                    && self.cache.shared_handle(&winner.id).is_none()
                {
                    return;
                }
            }
            let baseline = state.history().mean_of(win.index).unwrap_or(0.0);
            (plan.kernel.clone(), plan.input_shapes.clone(), winner.clone(), problem.size, baseline)
        };
        let exe = match self.cache.shared_handle(&variant.id) {
            Some(exe) => Some(exe),
            None => self.pool_handle(&variant),
        };
        match exe {
            Some(exe) => {
                log::debug!("fast lane: published {} for {kernel}", variant.id);
                lane.publish(fastlane::Publication {
                    kernel,
                    input_shapes: shapes,
                    variant_id: variant.id.clone(),
                    value: variant.value,
                    size,
                    baseline_s: baseline,
                    exe,
                });
            }
            None if self.pool.is_none() => {
                // Shareability is an engine property and never changes
                // at run time: remember the miss so the steady-state
                // leader path stops re-attempting publication.
                if let Some(bucket) = self.plans.get_mut(&hash) {
                    bucket[slot].unshareable = true;
                }
                log::debug!("fast lane: {} is thread-pinned; leader keeps serving", variant.id);
            }
            None => {
                // Pool attached but the install failed: the pool memoized
                // the failure, so re-attempts (the lazy self-heal on
                // leader tuned calls) cost one map lookup. A retune
                // clears the memo and retries the broadcast.
                log::debug!("fast lane: {} has no pool route; leader keeps serving", variant.id);
            }
        }
    }

    /// Replicated finalization: broadcast the winner (variant + HLO
    /// text) to the worker pool so every thread-pinned engine compiles a
    /// private copy, then wrap the pool in the `SharedKernel` the fast
    /// lane publishes. `None` when no pool is attached, the HLO cannot
    /// be read, or no worker could compile the winner.
    fn pool_handle(&mut self, variant: &Variant) -> Option<Arc<dyn SharedKernel>> {
        let pool = self.pool.clone()?;
        // Probe the failure memo before touching the HLO cache: the
        // steady-state self-heal retries this on every tuned leader
        // call, and a dead install must cost a lookup, not a text copy.
        if pool.install_failed(&variant.id) {
            return None;
        }
        let hlo = match self.cache.hlo_for(self.registry.manifest(), variant) {
            Ok(text) => text,
            Err(e) => {
                log::warn!("pool: cannot read HLO for {}: {e}", variant.id);
                pool.mark_failed(&variant.id);
                return None;
            }
        };
        if pool.install(variant.clone(), hlo) == 0 {
            return None;
        }
        Some(pool.handle_for(variant.id.clone()))
    }

    /// One tuning iteration: compile (uncached — the paper keeps only
    /// ASTs during tuning, not binaries), run under the metric, discard
    /// the executable, report the cost.
    fn explore(
        &mut self,
        key: &ProblemKey,
        variant: &Variant,
        idx: usize,
        inputs: &[HostTensor],
        t0: Instant,
    ) -> Result<CallOutcome> {
        let (output, cost, compiled) = {
            let manifest = self.registry.manifest();
            let (exe, compiled) = self.cache.get_or_compile(manifest, variant)?;
            let begin = self.metric.begin();
            let output = exe.execute(inputs)?;
            let cost = self.metric.end(begin);
            (output, cost, compiled)
        };
        // Tuning iterations do not populate the instantiation cache: only
        // tuning info is kept (paper §3.2 "Generating variants").
        self.cache.evict(&variant.id);
        let st = self.tuner.state(key, &[]);
        st.report(idx, cost);
        self.stats.explored(&variant.kernel, t0.elapsed());
        Ok(CallOutcome {
            output,
            variant_id: variant.id.clone(),
            value: variant.value,
            route: CallRoute::Explored,
            compiled,
            exec_cost: cost,
            total: t0.elapsed(),
        })
    }

    /// The winner's final compilation (paper: "generating the best
    /// specialization one last time ... inserted into __clang_jit's cache
    /// of instantiations"), plus eviction of the losers.
    fn finalize(
        &mut self,
        variant: &Variant,
        all_ids: &[String],
        inputs: &[HostTensor],
        t0: Instant,
    ) -> Result<CallOutcome> {
        self.cache.evict_losers(all_ids, &variant.id);
        let manifest = self.registry.manifest();
        let (exe, compiled) = self.cache.get_or_compile(manifest, variant)?;
        let begin = self.metric.begin();
        let output = exe.execute(inputs)?;
        let cost = self.metric.end(begin);
        Ok(CallOutcome {
            output,
            variant_id: variant.id.clone(),
            value: variant.value,
            route: CallRoute::Finalized,
            compiled,
            exec_cost: cost,
            total: t0.elapsed(),
        })
    }

    /// One drift-policy evaluation pass: drain every monitored fast-lane
    /// entry's latency window and retune the problems the policy flags.
    /// The coordinator's leader loop calls this every `DriftPolicy::window`;
    /// tests may drive it directly for determinism. Returns the number of
    /// retunes triggered (0 when no lane or no drift policy is attached).
    pub fn drift_tick(&mut self) -> usize {
        let Some(lane) = self.fast_lane.clone() else { return 0 };
        let hits = lane.drift_scan();
        let mut retuned = 0;
        for hit in hits {
            log::warn!(
                "drift: {}/n{} window mean {:.3}ms = {:.2}x baseline {:.3}ms ({}); retuning",
                hit.kernel,
                hit.size,
                hit.window.mean_s * 1e3,
                hit.window.ratio,
                hit.baseline_s * 1e3,
                hit.variant_id,
            );
            match self.retune(&hit.kernel, hit.size) {
                Ok(_) => {
                    self.stats.drift_retune(&hit.kernel, hit.window.ratio);
                    retuned += 1;
                }
                Err(e) => log::warn!("drift: retune of {}/n{} failed: {e}", hit.kernel, hit.size),
            }
        }
        retuned
    }

    /// One failure-breaker evaluation pass: drain every monitored
    /// fast-lane entry's ok/error window and *demote* the winners whose
    /// breaker tripped — the erroring variant is quarantined (marked
    /// failed and barred from re-selection for
    /// [`QuarantinePolicy::quarantine_for`]) and the next-best variant
    /// from tuning history is finalized and published as the fallback,
    /// immediately, without waiting for a caller. The leader loop calls
    /// this every `QuarantinePolicy::window`; tests may drive it
    /// directly. Returns the number of winners demoted.
    pub fn quarantine_tick(&mut self, now: Instant) -> usize {
        self.expire_quarantines(now);
        let Some(lane) = self.fast_lane.clone() else { return 0 };
        let hits = lane.quarantine_scan();
        if hits.is_empty() {
            return 0;
        }
        let quarantine_for = lane
            .quarantine_policy()
            .map(|p| p.quarantine_for)
            .unwrap_or_else(|| QuarantinePolicy::default().quarantine_for);
        let mut demoted = 0;
        for hit in hits {
            log::warn!(
                "quarantine: {}/n{} winner {} error rate {:.0}% over {} calls; demoting",
                hit.kernel,
                hit.size,
                hit.variant_id,
                hit.window.error_rate * 100.0,
                hit.window.samples,
            );
            // Resolve the problem's call plan from the entry's published
            // shapes (the plan exists — publication happens through it —
            // but synthesizing inputs keeps this pass self-sufficient).
            let inputs: Vec<HostTensor> =
                hit.input_shapes.iter().map(|s| HostTensor::zeros(s)).collect();
            let (hash, slot) = match self.plan_slot(&hit.kernel, &inputs) {
                Ok(id) => id,
                Err(e) => {
                    log::warn!("quarantine: cannot plan {}/n{}: {e}", hit.kernel, hit.size);
                    continue;
                }
            };
            let (key, values, idx) = {
                let plan = &self.plans[&hash][slot];
                let problem = &self.registry.manifest().problems[plan.problem_idx];
                let idx = problem.variants.iter().position(|v| v.id == hit.variant_id);
                (plan.key.clone(), plan.values.clone(), idx)
            };
            let Some(idx) = idx else {
                log::warn!("quarantine: {} is not a variant of {key}", hit.variant_id);
                continue;
            };
            // Evict the broken variant everywhere it might still serve:
            // fast lane entry, leader cache, pool replicas.
            lane.invalidate(&hit.kernel, &hit.input_shapes);
            self.cache.evict(&hit.variant_id);
            if let Some(pool) = &self.pool {
                pool.evict(std::slice::from_ref(&hit.variant_id));
            }
            if let Some(bg) = self.background.as_mut() {
                bg.forget_candidate(&key, idx);
            }
            self.quarantined.entry(key.clone()).or_default().push((idx, now + quarantine_for));
            self.stats.quarantine(&hit.kernel, &hit.variant_id, hit.window.error_rate);
            demoted += 1;
            match self.tuner.state(&key, &values).demote_winner(idx) {
                Some(fallback) => {
                    // Finalize the fallback right now so callers return
                    // to the fast lane (and the fleet hears about the
                    // demotion) without waiting for the next request.
                    self.finalize_pending(hash, slot, fallback, "after quarantine");
                }
                None => {
                    log::warn!(
                        "quarantine: {key} has no surviving variant; problem marked failed"
                    );
                }
            }
        }
        demoted
    }

    /// Drop expired quarantine marks so a later retune may try the
    /// variant again (the fault may have been environmental).
    fn expire_quarantines(&mut self, now: Instant) {
        self.quarantined.retain(|_, marks| {
            marks.retain(|&(_, until)| until > now);
            !marks.is_empty()
        });
    }

    /// Restart tuning for a problem: tuner state is reset to exploring,
    /// resident executables are evicted (every candidate pays its compile
    /// again — only HLO text persists, as in the paper), and the
    /// published fast-lane entry is invalidated so callers return to the
    /// leader until a new winner is finalized. Returns whether tuner
    /// state existed.
    pub fn retune(&mut self, kernel: &str, size: i64) -> Result<bool> {
        let (key, kernel_name, shapes, variant_ids) = {
            let problem = self.registry.problem(kernel, size)?;
            let shapes = problem.variants[0].input_shapes()?;
            let ids: Vec<String> = problem.variants.iter().map(|v| v.id.clone()).collect();
            (ProblemKey::for_problem(problem), problem.kernel.clone(), shapes, ids)
        };
        let existed = self.tuner.retune(&key);
        // In-flight background results were measured against the old
        // state: drop their bookkeeping so they cannot report into the
        // fresh one. Timeout strikes belong to the old state too.
        if let Some(bg) = self.background.as_mut() {
            bg.forget_key(&key);
        }
        self.timeout_strikes.retain(|(k, _), _| k != &key);
        // Re-apply unexpired quarantine marks: the rematch must not
        // immediately re-pick a variant the failure breaker just demoted.
        if existed {
            if let Some(marks) = self.quarantined.get(&key) {
                let now = Instant::now();
                for &(idx, until) in marks {
                    if until > now {
                        self.tuner.state(&key, &[]).report_failure(idx);
                    }
                }
            }
        }
        for id in &variant_ids {
            self.cache.evict(id);
        }
        if let Some(lane) = &self.fast_lane {
            lane.invalidate(&kernel_name, &shapes);
        }
        if let Some(pool) = &self.pool {
            // Workers drop their replicated copies and the failed-install
            // memo resets, so the rematch's winner re-broadcasts fresh.
            pool.evict(&variant_ids);
        }
        if existed {
            log::info!("retune: {key} reset to exploring; published entry invalidated");
        }
        Ok(existed)
    }

    /// Tuned parameter value for a kernel at a problem size, once tuned
    /// (the paper's Listing 6 parameter reuse).
    pub fn tuned_value(&self, kernel: &str, size: i64) -> Option<i64> {
        let problem = self.registry.problem(kernel, size).ok()?;
        let key =
            ProblemKey::new(&problem.kernel, &problem.param, problem.variants[0].inputs.join(","));
        self.tuner.tuned_value(&key)
    }

    /// Tuning phase for a kernel/size, if any state exists.
    pub fn phase(&self, kernel: &str, size: i64) -> Option<Phase> {
        let problem = self.registry.problem(kernel, size).ok()?;
        let key =
            ProblemKey::new(&problem.kernel, &problem.param, problem.variants[0].inputs.join(","));
        self.tuner.peek(&key).map(|s| s.phase())
    }

    /// Registry accessor.
    pub fn registry(&self) -> &KernelRegistry {
        &self.registry
    }

    /// Coordinator statistics.
    pub fn stats(&self) -> &CoordStats {
        &self.stats
    }

    /// Mutable statistics (the server leader records queue depths here).
    pub fn stats_mut(&mut self) -> &mut CoordStats {
        &mut self.stats
    }

    /// Compile-cache statistics.
    pub fn cache_stats(&self) -> CacheStats {
        self.cache.stats()
    }

    /// Autotuner report (CLI `inspect`).
    pub fn tuning_report(&self) -> crate::util::json::Value {
        self.tuner.report()
    }

    /// Persist tuned results to a JSON file (see
    /// [`crate::autotuner::Autotuner::export_state`]). The write is
    /// atomic (`.tmp` sibling + rename) so a crash mid-write can never
    /// leave a torn file for `load_state` or a hub import to choke on.
    pub fn save_state(&self, path: &std::path::Path) -> Result<usize> {
        let state = self.tuner.export_state();
        let n = state.as_arr().map(<[_]>::len).unwrap_or(0);
        crate::util::atomic_write(path, &state.to_json_pretty())?;
        Ok(n)
    }

    /// Warm-start from persisted tuning results — a plain `save_state`
    /// array or a `jitune state export` cache artifact. Entries are
    /// validated against the live manifest: a problem whose candidate
    /// values changed since the state was saved is skipped (stale
    /// results must not be trusted across artifact regenerations).
    /// Returns (imported, skipped).
    pub fn load_state(&mut self, path: &std::path::Path) -> Result<(usize, usize)> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::io(path.display().to_string(), e))?;
        let parsed = crate::util::json::parse(&text)?;
        let arr = crate::hub::state_entry_values(&parsed)?;
        let mut valid = Vec::new();
        let mut skipped = 0;
        for entry in arr {
            let kernel = entry.req_str("kernel")?;
            let param = entry.req_str("param")?;
            let signature = entry.req_str("signature")?;
            let values: Vec<i64> = entry
                .req_arr("values")?
                .iter()
                .filter_map(crate::util::json::Value::as_i64)
                .collect();
            let matches = self.matching_problem(kernel, param, signature, &values).is_some();
            if matches {
                valid.push(entry.clone());
            } else {
                log::warn!("state: skipping stale entry {kernel}/{param} ({signature})");
                skipped += 1;
            }
        }
        // Imported winners replace live tuning state wholesale; published
        // entries may describe superseded winners, so drop them all — the
        // leader republishes lazily after each import's finalization.
        if let Some(lane) = &self.fast_lane {
            lane.clear();
        }
        if let Some(pool) = &self.pool {
            pool.clear();
        }
        if let Some(bg) = self.background.as_mut() {
            bg.forget_all();
        }
        let imported = self.tuner.import_state(&crate::util::json::Value::Arr(valid))?;
        Ok((imported, skipped))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::mock::{MockEngine, MockSpec};
    use std::time::Duration;

    fn dispatcher(spec: MockSpec) -> Dispatcher {
        let manifest = crate::manifest::tests::sample_manifest().unwrap();
        let registry = KernelRegistry::new(manifest);
        Dispatcher::new(registry, Box::new(MockEngine::new(spec)))
    }

    fn inputs8() -> Vec<HostTensor> {
        vec![HostTensor::zeros(&[8, 8])]
    }

    #[test]
    fn full_lifecycle_explore_finalize_use() {
        // k.a.n8 (value 1) slow, k.b.n8 (value 2) fast → tuner must pick b.
        let spec = MockSpec::default()
            .with_cost("k.a.n8", Duration::from_micros(600))
            .with_cost("k.b.n8", Duration::from_micros(60));
        let mut d = dispatcher(spec);
        let routes: Vec<CallRoute> =
            (0..5).map(|_| d.call("k", &inputs8()).unwrap().route).collect();
        assert_eq!(
            routes,
            vec![
                CallRoute::Explored,
                CallRoute::Explored,
                CallRoute::Finalized,
                CallRoute::Tuned,
                CallRoute::Tuned
            ]
        );
        assert_eq!(d.tuned_value("k", 8), Some(2));
        // output of tuned calls encodes the winning variant's value
        let out = d.call("k", &inputs8()).unwrap();
        assert!(out.output.data().iter().all(|&x| x == 2.0));
    }

    #[test]
    fn explore_calls_pay_compile_finalize_pays_again() {
        let mut d = dispatcher(MockSpec::default());
        let o1 = d.call("k", &inputs8()).unwrap();
        assert!(o1.compiled, "tuning iteration JIT-compiles");
        let o2 = d.call("k", &inputs8()).unwrap();
        assert!(o2.compiled);
        let o3 = d.call("k", &inputs8()).unwrap();
        assert_eq!(o3.route, CallRoute::Finalized);
        assert!(o3.compiled, "the paper's final compilation is a real compile");
        let o4 = d.call("k", &inputs8()).unwrap();
        assert!(!o4.compiled, "steady state hits the instantiation cache");
        // cache holds only the winner
        assert_eq!(d.cache_stats().misses, 3);
    }

    #[test]
    fn different_shapes_are_independent_problems() {
        let spec = MockSpec::default()
            .with_cost("k.a.n8", Duration::from_micros(60))
            .with_cost("k.b.n8", Duration::from_micros(600));
        let mut d = dispatcher(spec);
        // tune the n8 problem to completion
        for _ in 0..4 {
            d.call("k", &inputs8()).unwrap();
        }
        assert_eq!(d.tuned_value("k", 8), Some(1));
        // n16 problem starts fresh (single variant k.a.n16)
        let o = d.call("k", &[HostTensor::zeros(&[16, 16])]).unwrap();
        assert_eq!(o.route, CallRoute::Explored);
        assert_eq!(d.tuned_value("k", 16), None);
    }

    #[test]
    fn compile_failure_skips_variant() {
        let mut spec = MockSpec::default()
            .with_cost("k.a.n8", Duration::from_micros(50))
            .with_cost("k.b.n8", Duration::from_micros(500));
        spec.fail_compile.insert("k.a.n8".into());
        let mut d = dispatcher(spec);
        // first call: variant a fails to compile, dispatcher retries with b
        let o = d.call("k", &inputs8()).unwrap();
        assert_eq!(o.variant_id, "k.b.n8");
        // tuning completes with only b alive
        let o2 = d.call("k", &inputs8()).unwrap();
        assert_eq!(o2.route, CallRoute::Finalized);
        assert_eq!(d.tuned_value("k", 8), Some(2));
        assert_eq!(d.stats().total_failures(), 1);
    }

    #[test]
    fn all_variants_failing_is_an_error() {
        let mut spec = MockSpec::default();
        spec.fail_compile.insert("k.a.n8".into());
        spec.fail_compile.insert("k.b.n8".into());
        let mut d = dispatcher(spec);
        let err = d.call("k", &inputs8()).err().expect("must fail");
        assert!(matches!(err, Error::Autotune(_)), "{err:?}");
        assert!(err.to_string().contains("every variant"), "{err}");
        // subsequent calls keep failing fast through Decision::Failed
        let err2 = d.call("k", &inputs8()).err().expect("still failing");
        assert!(matches!(err2, Error::Autotune(_)), "{err2:?}");
    }

    #[test]
    fn unknown_kernel_and_bad_shape() {
        let mut d = dispatcher(MockSpec::default());
        assert!(d.call("nope", &inputs8()).is_err());
        assert!(d.call("k", &[HostTensor::zeros(&[5, 5])]).is_err());
    }

    #[test]
    fn state_roundtrip_warm_starts_without_tuning() {
        let spec = MockSpec::default()
            .with_cost("k.a.n8", Duration::from_micros(600))
            .with_cost("k.b.n8", Duration::from_micros(60));
        let mut d = dispatcher(spec.clone());
        for _ in 0..4 {
            d.call("k", &inputs8()).unwrap();
        }
        assert_eq!(d.tuned_value("k", 8), Some(2));
        let path = std::env::temp_dir().join(format!("jitune-state-{}.json", std::process::id()));
        assert_eq!(d.save_state(&path).unwrap(), 1);

        // fresh dispatcher, same manifest layout: import → no explores
        let mut d2 = dispatcher(spec);
        let (imported, skipped) = d2.load_state(&path).unwrap();
        assert_eq!((imported, skipped), (1, 0));
        let first = d2.call("k", &inputs8()).unwrap();
        // warm start: the winner is recompiled once (HLO-text-only
        // persistence, like the paper's AST cache) but never explored
        assert_eq!(first.route, CallRoute::Finalized);
        assert_eq!(first.value, 2);
        let second = d2.call("k", &inputs8()).unwrap();
        assert_eq!(second.route, CallRoute::Tuned);
        assert_eq!(d2.stats().kernel("k").unwrap().explored, 0);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn stale_state_entries_are_skipped() {
        let mut d = dispatcher(MockSpec::default());
        let path =
            std::env::temp_dir().join(format!("jitune-stale-{}.json", std::process::id()));
        // candidate values [9, 99] do not match the manifest's [1, 2]
        std::fs::write(
            &path,
            r#"[{"kernel":"k","param":"p","signature":"f32[8,8]",
                 "values":[9,99],"winner_value":9}]"#,
        )
        .unwrap();
        let (imported, skipped) = d.load_state(&path).unwrap();
        assert_eq!((imported, skipped), (0, 1));
        // tuning starts from scratch
        let first = d.call("k", &inputs8()).unwrap();
        assert_eq!(first.route, CallRoute::Explored);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn corrupt_state_winner_errors_instead_of_panicking() {
        let mut d = dispatcher(MockSpec::default());
        let path =
            std::env::temp_dir().join(format!("jitune-corrupt-{}.json", std::process::id()));
        // candidate values match the manifest, but the recorded winner is
        // not among them: a corrupt / hand-edited state file
        std::fs::write(
            &path,
            r#"[{"kernel":"k","param":"p","signature":"f32[8,8]",
                 "values":[1,2],"winner_value":99}]"#,
        )
        .unwrap();
        let err = d.load_state(&path).err().expect("corrupt winner must error");
        assert!(matches!(err, Error::Autotune(_)), "{err:?}");
        assert!(err.to_string().contains("winner"), "{err}");
        // the dispatcher stays usable: tuning starts from scratch
        let first = d.call("k", &inputs8()).unwrap();
        assert_eq!(first.route, CallRoute::Explored);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn stats_accumulate() {
        let mut d = dispatcher(MockSpec::default());
        for _ in 0..6 {
            d.call("k", &inputs8()).unwrap();
        }
        let s = d.stats();
        assert_eq!(s.kernel("k").unwrap().explored, 2);
        assert_eq!(s.kernel("k").unwrap().finalized, 1);
        assert_eq!(s.kernel("k").unwrap().tuned, 3);
        assert_eq!(s.total_calls(), 6);
    }

    #[test]
    fn fast_lane_published_on_finalize() {
        let spec = MockSpec::default()
            .with_cost("k.a.n8", Duration::from_micros(600))
            .with_cost("k.b.n8", Duration::from_micros(60));
        let mut d = dispatcher(spec);
        let lane = Arc::new(FastLane::new());
        d.set_fast_lane(lane.clone());
        assert!(lane.lookup("k", &inputs8()).is_none());
        for _ in 0..3 {
            d.call("k", &inputs8()).unwrap();
        }
        // finalization published the winner; it executes off-leader
        let entry = lane.lookup("k", &inputs8()).expect("published on finalize");
        assert_eq!(entry.variant_id(), "k.b.n8");
        let out = entry.call(&inputs8(), Instant::now()).unwrap();
        assert_eq!(out.route, CallRoute::Tuned);
        assert!(out.output.data().iter().all(|&x| x == 2.0));
    }

    #[test]
    fn retune_invalidates_published_entry_and_reexplores() {
        let spec = MockSpec::default()
            .with_cost("k.a.n8", Duration::from_micros(600))
            .with_cost("k.b.n8", Duration::from_micros(60));
        let mut d = dispatcher(spec);
        let lane = Arc::new(FastLane::new());
        d.set_fast_lane(lane.clone());
        for _ in 0..3 {
            d.call("k", &inputs8()).unwrap();
        }
        assert!(lane.lookup("k", &inputs8()).is_some());
        assert!(d.retune("k", 8).unwrap());
        assert!(lane.lookup("k", &inputs8()).is_none(), "retune unpublishes");
        assert_eq!(d.tuned_value("k", 8), None);
        let o = d.call("k", &inputs8()).unwrap();
        assert_eq!(o.route, CallRoute::Explored);
        assert!(o.compiled, "retune evicted the resident winner");
        // tuning completes again and republishes
        for _ in 0..2 {
            d.call("k", &inputs8()).unwrap();
        }
        assert!(lane.lookup("k", &inputs8()).is_some(), "republished");
        // unknown problems report an error, untuned ones Ok(false)
        assert!(d.retune("nope", 8).is_err());
        assert!(!d.retune("k", 16).unwrap());
    }

    #[test]
    fn thread_pinned_engine_never_publishes_but_keeps_serving() {
        // An engine whose kernels keep the default `shared() -> None`
        // (the PJRT shape): the lane must stay empty, steady-state calls
        // must keep working through the leader path, and the plan
        // remembers the miss so publication is not re-attempted.
        struct PinnedKernel {
            id: String,
            shape: Vec<usize>,
        }
        impl crate::runtime::CompiledKernel for PinnedKernel {
            fn execute(&self, _inputs: &[HostTensor]) -> crate::Result<HostTensor> {
                Ok(HostTensor::full(&self.shape, 7.0))
            }
            fn variant_id(&self) -> &str {
                &self.id
            }
        }
        struct PinnedEngine;
        impl Engine for PinnedEngine {
            fn compile(
                &self,
                variant: &crate::manifest::Variant,
                _hlo: &str,
            ) -> crate::Result<Box<dyn crate::runtime::CompiledKernel>> {
                Ok(Box::new(PinnedKernel {
                    id: variant.id.clone(),
                    shape: variant.output_shape()?,
                }))
            }
            fn name(&self) -> &str {
                "pinned"
            }
        }

        let manifest = crate::manifest::tests::sample_manifest().unwrap();
        let mut d = Dispatcher::new(KernelRegistry::new(manifest), Box::new(PinnedEngine));
        let lane = Arc::new(FastLane::new());
        d.set_fast_lane(lane.clone());
        for _ in 0..6 {
            let o = d.call("k", &inputs8()).unwrap();
            assert!(o.output.data().iter().all(|&x| x == 7.0));
        }
        assert_eq!(lane.published(), 0, "thread-pinned executables never publish");
        assert_eq!(d.stats().kernel("k").unwrap().tuned, 3, "leader keeps serving");
    }

    #[test]
    fn lane_republished_lazily_after_late_attach() {
        let mut d = dispatcher(MockSpec::default());
        for _ in 0..4 {
            d.call("k", &inputs8()).unwrap();
        }
        // lane attached after tuning finished: the next steady call
        // self-heals the missing entry
        let lane = Arc::new(FastLane::new());
        d.set_fast_lane(lane.clone());
        assert!(lane.lookup("k", &inputs8()).is_none());
        let o = d.call("k", &inputs8()).unwrap();
        assert_eq!(o.route, CallRoute::Tuned);
        assert!(lane.lookup("k", &inputs8()).is_some(), "lazy republish");
    }

    #[test]
    fn drift_tick_retunes_a_degraded_winner() {
        use crate::coordinator::drift::DriftPolicy;
        let spec = MockSpec::default()
            .with_cost("k.a.n8", Duration::from_micros(500))
            .with_cost("k.b.n8", Duration::from_micros(300));
        let fault = spec.latency_fault.clone();
        let mut d = dispatcher(spec);
        let policy = DriftPolicy {
            min_samples: 4,
            ratio_threshold: 2.0,
            cooldown: Duration::ZERO,
            consecutive_windows: 2,
            ..DriftPolicy::default()
        };
        let lane = Arc::new(FastLane::with_drift(policy));
        d.set_fast_lane(lane.clone());
        for _ in 0..3 {
            d.call("k", &inputs8()).unwrap();
        }
        assert_eq!(d.tuned_value("k", 8), Some(2));
        assert_eq!(d.drift_tick(), 0, "healthy winner never retunes");

        // degrade the winner 3x at execution: 900us, well past a's 500us
        fault.set_scale("k.b.n8", 3.0);
        let entry = lane.lookup("k", &inputs8()).unwrap();
        for _ in 0..8 {
            entry.call(&inputs8(), Instant::now()).unwrap();
        }
        assert_eq!(d.drift_tick(), 0, "hysteresis: one bad window is not drift");
        let entry = lane.lookup("k", &inputs8()).expect("still published");
        for _ in 0..8 {
            entry.call(&inputs8(), Instant::now()).unwrap();
        }
        assert_eq!(d.drift_tick(), 1, "second consecutive bad window retunes");
        assert!(lane.lookup("k", &inputs8()).is_none(), "published entry invalidated");
        assert_eq!(d.tuned_value("k", 8), None);

        // re-exploration measures the degraded winner honestly: the
        // previously-losing variant wins the rematch
        for _ in 0..3 {
            d.call("k", &inputs8()).unwrap();
        }
        assert_eq!(d.tuned_value("k", 8), Some(1), "converged to a new winner");
        assert!(lane.lookup("k", &inputs8()).is_some(), "new winner republished");
        assert_eq!(d.stats().kernel("k").unwrap().drift_retunes, 1);
        assert_eq!(d.stats().drift_events().len(), 1);
        assert!(d.stats().drift_events()[0].ratio > 2.0);
    }

    #[test]
    fn quarantine_tick_demotes_erroring_winner_to_fallback() {
        let spec = MockSpec::default()
            .with_cost("k.a.n8", Duration::from_micros(500))
            .with_cost("k.b.n8", Duration::from_micros(300));
        let fault = spec.latency_fault.clone();
        let mut d = dispatcher(spec);
        let policy = QuarantinePolicy {
            min_samples: 4,
            error_threshold: 0.5,
            consecutive_windows: 1,
            cooldown: Duration::ZERO,
            ..QuarantinePolicy::default()
        };
        let lane = Arc::new(FastLane::with_policies(None, Some(policy)));
        d.set_fast_lane(lane.clone());
        for _ in 0..3 {
            d.call("k", &inputs8()).unwrap();
        }
        assert_eq!(d.tuned_value("k", 8), Some(2));
        let entry = lane.lookup("k", &inputs8()).unwrap();
        for _ in 0..6 {
            entry.call(&inputs8(), Instant::now()).unwrap();
        }
        assert_eq!(d.quarantine_tick(Instant::now()), 0, "healthy winner never demotes");

        // the published winner starts erroring at execution
        fault.fail_execute("k.b.n8");
        let entry = lane.lookup("k", &inputs8()).unwrap();
        for _ in 0..6 {
            entry.call(&inputs8(), Instant::now()).expect_err("injected exec error");
        }
        assert_eq!(d.quarantine_tick(Instant::now()), 1, "breaker demotes the winner");
        // the fallback (next-best from tuning history) finalized and
        // republished immediately — no caller had to pay the rematch
        assert_eq!(d.tuned_value("k", 8), Some(1), "next-best variant serves");
        let fallback = lane.lookup("k", &inputs8()).expect("fallback published");
        assert_eq!(fallback.value(), 1);
        let out = fallback.call(&inputs8(), Instant::now()).unwrap();
        assert!(out.output.data().iter().all(|&x| x == 1.0));
        assert_eq!(d.stats().quarantine_events().len(), 1);
        assert_eq!(d.stats().quarantine_events()[0].variant_id, "k.b.n8");

        // a retune inside the quarantine window re-applies the mark: the
        // rematch cannot re-pick the variant that just erred off the lane
        d.retune("k", 8).unwrap();
        for _ in 0..3 {
            let _ = d.call("k", &inputs8());
        }
        assert_eq!(d.tuned_value("k", 8), Some(1), "quarantined variant not re-picked");
    }

    #[test]
    fn quarantine_with_no_survivors_fails_the_problem() {
        let spec = MockSpec::default().with_cost("k.b.n8", Duration::from_micros(100));
        let fault = spec.latency_fault.clone();
        let mut d = dispatcher(spec);
        let policy = QuarantinePolicy {
            min_samples: 4,
            consecutive_windows: 1,
            cooldown: Duration::ZERO,
            ..QuarantinePolicy::default()
        };
        let lane = Arc::new(FastLane::with_policies(None, Some(policy)));
        d.set_fast_lane(lane.clone());
        for _ in 0..3 {
            d.call("k", &inputs8()).unwrap();
        }
        // kill the loser first so no fallback survives, then the winner
        let winner = d.tuned_value("k", 8).unwrap();
        let loser_idx = if winner == 2 { 0 } else { 1 };
        {
            let (hash, slot) = d.plan_slot("k", &inputs8()).unwrap();
            d.candidate_failed(hash, slot, loser_idx);
        }
        // candidate_failed invalidated the lane entry; the next tuned
        // leader call self-heals (republishes), then errors accumulate
        d.call("k", &inputs8()).unwrap();
        let entry = lane.lookup("k", &inputs8()).expect("republished");
        fault.fail_execute(if winner == 2 { "k.b.n8" } else { "k.a.n8" });
        for _ in 0..6 {
            entry.call(&inputs8(), Instant::now()).expect_err("injected exec error");
        }
        assert_eq!(d.quarantine_tick(Instant::now()), 1);
        assert_eq!(d.tuned_value("k", 8), None);
        assert!(lane.lookup("k", &inputs8()).is_none(), "nothing left to publish");
        let err = d.call("k", &inputs8()).expect_err("every variant dead");
        assert!(err.to_string().contains("failed"), "{err}");
    }

    #[test]
    fn candidate_timeout_first_strike_releases_then_escalates() {
        let mut d = dispatcher(MockSpec::default());
        let (hash, slot) = d.plan_slot("k", &inputs8()).unwrap();
        let (key, values) = {
            let plan = &d.plans[&hash][slot];
            (plan.key.clone(), plan.values.clone())
        };
        let Decision::Explore(idx) = d.tuner.state(&key, &values).decide() else {
            panic!("fresh problem explores");
        };
        // first timeout: transient — the candidate stays proposable
        d.candidate_timed_out(hash, slot, idx);
        let again = d.tuner.state(&key, &values).decide();
        assert!(
            matches!(again, Decision::Explore(i) if i == idx),
            "released candidate re-proposed: {again:?}"
        );
        // second timeout for the same candidate: permanent failure
        d.candidate_timed_out(hash, slot, idx);
        let next = d.tuner.state(&key, &values).decide();
        assert!(
            !matches!(next, Decision::Explore(i) if i == idx),
            "twice-wedged candidate excluded: {next:?}"
        );
    }

    #[test]
    fn hub_publish_and_warm_start_roundtrip() {
        use crate::hub::{HubClient, HubOptions, HubServer};
        let path = crate::testutil::temp_path("disp-hub", "sock");
        HubServer::bind(&path).unwrap().spawn();
        let spec = MockSpec::default()
            .with_cost("k.a.n8", Duration::from_micros(600))
            .with_cost("k.b.n8", Duration::from_micros(60));

        // process A tunes from scratch; finalization publishes to the hub
        let mut a = dispatcher(spec.clone());
        a.attach_hub(HubClient::connect(HubOptions::at(&path)).unwrap());
        for _ in 0..3 {
            a.call("k", &inputs8()).unwrap();
        }
        assert_eq!(a.tuned_value("k", 8), Some(2));
        assert_eq!(a.stats().hub().pushes, 1, "finalize pushed the winner");

        // process B warm-starts off the hub: zero explore iterations
        let mut b = dispatcher(spec);
        b.attach_hub(HubClient::connect(HubOptions::at(&path)).unwrap());
        assert_eq!(b.hub_pull().unwrap(), (1, 0));
        let first = b.call("k", &inputs8()).unwrap();
        assert_eq!(first.route, CallRoute::Finalized, "only the final compile remains");
        assert_eq!(first.value, 2);
        assert_eq!(b.stats().kernel("k").unwrap().explored, 0);
        // a re-pull with nothing new adopts nothing; refinalizing a
        // hub-adopted winner re-asserts it at its known version —
        // idempotent on the broker (no version burn, no conflict), and
        // the re-seed path should the in-memory broker ever restart
        assert_eq!(b.hub_pull().unwrap(), (0, 0));
        assert_eq!(b.stats().hub().pushes, 1, "re-assert, not a silent skip");
        assert_eq!(b.stats().hub().conflicts, 0, "re-assert merges as Stale");
        assert_eq!(b.stats().hub().pulls, 2);
        assert_eq!(b.stats().hub().adopted, 1);
        // the broker's entry is untouched by the re-assert
        let mut probe = HubClient::connect(HubOptions::at(&path)).unwrap();
        let held = probe.pull_all().unwrap();
        assert_eq!(held.len(), 1);
        assert_eq!((held[0].winner_value, held[0].version), (2, 1));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn fused_batch_explores_finalizes_in_round_and_counts() {
        let spec = MockSpec::default()
            .with_cost("k.a.n8", Duration::from_micros(600))
            .with_cost("k.b.n8", Duration::from_micros(60));
        let mut d = dispatcher(spec);
        let lane = Arc::new(FastLane::new());
        d.set_fast_lane(lane.clone());
        // 4 co-scheduled calls, 2 candidates: both explored in one round,
        // each with one surplus replica; the sweep converges and the
        // winner finalizes *within* the round.
        let round: Vec<Vec<HostTensor>> = (0..4).map(|_| inputs8()).collect();
        let results = d.call_batch("k", round);
        assert_eq!(results.len(), 4);
        for r in &results {
            let o = r.as_ref().expect("fused explores succeed");
            assert_eq!(o.route, CallRoute::Explored);
        }
        assert_eq!(d.tuned_value("k", 8), Some(2), "finalized in-round");
        assert!(lane.lookup("k", &inputs8()).is_some(), "winner published in-round");
        let f = d.stats().fused();
        assert_eq!(f.fused_rounds, 1);
        assert_eq!(f.fused_calls, 4);
        assert_eq!(f.replicated_measurements, 2);
        // serial dispatch reaches Tuned in 3 rounds (explore, explore,
        // finalize); the fused round does it in 1 — 2 rounds saved
        assert_eq!(f.explore_rounds_saved, 2);
        // each candidate compiled exactly once despite the replicas, and
        // the tuner saw exactly one (median) sample per candidate
        assert_eq!(d.cache_stats().misses, 3, "2 explores + 1 finalize compile");
        let st = d.stats().kernel("k").unwrap();
        // the in-round finalize has no caller: per-kernel counters stay
        // call-aligned (explored only), the fused counters carry the save
        assert_eq!((st.explored, st.finalized), (4, 0));
        // the next round is pure steady state
        let next = d.call_batch("k", vec![inputs8(), inputs8()]);
        for r in next {
            assert_eq!(r.unwrap().route, CallRoute::Tuned);
        }
    }

    #[test]
    fn fused_candidate_failure_only_fails_its_callers() {
        // b fails at execution: in a fused round of 4 (2 candidates × 2
        // replicas) exactly the two calls assigned to b error; a's calls
        // succeed, and the round still converges to a as the winner.
        let mut spec = MockSpec::default()
            .with_cost("k.a.n8", Duration::from_micros(600))
            .with_cost("k.b.n8", Duration::from_micros(60));
        spec.fail_execute.insert("k.b.n8".into());
        let mut d = dispatcher(spec);
        let round: Vec<Vec<HostTensor>> = (0..4).map(|_| inputs8()).collect();
        let results = d.call_batch("k", round);
        let (ok, err): (Vec<_>, Vec<_>) = results.iter().partition(|r| r.is_ok());
        assert_eq!(ok.len(), 2, "round-mates unaffected");
        assert_eq!(err.len(), 2, "only the failed candidate's callers error");
        for r in ok {
            assert_eq!(r.as_ref().unwrap().variant_id, "k.a.n8");
        }
        assert_eq!(d.tuned_value("k", 8), Some(1), "failed variant excluded in-round");
        assert_eq!(d.stats().total_failures(), 1, "replicas fail fast, counted once");
    }

    #[test]
    fn fused_batch_median_denoises_replicas() {
        // single-variant problem at n16: a fused round of 3 replicates
        // one candidate three times and reports exactly one sample (the
        // median) to the tuning state.
        let spec = MockSpec::default().with_cost("k.a.n16", Duration::from_micros(200));
        let mut d = dispatcher(spec);
        let round: Vec<Vec<HostTensor>> =
            (0..3).map(|_| vec![HostTensor::zeros(&[16, 16])]).collect();
        let results = d.call_batch("k", round);
        assert!(results.iter().all(Result::is_ok));
        assert_eq!(d.tuned_value("k", 16), Some(1));
        let report = d.tuning_report();
        let (_, problem) = report
            .as_obj()
            .unwrap()
            .iter()
            .find(|(k, _)| k.contains("16"))
            .expect("n16 problem reported")
            .clone();
        let variants = problem.get("variants").unwrap().as_arr().unwrap();
        assert_eq!(
            variants[0].get("samples").unwrap().as_i64(),
            Some(1),
            "3 replicas collapse to one denoised sample"
        );
        let f = d.stats().fused();
        assert_eq!(f.replicated_measurements, 2);
    }

    #[test]
    fn failed_candidate_never_published() {
        // b would be the fastest, but it fails at execution during
        // tuning: it is excluded and the published winner must be a.
        let mut spec = MockSpec::default()
            .with_cost("k.a.n8", Duration::from_micros(600))
            .with_cost("k.b.n8", Duration::from_micros(60));
        spec.fail_execute.insert("k.b.n8".into());
        let mut d = dispatcher(spec);
        let lane = Arc::new(FastLane::new());
        d.set_fast_lane(lane.clone());
        for _ in 0..3 {
            d.call("k", &inputs8()).unwrap();
        }
        assert_eq!(d.tuned_value("k", 8), Some(1), "failed variant cannot win");
        let entry = lane.lookup("k", &inputs8()).expect("winner published");
        assert_eq!(entry.variant_id(), "k.a.n8");
        assert_eq!(d.stats().total_failures(), 1);
    }
}
