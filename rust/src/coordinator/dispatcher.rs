//! The call dispatcher — `__clang_jit` with autotuning (paper §3.2).

use std::time::{Duration, Instant};

use crate::autotuner::{Autotuner, Decision, Metric, Phase, ProblemKey, WallClock};
use crate::error::{Error, Result};
use crate::manifest::Variant;
use crate::runtime::{CacheStats, CompileCache, Engine};
use crate::tensor::HostTensor;

use super::registry::KernelRegistry;
use super::stats::CoordStats;

/// How a call was routed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CallRoute {
    /// Tuning iteration: variant JIT-compiled, run, measured, discarded.
    Explored,
    /// The winner's final compilation into the instantiation cache.
    Finalized,
    /// Steady state: cached winner.
    Tuned,
}

/// Everything observable about one dispatched call (benches consume this
/// to regenerate the paper's figures).
#[derive(Debug, Clone)]
pub struct CallOutcome {
    /// Kernel output.
    pub output: HostTensor,
    /// Variant that actually ran.
    pub variant_id: String,
    /// Parameter value of that variant.
    pub value: i64,
    /// Routing phase of this call.
    pub route: CallRoute,
    /// Whether this call paid a JIT compilation.
    pub compiled: bool,
    /// Measured execution cost in metric units (tuning iterations) or
    /// wall seconds (steady state).
    pub exec_cost: f64,
    /// End-to-end call duration including any compilation.
    pub total: Duration,
}

/// The dispatcher: owns the registry, the JIT compile cache, the
/// autotuner and the measurement metric. Single-threaded by design (PJRT
/// pinning); the [`super::server::Coordinator`] provides the
/// multi-threaded facade.
/// Cached per-problem call metadata — built on a problem's first call so
/// the steady-state path performs no manifest walks and no allocations
/// beyond the reply itself (§Perf).
struct CallPlan {
    problem_idx: usize,
    key: ProblemKey,
    values: Vec<i64>,
}

pub struct Dispatcher {
    registry: KernelRegistry,
    cache: CompileCache,
    tuner: Autotuner,
    metric: Box<dyn Metric>,
    stats: CoordStats,
    plans: std::collections::HashMap<(String, String), CallPlan>,
}

impl Dispatcher {
    /// Dispatcher with the paper's defaults: sweep strategy + wall-clock
    /// metric.
    pub fn new(registry: KernelRegistry, engine: Box<dyn Engine>) -> Dispatcher {
        Dispatcher::with(registry, engine, Autotuner::sweep(), Box::new(WallClock::new()))
    }

    /// Fully parameterized constructor.
    pub fn with(
        registry: KernelRegistry,
        engine: Box<dyn Engine>,
        tuner: Autotuner,
        metric: Box<dyn Metric>,
    ) -> Dispatcher {
        Dispatcher {
            registry,
            cache: CompileCache::new(engine),
            tuner,
            metric,
            stats: CoordStats::new(),
            plans: std::collections::HashMap::new(),
        }
    }

    /// Dispatch one kernel call: the `__clang_jit` entry point.
    ///
    /// The problem is identified by the kernel name plus the *actual*
    /// argument signature (paper: a different argument set is a different
    /// autotuning problem).
    pub fn call(&mut self, kernel: &str, inputs: &[HostTensor]) -> Result<CallOutcome> {
        let t0 = Instant::now();
        // Resolve the cached call plan (built on the problem's first call
        // — steady-state calls do no manifest walks, §Perf).
        let sig = inputs.iter().map(HostTensor::signature).collect::<Vec<_>>().join(",");
        let plan_key = (kernel.to_string(), sig);
        if !self.plans.contains_key(&plan_key) {
            let (idx, problem) = {
                let p = self.registry.problem_for_inputs(kernel, inputs)?;
                let idx = self
                    .registry
                    .manifest()
                    .problems
                    .iter()
                    .position(|q| std::ptr::eq(q, p))
                    .expect("problem from this manifest");
                (idx, p)
            };
            let plan = CallPlan {
                problem_idx: idx,
                key: ProblemKey::for_problem(problem),
                values: problem.variants.iter().map(|v| v.value).collect(),
            };
            self.plans.insert(plan_key.clone(), plan);
        }
        let (pidx, key, values) = {
            let plan = &self.plans[&plan_key];
            (plan.problem_idx, plan.key.clone(), plan.values.clone())
        };

        // Failure-retry loop: a failing variant is excluded and the next
        // decision is consulted, until the call succeeds or every
        // candidate is dead.
        loop {
            let decision = {
                let st = self.tuner.state(&key, &values);
                if st.phase() == Phase::Failed {
                    return Err(Error::Autotune(format!(
                        "every variant of {key} failed; cannot execute"
                    )));
                }
                st.decide()
            };
            match decision {
                Decision::Explore(i) => {
                    let variant = self.registry.manifest().problems[pidx].variants[i].clone();
                    match self.explore(&key, &variant, i, inputs, t0) {
                        Ok(outcome) => return Ok(outcome),
                        Err(e) => {
                            log::warn!("variant {} failed during tuning: {e}", variant.id);
                            self.stats.failure(kernel);
                            self.tuner.state(&key, &values).report_failure(i);
                            continue;
                        }
                    }
                }
                Decision::Finalize(i) => {
                    let problem = &self.registry.manifest().problems[pidx];
                    let variant = problem.variants[i].clone();
                    let all_ids: Vec<String> =
                        problem.variants.iter().map(|v| v.id.clone()).collect();
                    match self.finalize(&variant, &all_ids, inputs, t0) {
                        Ok(mut outcome) => {
                            self.tuner.state(&key, &values).confirm_finalized(i);
                            self.stats.finalized(kernel, outcome.total);
                            outcome.route = CallRoute::Finalized;
                            log::info!(
                                "{key} tuned: value={} ({})",
                                outcome.value,
                                outcome.variant_id
                            );
                            return Ok(outcome);
                        }
                        Err(e) => {
                            log::warn!("winner {} failed finalization: {e}", variant.id);
                            self.stats.failure(kernel);
                            self.tuner.state(&key, &values).report_failure(i);
                            continue;
                        }
                    }
                }
                Decision::Use(i) => {
                    // §Perf fast path: no variant clone — disjoint field
                    // borrows let the executable run straight off the
                    // cache while the registry stays immutably borrowed.
                    let manifest = self.registry.manifest();
                    let variant = &manifest.problems[pidx].variants[i];
                    let (exe, compiled) = self.cache.get_or_compile(manifest, variant)?;
                    let begin = self.metric.begin();
                    let output = exe.execute(inputs)?;
                    let cost = self.metric.end(begin);
                    debug_assert!(!compiled, "steady-state call should hit the cache");
                    let outcome = CallOutcome {
                        output,
                        variant_id: variant.id.clone(),
                        value: variant.value,
                        route: CallRoute::Tuned,
                        compiled,
                        exec_cost: cost,
                        total: t0.elapsed(),
                    };
                    self.stats.tuned_call(kernel, outcome.total);
                    return Ok(outcome);
                }
            }
        }
    }

    /// One tuning iteration: compile (uncached — the paper keeps only
    /// ASTs during tuning, not binaries), run under the metric, discard
    /// the executable, report the cost.
    fn explore(
        &mut self,
        key: &ProblemKey,
        variant: &Variant,
        idx: usize,
        inputs: &[HostTensor],
        t0: Instant,
    ) -> Result<CallOutcome> {
        let (output, cost, compiled) = {
            let manifest = self.registry.manifest();
            let (exe, compiled) = self.cache.get_or_compile(manifest, variant)?;
            let begin = self.metric.begin();
            let output = exe.execute(inputs)?;
            let cost = self.metric.end(begin);
            (output, cost, compiled)
        };
        // Tuning iterations do not populate the instantiation cache: only
        // tuning info is kept (paper §3.2 "Generating variants").
        self.cache.evict(&variant.id);
        let st = self.tuner.state(key, &[]);
        st.report(idx, cost);
        self.stats.explored(&variant.kernel, t0.elapsed());
        Ok(CallOutcome {
            output,
            variant_id: variant.id.clone(),
            value: variant.value,
            route: CallRoute::Explored,
            compiled,
            exec_cost: cost,
            total: t0.elapsed(),
        })
    }

    /// The winner's final compilation (paper: "generating the best
    /// specialization one last time ... inserted into __clang_jit's cache
    /// of instantiations"), plus eviction of the losers.
    fn finalize(
        &mut self,
        variant: &Variant,
        all_ids: &[String],
        inputs: &[HostTensor],
        t0: Instant,
    ) -> Result<CallOutcome> {
        self.cache.evict_losers(all_ids, &variant.id);
        let manifest = self.registry.manifest();
        let (exe, compiled) = self.cache.get_or_compile(manifest, variant)?;
        let begin = self.metric.begin();
        let output = exe.execute(inputs)?;
        let cost = self.metric.end(begin);
        Ok(CallOutcome {
            output,
            variant_id: variant.id.clone(),
            value: variant.value,
            route: CallRoute::Finalized,
            compiled,
            exec_cost: cost,
            total: t0.elapsed(),
        })
    }

    /// Tuned parameter value for a kernel at a problem size, once tuned
    /// (the paper's Listing 6 parameter reuse).
    pub fn tuned_value(&self, kernel: &str, size: i64) -> Option<i64> {
        let problem = self.registry.problem(kernel, size).ok()?;
        let key =
            ProblemKey::new(&problem.kernel, &problem.param, problem.variants[0].inputs.join(","));
        self.tuner.tuned_value(&key)
    }

    /// Tuning phase for a kernel/size, if any state exists.
    pub fn phase(&self, kernel: &str, size: i64) -> Option<Phase> {
        let problem = self.registry.problem(kernel, size).ok()?;
        let key =
            ProblemKey::new(&problem.kernel, &problem.param, problem.variants[0].inputs.join(","));
        self.tuner.peek(&key).map(|s| s.phase())
    }

    /// Registry accessor.
    pub fn registry(&self) -> &KernelRegistry {
        &self.registry
    }

    /// Coordinator statistics.
    pub fn stats(&self) -> &CoordStats {
        &self.stats
    }

    /// Mutable statistics (the server leader records queue depths here).
    pub fn stats_mut(&mut self) -> &mut CoordStats {
        &mut self.stats
    }

    /// Compile-cache statistics.
    pub fn cache_stats(&self) -> CacheStats {
        self.cache.stats()
    }

    /// Autotuner report (CLI `inspect`).
    pub fn tuning_report(&self) -> crate::util::json::Value {
        self.tuner.report()
    }

    /// Persist tuned results to a JSON file (see
    /// [`crate::autotuner::Autotuner::export_state`]).
    pub fn save_state(&self, path: &std::path::Path) -> Result<usize> {
        let state = self.tuner.export_state();
        let n = state.as_arr().map(<[_]>::len).unwrap_or(0);
        std::fs::write(path, state.to_json_pretty())
            .map_err(|e| Error::io(path.display().to_string(), e))?;
        Ok(n)
    }

    /// Warm-start from persisted tuning results. Entries are validated
    /// against the live manifest: a problem whose candidate values
    /// changed since the state was saved is skipped (stale results must
    /// not be trusted across artifact regenerations). Returns
    /// (imported, skipped).
    pub fn load_state(&mut self, path: &std::path::Path) -> Result<(usize, usize)> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::io(path.display().to_string(), e))?;
        let parsed = crate::util::json::parse(&text)?;
        let arr = parsed
            .as_arr()
            .ok_or_else(|| Error::Autotune("state file: expected array".into()))?;
        let mut valid = Vec::new();
        let mut skipped = 0;
        for entry in arr {
            let kernel = entry.req_str("kernel")?;
            let param = entry.req_str("param")?;
            let signature = entry.req_str("signature")?;
            let values: Vec<i64> = entry
                .req_arr("values")?
                .iter()
                .filter_map(crate::util::json::Value::as_i64)
                .collect();
            let matches = self.registry.manifest().problems.iter().any(|p| {
                p.kernel == kernel
                    && p.param == param
                    && p.variants[0].inputs.join(",") == signature
                    && p.variants.iter().map(|v| v.value).collect::<Vec<_>>() == values
            });
            if matches {
                valid.push(entry.clone());
            } else {
                log::warn!("state: skipping stale entry {kernel}/{param} ({signature})");
                skipped += 1;
            }
        }
        let imported =
            self.tuner.import_state(&crate::util::json::Value::Arr(valid))?;
        Ok((imported, skipped))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::mock::{MockEngine, MockSpec};
    use std::time::Duration;

    fn dispatcher(spec: MockSpec) -> Dispatcher {
        let manifest = crate::manifest::tests::sample_manifest().unwrap();
        let registry = KernelRegistry::new(manifest);
        Dispatcher::new(registry, Box::new(MockEngine::new(spec)))
    }

    fn inputs8() -> Vec<HostTensor> {
        vec![HostTensor::zeros(&[8, 8])]
    }

    #[test]
    fn full_lifecycle_explore_finalize_use() {
        // k.a.n8 (value 1) slow, k.b.n8 (value 2) fast → tuner must pick b.
        let spec = MockSpec::default()
            .with_cost("k.a.n8", Duration::from_micros(600))
            .with_cost("k.b.n8", Duration::from_micros(60));
        let mut d = dispatcher(spec);
        let routes: Vec<CallRoute> =
            (0..5).map(|_| d.call("k", &inputs8()).unwrap().route).collect();
        assert_eq!(
            routes,
            vec![
                CallRoute::Explored,
                CallRoute::Explored,
                CallRoute::Finalized,
                CallRoute::Tuned,
                CallRoute::Tuned
            ]
        );
        assert_eq!(d.tuned_value("k", 8), Some(2));
        // output of tuned calls encodes the winning variant's value
        let out = d.call("k", &inputs8()).unwrap();
        assert!(out.output.data().iter().all(|&x| x == 2.0));
    }

    #[test]
    fn explore_calls_pay_compile_finalize_pays_again() {
        let mut d = dispatcher(MockSpec::default());
        let o1 = d.call("k", &inputs8()).unwrap();
        assert!(o1.compiled, "tuning iteration JIT-compiles");
        let o2 = d.call("k", &inputs8()).unwrap();
        assert!(o2.compiled);
        let o3 = d.call("k", &inputs8()).unwrap();
        assert_eq!(o3.route, CallRoute::Finalized);
        assert!(o3.compiled, "the paper's final compilation is a real compile");
        let o4 = d.call("k", &inputs8()).unwrap();
        assert!(!o4.compiled, "steady state hits the instantiation cache");
        // cache holds only the winner
        assert_eq!(d.cache_stats().misses, 3);
    }

    #[test]
    fn different_shapes_are_independent_problems() {
        let spec = MockSpec::default()
            .with_cost("k.a.n8", Duration::from_micros(60))
            .with_cost("k.b.n8", Duration::from_micros(600));
        let mut d = dispatcher(spec);
        // tune the n8 problem to completion
        for _ in 0..4 {
            d.call("k", &inputs8()).unwrap();
        }
        assert_eq!(d.tuned_value("k", 8), Some(1));
        // n16 problem starts fresh (single variant k.a.n16)
        let o = d.call("k", &[HostTensor::zeros(&[16, 16])]).unwrap();
        assert_eq!(o.route, CallRoute::Explored);
        assert_eq!(d.tuned_value("k", 16), None);
    }

    #[test]
    fn compile_failure_skips_variant() {
        let mut spec = MockSpec::default()
            .with_cost("k.a.n8", Duration::from_micros(50))
            .with_cost("k.b.n8", Duration::from_micros(500));
        spec.fail_compile.insert("k.a.n8".into());
        let mut d = dispatcher(spec);
        // first call: variant a fails to compile, dispatcher retries with b
        let o = d.call("k", &inputs8()).unwrap();
        assert_eq!(o.variant_id, "k.b.n8");
        // tuning completes with only b alive
        let o2 = d.call("k", &inputs8()).unwrap();
        assert_eq!(o2.route, CallRoute::Finalized);
        assert_eq!(d.tuned_value("k", 8), Some(2));
        assert_eq!(d.stats().total_failures(), 1);
    }

    #[test]
    fn all_variants_failing_is_an_error() {
        let mut spec = MockSpec::default();
        spec.fail_compile.insert("k.a.n8".into());
        spec.fail_compile.insert("k.b.n8".into());
        let mut d = dispatcher(spec);
        let err = d.call("k", &inputs8()).err().expect("must fail");
        assert!(err.to_string().contains("every variant"), "{err}");
        // subsequent calls keep failing fast
        assert!(d.call("k", &inputs8()).is_err());
    }

    #[test]
    fn unknown_kernel_and_bad_shape() {
        let mut d = dispatcher(MockSpec::default());
        assert!(d.call("nope", &inputs8()).is_err());
        assert!(d.call("k", &[HostTensor::zeros(&[5, 5])]).is_err());
    }

    #[test]
    fn state_roundtrip_warm_starts_without_tuning() {
        let spec = MockSpec::default()
            .with_cost("k.a.n8", Duration::from_micros(600))
            .with_cost("k.b.n8", Duration::from_micros(60));
        let mut d = dispatcher(spec.clone());
        for _ in 0..4 {
            d.call("k", &inputs8()).unwrap();
        }
        assert_eq!(d.tuned_value("k", 8), Some(2));
        let path = std::env::temp_dir().join(format!("jitune-state-{}.json", std::process::id()));
        assert_eq!(d.save_state(&path).unwrap(), 1);

        // fresh dispatcher, same manifest layout: import → no explores
        let mut d2 = dispatcher(spec);
        let (imported, skipped) = d2.load_state(&path).unwrap();
        assert_eq!((imported, skipped), (1, 0));
        let first = d2.call("k", &inputs8()).unwrap();
        // warm start: the winner is recompiled once (HLO-text-only
        // persistence, like the paper's AST cache) but never explored
        assert_eq!(first.route, CallRoute::Finalized);
        assert_eq!(first.value, 2);
        let second = d2.call("k", &inputs8()).unwrap();
        assert_eq!(second.route, CallRoute::Tuned);
        assert_eq!(d2.stats().kernel("k").unwrap().explored, 0);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn stale_state_entries_are_skipped() {
        let mut d = dispatcher(MockSpec::default());
        let path =
            std::env::temp_dir().join(format!("jitune-stale-{}.json", std::process::id()));
        // candidate values [9, 99] do not match the manifest's [1, 2]
        std::fs::write(
            &path,
            r#"[{"kernel":"k","param":"p","signature":"f32[8,8]",
                 "values":[9,99],"winner_value":9}]"#,
        )
        .unwrap();
        let (imported, skipped) = d.load_state(&path).unwrap();
        assert_eq!((imported, skipped), (0, 1));
        // tuning starts from scratch
        let first = d.call("k", &inputs8()).unwrap();
        assert_eq!(first.route, CallRoute::Explored);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn stats_accumulate() {
        let mut d = dispatcher(MockSpec::default());
        for _ in 0..6 {
            d.call("k", &inputs8()).unwrap();
        }
        let s = d.stats();
        assert_eq!(s.kernel("k").unwrap().explored, 2);
        assert_eq!(s.kernel("k").unwrap().finalized, 1);
        assert_eq!(s.kernel("k").unwrap().tuned, 3);
        assert_eq!(s.total_calls(), 6);
    }
}
