//! Coordinator statistics: per-kernel counters + latency histograms.

use std::collections::BTreeMap;
use std::time::Duration;

use crate::util::hist::Histogram;
use crate::util::json::{n, s, Value};

/// Counters for one kernel family.
#[derive(Debug, Clone)]
pub struct KernelStats {
    /// Tuning iterations dispatched.
    pub explored: u64,
    /// Final compilations performed.
    pub finalized: u64,
    /// Steady-state (tuned) calls.
    pub tuned: u64,
    /// Variant failures observed (compile or execute).
    pub failures: u64,
    /// Retunes triggered automatically by the drift policy.
    pub drift_retunes: u64,
    /// Winners demoted by the failure-rate breaker.
    pub quarantines: u64,
    /// End-to-end latency of every call.
    pub latency: Histogram,
    /// Latency of steady-state calls only (the post-tuning service level).
    pub tuned_latency: Histogram,
}

impl KernelStats {
    fn new() -> KernelStats {
        KernelStats {
            explored: 0,
            finalized: 0,
            tuned: 0,
            failures: 0,
            drift_retunes: 0,
            quarantines: 0,
            latency: Histogram::latency(),
            tuned_latency: Histogram::latency(),
        }
    }

    /// Total calls routed for this kernel.
    pub fn calls(&self) -> u64 {
        self.explored + self.finalized + self.tuned
    }
}

/// One automatic drift-triggered retune, for the event log exposed in
/// `stats_json()`.
#[derive(Debug, Clone)]
pub struct DriftEvent {
    /// Kernel whose published winner drifted.
    pub kernel: String,
    /// Observed window-mean / baseline ratio that tripped the policy.
    pub ratio: f64,
}

/// Cap on the retained drift-event log (oldest evicted first).
const MAX_DRIFT_EVENTS: usize = 64;

/// One failure-breaker demotion, for the event log exposed in
/// `stats_json()`.
#[derive(Debug, Clone)]
pub struct QuarantineEvent {
    /// Kernel whose published winner was demoted.
    pub kernel: String,
    /// The variant that erred its way off the lane.
    pub variant_id: String,
    /// Windowed error rate that tripped the breaker.
    pub error_rate: f64,
}

/// Cap on the retained quarantine-event log (oldest evicted first).
const MAX_QUARANTINE_EVENTS: usize = 64;

/// Serving-path resilience counters (process-wide): calls the admission
/// gate or deadline enforcement turned away instead of queueing without
/// bound. Synced by the leader from the server's shared gauge.
#[derive(Debug, Clone, Copy, Default)]
pub struct ResilienceStats {
    /// Calls shed by the admission gate (`Error::Overloaded`).
    pub shed: u64,
    /// Calls released by an expired budget (`Error::DeadlineExceeded`).
    pub deadline_exceeded: u64,
}

/// Fused-exploration-round counters (process-wide): how much tuning-time
/// work the leader's round batching absorbed.
#[derive(Debug, Clone, Copy, Default)]
pub struct FusedStats {
    /// Scheduling rounds where ≥2 co-scheduled calls of one exploring
    /// problem were fused into a single batched exploration.
    pub fused_rounds: u64,
    /// Calls executed through the fused path.
    pub fused_calls: u64,
    /// Surplus co-scheduled calls that replicated a round-mate's
    /// candidate (their median denoises the measurement).
    pub replicated_measurements: u64,
    /// Leader rounds-to-tuned saved versus serial dispatch: per fused
    /// round, the distinct candidates measured minus one (replicas save
    /// nothing — serially they would have been steady-state calls), plus
    /// one for each finalization performed in-round.
    pub explore_rounds_saved: u64,
}

/// Background shadow-exploration counters (process-wide): what the
/// serve/explore split moved off the serving path (see
/// [`super::background`]).
#[derive(Debug, Clone, Copy, Default)]
pub struct BackgroundStats {
    /// Background compile+measure jobs completed by explore workers
    /// (including stale results whose measurement was dropped — the
    /// worker still ran them).
    pub jobs_run: u64,
    /// Total worker time those jobs consumed.
    pub busy: Duration,
    /// In-flight jobs written off by the hedge deadline.
    pub hedges_fired: u64,
    /// Calls served the current-best/default variant while their problem
    /// was still tuning — each one a call that would have paid an inline
    /// explore or finalize.
    pub serve_while_exploring: u64,
    /// Completed duty-cycle windows.
    pub windows: u64,
    /// Sum of realized per-window duty-cycle percentages (mean =
    /// [`BackgroundStats::duty_cycle_pct`]).
    pub duty_pct_sum: f64,
    /// Realized duty-cycle percentage of the most recent window.
    pub last_duty_pct: f64,
}

impl BackgroundStats {
    /// Mean realized duty-cycle percentage across completed windows.
    pub fn duty_cycle_pct(&self) -> f64 {
        if self.windows == 0 {
            0.0
        } else {
            self.duty_pct_sum / self.windows as f64
        }
    }
}

/// Tuned-state hub traffic counters (process-wide, not per kernel).
#[derive(Debug, Clone, Copy, Default)]
pub struct HubStats {
    /// Winners published to the hub.
    pub pushes: u64,
    /// Full-map pulls performed (startup warm-start + periodic/explicit).
    pub pulls: u64,
    /// Entries adopted from pulls (warm-started or winner-switched).
    pub adopted: u64,
    /// Publishes the broker resolved as version conflicts (another
    /// process published the same problem concurrently).
    pub conflicts: u64,
}

/// All coordinator statistics.
#[derive(Debug, Clone)]
pub struct CoordStats {
    kernels: BTreeMap<String, KernelStats>,
    /// Scheduling-round sizes observed by the leader loop (queue depth
    /// at drain time) → occurrence count.
    rounds: BTreeMap<usize, u64>,
    /// Most recent drift-triggered retunes, newest last.
    drift_events: Vec<DriftEvent>,
    /// Most recent failure-breaker demotions, newest last.
    quarantine_events: Vec<QuarantineEvent>,
    /// Shed / deadline-exceeded call counts.
    resilience: ResilienceStats,
    /// Hub traffic, when a hub is attached.
    hub: HubStats,
    /// Fused exploration rounds, when co-scheduled calls got batched.
    fused: FusedStats,
    /// Background shadow exploration, when a scheduler is attached.
    background: BackgroundStats,
}

impl CoordStats {
    /// Empty stats.
    pub fn new() -> CoordStats {
        CoordStats {
            kernels: BTreeMap::new(),
            rounds: BTreeMap::new(),
            drift_events: Vec::new(),
            quarantine_events: Vec::new(),
            resilience: ResilienceStats::default(),
            hub: HubStats::default(),
            fused: FusedStats::default(),
            background: BackgroundStats::default(),
        }
    }

    /// Record the queue depth of one leader scheduling round.
    pub fn enqueue_round(&mut self, depth: usize) {
        *self.rounds.entry(depth).or_default() += 1;
    }

    /// Distribution of scheduling-round sizes.
    pub fn round_sizes(&self) -> &BTreeMap<usize, u64> {
        &self.rounds
    }

    /// Maximum observed queue depth.
    pub fn max_queue_depth(&self) -> usize {
        self.rounds.keys().max().copied().unwrap_or(0)
    }

    fn entry(&mut self, kernel: &str) -> &mut KernelStats {
        self.kernels.entry(kernel.to_string()).or_insert_with(KernelStats::new)
    }

    /// Record a tuning iteration.
    pub fn explored(&mut self, kernel: &str, total: Duration) {
        let e = self.entry(kernel);
        e.explored += 1;
        e.latency.record(total.as_secs_f64());
    }

    /// Record a finalization call.
    pub fn finalized(&mut self, kernel: &str, total: Duration) {
        let e = self.entry(kernel);
        e.finalized += 1;
        e.latency.record(total.as_secs_f64());
    }

    /// Record a steady-state call.
    pub fn tuned_call(&mut self, kernel: &str, total: Duration) {
        let e = self.entry(kernel);
        e.tuned += 1;
        e.latency.record(total.as_secs_f64());
        e.tuned_latency.record(total.as_secs_f64());
    }

    /// Record a variant failure.
    pub fn failure(&mut self, kernel: &str) {
        self.entry(kernel).failures += 1;
    }

    /// Record an automatic drift-triggered retune.
    pub fn drift_retune(&mut self, kernel: &str, ratio: f64) {
        self.entry(kernel).drift_retunes += 1;
        if self.drift_events.len() == MAX_DRIFT_EVENTS {
            self.drift_events.remove(0);
        }
        self.drift_events.push(DriftEvent { kernel: kernel.to_string(), ratio });
    }

    /// Retained drift-retune events, oldest first.
    pub fn drift_events(&self) -> &[DriftEvent] {
        &self.drift_events
    }

    /// Total drift-triggered retunes across kernels.
    pub fn total_drift_retunes(&self) -> u64 {
        self.kernels.values().map(|k| k.drift_retunes).sum()
    }

    /// Drift-event log as JSON (the `drift_events` array in
    /// `stats_json()`).
    pub fn drift_events_json(&self) -> Value {
        Value::Arr(
            self.drift_events
                .iter()
                .map(|e| {
                    Value::Obj(vec![
                        ("kernel".into(), s(e.kernel.clone())),
                        ("ratio".into(), n(e.ratio)),
                    ])
                })
                .collect(),
        )
    }

    /// Record one failure-breaker demotion.
    pub fn quarantine(&mut self, kernel: &str, variant_id: &str, error_rate: f64) {
        self.entry(kernel).quarantines += 1;
        if self.quarantine_events.len() == MAX_QUARANTINE_EVENTS {
            self.quarantine_events.remove(0);
        }
        self.quarantine_events.push(QuarantineEvent {
            kernel: kernel.to_string(),
            variant_id: variant_id.to_string(),
            error_rate,
        });
    }

    /// Retained quarantine events, oldest first.
    pub fn quarantine_events(&self) -> &[QuarantineEvent] {
        &self.quarantine_events
    }

    /// Total breaker demotions across kernels.
    pub fn total_quarantines(&self) -> u64 {
        self.kernels.values().map(|k| k.quarantines).sum()
    }

    /// Quarantine-event log as JSON (the `quarantine_events` array in
    /// `stats_json()`).
    pub fn quarantine_events_json(&self) -> Value {
        Value::Arr(
            self.quarantine_events
                .iter()
                .map(|e| {
                    Value::Obj(vec![
                        ("kernel".into(), s(e.kernel.clone())),
                        ("variant_id".into(), s(e.variant_id.clone())),
                        ("error_rate".into(), n(e.error_rate)),
                    ])
                })
                .collect(),
        )
    }

    /// Overwrite the shed / deadline-exceeded counts from the server's
    /// shared gauge (handles record there lock-free; the leader syncs
    /// before answering a stats request).
    pub fn set_resilience(&mut self, shed: u64, deadline_exceeded: u64) {
        self.resilience = ResilienceStats { shed, deadline_exceeded };
    }

    /// Shed / deadline-exceeded call counts.
    pub fn resilience(&self) -> ResilienceStats {
        self.resilience
    }

    /// Resilience counters as JSON (the `resilience` object in
    /// `stats_json()`).
    pub fn resilience_json(&self) -> Value {
        Value::Obj(vec![
            ("shed".into(), n(self.resilience.shed as f64)),
            ("deadline_exceeded".into(), n(self.resilience.deadline_exceeded as f64)),
        ])
    }

    /// Record one fused exploration round: `calls` co-scheduled calls
    /// batched, of which `replicated` were surplus replicas, saving
    /// `saved` serial leader rounds.
    pub fn fused_round(&mut self, calls: u64, replicated: u64, saved: u64) {
        self.fused.fused_rounds += 1;
        self.fused.fused_calls += calls;
        self.fused.replicated_measurements += replicated;
        self.fused.explore_rounds_saved += saved;
    }

    /// Record a finalization performed *inside* a fused round (the
    /// strategy converged mid-round): one more serial round saved.
    pub fn fused_inround_finalize(&mut self) {
        self.fused.explore_rounds_saved += 1;
    }

    /// Fused-round counters.
    pub fn fused(&self) -> FusedStats {
        self.fused
    }

    /// Fused-round counters as JSON (the `fused` object in
    /// `stats_json()`).
    pub fn fused_json(&self) -> Value {
        Value::Obj(vec![
            ("fused_rounds".into(), n(self.fused.fused_rounds as f64)),
            ("fused_calls".into(), n(self.fused.fused_calls as f64)),
            (
                "replicated_measurements".into(),
                n(self.fused.replicated_measurements as f64),
            ),
            ("explore_rounds_saved".into(), n(self.fused.explore_rounds_saved as f64)),
        ])
    }

    /// Record one completed background explore job and the worker time
    /// it consumed.
    pub fn background_job(&mut self, busy: Duration) {
        self.background.jobs_run += 1;
        self.background.busy += busy;
    }

    /// Record one hedged (written-off) background job.
    pub fn background_hedge(&mut self) {
        self.background.hedges_fired += 1;
    }

    /// Record one call served the current-best/default variant while its
    /// problem was still tuning.
    pub fn background_serve(&mut self) {
        self.background.serve_while_exploring += 1;
    }

    /// Record one completed duty-cycle window's realized percentage.
    pub fn background_window(&mut self, pct: f64) {
        self.background.windows += 1;
        self.background.duty_pct_sum += pct;
        self.background.last_duty_pct = pct;
    }

    /// Background shadow-exploration counters.
    pub fn background(&self) -> BackgroundStats {
        self.background
    }

    /// Background counters as JSON (the `background` object in
    /// `stats_json()`).
    pub fn background_json(&self) -> Value {
        Value::Obj(vec![
            ("jobs_run".into(), n(self.background.jobs_run as f64)),
            ("busy_s".into(), n(self.background.busy.as_secs_f64())),
            ("hedges_fired".into(), n(self.background.hedges_fired as f64)),
            (
                "serve_while_exploring".into(),
                n(self.background.serve_while_exploring as f64),
            ),
            ("windows".into(), n(self.background.windows as f64)),
            ("duty_cycle_pct".into(), n(self.background.duty_cycle_pct())),
        ])
    }

    /// Record one hub publish (and whether the broker reported a merge
    /// conflict for it).
    pub fn hub_push(&mut self, conflict: bool) {
        self.hub.pushes += 1;
        if conflict {
            self.hub.conflicts += 1;
        }
    }

    /// Record one hub pull and how many entries it adopted.
    pub fn hub_pull(&mut self, adopted: u64) {
        self.hub.pulls += 1;
        self.hub.adopted += adopted;
    }

    /// Hub traffic counters.
    pub fn hub(&self) -> HubStats {
        self.hub
    }

    /// Hub counters as JSON (the `hub` object in `stats_json()`).
    pub fn hub_json(&self) -> Value {
        Value::Obj(vec![
            ("pushes".into(), n(self.hub.pushes as f64)),
            ("pulls".into(), n(self.hub.pulls as f64)),
            ("adopted".into(), n(self.hub.adopted as f64)),
            ("conflicts".into(), n(self.hub.conflicts as f64)),
        ])
    }

    /// Stats for one kernel.
    pub fn kernel(&self, kernel: &str) -> Option<&KernelStats> {
        self.kernels.get(kernel)
    }

    /// Total calls across kernels.
    pub fn total_calls(&self) -> u64 {
        self.kernels.values().map(KernelStats::calls).sum()
    }

    /// Total failures across kernels.
    pub fn total_failures(&self) -> u64 {
        self.kernels.values().map(|k| k.failures).sum()
    }

    /// JSON export (CLI / server introspection).
    pub fn to_json(&self) -> Value {
        Value::Obj(
            self.kernels
                .iter()
                .map(|(k, s)| {
                    (
                        k.clone(),
                        Value::Obj(vec![
                            ("explored".into(), n(s.explored as f64)),
                            ("finalized".into(), n(s.finalized as f64)),
                            ("tuned".into(), n(s.tuned as f64)),
                            ("failures".into(), n(s.failures as f64)),
                            ("drift_retunes".into(), n(s.drift_retunes as f64)),
                            ("quarantines".into(), n(s.quarantines as f64)),
                            ("mean_latency_s".into(), n(s.latency.mean())),
                            ("p95_latency_s".into(), n(s.latency.percentile(95.0))),
                            ("tuned_mean_latency_s".into(), n(s.tuned_latency.mean())),
                        ]),
                    )
                })
                .collect(),
        )
    }

    /// Human-readable multi-line rendering.
    pub fn render(&self) -> String {
        let mut out = String::new();
        if !self.rounds.is_empty() {
            let depths: Vec<String> =
                self.rounds.iter().map(|(d, c)| format!("{d}x{c}")).collect();
            out.push_str(&format!(
                "scheduling rounds (depth x count): {} (max depth {})\n",
                depths.join(" "),
                self.max_queue_depth()
            ));
        }
        if !self.drift_events.is_empty() {
            let last = &self.drift_events[self.drift_events.len() - 1];
            out.push_str(&format!(
                "drift retunes: {} (last: {} at {:.2}x baseline)\n",
                self.total_drift_retunes(),
                last.kernel,
                last.ratio
            ));
        }
        if !self.quarantine_events.is_empty() {
            let last = &self.quarantine_events[self.quarantine_events.len() - 1];
            out.push_str(&format!(
                "quarantines: {} (last: {} demoted {} at {:.0}% errors)\n",
                self.total_quarantines(),
                last.kernel,
                last.variant_id,
                last.error_rate * 100.0
            ));
        }
        if self.resilience.shed + self.resilience.deadline_exceeded > 0 {
            out.push_str(&format!(
                "resilience: shed={} deadline_exceeded={}\n",
                self.resilience.shed, self.resilience.deadline_exceeded
            ));
        }
        if self.hub.pushes + self.hub.pulls > 0 {
            out.push_str(&format!(
                "hub: pushes={} pulls={} adopted={} conflicts={}\n",
                self.hub.pushes, self.hub.pulls, self.hub.adopted, self.hub.conflicts
            ));
        }
        if self.fused.fused_rounds > 0 {
            out.push_str(&format!(
                "fused rounds: {} ({} calls, {} replicated, {} round(s) saved)\n",
                self.fused.fused_rounds,
                self.fused.fused_calls,
                self.fused.replicated_measurements,
                self.fused.explore_rounds_saved
            ));
        }
        if self.background.jobs_run > 0 || self.background.serve_while_exploring > 0 {
            out.push_str(&format!(
                "background: jobs={} busy={:.1}ms hedges={} served-while-exploring={} \
                 duty={:.2}%\n",
                self.background.jobs_run,
                self.background.busy.as_secs_f64() * 1e3,
                self.background.hedges_fired,
                self.background.serve_while_exploring,
                self.background.duty_cycle_pct()
            ));
        }
        for (k, s) in &self.kernels {
            out.push_str(&format!(
                "{k}: calls={} (explore={} finalize={} tuned={} failures={} \
                 drift_retunes={})\n  all   {}\n  tuned {}\n",
                s.calls(),
                s.explored,
                s.finalized,
                s.tuned,
                s.failures,
                s.drift_retunes,
                s.latency.render_ms(),
                s.tuned_latency.render_ms(),
            ));
        }
        out
    }
}

impl Default for CoordStats {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_latency() {
        let mut s = CoordStats::new();
        s.explored("k", Duration::from_millis(10));
        s.explored("k", Duration::from_millis(12));
        s.finalized("k", Duration::from_millis(11));
        s.tuned_call("k", Duration::from_millis(1));
        s.failure("k");
        let ks = s.kernel("k").unwrap();
        assert_eq!(ks.explored, 2);
        assert_eq!(ks.finalized, 1);
        assert_eq!(ks.tuned, 1);
        assert_eq!(ks.failures, 1);
        assert_eq!(ks.calls(), 4);
        assert_eq!(s.total_calls(), 4);
        // tuned latency only tracks the steady-state call
        assert_eq!(ks.tuned_latency.count(), 1);
        assert!(ks.tuned_latency.mean() < ks.latency.mean());
    }

    #[test]
    fn json_export_shape() {
        let mut s = CoordStats::new();
        s.tuned_call("a", Duration::from_millis(5));
        let v = s.to_json();
        assert_eq!(v.get("a").unwrap().get("tuned").unwrap().as_i64(), Some(1));
    }

    #[test]
    fn render_contains_kernels() {
        let mut s = CoordStats::new();
        s.explored("matmul", Duration::from_millis(1));
        assert!(s.render().contains("matmul"));
    }

    #[test]
    fn drift_events_capped_and_exported() {
        let mut s = CoordStats::new();
        for i in 0..70 {
            s.drift_retune("k", 2.0 + i as f64 * 0.01);
        }
        assert_eq!(s.total_drift_retunes(), 70);
        assert_eq!(s.drift_events().len(), 64, "event log is capped");
        // oldest evicted: the first retained event is the 7th recorded
        assert!((s.drift_events()[0].ratio - 2.06).abs() < 1e-9);
        let json = s.drift_events_json();
        assert_eq!(json.as_arr().unwrap().len(), 64);
        assert_eq!(s.kernel("k").unwrap().drift_retunes, 70);
        assert!(s.render().contains("drift retunes: 70"), "{}", s.render());
        let per_kernel = s.to_json();
        assert_eq!(
            per_kernel.get("k").unwrap().get("drift_retunes").unwrap().as_i64(),
            Some(70)
        );
    }

    #[test]
    fn quarantine_events_capped_and_exported() {
        let mut s = CoordStats::new();
        for i in 0..70 {
            s.quarantine("k", &format!("k.v{i}"), 0.5 + (i as f64) * 0.001);
        }
        assert_eq!(s.total_quarantines(), 70);
        assert_eq!(s.quarantine_events().len(), 64, "event log is capped");
        assert_eq!(s.quarantine_events()[0].variant_id, "k.v6", "oldest evicted");
        let json = s.quarantine_events_json();
        assert_eq!(json.as_arr().unwrap().len(), 64);
        assert_eq!(s.kernel("k").unwrap().quarantines, 70);
        assert!(s.render().contains("quarantines: 70"), "{}", s.render());
        let per_kernel = s.to_json();
        assert_eq!(
            per_kernel.get("k").unwrap().get("quarantines").unwrap().as_i64(),
            Some(70)
        );
    }

    #[test]
    fn resilience_counters_synced_and_rendered() {
        let mut s = CoordStats::new();
        assert!(!s.render().contains("resilience:"), "no line before any shed");
        s.set_resilience(3, 5);
        let r = s.resilience();
        assert_eq!((r.shed, r.deadline_exceeded), (3, 5));
        let json = s.resilience_json();
        assert_eq!(json.get("shed").unwrap().as_i64(), Some(3));
        assert_eq!(json.get("deadline_exceeded").unwrap().as_i64(), Some(5));
        assert!(s.render().contains("resilience: shed=3 deadline_exceeded=5"));
    }

    #[test]
    fn hub_counters_tracked_and_rendered() {
        let mut s = CoordStats::new();
        assert!(!s.render().contains("hub:"), "no hub line without traffic");
        s.hub_push(false);
        s.hub_push(true);
        s.hub_pull(3);
        s.hub_pull(0);
        let h = s.hub();
        assert_eq!((h.pushes, h.pulls, h.adopted, h.conflicts), (2, 2, 3, 1));
        let json = s.hub_json();
        assert_eq!(json.get("pushes").unwrap().as_i64(), Some(2));
        assert_eq!(json.get("adopted").unwrap().as_i64(), Some(3));
        assert_eq!(json.get("conflicts").unwrap().as_i64(), Some(1));
        assert!(s.render().contains("hub: pushes=2 pulls=2 adopted=3 conflicts=1"));
    }

    #[test]
    fn fused_counters_tracked_and_rendered() {
        let mut s = CoordStats::new();
        assert!(!s.render().contains("fused rounds"), "no fused line before any round");
        s.fused_round(4, 1, 3);
        s.fused_inround_finalize();
        s.fused_round(2, 0, 1);
        let f = s.fused();
        assert_eq!(
            (f.fused_rounds, f.fused_calls, f.replicated_measurements, f.explore_rounds_saved),
            (2, 6, 1, 5)
        );
        let json = s.fused_json();
        assert_eq!(json.get("fused_rounds").unwrap().as_i64(), Some(2));
        assert_eq!(json.get("fused_calls").unwrap().as_i64(), Some(6));
        assert_eq!(json.get("replicated_measurements").unwrap().as_i64(), Some(1));
        assert_eq!(json.get("explore_rounds_saved").unwrap().as_i64(), Some(5));
        assert!(s.render().contains("fused rounds: 2"), "{}", s.render());
    }

    #[test]
    fn background_counters_tracked_and_rendered() {
        let mut s = CoordStats::new();
        assert!(!s.render().contains("background:"), "no line before any activity");
        s.background_job(Duration::from_millis(2));
        s.background_job(Duration::from_millis(4));
        s.background_hedge();
        s.background_serve();
        s.background_serve();
        s.background_window(4.0);
        s.background_window(6.0);
        let b = s.background();
        assert_eq!((b.jobs_run, b.hedges_fired, b.serve_while_exploring), (2, 1, 2));
        assert_eq!(b.busy, Duration::from_millis(6));
        assert!((b.duty_cycle_pct() - 5.0).abs() < 1e-9);
        assert!((b.last_duty_pct - 6.0).abs() < 1e-9);
        let json = s.background_json();
        assert_eq!(json.get("jobs_run").unwrap().as_i64(), Some(2));
        assert_eq!(json.get("hedges_fired").unwrap().as_i64(), Some(1));
        assert_eq!(json.get("serve_while_exploring").unwrap().as_i64(), Some(2));
        assert_eq!(json.get("windows").unwrap().as_i64(), Some(2));
        assert!(s.render().contains("background: jobs=2"), "{}", s.render());
    }

    #[test]
    fn scheduling_rounds_tracked() {
        let mut s = CoordStats::new();
        s.enqueue_round(1);
        s.enqueue_round(1);
        s.enqueue_round(5);
        assert_eq!(s.max_queue_depth(), 5);
        assert_eq!(s.round_sizes().get(&1), Some(&2));
        assert!(s.render().contains("max depth 5"));
    }
}
