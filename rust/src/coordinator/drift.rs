//! Drift-detection retune policy: notice a published winner going bad and
//! re-open tuning automatically.
//!
//! The paper observes that its JIT autotuner "re-optimizes kernels when
//! they are called with other parameters" and that a found optimum "seems
//! stable" — but a winner picked once can *drift*: thermal throttling,
//! co-tenant interference, or an input-distribution shift can turn
//! yesterday's fastest variant into today's slowest. The fast lane's
//! per-call latency stream is exactly the runtime performance monitor
//! dynamic-autotuning systems (KTT, online machine-code tuning) use to
//! re-trigger search, so this module closes the loop:
//!
//! * At finalization the leader captures a **baseline** for the published
//!   entry (the winner's *mean* measured tuning cost; a warm-started
//!   entry with no history self-calibrates from its first full window).
//! * Every fast-lane hit feeds its *execution* latency — the same
//!   quantity the tuning metric measured, so fixed lane overhead cannot
//!   masquerade as drift — into a [`DriftMonitor`]: sharded atomic
//!   window counters (count, summed nanos, log₂ latency buckets for an
//!   approximate p95) that concurrent caller threads update without
//!   contending on a shared cache line.
//! * The leader loop periodically drains the window ([`DriftMonitor::scan`])
//!   and evaluates the [`DriftPolicy`]: a window with at least
//!   `min_samples` calls whose mean exceeds `ratio_threshold` × baseline
//!   increments a streak; `consecutive_windows` such windows in a row —
//!   the hysteresis that keeps a single noisy window from flapping — plus
//!   an expired `cooldown` trigger an automatic
//!   [`Dispatcher::retune`](super::Dispatcher::retune).
//!
//! The monitor lives inside the published
//! [`TunedEntry`](super::fastlane::TunedEntry), so invalidation (retune,
//! demotion, failure) retires the monitor with the entry and the
//! replacement winner starts a fresh baseline + cooldown — retriggering
//! cannot race a stale monitor.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::time::{Duration, Instant};

use crate::sync::TrackedMutex;
use crate::util::json::{n, Value};

/// When to declare a published winner drifted and retune it.
///
/// Enabled via `ServerOptions { drift: Some(policy), .. }`; `None` keeps
/// the manual-retune-only behaviour bit-for-bit.
#[derive(Debug, Clone, Copy)]
pub struct DriftPolicy {
    /// Evaluation cadence: how often the leader drains each entry's
    /// window counters and re-evaluates the policy.
    pub window: Duration,
    /// Minimum accumulated fast-lane calls before a window is judged.
    /// Sparser scans carry their samples forward (they neither
    /// strengthen nor erase drift evidence until enough accumulate).
    pub min_samples: u64,
    /// A window is *bad* when its mean latency exceeds this multiple of
    /// the entry's baseline.
    pub ratio_threshold: f64,
    /// Grace period after publication during which no retune fires —
    /// bounds retune frequency and lets a fresh winner warm up.
    pub cooldown: Duration,
    /// Number of consecutive bad windows required to trigger (hysteresis
    /// against one noisy window).
    pub consecutive_windows: u32,
    /// Smoothing factor for the exponentially weighted moving average of
    /// window means exposed in stats, in (0, 1].
    pub ewma_alpha: f64,
}

impl Default for DriftPolicy {
    fn default() -> Self {
        DriftPolicy {
            window: Duration::from_millis(250),
            min_samples: 32,
            ratio_threshold: 2.0,
            cooldown: Duration::from_secs(5),
            consecutive_windows: 2,
            ewma_alpha: 0.3,
        }
    }
}

/// One evaluated window of fast-lane latencies for a published entry.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct WindowSummary {
    /// Fast-lane calls observed in the window.
    pub samples: u64,
    /// Mean execution latency (seconds).
    pub mean_s: f64,
    /// Approximate 95th percentile (upper bound of the log₂ bucket
    /// holding the p95 observation), seconds.
    pub p95_s: f64,
    /// `mean_s / baseline` — the drift signal the policy thresholds.
    pub ratio: f64,
}

/// A policy decision to retune one published entry, as returned by
/// [`super::FastLane::drift_scan`] and consumed by
/// [`super::Dispatcher::drift_tick`].
#[derive(Debug, Clone)]
pub struct DriftHit {
    /// Kernel family of the drifted entry.
    pub kernel: String,
    /// Problem size (the registry's retune key).
    pub size: i64,
    /// Variant that was serving when drift was detected.
    pub variant_id: String,
    /// Baseline the window was compared against (seconds).
    pub baseline_s: f64,
    /// The triggering window.
    pub window: WindowSummary,
}

const DRIFT_SHARDS: usize = 8;

/// Log₂ nanosecond buckets: index `i` covers `[2^(i-1), 2^i)` ns, so 40
/// buckets reach ~9 minutes — far beyond any sane kernel latency.
const BUCKETS: usize = 40;

fn bucket_of(nanos: u64) -> usize {
    (64 - nanos.leading_zeros() as usize).min(BUCKETS - 1)
}

/// One window-counter shard, aligned so concurrent recorders on
/// different threads do not false-share the hot `hits`/`nanos` line.
#[repr(align(64))]
struct DriftShard {
    hits: AtomicU64,                // relaxed-counter: window tally, drained by the leader's scan
    nanos: AtomicU64,               // relaxed-counter: window latency sum
    buckets: [AtomicU64; BUCKETS], // relaxed-counter: window histogram tallies
}

// relaxed-counter: shard-assignment cursor, any interleaving is fine
static NEXT_DRIFT_SHARD: AtomicUsize = AtomicUsize::new(0);

thread_local! {
    static DRIFT_SHARD_INDEX: usize =
        NEXT_DRIFT_SHARD.fetch_add(1, Ordering::Relaxed) % DRIFT_SHARDS;
}

/// Leader-side evaluation state. Only the leader's periodic scan touches
/// it, so a plain mutex is uncontended.
struct EvalState {
    baseline_s: f64,
    /// Whether the baseline has been confirmed (or replaced) by a full
    /// serving window — the tuning-time baseline can be a single,
    /// possibly anomalous measurement, and excludes call overhead.
    calibrated: bool,
    ewma_s: f64,
    streak: u32,
    last: Option<WindowSummary>,
    triggered: u64,
    /// When the last retune fired. Re-arms the cooldown even if the
    /// retune failed and this monitor survived.
    last_trigger: Option<Instant>,
    /// Samples carried over from scans too sparse to judge — a low-rate
    /// entry accumulates evidence across windows instead of having it
    /// silently discarded.
    pending_hits: u64,
    pending_nanos: u64,
    pending_buckets: [u64; BUCKETS],
}

/// Windowed latency monitor for one published fast-lane entry.
///
/// Caller threads feed [`record`](DriftMonitor::record) (lock-free
/// sharded atomics); the leader periodically drains the window with
/// [`scan`](DriftMonitor::scan), which applies the [`DriftPolicy`] and
/// reports whether a retune should fire.
pub struct DriftMonitor {
    shards: [DriftShard; DRIFT_SHARDS],
    created: Instant,
    eval: TrackedMutex<EvalState>,
}

impl DriftMonitor {
    /// Monitor with the given baseline (seconds). A non-finite or
    /// non-positive baseline — e.g. a warm-started winner with no tuning
    /// history — self-calibrates: the first full window sets it.
    pub fn new(baseline_s: f64) -> DriftMonitor {
        let baseline = if baseline_s.is_finite() && baseline_s > 0.0 { baseline_s } else { 0.0 };
        DriftMonitor {
            shards: std::array::from_fn(|_| DriftShard {
                hits: AtomicU64::new(0),
                nanos: AtomicU64::new(0),
                buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            }),
            created: Instant::now(),
            eval: TrackedMutex::new("coordinator.drift.eval", EvalState {
                baseline_s: baseline,
                calibrated: false,
                ewma_s: 0.0,
                streak: 0,
                last: None,
                triggered: 0,
                last_trigger: None,
                pending_hits: 0,
                pending_nanos: 0,
                pending_buckets: [0; BUCKETS],
            }),
        }
    }

    /// Record one fast-lane call's execution latency (the same quantity
    /// the tuning-time baseline measured). Hot path: three relaxed
    /// `fetch_add`s on a thread-private shard.
    pub fn record(&self, latency: Duration) {
        let shard = &self.shards[DRIFT_SHARD_INDEX.with(|i| *i)];
        let nanos = latency.as_nanos() as u64;
        shard.hits.fetch_add(1, Ordering::Relaxed);
        shard.nanos.fetch_add(nanos, Ordering::Relaxed);
        shard.buckets[bucket_of(nanos)].fetch_add(1, Ordering::Relaxed);
    }

    /// Drain the current window and evaluate `policy`. Leader-only.
    /// Returns the triggering window when an automatic retune should
    /// fire, `None` otherwise.
    ///
    /// A scan with fewer than `min_samples` accumulated calls is not
    /// judged, but the samples are *carried forward* — a low-rate entry
    /// accumulates evidence across scans until it can be judged instead
    /// of having drift rendered permanently undetectable.
    pub fn scan(&self, policy: &DriftPolicy, now: Instant) -> Option<WindowSummary> {
        let mut hits = 0u64;
        let mut nanos = 0u64;
        let mut buckets = [0u64; BUCKETS];
        for shard in &self.shards {
            hits += shard.hits.swap(0, Ordering::Relaxed);
            nanos += shard.nanos.swap(0, Ordering::Relaxed);
            for (acc, b) in buckets.iter_mut().zip(&shard.buckets) {
                *acc += b.swap(0, Ordering::Relaxed); // relaxed-counter: draining bucket tallies
            }
        }
        let mut eval = self.eval.lock();
        eval.pending_hits += hits;
        eval.pending_nanos += nanos;
        for (acc, b) in eval.pending_buckets.iter_mut().zip(&buckets) {
            *acc += b;
        }
        if eval.pending_hits < policy.min_samples.max(1) {
            // Not enough evidence yet: keep accumulating; the streak and
            // EWMA stay untouched.
            return None;
        }
        let samples = eval.pending_hits;
        let mean_s = (eval.pending_nanos as f64 / samples as f64) * 1e-9;
        let p95_s = p95_from(&eval.pending_buckets, samples);
        eval.pending_hits = 0;
        eval.pending_nanos = 0;
        eval.pending_buckets = [0; BUCKETS];
        if eval.baseline_s <= 0.0 {
            // Self-calibration: adopt the first judged window as the
            // baseline and never treat it as drifted.
            eval.baseline_s = mean_s;
            eval.calibrated = true;
            eval.ewma_s = mean_s;
            eval.last = Some(WindowSummary { samples, mean_s, p95_s, ratio: 1.0 });
            return None;
        }
        if !eval.calibrated {
            eval.calibrated = true;
            if mean_s / eval.baseline_s <= policy.ratio_threshold {
                // The tuning-time baseline can be a single, anomalously
                // fast measurement and excludes call overhead. A first
                // window that still looks healthy replaces it with the
                // steadier serving-time mean, so modest optimism cannot
                // snowball into retune flapping. A first window already
                // past the threshold falls through and is judged against
                // the tuning baseline — that is genuine-looking drift.
                eval.baseline_s = mean_s;
                eval.ewma_s = mean_s;
                eval.last = Some(WindowSummary { samples, mean_s, p95_s, ratio: 1.0 });
                return None;
            }
        }
        let alpha = policy.ewma_alpha.clamp(0.01, 1.0);
        eval.ewma_s =
            if eval.ewma_s > 0.0 { alpha * mean_s + (1.0 - alpha) * eval.ewma_s } else { mean_s };
        let ratio = mean_s / eval.baseline_s;
        let summary = WindowSummary { samples, mean_s, p95_s, ratio };
        eval.last = Some(summary);
        if ratio > policy.ratio_threshold {
            eval.streak += 1;
        } else {
            eval.streak = 0;
        }
        // Cooldown re-arms from the last trigger (covers a failed retune
        // that left this monitor alive), falling back to publication.
        let anchor = eval.last_trigger.unwrap_or(self.created);
        let warm = now.saturating_duration_since(anchor) >= policy.cooldown;
        if warm && eval.streak >= policy.consecutive_windows.max(1) {
            eval.streak = 0;
            eval.triggered += 1;
            eval.last_trigger = Some(now);
            return Some(summary);
        }
        None
    }

    /// Current baseline (seconds); 0 until self-calibration completes.
    pub fn baseline_s(&self) -> f64 {
        self.eval.lock().baseline_s
    }

    /// EWMA of judged window means (seconds); 0 before the first window.
    pub fn ewma_s(&self) -> f64 {
        self.eval.lock().ewma_s
    }

    /// Consecutive bad windows so far.
    pub fn streak(&self) -> u32 {
        self.eval.lock().streak
    }

    /// Retunes this monitor has triggered.
    pub fn triggers(&self) -> u64 {
        self.eval.lock().triggered
    }

    /// Most recently judged window.
    pub fn last_window(&self) -> Option<WindowSummary> {
        self.eval.lock().last
    }

    /// Machine-readable monitor state for `stats_json()`.
    pub fn status_json(&self) -> Value {
        let eval = self.eval.lock();
        let mut obj = vec![
            ("baseline_s".to_string(), n(eval.baseline_s)),
            ("ewma_s".to_string(), n(eval.ewma_s)),
            ("streak".to_string(), n(eval.streak as f64)),
            ("triggers".to_string(), n(eval.triggered as f64)),
        ];
        if let Some(w) = eval.last {
            obj.push(("window_samples".to_string(), n(w.samples as f64)));
            obj.push(("window_mean_s".to_string(), n(w.mean_s)));
            obj.push(("window_p95_s".to_string(), n(w.p95_s)));
            obj.push(("window_ratio".to_string(), n(w.ratio)));
        }
        Value::Obj(obj)
    }
}

/// When to declare a published winner *broken* (erroring at run time)
/// and quarantine it.
///
/// The failure-rate sibling of [`DriftPolicy`]: drift demotes winners
/// that got slow, quarantine demotes winners that started *erroring* —
/// a driver regression, a device fault, an input class the variant
/// cannot handle. Enabled via `ServerOptions { quarantine: Some(policy),
/// .. }`; `None` keeps the evict-on-first-error behaviour bit-for-bit.
#[derive(Debug, Clone, Copy)]
pub struct QuarantinePolicy {
    /// Evaluation cadence: how often the leader drains each entry's
    /// ok/error window counters.
    pub window: Duration,
    /// Minimum calls (successes + errors) before a window is judged;
    /// sparser scans carry their samples forward.
    pub min_samples: u64,
    /// A window is *bad* when `errors / samples` reaches this fraction.
    pub error_threshold: f64,
    /// Consecutive bad windows required to trip the breaker.
    pub consecutive_windows: u32,
    /// Grace period after publication during which the breaker never
    /// trips (a winner warming up may hit transient errors).
    pub cooldown: Duration,
    /// How long a demoted variant stays off-limits: a retune fired
    /// within this span cannot re-pick the quarantined variant.
    pub quarantine_for: Duration,
}

impl Default for QuarantinePolicy {
    fn default() -> Self {
        QuarantinePolicy {
            window: Duration::from_millis(250),
            min_samples: 16,
            error_threshold: 0.5,
            consecutive_windows: 1,
            cooldown: Duration::from_millis(500),
            quarantine_for: Duration::from_secs(60),
        }
    }
}

/// One evaluated ok/error window for a published entry.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FailureWindow {
    /// Calls observed in the window (successes + errors).
    pub samples: u64,
    /// Errors among them.
    pub errors: u64,
    /// `errors / samples` — the signal the policy thresholds.
    pub error_rate: f64,
}

/// A breaker decision to quarantine one published entry, as returned by
/// [`super::FastLane::quarantine_scan`] and consumed by
/// [`super::Dispatcher::quarantine_tick`].
#[derive(Debug, Clone)]
pub struct QuarantineHit {
    /// Kernel family of the broken entry.
    pub kernel: String,
    /// Problem size (the registry's key).
    pub size: i64,
    /// Input shapes the entry was published for.
    pub input_shapes: Vec<Vec<usize>>,
    /// Variant that was serving when the breaker tripped.
    pub variant_id: String,
    /// The triggering window.
    pub window: FailureWindow,
}

/// One ok/error counter shard, aligned like [`DriftShard`] so concurrent
/// recorders do not false-share.
#[repr(align(64))]
struct FailShard {
    ok: AtomicU64,  // relaxed-counter: window success tally, drained by the leader's scan
    err: AtomicU64, // relaxed-counter: window error tally
}

/// Leader-side breaker state; only the leader's periodic scan touches it.
struct FailEval {
    streak: u32,
    last: Option<FailureWindow>,
    tripped: u64,
    pending_ok: u64,
    pending_err: u64,
}

/// Windowed failure-rate breaker for one published fast-lane entry —
/// the [`DriftMonitor`] shape applied to errors instead of latency.
///
/// Caller threads feed [`record_ok`](FailureMonitor::record_ok) /
/// [`record_err`](FailureMonitor::record_err) (lock-free sharded
/// atomics); the leader drains the window with
/// [`scan`](FailureMonitor::scan), which applies the
/// [`QuarantinePolicy`] and reports whether the breaker tripped.
pub struct FailureMonitor {
    shards: [FailShard; DRIFT_SHARDS],
    created: Instant,
    eval: TrackedMutex<FailEval>,
}

impl Default for FailureMonitor {
    fn default() -> Self {
        FailureMonitor::new()
    }
}

impl FailureMonitor {
    /// A fresh breaker (armed from publication time; the policy cooldown
    /// is anchored here).
    pub fn new() -> FailureMonitor {
        FailureMonitor {
            shards: std::array::from_fn(|_| FailShard {
                ok: AtomicU64::new(0),
                err: AtomicU64::new(0),
            }),
            created: Instant::now(),
            eval: TrackedMutex::new("coordinator.drift.fail_eval", FailEval {
                streak: 0,
                last: None,
                tripped: 0,
                pending_ok: 0,
                pending_err: 0,
            }),
        }
    }

    /// Record one successful call. Hot path: one relaxed `fetch_add` on
    /// a thread-private shard.
    pub fn record_ok(&self) {
        self.shards[DRIFT_SHARD_INDEX.with(|i| *i)].ok.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one failed call.
    pub fn record_err(&self) {
        self.shards[DRIFT_SHARD_INDEX.with(|i| *i)].err.fetch_add(1, Ordering::Relaxed);
    }

    /// Drain the current window and evaluate `policy`. Leader-only.
    /// Returns the triggering window when the breaker trips, `None`
    /// otherwise. Sparse windows carry forward like
    /// [`DriftMonitor::scan`].
    pub fn scan(&self, policy: &QuarantinePolicy, now: Instant) -> Option<FailureWindow> {
        let mut ok = 0u64;
        let mut err = 0u64;
        for shard in &self.shards {
            ok += shard.ok.swap(0, Ordering::Relaxed);
            err += shard.err.swap(0, Ordering::Relaxed);
        }
        let mut eval = self.eval.lock();
        eval.pending_ok += ok;
        eval.pending_err += err;
        let samples = eval.pending_ok + eval.pending_err;
        if samples < policy.min_samples.max(1) {
            return None;
        }
        let errors = eval.pending_err;
        eval.pending_ok = 0;
        eval.pending_err = 0;
        let error_rate = errors as f64 / samples as f64;
        let window = FailureWindow { samples, errors, error_rate };
        eval.last = Some(window);
        if error_rate >= policy.error_threshold {
            eval.streak += 1;
        } else {
            eval.streak = 0;
        }
        let warm = now.saturating_duration_since(self.created) >= policy.cooldown;
        if warm && eval.streak >= policy.consecutive_windows.max(1) {
            eval.streak = 0;
            eval.tripped += 1;
            return Some(window);
        }
        None
    }

    /// Consecutive bad windows so far.
    pub fn streak(&self) -> u32 {
        self.eval.lock().streak
    }

    /// Times this breaker has tripped.
    pub fn trips(&self) -> u64 {
        self.eval.lock().tripped
    }

    /// Most recently judged window.
    pub fn last_window(&self) -> Option<FailureWindow> {
        self.eval.lock().last
    }

    /// Machine-readable breaker state for `stats_json()`.
    pub fn status_json(&self) -> Value {
        let eval = self.eval.lock();
        let mut obj = vec![
            ("streak".to_string(), n(eval.streak as f64)),
            ("trips".to_string(), n(eval.tripped as f64)),
        ];
        if let Some(w) = eval.last {
            obj.push(("window_samples".to_string(), n(w.samples as f64)));
            obj.push(("window_errors".to_string(), n(w.errors as f64)));
            obj.push(("window_error_rate".to_string(), n(w.error_rate)));
        }
        Value::Obj(obj)
    }
}

/// Upper bound (seconds) of the bucket holding the p95 observation.
fn p95_from(buckets: &[u64; BUCKETS], total: u64) -> f64 {
    if total == 0 {
        return 0.0;
    }
    let target = ((0.95 * total as f64).ceil() as u64).max(1);
    let mut seen = 0u64;
    for (i, &c) in buckets.iter().enumerate() {
        seen += c;
        if seen >= target {
            return (1u64 << i) as f64 * 1e-9;
        }
    }
    (1u64 << (BUCKETS - 1)) as f64 * 1e-9
}

#[cfg(test)]
mod tests {
    use super::*;

    fn policy() -> DriftPolicy {
        DriftPolicy {
            window: Duration::from_millis(10),
            min_samples: 4,
            ratio_threshold: 2.0,
            cooldown: Duration::ZERO,
            consecutive_windows: 2,
            ewma_alpha: 0.5,
        }
    }

    fn fill(m: &DriftMonitor, count: usize, each: Duration) {
        for _ in 0..count {
            m.record(each);
        }
    }

    #[test]
    fn healthy_windows_never_trigger() {
        let m = DriftMonitor::new(100e-6);
        let p = policy();
        for _ in 0..10 {
            fill(&m, 8, Duration::from_micros(100));
            assert!(m.scan(&p, Instant::now()).is_none());
        }
        assert_eq!(m.triggers(), 0);
        assert!((m.ewma_s() - 100e-6).abs() < 20e-6, "ewma tracks the mean");
    }

    #[test]
    fn consecutive_bad_windows_trigger_once_and_reset() {
        let m = DriftMonitor::new(100e-6);
        let p = policy();
        fill(&m, 8, Duration::from_micros(300));
        assert!(m.scan(&p, Instant::now()).is_none(), "hysteresis: one bad window");
        assert_eq!(m.streak(), 1);
        fill(&m, 8, Duration::from_micros(300));
        let w = m.scan(&p, Instant::now()).expect("second consecutive bad window");
        assert!(w.ratio > 2.0, "ratio {}", w.ratio);
        assert_eq!(w.samples, 8);
        assert_eq!(m.triggers(), 1);
        assert_eq!(m.streak(), 0, "streak resets after a trigger");
    }

    #[test]
    fn single_noisy_window_resets_streak() {
        let m = DriftMonitor::new(100e-6);
        let p = policy();
        fill(&m, 8, Duration::from_micros(300));
        assert!(m.scan(&p, Instant::now()).is_none());
        fill(&m, 8, Duration::from_micros(100)); // healthy again
        assert!(m.scan(&p, Instant::now()).is_none());
        assert_eq!(m.streak(), 0, "healthy window clears the streak");
        fill(&m, 8, Duration::from_micros(300));
        assert!(m.scan(&p, Instant::now()).is_none(), "no flapping on isolated noise");
    }

    #[test]
    fn sparse_window_neither_triggers_nor_resets() {
        let m = DriftMonitor::new(100e-6);
        let p = policy();
        fill(&m, 8, Duration::from_micros(300));
        assert!(m.scan(&p, Instant::now()).is_none());
        assert_eq!(m.streak(), 1);
        fill(&m, 2, Duration::from_micros(300)); // below min_samples
        assert!(m.scan(&p, Instant::now()).is_none());
        assert_eq!(m.streak(), 1, "sparse window leaves evidence untouched");
    }

    #[test]
    fn sparse_windows_accumulate_until_judgeable() {
        let m = DriftMonitor::new(100e-6);
        let p = policy(); // min_samples 4
        // two scans of 2 samples each: the first carries forward, the
        // second reaches 4 accumulated and is judged (bad → streak 1)
        fill(&m, 2, Duration::from_micros(300));
        assert!(m.scan(&p, Instant::now()).is_none());
        assert_eq!(m.streak(), 0, "still accumulating");
        fill(&m, 2, Duration::from_micros(300));
        assert!(m.scan(&p, Instant::now()).is_none());
        assert_eq!(m.streak(), 1, "accumulated sparse windows were judged");
        // a second accumulated bad window completes the streak
        fill(&m, 2, Duration::from_micros(300));
        assert!(m.scan(&p, Instant::now()).is_none());
        fill(&m, 2, Duration::from_micros(300));
        assert!(
            m.scan(&p, Instant::now()).is_some(),
            "low-rate drift is detected, just more slowly"
        );
    }

    #[test]
    fn first_healthy_window_refines_an_optimistic_baseline() {
        // Tuning-time best was anomalously fast (60us) but real serving
        // runs at 100us (1.67x, under the 2x threshold): the first
        // window absorbs the bias instead of snowballing into retunes.
        let m = DriftMonitor::new(60e-6);
        let p = policy();
        fill(&m, 8, Duration::from_micros(100));
        assert!(m.scan(&p, Instant::now()).is_none());
        assert!((m.baseline_s() - 100e-6).abs() < 5e-6, "baseline refined to window mean");
        for _ in 0..5 {
            fill(&m, 8, Duration::from_micros(100));
            assert!(m.scan(&p, Instant::now()).is_none());
        }
        assert_eq!(m.triggers(), 0);
        assert_eq!(m.streak(), 0);
    }

    #[test]
    fn cooldown_rearms_after_a_trigger() {
        let m = DriftMonitor::new(100e-6);
        let mut p = policy();
        p.consecutive_windows = 1;
        fill(&m, 8, Duration::from_micros(300));
        assert!(m.scan(&p, Instant::now()).is_some(), "cooldown zero: fires at once");
        // the retune failed and this monitor survived: with a real
        // cooldown it must not fire again immediately
        p.cooldown = Duration::from_secs(3600);
        for _ in 0..3 {
            fill(&m, 8, Duration::from_micros(300));
            assert!(m.scan(&p, Instant::now()).is_none(), "re-armed from last trigger");
        }
        assert_eq!(m.triggers(), 1);
    }

    #[test]
    fn cooldown_blocks_triggering() {
        let m = DriftMonitor::new(100e-6);
        let mut p = policy();
        p.cooldown = Duration::from_secs(3600);
        for _ in 0..5 {
            fill(&m, 8, Duration::from_micros(300));
            assert!(m.scan(&p, Instant::now()).is_none(), "cooldown suppresses triggers");
        }
        assert_eq!(m.triggers(), 0);
    }

    #[test]
    fn zero_baseline_self_calibrates() {
        let m = DriftMonitor::new(0.0);
        let p = policy();
        fill(&m, 8, Duration::from_micros(100));
        assert!(m.scan(&p, Instant::now()).is_none(), "calibration window never drifts");
        assert!((m.baseline_s() - 100e-6).abs() < 5e-6);
        fill(&m, 8, Duration::from_micros(300));
        assert!(m.scan(&p, Instant::now()).is_none());
        fill(&m, 8, Duration::from_micros(300));
        assert!(
            m.scan(&p, Instant::now()).is_some(),
            "drift detected against the self-calibrated baseline"
        );
    }

    fn q_policy() -> QuarantinePolicy {
        QuarantinePolicy {
            window: Duration::from_millis(10),
            min_samples: 4,
            error_threshold: 0.5,
            consecutive_windows: 1,
            cooldown: Duration::ZERO,
            quarantine_for: Duration::from_secs(60),
        }
    }

    fn feed(m: &FailureMonitor, ok: usize, err: usize) {
        for _ in 0..ok {
            m.record_ok();
        }
        for _ in 0..err {
            m.record_err();
        }
    }

    #[test]
    fn healthy_entry_never_trips_the_breaker() {
        let m = FailureMonitor::new();
        let p = q_policy();
        for _ in 0..10 {
            feed(&m, 8, 1); // 11% errors, under the 50% threshold
            assert!(m.scan(&p, Instant::now()).is_none());
        }
        assert_eq!(m.trips(), 0);
    }

    #[test]
    fn erroring_entry_trips_with_rate_and_counts() {
        let m = FailureMonitor::new();
        let p = q_policy();
        feed(&m, 2, 6);
        let w = m.scan(&p, Instant::now()).expect("75% errors trips a 50% breaker");
        assert_eq!(w.samples, 8);
        assert_eq!(w.errors, 6);
        assert!((w.error_rate - 0.75).abs() < 1e-9);
        assert_eq!(m.trips(), 1);
        assert_eq!(m.streak(), 0, "streak resets after a trip");
    }

    #[test]
    fn breaker_hysteresis_requires_consecutive_windows() {
        let m = FailureMonitor::new();
        let mut p = q_policy();
        p.consecutive_windows = 2;
        feed(&m, 0, 8);
        assert!(m.scan(&p, Instant::now()).is_none(), "one bad window is not enough");
        assert_eq!(m.streak(), 1);
        feed(&m, 8, 0); // healthy window clears the streak
        assert!(m.scan(&p, Instant::now()).is_none());
        assert_eq!(m.streak(), 0);
        feed(&m, 0, 8);
        assert!(m.scan(&p, Instant::now()).is_none());
        feed(&m, 0, 8);
        assert!(m.scan(&p, Instant::now()).is_some(), "two consecutive bad windows trip");
    }

    #[test]
    fn sparse_failure_windows_accumulate() {
        let m = FailureMonitor::new();
        let p = q_policy(); // min_samples 4
        feed(&m, 0, 2);
        assert!(m.scan(&p, Instant::now()).is_none(), "below min_samples: carried forward");
        feed(&m, 0, 2);
        assert!(
            m.scan(&p, Instant::now()).is_some(),
            "accumulated sparse evidence is judged"
        );
    }

    #[test]
    fn breaker_cooldown_gives_fresh_winners_grace() {
        let m = FailureMonitor::new();
        let mut p = q_policy();
        p.cooldown = Duration::from_secs(3600);
        for _ in 0..3 {
            feed(&m, 0, 8);
            assert!(m.scan(&p, Instant::now()).is_none(), "cooldown suppresses trips");
        }
        assert_eq!(m.trips(), 0);
        let json = m.status_json();
        assert!(json.get("window_error_rate").is_some());
    }

    #[test]
    fn window_summary_reports_mean_and_p95() {
        let m = DriftMonitor::new(100e-6);
        let p = policy();
        fill(&m, 8, Duration::from_micros(300));
        m.scan(&p, Instant::now());
        let w = m.last_window().expect("window recorded");
        assert_eq!(w.samples, 8);
        assert!((w.mean_s - 300e-6).abs() < 5e-6, "mean {}", w.mean_s);
        assert!(w.p95_s >= w.mean_s, "bucket upper bound dominates the mean");
        assert!(w.p95_s <= 4.0 * w.mean_s, "log2 bucket stays within 2x");
        let json = m.status_json();
        assert!(json.get("window_ratio").is_some());
        assert!(json.get("baseline_s").is_some());
    }
}
