//! The tuned-path fast lane: a read-mostly map of published winners.
//!
//! Once a problem reaches `Phase::Tuned`, the leader publishes an
//! immutable [`TunedEntry`] — the winning variant plus a `Send + Sync`
//! handle to its finalized executable — into this map. Application
//! threads consult it from [`super::server::CoordinatorHandle::call`]
//! *before* touching the leader's channel: a hit executes right on the
//! calling thread, so steady-state throughput scales with application
//! threads instead of being capped at one leader-serialized call at a
//! time. Misses (exploring / finalizing / retuned / non-shareable
//! backend) fall through to the leader exactly as before, which keeps the
//! paper's "compilation protected by a mutex" guarantee: only the leader
//! ever compiles or measures.
//!
//! Concurrency model: `RwLock<HashMap>` with entries behind `Arc`. Reads
//! hold the lock only for the lookup (the returned entry is an `Arc`
//! clone), writes happen once per tuning lifecycle event (publish,
//! retune, demotion), so contention on the lock is negligible. Call
//! statistics use sharded atomic counters so concurrent recorders do not
//! bounce a single cache line.
//!
//! Invalidation: an in-flight call that obtained an entry just before its
//! invalidation may still complete on the old executable — equivalent to
//! a call that started a moment earlier, and the executable stays alive
//! through the `Arc`. New lookups miss immediately.

use std::collections::hash_map::DefaultHasher;
use std::collections::{BTreeMap, HashMap};
use std::hash::{Hash, Hasher};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::coordinator::dispatcher::{CallOutcome, CallRoute};
use crate::coordinator::drift::{
    DriftHit, DriftMonitor, DriftPolicy, FailureMonitor, QuarantineHit, QuarantinePolicy,
};
use crate::error::{Error, Result};
use crate::runtime::SharedKernel;
use crate::sync::{TrackedMutex, TrackedRwLock};
use crate::tensor::HostTensor;
use crate::util::json::{n, Value};

/// Hash identifying a (kernel, argument-signature) call plan without
/// allocating: the dispatcher and the fast lane key their maps on this.
/// Entries verify the full key on hit, so a collision degrades to a miss,
/// never to a wrong kernel.
pub fn plan_hash(kernel: &str, inputs: &[HostTensor]) -> u64 {
    let mut h = DefaultHasher::new();
    kernel.hash(&mut h);
    inputs.len().hash(&mut h);
    for t in inputs {
        t.shape().hash(&mut h);
    }
    h.finish()
}

/// Whether a stored (kernel, shapes) key serves a call with these inputs
/// — the single definition used by both the dispatcher's `CallPlan` and
/// [`TunedEntry`], so the two maps can never disagree about which calls
/// a key serves.
pub(crate) fn shapes_match(
    stored_kernel: &str,
    stored_shapes: &[Vec<usize>],
    kernel: &str,
    inputs: &[HostTensor],
) -> bool {
    stored_kernel == kernel
        && stored_shapes.len() == inputs.len()
        && stored_shapes.iter().zip(inputs).all(|(s, t)| s.as_slice() == t.shape())
}

/// Same hash computed from stored shapes (publication/invalidation side).
/// Must agree with [`plan_hash`]: `Vec<usize>` hashes as its slice.
fn shape_hash(kernel: &str, shapes: &[Vec<usize>]) -> u64 {
    let mut h = DefaultHasher::new();
    kernel.hash(&mut h);
    shapes.len().hash(&mut h);
    for shape in shapes {
        shape.as_slice().hash(&mut h);
    }
    h.finish()
}

const LANE_SHARDS: usize = 8;

/// One counter shard, alone on its cache line so concurrent recorders on
/// different threads do not false-share.
#[repr(align(64))]
struct LaneShard {
    hits: AtomicU64,  // relaxed-counter: stats-only tally
    nanos: AtomicU64, // relaxed-counter: stats-only latency sum
}

/// Sharded hit/latency counters for one kernel family. Threads are
/// assigned shards round-robin on first use (thread-local cache), so the
/// common case is an uncontended `fetch_add` on a private line.
pub struct LaneCounters {
    shards: [LaneShard; LANE_SHARDS],
}

// relaxed-counter: shard-assignment cursor, any interleaving is fine
static NEXT_SHARD: AtomicUsize = AtomicUsize::new(0);

thread_local! {
    static SHARD_INDEX: usize = NEXT_SHARD.fetch_add(1, Ordering::Relaxed) % LANE_SHARDS;
}

impl LaneCounters {
    fn new() -> LaneCounters {
        LaneCounters {
            shards: std::array::from_fn(|_| LaneShard {
                hits: AtomicU64::new(0),
                nanos: AtomicU64::new(0),
            }),
        }
    }

    fn record(&self, total: Duration) {
        let shard = &self.shards[SHARD_INDEX.with(|i| *i)];
        shard.hits.fetch_add(1, Ordering::Relaxed);
        shard.nanos.fetch_add(total.as_nanos() as u64, Ordering::Relaxed);
    }

    /// (hit count, summed latency) across shards.
    pub fn totals(&self) -> (u64, Duration) {
        let mut hits = 0u64;
        let mut nanos = 0u64;
        for shard in &self.shards {
            hits += shard.hits.load(Ordering::Relaxed);
            nanos += shard.nanos.load(Ordering::Relaxed);
        }
        (hits, Duration::from_nanos(nanos))
    }
}

/// Everything the leader hands the lane when publishing a winner.
pub struct Publication {
    /// Kernel family.
    pub kernel: String,
    /// Input shapes the entry serves (the invalidation key).
    pub input_shapes: Vec<Vec<usize>>,
    /// Winning variant id.
    pub variant_id: String,
    /// Winning parameter value.
    pub value: i64,
    /// Problem size (the `Dispatcher::retune` key).
    pub size: i64,
    /// Winner's tuning-time latency baseline for drift detection, in
    /// seconds. Pass 0 to self-calibrate from the first full window;
    /// ignored when the lane has no drift policy.
    pub baseline_s: f64,
    /// Shareable executable handle.
    pub exe: Arc<dyn SharedKernel>,
}

/// An immutable published winner: everything a caller thread needs to
/// execute a tuned problem without the leader.
pub struct TunedEntry {
    kernel: String,
    input_shapes: Vec<Vec<usize>>,
    variant_id: String,
    value: i64,
    /// Problem size — the key `Dispatcher::retune` takes, carried so a
    /// drift trigger can name the problem without a registry lookup.
    size: i64,
    exe: Arc<dyn SharedKernel>,
    counters: Arc<LaneCounters>,
    /// Windowed drift monitor; present only when the lane was built with
    /// a [`DriftPolicy`], so `drift: None` keeps the hit path unchanged.
    monitor: Option<DriftMonitor>,
    /// Windowed failure-rate breaker; present only when the lane was
    /// built with a [`QuarantinePolicy`]. Without one, a failing entry is
    /// invalidated on first error by its caller (the original behaviour).
    breaker: Option<FailureMonitor>,
}

impl TunedEntry {
    /// Winning variant id.
    pub fn variant_id(&self) -> &str {
        &self.variant_id
    }

    /// Winning parameter value.
    pub fn value(&self) -> i64 {
        self.value
    }

    /// Input shapes this entry serves (the lane's invalidation key).
    pub fn input_shapes(&self) -> &[Vec<usize>] {
        &self.input_shapes
    }

    /// Problem size this entry serves.
    pub fn size(&self) -> i64 {
        self.size
    }

    /// The entry's drift monitor, when the lane has a drift policy.
    pub fn drift_monitor(&self) -> Option<&DriftMonitor> {
        self.monitor.as_ref()
    }

    /// The entry's failure breaker, when the lane has a quarantine
    /// policy. Callers that observe the entry erroring use its presence
    /// to decide between recording the error (breaker demotes on rate)
    /// and invalidating on the spot (no policy).
    pub fn failure_breaker(&self) -> Option<&FailureMonitor> {
        self.breaker.as_ref()
    }

    fn matches(&self, kernel: &str, inputs: &[HostTensor]) -> bool {
        shapes_match(&self.kernel, &self.input_shapes, kernel, inputs)
    }

    /// Execute the published winner on the calling thread. `t0` is the
    /// caller's call-entry instant so end-to-end latency stats line up
    /// with the leader lane's. Stats are recorded only on success — a
    /// failing call falls back to the leader and is counted there.
    pub fn call(&self, inputs: &[HostTensor], t0: Instant) -> Result<CallOutcome> {
        self.call_deadline(inputs, t0, None)
    }

    /// [`call`](TunedEntry::call) with an optional absolute deadline.
    ///
    /// The budget is checked *before* executing (an in-place kernel
    /// cannot be interrupted mid-run, so a call whose budget is already
    /// gone fails fast instead of starting doomed work) and passed down
    /// to [`SharedKernel::execute_measured_deadline`] so pool-routed
    /// entries bound their cross-thread wait too. A deadline error is
    /// not an entry failure: it says nothing about the variant's health,
    /// so the breaker only counts genuine execution errors.
    pub fn call_deadline(
        &self,
        inputs: &[HostTensor],
        t0: Instant,
        deadline: Option<Instant>,
    ) -> Result<CallOutcome> {
        if let Some(d) = deadline {
            if Instant::now() >= d {
                return Err(Error::DeadlineExceeded {
                    kernel: self.kernel.clone(),
                    deadline: d.saturating_duration_since(t0),
                });
            }
        }
        let (output, exec) = match self.exe.execute_measured_deadline(inputs, deadline) {
            Ok(r) => r,
            Err(e) => {
                if let Some(breaker) = &self.breaker {
                    // Only genuine execution errors count toward
                    // quarantine — a deadline/overload says nothing
                    // about the variant itself.
                    if !matches!(e, Error::DeadlineExceeded { .. } | Error::Overloaded(_)) {
                        breaker.record_err();
                    }
                }
                return Err(e);
            }
        };
        if let Some(breaker) = &self.breaker {
            breaker.record_ok();
        }
        let total = t0.elapsed();
        self.counters.record(total);
        if let Some(monitor) = &self.monitor {
            // Execution time, not end-to-end: the baseline was measured
            // around `execute` alone during tuning, so feeding the same
            // quantity keeps the drift ratio apples-to-apples — fixed
            // lane overhead on a microsecond kernel must not read as
            // drift. Pool-routed entries return the *worker-measured*
            // time here, so queue wait under caller contention cannot
            // trip the policy either.
            monitor.record(exec);
        }
        Ok(CallOutcome {
            output,
            variant_id: self.variant_id.clone(),
            value: self.value,
            route: CallRoute::Tuned,
            compiled: false,
            exec_cost: exec.as_secs_f64(),
            total,
        })
    }
}

/// The published-winner map shared between the leader (writer) and every
/// [`super::server::CoordinatorHandle`] (readers).
pub struct FastLane {
    /// plan hash → entries (a `Vec` bucket absorbs hash collisions;
    /// entries verify kernel + shapes on hit).
    entries: TrackedRwLock<HashMap<u64, Vec<Arc<TunedEntry>>>>,
    /// Per-kernel counters, kept across invalidations so stats survive
    /// retunes. `Mutex` (not `RwLock`): touched only on publish and on
    /// stats rendering.
    counters: TrackedMutex<BTreeMap<String, Arc<LaneCounters>>>,
    /// Drift-retune policy; `None` disables monitoring entirely (no
    /// window counters are even allocated on publish).
    drift: Option<DriftPolicy>,
    /// Failure-rate quarantine policy; `None` keeps the original
    /// invalidate-on-first-error behaviour (no breakers allocated).
    quarantine: Option<QuarantinePolicy>,
}

impl FastLane {
    /// An empty lane without drift monitoring.
    pub fn new() -> FastLane {
        FastLane::with_policies(None, None)
    }

    /// An empty lane whose published entries carry drift monitors
    /// evaluated against `policy`.
    pub fn with_drift(policy: DriftPolicy) -> FastLane {
        FastLane::with_policies(Some(policy), None)
    }

    /// An empty lane with any combination of drift and quarantine
    /// policies; published entries only carry the monitors their
    /// policies demand.
    pub fn with_policies(
        drift: Option<DriftPolicy>,
        quarantine: Option<QuarantinePolicy>,
    ) -> FastLane {
        FastLane {
            entries: TrackedRwLock::new("coordinator.fastlane.entries", HashMap::new()),
            counters: TrackedMutex::new("coordinator.fastlane.counters", BTreeMap::new()),
            drift,
            quarantine,
        }
    }

    /// The lane's drift policy, if monitoring is enabled.
    pub fn drift_policy(&self) -> Option<&DriftPolicy> {
        self.drift.as_ref()
    }

    /// The lane's quarantine policy, if the failure breaker is enabled.
    pub fn quarantine_policy(&self) -> Option<&QuarantinePolicy> {
        self.quarantine.as_ref()
    }

    /// Look up the published entry serving `kernel` called with `inputs`.
    /// This is the per-call read path: one hash, one brief read lock, one
    /// `Arc` clone.
    pub fn lookup(&self, kernel: &str, inputs: &[HostTensor]) -> Option<Arc<TunedEntry>> {
        let map = self.entries.read();
        map.get(&plan_hash(kernel, inputs))?
            .iter()
            .find(|e| e.matches(kernel, inputs))
            .cloned()
    }

    /// Whether an entry is published for this call shape.
    pub fn contains(&self, kernel: &str, inputs: &[HostTensor]) -> bool {
        self.lookup(kernel, inputs).is_some()
    }

    /// Publish (or replace) the winner for a (kernel, shapes) problem.
    /// Leader-only.
    pub fn publish(&self, publication: Publication) {
        let Publication { kernel, input_shapes, variant_id, value, size, baseline_s, exe } =
            publication;
        let counters = self
            .counters
            .lock()
            .entry(kernel.clone())
            .or_insert_with(|| Arc::new(LaneCounters::new()))
            .clone();
        let hash = shape_hash(&kernel, &input_shapes);
        let monitor = self.drift.map(|_| DriftMonitor::new(baseline_s));
        let breaker = self.quarantine.map(|_| FailureMonitor::new());
        let entry = Arc::new(TunedEntry {
            kernel,
            input_shapes,
            variant_id,
            value,
            size,
            exe,
            counters,
            monitor,
            breaker,
        });
        let mut map = self.entries.write();
        let bucket = map.entry(hash).or_default();
        bucket.retain(|e| !(e.kernel == entry.kernel && e.input_shapes == entry.input_shapes));
        bucket.push(entry);
    }

    /// Drop the published entry for a (kernel, shapes) problem — retune,
    /// demotion, or a winner failing at execution. Returns whether an
    /// entry was removed.
    pub fn invalidate(&self, kernel: &str, input_shapes: &[Vec<usize>]) -> bool {
        let hash = shape_hash(kernel, input_shapes);
        let mut map = self.entries.write();
        let Some(bucket) = map.get_mut(&hash) else { return false };
        let before = bucket.len();
        bucket.retain(|e| !(e.kernel == kernel && e.input_shapes.as_slice() == input_shapes));
        let removed = bucket.len() != before;
        if bucket.is_empty() {
            map.remove(&hash);
        }
        removed
    }

    /// Remove exactly this entry (pointer identity). Used by callers
    /// that observed the entry failing: invalidating by key instead
    /// could clobber a newer, healthy entry the leader republished after
    /// the failing caller's lookup. Returns whether the entry was still
    /// published.
    pub fn invalidate_entry(&self, entry: &Arc<TunedEntry>) -> bool {
        let hash = shape_hash(&entry.kernel, &entry.input_shapes);
        let mut map = self.entries.write();
        let Some(bucket) = map.get_mut(&hash) else { return false };
        let before = bucket.len();
        bucket.retain(|e| !Arc::ptr_eq(e, entry));
        let removed = bucket.len() != before;
        if bucket.is_empty() {
            map.remove(&hash);
        }
        removed
    }

    /// Drop every published entry (state import / bulk reset).
    pub fn clear(&self) {
        self.entries.write().clear();
    }

    /// Number of published entries.
    pub fn published(&self) -> usize {
        self.entries.read().values().map(Vec::len).sum()
    }

    /// Drain every monitored entry's latency window and evaluate the
    /// drift policy. Leader-only (the scan consumes the window counters).
    /// Returns the entries whose windows demand a retune; empty when the
    /// lane has no drift policy.
    pub fn drift_scan(&self) -> Vec<DriftHit> {
        let Some(policy) = self.drift else { return Vec::new() };
        // Collect Arc clones first so policy evaluation runs without
        // holding the read lock.
        let entries: Vec<Arc<TunedEntry>> =
            self.entries.read().values().flat_map(|b| b.iter().cloned()).collect();
        let now = Instant::now();
        let mut hits = Vec::new();
        for entry in entries {
            let Some(monitor) = &entry.monitor else { continue };
            if let Some(window) = monitor.scan(&policy, now) {
                hits.push(DriftHit {
                    kernel: entry.kernel.clone(),
                    size: entry.size,
                    variant_id: entry.variant_id.clone(),
                    baseline_s: monitor.baseline_s(),
                    window,
                });
            }
        }
        hits
    }

    /// Drain every published entry's ok/error window and evaluate the
    /// quarantine policy. Leader-only (the scan consumes the window
    /// counters). Returns the entries whose error rate tripped the
    /// breaker; empty when the lane has no quarantine policy.
    pub fn quarantine_scan(&self) -> Vec<QuarantineHit> {
        let Some(policy) = self.quarantine else { return Vec::new() };
        let entries: Vec<Arc<TunedEntry>> =
            self.entries.read().values().flat_map(|b| b.iter().cloned()).collect();
        let now = Instant::now();
        let mut hits = Vec::new();
        for entry in entries {
            let Some(breaker) = &entry.breaker else { continue };
            if let Some(window) = breaker.scan(&policy, now) {
                hits.push(QuarantineHit {
                    kernel: entry.kernel.clone(),
                    size: entry.size,
                    input_shapes: entry.input_shapes.clone(),
                    variant_id: entry.variant_id.clone(),
                    window,
                });
            }
        }
        hits
    }

    /// Per-kernel (hits, mean latency seconds) snapshot, sorted by kernel.
    pub fn snapshot(&self) -> Vec<(String, u64, f64)> {
        self.counters
            .lock()
            .iter()
            .map(|(kernel, c)| {
                let (hits, total) = c.totals();
                let mean = if hits > 0 { total.as_secs_f64() / hits as f64 } else { 0.0 };
                (kernel.clone(), hits, mean)
            })
            .collect()
    }

    /// Human-readable rendering for the coordinator's stats output.
    pub fn render(&self) -> String {
        let snap = self.snapshot();
        let mut out = format!("fast lane: {} published entr(ies)\n", self.published());
        for (kernel, hits, mean) in snap {
            out.push_str(&format!(
                "  {kernel}: hits={hits} mean={:.3}ms\n",
                mean * 1e3
            ));
        }
        if self.drift.is_some() {
            let mut lines: Vec<String> = self
                .entries
                .read()
                .values()
                .flatten()
                .filter_map(|e| {
                    e.monitor.as_ref().map(|m| {
                        format!(
                            "  drift {}/n{}: baseline={:.3}ms ewma={:.3}ms streak={}\n",
                            e.kernel,
                            e.size,
                            m.baseline_s() * 1e3,
                            m.ewma_s() * 1e3,
                            m.streak(),
                        )
                    })
                })
                .collect();
            lines.sort();
            for line in lines {
                out.push_str(&line);
            }
        }
        out
    }

    /// JSON export for machine-readable stats.
    pub fn to_json(&self) -> Value {
        let kernels = self
            .snapshot()
            .into_iter()
            .map(|(kernel, hits, mean)| {
                (
                    kernel,
                    Value::Obj(vec![
                        ("hits".into(), n(hits as f64)),
                        ("mean_latency_s".into(), n(mean)),
                    ]),
                )
            })
            .collect();
        let mut obj = vec![
            ("published".into(), n(self.published() as f64)),
            ("kernels".into(), Value::Obj(kernels)),
        ];
        if self.drift.is_some() {
            let mut monitors: Vec<(String, Value)> = self
                .entries
                .read()
                .values()
                .flatten()
                .filter_map(|e| {
                    e.monitor
                        .as_ref()
                        .map(|m| (format!("{}/n{}", e.kernel, e.size), m.status_json()))
                })
                .collect();
            monitors.sort_by(|a, b| a.0.cmp(&b.0));
            obj.push(("drift".into(), Value::Obj(monitors)));
        }
        if self.quarantine.is_some() {
            let mut breakers: Vec<(String, Value)> = self
                .entries
                .read()
                .values()
                .flatten()
                .filter_map(|e| {
                    e.breaker
                        .as_ref()
                        .map(|b| (format!("{}/n{}", e.kernel, e.size), b.status_json()))
                })
                .collect();
            breakers.sort_by(|a, b| a.0.cmp(&b.0));
            obj.push(("quarantine".into(), Value::Obj(breakers)));
        }
        Value::Obj(obj)
    }
}

impl Default for FastLane {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::error::Error;

    struct FixedKernel {
        id: String,
        value: f32,
        fail: bool,
    }

    impl SharedKernel for FixedKernel {
        fn execute(&self, _inputs: &[HostTensor]) -> Result<HostTensor> {
            if self.fail {
                return Err(Error::Xla("boom".into()));
            }
            Ok(HostTensor::full(&[2, 2], self.value))
        }

        fn variant_id(&self) -> &str {
            &self.id
        }
    }

    fn publish_fixed(lane: &FastLane, kernel: &str, dim: usize, value: f32, fail: bool) {
        lane.publish(Publication {
            kernel: kernel.to_string(),
            input_shapes: vec![vec![dim, dim]],
            variant_id: format!("{kernel}.v{value}"),
            value: value as i64,
            size: dim as i64,
            baseline_s: 100e-6,
            exe: Arc::new(FixedKernel { id: format!("{kernel}.v{value}"), value, fail }),
        });
    }

    #[test]
    fn lookup_hits_only_matching_kernel_and_shapes() {
        let lane = FastLane::new();
        publish_fixed(&lane, "k", 2, 7.0, false);
        let inputs = [HostTensor::zeros(&[2, 2])];
        let entry = lane.lookup("k", &inputs).expect("published");
        assert_eq!(entry.value(), 7);
        assert!(lane.lookup("other", &inputs).is_none());
        assert!(lane.lookup("k", &[HostTensor::zeros(&[3, 3])]).is_none());
        assert!(lane.lookup("k", &[]).is_none());
        assert_eq!(lane.published(), 1);
    }

    #[test]
    fn call_executes_and_records_stats() {
        let lane = FastLane::new();
        publish_fixed(&lane, "k", 2, 3.0, false);
        let inputs = [HostTensor::zeros(&[2, 2])];
        let entry = lane.lookup("k", &inputs).unwrap();
        let out = entry.call(&inputs, Instant::now()).unwrap();
        assert_eq!(out.route, CallRoute::Tuned);
        assert!(!out.compiled);
        assert!(out.output.data().iter().all(|&x| x == 3.0));
        let snap = lane.snapshot();
        assert_eq!(snap.len(), 1);
        assert_eq!((snap[0].0.as_str(), snap[0].1), ("k", 1));
    }

    #[test]
    fn republish_replaces_and_invalidate_removes() {
        let lane = FastLane::new();
        publish_fixed(&lane, "k", 2, 1.0, false);
        publish_fixed(&lane, "k", 2, 2.0, false); // retune picked a new winner
        assert_eq!(lane.published(), 1, "replaced, not duplicated");
        let inputs = [HostTensor::zeros(&[2, 2])];
        assert_eq!(lane.lookup("k", &inputs).unwrap().value(), 2);
        assert!(lane.invalidate("k", &[vec![2, 2]]));
        assert!(!lane.invalidate("k", &[vec![2, 2]]), "already gone");
        assert!(lane.lookup("k", &inputs).is_none());
        assert_eq!(lane.published(), 0);
    }

    #[test]
    fn invalidate_entry_spares_a_newer_republished_entry() {
        let lane = FastLane::new();
        publish_fixed(&lane, "k", 2, 1.0, false);
        let inputs = [HostTensor::zeros(&[2, 2])];
        let stale = lane.lookup("k", &inputs).unwrap();
        // leader republishes (retune picked a new winner) while a caller
        // still holds the old entry it observed failing
        publish_fixed(&lane, "k", 2, 2.0, false);
        assert!(!lane.invalidate_entry(&stale), "stale entry already replaced");
        let current = lane.lookup("k", &inputs).expect("healthy entry survives");
        assert_eq!(current.value(), 2);
        // identity invalidation does remove a still-published entry
        assert!(lane.invalidate_entry(&current));
        assert!(lane.lookup("k", &inputs).is_none());
    }

    #[test]
    fn clear_drops_everything_but_keeps_counters() {
        let lane = FastLane::new();
        publish_fixed(&lane, "a", 2, 1.0, false);
        publish_fixed(&lane, "b", 4, 2.0, false);
        let inputs = [HostTensor::zeros(&[2, 2])];
        lane.lookup("a", &inputs).unwrap().call(&inputs, Instant::now()).unwrap();
        lane.clear();
        assert_eq!(lane.published(), 0);
        // hit history survives for reporting
        let snap = lane.snapshot();
        assert_eq!(snap.iter().find(|(k, _, _)| k == "a").unwrap().1, 1);
        let json = lane.to_json();
        assert_eq!(json.get("published").unwrap().as_i64(), Some(0));
    }

    #[test]
    fn concurrent_readers_and_stats() {
        let lane = Arc::new(FastLane::new());
        publish_fixed(&lane, "k", 2, 5.0, false);
        let mut joins = Vec::new();
        for _ in 0..4 {
            let lane = lane.clone();
            joins.push(std::thread::spawn(move || {
                let inputs = [HostTensor::zeros(&[2, 2])];
                for _ in 0..50 {
                    let entry = lane.lookup("k", &inputs).unwrap();
                    let out = entry.call(&inputs, Instant::now()).unwrap();
                    assert!(out.output.data().iter().all(|&x| x == 5.0));
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        let snap = lane.snapshot();
        assert_eq!(snap[0].1, 200, "every hit counted across shards");
        assert!(lane.render().contains("hits=200"));
    }

    #[test]
    fn plan_hash_matches_shape_hash() {
        let inputs = [HostTensor::zeros(&[8, 8]), HostTensor::zeros(&[8])];
        let shapes = vec![vec![8usize, 8], vec![8usize]];
        assert_eq!(plan_hash("k", &inputs), shape_hash("k", &shapes));
        assert_ne!(plan_hash("k", &inputs), shape_hash("j", &shapes));
    }

    #[test]
    fn drift_monitor_only_exists_with_policy() {
        use crate::coordinator::drift::DriftPolicy;
        let plain = FastLane::new();
        publish_fixed(&plain, "k", 2, 1.0, false);
        let inputs = [HostTensor::zeros(&[2, 2])];
        assert!(plain.lookup("k", &inputs).unwrap().drift_monitor().is_none());
        assert!(plain.drift_scan().is_empty());
        assert!(plain.to_json().get("drift").is_none(), "no drift key without policy");

        let lane = FastLane::with_drift(DriftPolicy::default());
        publish_fixed(&lane, "k", 2, 1.0, false);
        let entry = lane.lookup("k", &inputs).unwrap();
        assert_eq!(entry.size(), 2);
        let monitor = entry.drift_monitor().expect("policy arms a monitor");
        assert!((monitor.baseline_s() - 100e-6).abs() < 1e-12);
        entry.call(&inputs, Instant::now()).unwrap();
        // healthy traffic: scan judges the window but demands nothing
        assert!(lane.drift_scan().is_empty());
        assert!(lane.to_json().get("drift").is_some());
        assert!(lane.render().contains("drift k/n2"), "{}", lane.render());
    }

    #[test]
    fn drift_scan_flags_degraded_entry() {
        use crate::coordinator::drift::DriftPolicy;
        let policy = DriftPolicy {
            min_samples: 1,
            ratio_threshold: 2.0,
            cooldown: Duration::from_secs(0),
            consecutive_windows: 1,
            ..DriftPolicy::default()
        };
        let lane = FastLane::with_drift(policy);
        publish_fixed(&lane, "k", 2, 1.0, false);
        let inputs = [HostTensor::zeros(&[2, 2])];
        let entry = lane.lookup("k", &inputs).unwrap();
        // feed the monitor directly: 10 calls at 3x the 100us baseline
        let monitor = entry.drift_monitor().unwrap();
        for _ in 0..10 {
            monitor.record(Duration::from_micros(300));
        }
        let hits = lane.drift_scan();
        assert_eq!(hits.len(), 1);
        assert_eq!(hits[0].kernel, "k");
        assert_eq!(hits[0].size, 2);
        assert!(hits[0].window.ratio > 2.0);
        // window was drained: an immediate rescan is quiet
        assert!(lane.drift_scan().is_empty());
    }

    #[test]
    fn failing_entry_surfaces_error_without_recording_hit() {
        let lane = FastLane::new();
        publish_fixed(&lane, "k", 2, 9.0, true);
        let inputs = [HostTensor::zeros(&[2, 2])];
        let entry = lane.lookup("k", &inputs).unwrap();
        assert!(entry.call(&inputs, Instant::now()).is_err());
        assert_eq!(lane.snapshot()[0].1, 0);
    }

    #[test]
    fn expired_deadline_fails_fast_without_executing() {
        let lane = FastLane::new();
        publish_fixed(&lane, "k", 2, 5.0, false);
        let inputs = [HostTensor::zeros(&[2, 2])];
        let entry = lane.lookup("k", &inputs).unwrap();
        let t0 = Instant::now() - Duration::from_millis(10);
        let gone = Some(Instant::now() - Duration::from_millis(1));
        match entry.call_deadline(&inputs, t0, gone) {
            Err(Error::DeadlineExceeded { kernel, .. }) => assert_eq!(kernel, "k"),
            Err(e) => panic!("expected DeadlineExceeded, got {e}"),
            Ok(_) => panic!("expected DeadlineExceeded, got a result"),
        }
        assert_eq!(lane.snapshot()[0].1, 0, "doomed call never executed");
        // a generous deadline serves normally
        let ok = entry
            .call_deadline(&inputs, Instant::now(), Some(Instant::now() + Duration::from_secs(5)))
            .unwrap();
        assert_eq!(ok.route, CallRoute::Tuned);
    }

    #[test]
    fn quarantine_breaker_only_exists_with_policy() {
        use crate::coordinator::drift::QuarantinePolicy;
        let plain = FastLane::new();
        publish_fixed(&plain, "k", 2, 1.0, false);
        let inputs = [HostTensor::zeros(&[2, 2])];
        assert!(plain.lookup("k", &inputs).unwrap().failure_breaker().is_none());
        assert!(plain.quarantine_scan().is_empty());
        assert!(plain.to_json().get("quarantine").is_none());

        let lane = FastLane::with_policies(None, Some(QuarantinePolicy::default()));
        publish_fixed(&lane, "k", 2, 1.0, false);
        let entry = lane.lookup("k", &inputs).unwrap();
        assert!(entry.failure_breaker().is_some(), "policy arms a breaker");
        assert!(entry.drift_monitor().is_none(), "no drift policy, no monitor");
        assert!(lane.to_json().get("quarantine").is_some());
    }

    #[test]
    fn quarantine_scan_flags_erroring_entry() {
        use crate::coordinator::drift::QuarantinePolicy;
        let policy = QuarantinePolicy {
            min_samples: 4,
            error_threshold: 0.5,
            consecutive_windows: 1,
            cooldown: Duration::ZERO,
            ..QuarantinePolicy::default()
        };
        let lane = FastLane::with_policies(None, Some(policy));
        publish_fixed(&lane, "k", 2, 9.0, true);
        let inputs = [HostTensor::zeros(&[2, 2])];
        let entry = lane.lookup("k", &inputs).unwrap();
        for _ in 0..8 {
            assert!(entry.call(&inputs, Instant::now()).is_err());
        }
        let hits = lane.quarantine_scan();
        assert_eq!(hits.len(), 1);
        assert_eq!(hits[0].kernel, "k");
        assert_eq!(hits[0].size, 2);
        assert_eq!(hits[0].input_shapes, vec![vec![2, 2]]);
        assert!((hits[0].window.error_rate - 1.0).abs() < 1e-9);
        // window was drained: an immediate rescan is quiet
        assert!(lane.quarantine_scan().is_empty());
    }

    #[test]
    fn healthy_entry_with_breaker_never_trips() {
        use crate::coordinator::drift::QuarantinePolicy;
        let policy = QuarantinePolicy {
            min_samples: 4,
            cooldown: Duration::ZERO,
            ..QuarantinePolicy::default()
        };
        let lane = FastLane::with_policies(None, Some(policy));
        publish_fixed(&lane, "k", 2, 5.0, false);
        let inputs = [HostTensor::zeros(&[2, 2])];
        let entry = lane.lookup("k", &inputs).unwrap();
        for _ in 0..16 {
            entry.call(&inputs, Instant::now()).unwrap();
        }
        assert!(lane.quarantine_scan().is_empty(), "all-ok windows never trip");
    }
}
