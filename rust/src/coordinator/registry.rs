//! Kernel registry: resolves calls to manifest problems.

use std::collections::HashMap;

use crate::error::{Error, Result};
use crate::manifest::{Manifest, Problem};
use crate::tensor::HostTensor;

/// Index over the manifest for O(1) call resolution.
pub struct KernelRegistry {
    manifest: Manifest,
    /// (kernel, size) → problem index in `manifest.problems`.
    by_kernel_size: HashMap<(String, i64), usize>,
    /// (kernel, input signature) → problem index.
    by_kernel_sig: HashMap<(String, String), usize>,
}

impl KernelRegistry {
    /// Build the index.
    pub fn new(manifest: Manifest) -> KernelRegistry {
        let mut by_kernel_size = HashMap::new();
        let mut by_kernel_sig = HashMap::new();
        for (i, p) in manifest.problems.iter().enumerate() {
            by_kernel_size.insert((p.kernel.clone(), p.size), i);
            by_kernel_sig.insert((p.kernel.clone(), p.variants[0].inputs.join(",")), i);
        }
        KernelRegistry { manifest, by_kernel_size, by_kernel_sig }
    }

    /// The wrapped manifest.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Resolve by kernel + problem size.
    pub fn problem(&self, kernel: &str, size: i64) -> Result<&Problem> {
        self.by_kernel_size
            .get(&(kernel.to_string(), size))
            .map(|&i| &self.manifest.problems[i])
            .ok_or_else(|| Error::Unknown { kind: "problem", name: format!("{kernel}/n{size}") })
    }

    /// Resolve by kernel + the actual call arguments: the paper's
    /// "calls with different arguments are a different autotuning
    /// problem" — the signature is derived from the inputs themselves.
    pub fn problem_for_inputs(&self, kernel: &str, inputs: &[HostTensor]) -> Result<&Problem> {
        let sig = inputs.iter().map(HostTensor::signature).collect::<Vec<_>>().join(",");
        self.by_kernel_sig
            .get(&(kernel.to_string(), sig.clone()))
            .map(|&i| &self.manifest.problems[i])
            .ok_or_else(|| Error::ShapeMismatch {
                kernel: kernel.to_string(),
                expected: self.known_signatures(kernel),
                got: sig,
            })
    }

    /// Candidate parameter values of a problem, declaration order.
    pub fn values(&self, p: &Problem) -> Vec<i64> {
        p.variants.iter().map(|v| v.value).collect()
    }

    fn known_signatures(&self, kernel: &str) -> String {
        let mut sigs: Vec<String> = self
            .manifest
            .problems
            .iter()
            .filter(|p| p.kernel == kernel)
            .map(|p| p.variants[0].inputs.join(","))
            .collect();
        sigs.sort();
        if sigs.is_empty() {
            format!("(unknown kernel `{kernel}`)")
        } else {
            sigs.join(" | ")
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn registry() -> KernelRegistry {
        KernelRegistry::new(crate::manifest::tests::sample_manifest().unwrap())
    }

    #[test]
    fn resolves_by_size_and_signature() {
        let r = registry();
        assert_eq!(r.problem("k", 8).unwrap().size, 8);
        let inputs = [HostTensor::zeros(&[8, 8])];
        assert_eq!(r.problem_for_inputs("k", &inputs).unwrap().size, 8);
        let inputs16 = [HostTensor::zeros(&[16, 16])];
        assert_eq!(r.problem_for_inputs("k", &inputs16).unwrap().size, 16);
    }

    #[test]
    fn unknown_kernel_and_shape_errors() {
        let r = registry();
        assert!(r.problem("nope", 8).is_err());
        let bad = [HostTensor::zeros(&[3, 3])];
        let err = r.problem_for_inputs("k", &bad).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("f32[3,3]"), "{msg}");
        assert!(msg.contains("f32[8,8]"), "should list known signatures: {msg}");
    }

    #[test]
    fn values_in_declaration_order() {
        let r = registry();
        let p = r.problem("k", 8).unwrap();
        assert_eq!(r.values(p), vec![1, 2]);
    }
}
