//! Worker pool for thread-pinned engines: the third lane.
//!
//! The shared fast lane needs a `Send + Sync` executable handle, which
//! backends like PJRT cannot provide — their executables are `Rc`-based
//! and thread-pinned, so before this module every tuned PJRT call
//! funnelled through the single leader thread. The [`WorkerPool`]
//! removes that cap without ever moving an executable across threads:
//!
//! * **One engine per worker.** Each worker thread builds its *own*
//!   engine via an [`EngineFactory`] — `create` runs on the worker
//!   thread, so a thread-pinned client is born on the thread that will
//!   own it forever.
//! * **Replicated finalization.** When the leader finalizes a winner it
//!   broadcasts the variant (plus its HLO text) to every worker; each
//!   compiles its own copy once into a private cache and acks. The
//!   winners' *compilation* therefore happens N times — the price of
//!   thread pinning — but exploration and measurement stay exclusively
//!   on the leader, preserving the paper's "compilation protected by a
//!   mutex" guarantee for everything that *tunes*.
//! * **Sharded MPMC queue with work stealing.** Tuned calls are pushed
//!   onto per-worker shards (round-robin, bounded by `queue_depth`,
//!   blocking for backpressure when every ready shard is full) and each
//!   worker drains its own shard — callers contend only on one shard
//!   mutex per call, never on a global queue. An idle worker steals one
//!   exec job from a sibling's shard before parking on its own queue
//!   (re-checking on a bounded poll while parked), so a slow job on one
//!   worker cannot strand its queued followers while the rest of the
//!   pool sits idle. Control jobs — installs, evicts — are owner-only
//!   and never stolen, and a worker only steals variants it is routed
//!   for (its own install compile succeeded); steals are counted per
//!   worker in `stats_json()`.
//! * **Fault containment.** A worker whose compile fails at replicated
//!   finalization is excluded from that variant's routing; if *no*
//!   worker can compile, the install is memoized as failed and the
//!   leader keeps serving (no deadlock, no republish storm). A worker
//!   that panics mid-job drops the job's reply (the caller falls back to
//!   the leader — no call is lost) and is respawned with a fresh engine;
//!   its private cache re-fills lazily from the pool's install specs,
//!   and a worker whose lazy recompile fails deregisters itself from
//!   that variant's routing (the last one out memoizes the failure). A
//!   worker whose engine cannot even be re-created marks itself dead and
//!   drains its shard with errors — pushes re-check liveness under the
//!   shard lock, so callers are never left hanging.
//! * **Deadlines.** [`WorkerPool::submit_deadline`] bounds the whole
//!   round trip — backpressure wait, queue wait, and execution — and
//!   returns [`Error::DeadlineExceeded`] when the budget elapses. The
//!   caller drops its reply receiver; the worker's eventual send fails
//!   harmlessly (result discarded on arrival) and the worker lives on.
//!
//! The pool publishes into the existing [`super::FastLane`] through
//! [`WorkerPool::handle_for`] — a `SharedKernel` whose `execute` submits
//! to the queue and waits. Lane stats, drift windows and invalidation
//! therefore work identically for pool-backed entries; the pool adds
//! per-worker atomic counters on top (executed/errors/compiles, exported
//! under `"pool"` in `stats_json()`).

use std::collections::{HashMap, HashSet, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::autotuner::ProblemKey;
use crate::error::{Error, Result};
use crate::manifest::Variant;
use crate::runtime::{CompiledKernel, Engine, EngineFactory, SharedKernel};
use crate::sync::{TrackedCondvar, TrackedMutex, TrackedRwLock};
use crate::tensor::HostTensor;
use crate::util::json::{n, s, Value};

use super::background::ExploreResult;

/// Worker-pool configuration, carried in
/// [`super::ServerOptions`]`::pool`.
#[derive(Clone)]
pub struct PoolOptions {
    /// Worker threads (each with its own engine). Clamped to ≥ 1.
    pub workers: usize,
    /// Per-worker queue bound; a caller finding every ready shard full
    /// blocks for backpressure instead of dropping the call. Clamped
    /// to ≥ 1.
    pub queue_depth: usize,
    /// Builds each worker's private engine, on the worker's own thread.
    pub factory: Arc<dyn EngineFactory>,
}

impl PoolOptions {
    /// Defaults: 4 workers, queue depth 64.
    pub fn new(factory: Arc<dyn EngineFactory>) -> PoolOptions {
        PoolOptions { workers: 4, queue_depth: 64, factory }
    }

    /// Builder helper: set the worker count.
    pub fn with_workers(mut self, workers: usize) -> PoolOptions {
        self.workers = workers;
        self
    }

    /// Builder helper: set the per-worker queue bound.
    pub fn with_queue_depth(mut self, depth: usize) -> PoolOptions {
        self.queue_depth = depth;
        self
    }
}

impl std::fmt::Debug for PoolOptions {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PoolOptions")
            .field("workers", &self.workers)
            .field("queue_depth", &self.queue_depth)
            .field("factory", &self.factory.name())
            .finish()
    }
}

/// Everything a worker needs to compile a finalized winner locally.
struct InstallSpec {
    variant: Variant,
    hlo_text: String,
}

/// Routing state for one installed variant: the spec (for lazy recompiles
/// after a respawn) plus the workers whose install compile succeeded.
struct VariantRoute {
    spec: Arc<InstallSpec>,
    ready: Vec<usize>,
}

enum Job {
    /// Execute an installed variant and reply with the output plus the
    /// worker-measured execution duration (what drift monitors consume —
    /// queue wait must not read as kernel drift).
    Exec {
        variant_id: String,
        inputs: Vec<HostTensor>,
        reply: mpsc::SyncSender<Result<(HostTensor, Duration)>>,
    },
    /// Replicated finalization: compile the spec into the worker's cache.
    Install {
        spec: Arc<InstallSpec>,
        reply: mpsc::SyncSender<Result<()>>,
    },
    /// Drop cached executables (retune / state import).
    Evict { variant_ids: Vec<String> },
    /// Background explore: scratch-compile the candidate, measure one
    /// execution on synthetic inputs, report to the leader's background
    /// scheduler, and drop the executable — the worker's serving cache
    /// is never touched, so a losing candidate leaves nothing to evict.
    Explore {
        spec: Arc<InstallSpec>,
        inputs: Vec<HostTensor>,
        key: ProblemKey,
        candidate: usize,
        seq: u64,
        reply: mpsc::Sender<ExploreResult>,
    },
}

/// One per-worker queue shard: a main lane (exec + control, bounded by
/// `queue_depth`) plus a background lane for explore jobs, drained only
/// when the main lane is empty — serving traffic always overtakes
/// candidate exploration.
struct Shard {
    queue: TrackedMutex<ShardQueues>,
    not_empty: TrackedCondvar,
    not_full: TrackedCondvar,
}

/// The two priority classes of one shard.
#[derive(Default)]
struct ShardQueues {
    /// Exec + control jobs, FIFO, bounded by `queue_depth`.
    main: VecDeque<Job>,
    /// Background explore jobs, FIFO, depth-exempt (the leader's
    /// duty-cycle pipeline cap already bounds how many are in flight).
    bg: VecDeque<Job>,
}

impl ShardQueues {
    fn is_empty(&self) -> bool {
        self.main.is_empty() && self.bg.is_empty()
    }
}

impl Shard {
    fn new() -> Shard {
        Shard {
            // All shard instances share one site label: acquisition
            // *order* is a per-class property, and no path ever holds
            // two shard queues at once.
            queue: TrackedMutex::new("coordinator.pool.shard", ShardQueues::default()),
            not_empty: TrackedCondvar::new(),
            not_full: TrackedCondvar::new(),
        }
    }
}

/// Per-worker atomic counters (updated by the worker, read by stats),
/// each alone on its cache line so neighbouring workers do not
/// false-share.
#[repr(align(64))]
struct WorkerSlot {
    executed: AtomicU64,   // relaxed-counter: stats-only tally, no data published
    exec_nanos: AtomicU64, // relaxed-counter: stats-only latency sum
    errors: AtomicU64,     // relaxed-counter: stats-only tally
    compiles: AtomicU64,   // relaxed-counter: stats-only tally
    steals: AtomicU64,     // relaxed-counter: stats-only tally
    alive: AtomicBool,
}

impl WorkerSlot {
    fn new() -> WorkerSlot {
        WorkerSlot {
            executed: AtomicU64::new(0),
            exec_nanos: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            compiles: AtomicU64::new(0),
            steals: AtomicU64::new(0),
            alive: AtomicBool::new(true),
        }
    }
}

/// Snapshot of one worker's counters.
#[derive(Debug, Clone)]
pub struct WorkerSnapshot {
    /// Successful executions served.
    pub executed: u64,
    /// Execution errors replied (compile-on-demand or execute failures).
    pub errors: u64,
    /// Compilations performed (install broadcasts + lazy recompiles).
    pub compiles: u64,
    /// Jobs this worker stole from a sibling's shard while idle.
    pub steals: u64,
    /// Mean execution latency in seconds (0 when idle so far).
    pub mean_exec_s: f64,
    /// Whether the worker thread is still serving.
    pub alive: bool,
}

/// Snapshot of the whole pool.
#[derive(Debug, Clone)]
pub struct PoolSnapshot {
    /// Per-worker counters, indexed by worker.
    pub workers: Vec<WorkerSnapshot>,
    /// Variants currently installed (routable).
    pub installed: usize,
    /// Worker respawns after a panic.
    pub respawns: u64,
    /// Engine name reported by the factory.
    pub engine: String,
    /// Configured per-worker queue bound.
    pub queue_depth: usize,
}

impl PoolSnapshot {
    /// Total successful executions across workers.
    pub fn total_executed(&self) -> u64 {
        self.workers.iter().map(|w| w.executed).sum()
    }
}

/// A pool of worker threads, each owning a private (possibly `!Send`)
/// engine, serving tuned calls for backends whose executables cannot be
/// shared across threads. See the module docs for the full contract.
pub struct WorkerPool {
    shards: Vec<Shard>,
    workers: Vec<WorkerSlot>,
    joins: TrackedMutex<Vec<JoinHandle<()>>>,
    shutdown: AtomicBool,
    queue_depth: usize,
    rr: AtomicUsize, // relaxed-counter: round-robin cursor, any interleaving is fine
    /// variant id → install spec + ready workers.
    routes: TrackedRwLock<HashMap<String, VariantRoute>>,
    /// Variants no worker could compile — memoized so the leader's lazy
    /// republish probe costs one lookup instead of a re-broadcast per
    /// tuned call. Cleared by [`WorkerPool::evict`] (retune) so a fresh
    /// finalization retries.
    failed_installs: TrackedMutex<HashSet<String>>,
    respawns: AtomicU64, // relaxed-counter: stats-only tally
    engine_name: String,
}

impl WorkerPool {
    /// Spawn `opts.workers` worker threads, each creating its own engine
    /// via the factory *on its own thread*. Fails (and reaps the threads
    /// already started) if any worker's engine cannot be created.
    pub fn spawn(opts: PoolOptions) -> Result<Arc<WorkerPool>> {
        let workers = opts.workers.max(1);
        let queue_depth = opts.queue_depth.max(1);
        let pool = Arc::new(WorkerPool {
            shards: (0..workers).map(|_| Shard::new()).collect(),
            workers: (0..workers).map(|_| WorkerSlot::new()).collect(),
            joins: TrackedMutex::new("coordinator.pool.joins", Vec::new()),
            shutdown: AtomicBool::new(false),
            queue_depth,
            rr: AtomicUsize::new(0),
            routes: TrackedRwLock::new("coordinator.pool.routes", HashMap::new()),
            failed_installs: TrackedMutex::new(
                "coordinator.pool.failed_installs",
                HashSet::new(),
            ),
            respawns: AtomicU64::new(0),
            engine_name: opts.factory.name().to_string(),
        });
        let mut inits = Vec::new();
        for idx in 0..workers {
            let shared = pool.clone();
            let factory = opts.factory.clone();
            let (init_tx, init_rx) = mpsc::sync_channel::<Result<()>>(1);
            let join = match std::thread::Builder::new()
                .name(format!("jitune-pool-{idx}"))
                .spawn(move || worker_main(shared, factory, idx, init_tx))
            {
                Ok(join) => join,
                Err(e) => {
                    // reap the workers already started before bailing
                    pool.stop();
                    return Err(Error::Coordinator(format!("pool worker spawn: {e}")));
                }
            };
            pool.joins.lock().push(join);
            inits.push(init_rx);
        }
        for (idx, rx) in inits.into_iter().enumerate() {
            // jitune-lint: allow(L006): init handshake — the worker sends exactly
            // once and its thread death drops the sender, disconnecting this recv
            match rx.recv() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => {
                    pool.stop();
                    return Err(Error::Coordinator(format!(
                        "pool worker {idx}: engine creation failed: {e}"
                    )));
                }
                Err(_) => {
                    pool.stop();
                    return Err(Error::Coordinator(format!(
                        "pool worker {idx} died during init"
                    )));
                }
            }
        }
        log::info!("pool: {workers} worker(s) up ({})", pool.engine_name);
        Ok(pool)
    }

    /// Number of worker threads.
    pub fn worker_count(&self) -> usize {
        self.workers.len()
    }

    /// Worker respawns after a panic so far.
    pub fn respawns(&self) -> u64 {
        self.respawns.load(Ordering::Relaxed)
    }

    /// Replicated finalization: broadcast `variant` (with its HLO text)
    /// so every live worker compiles a private copy, and record the
    /// routing. Returns the number of workers ready to serve it — 0
    /// means the variant cannot take the pool lane (the failure is
    /// memoized; a later [`WorkerPool::evict`] clears the memo).
    ///
    /// Idempotent: re-installing an already-routed variant skips the
    /// broadcast and reports the current live-ready count.
    pub fn install(&self, variant: Variant, hlo_text: String) -> usize {
        if self.shutdown.load(Ordering::SeqCst) {
            return 0;
        }
        let id = variant.id.clone();
        if let Some(route) = self.routes.read().get(&id) {
            return route
                .ready
                .iter()
                .filter(|&&i| self.workers[i].alive.load(Ordering::SeqCst))
                .count();
        }
        if self.failed_installs.lock().contains(&id) {
            return 0;
        }
        let spec = Arc::new(InstallSpec { variant, hlo_text });
        let mut pending = Vec::new();
        for idx in 0..self.workers.len() {
            if !self.workers[idx].alive.load(Ordering::SeqCst) {
                continue;
            }
            let (reply, rx) = mpsc::sync_channel::<Result<()>>(1);
            if self.push_ctrl(idx, Job::Install { spec: spec.clone(), reply }).is_ok() {
                pending.push((idx, rx));
            }
        }
        let mut ready = Vec::new();
        for (idx, rx) in pending {
            // jitune-lint: allow(L006): install ack — the worker replies to every
            // Install job and a worker death drops the sender, disconnecting this
            match rx.recv() {
                Ok(Ok(())) => ready.push(idx),
                Ok(Err(e)) => log::warn!("pool worker {idx}: compile of {id} failed: {e}"),
                Err(_) => log::warn!("pool worker {idx}: died during install of {id}"),
            }
        }
        let count = ready.len();
        if count == 0 {
            log::warn!("pool: no worker could compile {id}; leader keeps serving it");
            self.failed_installs.lock().insert(id);
        } else {
            log::debug!("pool: {id} replicated on {count} worker(s)");
            self.routes.write().insert(id, VariantRoute { spec, ready });
        }
        count
    }

    /// Drop the given variants from routing and every worker's cache
    /// (retune / demotion / state import), and clear their failed-install
    /// memos so a fresh finalization retries the broadcast.
    pub fn evict(&self, variant_ids: &[String]) {
        if variant_ids.is_empty() {
            return;
        }
        {
            let mut routes = self.routes.write();
            for id in variant_ids {
                routes.remove(id);
            }
        }
        {
            let mut failed = self.failed_installs.lock();
            for id in variant_ids {
                failed.remove(id);
            }
        }
        for idx in 0..self.workers.len() {
            if !self.workers[idx].alive.load(Ordering::SeqCst) {
                continue;
            }
            let _ = self.push_ctrl(idx, Job::Evict { variant_ids: variant_ids.to_vec() });
        }
    }

    /// Drop every installed variant (bulk reset on state import).
    pub fn clear(&self) {
        let ids: Vec<String> = self.routes.read().keys().cloned().collect();
        self.failed_installs.lock().clear();
        self.evict(&ids);
    }

    /// Number of installed (routable) variants.
    pub fn installed(&self) -> usize {
        self.routes.read().len()
    }

    /// Whether this variant's install is memoized as failed. The
    /// leader's lazy republish probe checks this *before* cloning the
    /// variant's HLO text, so a dead install costs one lookup per
    /// tuned call, not a broadcast or a text copy.
    pub fn install_failed(&self, variant_id: &str) -> bool {
        self.failed_installs.lock().contains(variant_id)
    }

    /// Memoize a publish-side failure that happened before the
    /// broadcast (e.g. the winner's HLO text could not be read), so the
    /// republish probe goes quiet. Cleared by [`WorkerPool::evict`]
    /// exactly like a failed install.
    pub fn mark_failed(&self, variant_id: &str) {
        self.failed_installs.lock().insert(variant_id.to_string());
    }

    /// A `Send + Sync` handle executing `variant_id` on the pool — what
    /// the leader publishes into the fast lane for thread-pinned
    /// backends. Call after a successful [`WorkerPool::install`].
    pub fn handle_for(self: &Arc<Self>, variant_id: String) -> Arc<dyn SharedKernel> {
        Arc::new(PoolKernel { pool: self.clone(), variant_id })
    }

    /// Execute one call on the pool: route to a ready worker's shard and
    /// wait for the reply — the output plus the worker-measured
    /// execution duration. Errors (not installed, pool stopped, worker
    /// died mid-call) surface to the caller, whose fast-lane fallback
    /// retries through the leader — a call can fail over, never hang.
    pub fn submit(&self, variant_id: &str, inputs: &[HostTensor]) -> Result<(HostTensor, Duration)> {
        self.submit_deadline(variant_id, inputs, None)
    }

    /// [`submit`](WorkerPool::submit) with an optional absolute deadline
    /// covering the *whole* pool round trip — backpressure wait, queue
    /// wait, and execution. A call that cannot finish in budget returns
    /// [`Error::DeadlineExceeded`] and drops its reply receiver; the
    /// worker's eventual `reply.send` fails harmlessly, so the
    /// worker-side result is discarded on arrival and the worker itself
    /// is never killed.
    pub fn submit_deadline(
        &self,
        variant_id: &str,
        inputs: &[HostTensor],
        deadline: Option<Instant>,
    ) -> Result<(HostTensor, Duration)> {
        if self.shutdown.load(Ordering::SeqCst) {
            return Err(Error::Coordinator("worker pool stopped".into()));
        }
        let ready: Vec<usize> = {
            let routes = self.routes.read();
            let Some(route) = routes.get(variant_id) else {
                return Err(Error::Coordinator(format!(
                    "pool: {variant_id} is not installed"
                )));
            };
            route
                .ready
                .iter()
                .copied()
                .filter(|&i| self.workers[i].alive.load(Ordering::SeqCst))
                .collect()
        };
        if ready.is_empty() {
            return Err(Error::Coordinator(format!(
                "pool: no live worker holds {variant_id}"
            )));
        }
        let t0 = Instant::now();
        let (reply, rx) = mpsc::sync_channel::<Result<(HostTensor, Duration)>>(1);
        self.push_exec(
            Job::Exec { variant_id: variant_id.to_string(), inputs: inputs.to_vec(), reply },
            &ready,
            deadline,
        )
        .map_err(|e| match (e, deadline) {
            // push_exec can't see the call's start, so it reports a zero
            // budget; rewrite it to the real one.
            (Error::DeadlineExceeded { kernel, .. }, Some(d)) => {
                Error::DeadlineExceeded { kernel, deadline: d.saturating_duration_since(t0) }
            }
            (other, _) => other,
        })?;
        match deadline {
            Some(d) => match rx.recv_timeout(d.saturating_duration_since(Instant::now())) {
                Ok(result) => result,
                Err(mpsc::RecvTimeoutError::Timeout) => Err(Error::DeadlineExceeded {
                    kernel: variant_id.to_string(),
                    deadline: d.saturating_duration_since(t0),
                }),
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    Err(Error::Coordinator("pool worker died mid-call".into()))
                }
            },
            None => {
                // jitune-lint: allow(L006): a worker death or shard drain drops the
                // reply sender, so this recv disconnects instead of hanging
                rx.recv()
                    .map_err(|_| Error::Coordinator("pool worker died mid-call".into()))?
            }
        }
    }

    /// Per-worker counter snapshot plus pool-level gauges.
    pub fn snapshot(&self) -> PoolSnapshot {
        let workers = self
            .workers
            .iter()
            .map(|w| {
                let executed = w.executed.load(Ordering::Relaxed);
                let nanos = w.exec_nanos.load(Ordering::Relaxed);
                WorkerSnapshot {
                    executed,
                    errors: w.errors.load(Ordering::Relaxed),
                    compiles: w.compiles.load(Ordering::Relaxed),
                    steals: w.steals.load(Ordering::Relaxed),
                    mean_exec_s: if executed > 0 {
                        nanos as f64 / 1e9 / executed as f64
                    } else {
                        0.0
                    },
                    alive: w.alive.load(Ordering::SeqCst),
                }
            })
            .collect();
        PoolSnapshot {
            workers,
            installed: self.installed(),
            respawns: self.respawns(),
            engine: self.engine_name.clone(),
            queue_depth: self.queue_depth,
        }
    }

    /// JSON export for `stats_json()` (the `"pool"` object).
    pub fn to_json(&self) -> Value {
        let snap = self.snapshot();
        let per_worker = snap
            .workers
            .iter()
            .map(|w| {
                Value::Obj(vec![
                    ("executed".into(), n(w.executed as f64)),
                    ("errors".into(), n(w.errors as f64)),
                    ("compiles".into(), n(w.compiles as f64)),
                    ("steals".into(), n(w.steals as f64)),
                    ("mean_exec_s".into(), n(w.mean_exec_s)),
                    ("alive".into(), Value::Bool(w.alive)),
                ])
            })
            .collect();
        Value::Obj(vec![
            ("workers".into(), n(snap.workers.len() as f64)),
            ("queue_depth".into(), n(snap.queue_depth as f64)),
            ("installed".into(), n(snap.installed as f64)),
            ("respawns".into(), n(snap.respawns as f64)),
            ("executed".into(), n(snap.total_executed() as f64)),
            ("engine".into(), s(snap.engine.clone())),
            ("per_worker".into(), Value::Arr(per_worker)),
        ])
    }

    /// Human-readable rendering for the coordinator's stats output.
    pub fn render(&self) -> String {
        let snap = self.snapshot();
        let mut out = format!(
            "worker pool ({}): {} worker(s), {} installed, {} respawn(s)\n",
            snap.engine,
            snap.workers.len(),
            snap.installed,
            snap.respawns
        );
        for (idx, w) in snap.workers.iter().enumerate() {
            out.push_str(&format!(
                "  worker {idx}: executed={} errors={} compiles={} steals={} mean={:.3}ms{}\n",
                w.executed,
                w.errors,
                w.compiles,
                w.steals,
                w.mean_exec_s * 1e3,
                if w.alive { "" } else { " (dead)" }
            ));
        }
        out
    }

    /// Stop serving: reject new submissions, let workers drain queued
    /// jobs, join the threads. Idempotent; also invoked by the
    /// coordinator's shutdown.
    pub fn stop(&self) {
        self.shutdown.store(true, Ordering::SeqCst);
        for shard in &self.shards {
            // lock-step with push/pop so no waiter can miss the wake-up
            let _q = shard.queue.lock();
            shard.not_empty.notify_all();
            shard.not_full.notify_all();
        }
        let joins: Vec<JoinHandle<()>> = self.joins.lock().drain(..).collect();
        for join in joins {
            // jitune-lint: allow(L006): shutdown join — the stored shutdown flag
            // plus the wake-up broadcast above guarantee every worker loop exits
            let _ = join.join();
        }
    }

    /// Install spec for a variant (workers use it for lazy recompiles
    /// after a respawn emptied their cache).
    fn route_spec(&self, variant_id: &str) -> Option<Arc<InstallSpec>> {
        self.routes.read().get(variant_id).map(|r| r.spec.clone())
    }

    /// Remove one worker from a variant's routing — its lazy recompile
    /// failed, so keeping it routed would retry (and fail) on every
    /// call. A variant that loses its last ready worker is dropped and
    /// memoized as failed, so the leader's republish probe goes quiet
    /// instead of churning; the next retune clears the memo.
    fn deregister(&self, variant_id: &str, idx: usize) {
        let mut routes = self.routes.write();
        let Some(route) = routes.get_mut(variant_id) else { return };
        route.ready.retain(|&i| i != idx);
        if route.ready.is_empty() {
            routes.remove(variant_id);
            self.failed_installs.lock().insert(variant_id.to_string());
            log::warn!("pool: {variant_id} lost its last ready worker; leader keeps serving it");
        }
    }

    /// Push an exec job to one of `ready`'s shards: one non-blocking
    /// round-robin pass, then a backpressure block on the first choice.
    ///
    /// Liveness is re-checked *under each shard lock*: a worker's death
    /// path stores `alive = false` before draining its shard, so a push
    /// that acquires the lock after the drain observes the flag and
    /// skips — a job can never be parked on a shard nobody will pop.
    /// (A push that lands just *before* the drain is cleared by it, and
    /// the dropped reply unblocks the caller into the leader fallback.)
    /// With a `deadline`, the backpressure block is bounded: queue wait
    /// counts against the call's budget, and a budget that dies waiting
    /// for queue space returns [`Error::DeadlineExceeded`] instead of
    /// parking the caller on a wedged shard.
    fn push_exec(&self, job: Job, ready: &[usize], deadline: Option<Instant>) -> Result<()> {
        let start = self.rr.fetch_add(1, Ordering::Relaxed) % ready.len();
        let mut job = Some(job);
        for k in 0..ready.len() {
            let idx = ready[(start + k) % ready.len()];
            let shard = &self.shards[idx];
            let mut q = shard.queue.lock();
            if self.shutdown.load(Ordering::SeqCst) {
                return Err(Error::Coordinator("worker pool stopped".into()));
            }
            if !self.workers[idx].alive.load(Ordering::SeqCst) {
                continue;
            }
            if q.main.len() < self.queue_depth {
                // jitune-lint: allow(L005): job is consumed at most once per loop iteration
                q.main.push_back(job.take().expect("job unconsumed"));
                shard.not_empty.notify_one();
                return Ok(());
            }
        }
        // Every live ready shard is full: block on the first live
        // choice for backpressure. A dying worker's drain notifies
        // `not_full`, so the wait re-checks liveness and bails out.
        let Some(idx) = (0..ready.len())
            .map(|k| ready[(start + k) % ready.len()])
            .find(|&i| self.workers[i].alive.load(Ordering::SeqCst))
        else {
            return Err(Error::Coordinator("pool: no live worker for this variant".into()));
        };
        let shard = &self.shards[idx];
        let mut q = shard.queue.lock();
        loop {
            if self.shutdown.load(Ordering::SeqCst) {
                return Err(Error::Coordinator("worker pool stopped".into()));
            }
            if !self.workers[idx].alive.load(Ordering::SeqCst) {
                return Err(Error::Coordinator(format!("pool worker {idx} died")));
            }
            if q.main.len() < self.queue_depth {
                // jitune-lint: allow(L005): job is consumed exactly once — the push returns
                q.main.push_back(job.take().expect("job unconsumed"));
                shard.not_empty.notify_one();
                return Ok(());
            }
            match deadline {
                Some(d) => {
                    let now = Instant::now();
                    if now >= d {
                        let kernel = match job.as_ref() {
                            Some(Job::Exec { variant_id, .. }) => variant_id.clone(),
                            _ => String::new(),
                        };
                        return Err(Error::DeadlineExceeded {
                            kernel,
                            deadline: Duration::ZERO,
                        });
                    }
                    let (guard, _) =
                        shard.not_full.wait_timeout(q, d.saturating_duration_since(now));
                    q = guard;
                }
                // jitune-lint: allow(L006): only reached when no deadline is set; a
                // dying worker's drain notifies not_full and the loop re-checks liveness
                None => q = shard.not_full.wait(q),
            }
        }
    }

    /// Push a control job (install/evict) to a specific worker's shard,
    /// exempt from the depth bound so control never deadlocks against
    /// backpressure. Liveness is checked under the shard lock, exactly
    /// like [`WorkerPool::push_exec`]: an install parked on a dead
    /// worker's drained shard would otherwise block the leader forever
    /// on its ack.
    fn push_ctrl(&self, idx: usize, job: Job) -> Result<()> {
        let shard = &self.shards[idx];
        let mut q = shard.queue.lock();
        if self.shutdown.load(Ordering::SeqCst) {
            return Err(Error::Coordinator("worker pool stopped".into()));
        }
        if !self.workers[idx].alive.load(Ordering::SeqCst) {
            return Err(Error::Coordinator(format!("pool worker {idx} died")));
        }
        q.main.push_back(job);
        shard.not_empty.notify_one();
        Ok(())
    }

    /// Push a background explore job: round-robin over live workers,
    /// onto the shard's *background* lane (served only when the main
    /// lane is empty, stealable by any idle worker). Depth-exempt — the
    /// scheduler's duty-cycle pipeline cap already bounds issuance.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn submit_explore(
        &self,
        variant: Variant,
        hlo_text: String,
        inputs: Vec<HostTensor>,
        key: ProblemKey,
        candidate: usize,
        seq: u64,
        reply: mpsc::Sender<ExploreResult>,
    ) -> Result<()> {
        let spec = Arc::new(InstallSpec { variant, hlo_text });
        let mut job = Some(Job::Explore { spec, inputs, key, candidate, seq, reply });
        let n = self.shards.len();
        let start = self.rr.fetch_add(1, Ordering::Relaxed) % n;
        for k in 0..n {
            let idx = (start + k) % n;
            let shard = &self.shards[idx];
            let mut q = shard.queue.lock();
            if self.shutdown.load(Ordering::SeqCst) {
                return Err(Error::Coordinator("worker pool stopped".into()));
            }
            if !self.workers[idx].alive.load(Ordering::SeqCst) {
                continue;
            }
            // jitune-lint: allow(L005): job is consumed at most once per loop iteration
            q.bg.push_back(job.take().expect("job unconsumed"));
            shard.not_empty.notify_one();
            return Ok(());
        }
        Err(Error::Coordinator("pool: no live worker for background explore".into()))
    }

    /// Worker-side blocking pop: drains the shard even after shutdown
    /// (graceful stop serves queued work), returns `None` once the shard
    /// is empty *and* shutdown was requested.
    ///
    /// Work stealing: a worker whose own shard is empty steals one exec
    /// job from a sibling's shard *before* parking on its own queue —
    /// an idle worker must not sit parked while a slow sibling's shard
    /// backs up. Only [`Job::Exec`] is stealable (installs compile into a
    /// specific worker's private cache and evicts clear it — both are
    /// owner-only), only from the shard's front (a sibling's control
    /// ordering is never overtaken), and only for variants this worker is
    /// *routed* for — a worker outside the variant's ready set would just
    /// error a job a capable sibling could serve. A stolen variant
    /// missing from the stealer's cache lazily recompiles from the
    /// install spec, exactly like a post-respawn cache miss.
    ///
    /// A job landing on a busy sibling's shard signals only that shard's
    /// condvar, so a multi-worker park uses a bounded wait and re-runs
    /// the steal pass on timeout: a stranded job waits at most one poll
    /// interval, never the sibling's whole in-flight job. The poll backs
    /// off exponentially (1ms → 50ms) while nothing turns up, so a
    /// hot-idle pool wakes each worker ~20x/s instead of 1000x/s; a push
    /// to the worker's own shard still wakes it immediately.
    fn pop(&self, idx: usize) -> Option<Job> {
        let mut poll = Duration::from_millis(1);
        loop {
            {
                let shard = &self.shards[idx];
                let mut q = shard.queue.lock();
                if let Some(job) = q.main.pop_front().or_else(|| q.bg.pop_front()) {
                    shard.not_full.notify_one();
                    return Some(job);
                }
                if self.shutdown.load(Ordering::SeqCst) {
                    return None;
                }
            }
            // Own shard empty: one steal pass over the siblings before
            // parking. (After shutdown the stop protocol has every
            // worker drain only its own shard; the loop above exits.)
            if let Some(job) = self.steal_from_sibling(idx) {
                self.workers[idx].steals.fetch_add(1, Ordering::Relaxed);
                return Some(job);
            }
            let shard = &self.shards[idx];
            let q = shard.queue.lock();
            if !q.is_empty() || self.shutdown.load(Ordering::SeqCst) {
                continue; // re-check holding nothing stale
            }
            if self.shards.len() > 1 {
                let _ = shard.not_empty.wait_timeout(q, poll);
                poll = (poll * 2).min(Duration::from_millis(50));
            } else {
                // single worker: nothing to steal, park indefinitely
                let _ = shard.not_empty.wait(q);
            }
        }
    }

    /// Try to steal one queued job from a sibling's shard (front only;
    /// control jobs are never stolen; an exec's variant must route to
    /// this worker). Background explore jobs are stealable by *any*
    /// worker — they scratch-compile and never touch the serving cache,
    /// so a candidate queued behind a slow sibling migrates to whoever
    /// idles first. Unblocks the victim's backpressure waiters on
    /// success. Lock order: shard lock, then a `routes` read — safe
    /// because no path holds the `routes` write lock while acquiring a
    /// shard lock.
    fn steal_from_sibling(&self, idx: usize) -> Option<Job> {
        let n = self.shards.len();
        for offset in 1..n {
            let victim = (idx + offset) % n;
            let shard = &self.shards[victim];
            let mut q = shard.queue.lock();
            let stealable = match q.main.front() {
                Some(Job::Exec { variant_id, .. }) => self
                    .routes
                    .read()
                    .get(variant_id)
                    .is_some_and(|route| route.ready.contains(&idx)),
                _ => false,
            };
            if stealable {
                let job = q.main.pop_front();
                shard.not_full.notify_one();
                return job;
            }
            if q.main.is_empty() {
                if let Some(job) = q.bg.pop_front() {
                    return Some(job);
                }
            }
        }
        None
    }

    /// Death path: drop every queued job in the worker's shard so their
    /// reply senders close and no caller is left waiting forever.
    fn drain_shard(&self, idx: usize) {
        let shard = &self.shards[idx];
        let mut q = shard.queue.lock();
        q.main.clear();
        q.bg.clear();
        shard.not_full.notify_all();
    }
}

/// The `SharedKernel` face of the pool: `execute` routes through the
/// sharded queue to a worker that owns a compiled copy of the variant.
struct PoolKernel {
    pool: Arc<WorkerPool>,
    variant_id: String,
}

impl SharedKernel for PoolKernel {
    fn execute(&self, inputs: &[HostTensor]) -> Result<HostTensor> {
        self.pool.submit(&self.variant_id, inputs).map(|(output, _)| output)
    }

    fn execute_measured(&self, inputs: &[HostTensor]) -> Result<(HostTensor, Duration)> {
        // The worker times the execution itself: queue wait and
        // cross-thread dispatch never reach the drift monitor.
        self.pool.submit(&self.variant_id, inputs)
    }

    fn execute_measured_deadline(
        &self,
        inputs: &[HostTensor],
        deadline: Option<Instant>,
    ) -> Result<(HostTensor, Duration)> {
        self.pool.submit_deadline(&self.variant_id, inputs, deadline)
    }

    fn variant_id(&self) -> &str {
        &self.variant_id
    }
}

/// Worker thread body: create an engine, serve until shutdown; on a
/// panic, respawn with a fresh engine (the private cache re-fills lazily
/// from install specs). If the engine cannot be (re)created, the worker
/// marks itself dead and drains its shard so nothing hangs.
fn worker_main(
    pool: Arc<WorkerPool>,
    factory: Arc<dyn EngineFactory>,
    idx: usize,
    init_tx: mpsc::SyncSender<Result<()>>,
) {
    let mut init_tx = Some(init_tx);
    // Consecutive quick deaths back off exponentially: a kernel that
    // panics deterministically must not thrash engine creation (a PJRT
    // client init can take seconds). A serve stint that survived a
    // while resets the streak.
    let mut panic_streak: u32 = 0;
    loop {
        let engine = match factory.create() {
            Ok(engine) => engine,
            Err(e) => {
                log::error!("pool worker {idx}: engine creation failed: {e}");
                if let Some(tx) = init_tx.take() {
                    let _ = tx.send(Err(e));
                }
                break;
            }
        };
        if let Some(tx) = init_tx.take() {
            let _ = tx.send(Ok(()));
        }
        let stint = Instant::now();
        let serve = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            worker_serve(&pool, idx, engine.as_ref());
        }));
        match serve {
            Ok(()) => break, // graceful shutdown, shard drained
            Err(_) => {
                // The in-flight job's reply sender was dropped by the
                // unwind, so its caller already failed over to the
                // leader. Queued jobs are still in the shard; the
                // respawned loop picks them up.
                pool.respawns.fetch_add(1, Ordering::Relaxed);
                if stint.elapsed() > Duration::from_secs(1) {
                    panic_streak = 0;
                } else {
                    panic_streak = panic_streak.saturating_add(1);
                }
                // first respawn is immediate; streaks wait 50ms..3.2s
                let backoff = match panic_streak {
                    0 | 1 => Duration::ZERO,
                    n => Duration::from_millis(50) * (1u32 << (n - 2).min(6)),
                };
                log::warn!(
                    "pool worker {idx}: panicked; respawning with a fresh engine \
                     (streak {panic_streak}, backoff {backoff:?})"
                );
                // shutdown-aware backoff: sleep in slices so stop()
                // never waits on a parked respawn loop
                let until = Instant::now() + backoff;
                while Instant::now() < until && !pool.shutdown.load(Ordering::SeqCst) {
                    std::thread::sleep(Duration::from_millis(25));
                }
                if pool.shutdown.load(Ordering::SeqCst) {
                    break;
                }
            }
        }
    }
    pool.workers[idx].alive.store(false, Ordering::SeqCst);
    pool.drain_shard(idx);
}

/// One worker's serve loop over its shard.
fn worker_serve(pool: &WorkerPool, idx: usize, engine: &dyn Engine) {
    let mut cache: HashMap<String, Box<dyn CompiledKernel>> = HashMap::new();
    let slot = &pool.workers[idx];
    while let Some(job) = pool.pop(idx) {
        match job {
            Job::Install { spec, reply } => {
                let result = compile_into(&mut cache, engine, &spec, slot);
                let _ = reply.send(result);
            }
            Job::Evict { variant_ids } => {
                for id in &variant_ids {
                    cache.remove(id);
                }
            }
            Job::Exec { variant_id, inputs, reply } => {
                let result = execute_local(&mut cache, engine, pool, idx, &variant_id, &inputs, slot);
                if result.is_err() {
                    slot.errors.fetch_add(1, Ordering::Relaxed);
                }
                let _ = reply.send(result);
            }
            Job::Explore { spec, inputs, key, candidate, seq, reply } => {
                let t0 = Instant::now();
                let cost = explore_scratch(engine, &spec, &inputs);
                let busy = t0.elapsed();
                let _ = reply.send(ExploreResult { key, candidate, seq, cost, busy });
            }
        }
    }
}

/// Background candidate measurement: compile into a scratch executable,
/// time one execution, drop everything. The worker's serving cache and
/// its exec counters are untouched — background work is accounted by the
/// leader's `BackgroundStats`, not the pool's serving stats.
fn explore_scratch(engine: &dyn Engine, spec: &InstallSpec, inputs: &[HostTensor]) -> Result<f64> {
    let exe = engine.compile(&spec.variant, &spec.hlo_text)?;
    let t0 = Instant::now();
    exe.execute(inputs)?;
    Ok(t0.elapsed().as_secs_f64())
}

fn compile_into(
    cache: &mut HashMap<String, Box<dyn CompiledKernel>>,
    engine: &dyn Engine,
    spec: &InstallSpec,
    slot: &WorkerSlot,
) -> Result<()> {
    if cache.contains_key(&spec.variant.id) {
        return Ok(());
    }
    let exe = engine.compile(&spec.variant, &spec.hlo_text)?;
    slot.compiles.fetch_add(1, Ordering::Relaxed);
    cache.insert(spec.variant.id.clone(), exe);
    Ok(())
}

fn execute_local(
    cache: &mut HashMap<String, Box<dyn CompiledKernel>>,
    engine: &dyn Engine,
    pool: &WorkerPool,
    idx: usize,
    variant_id: &str,
    inputs: &[HostTensor],
    slot: &WorkerSlot,
) -> Result<(HostTensor, Duration)> {
    if !cache.contains_key(variant_id) {
        // Lazy recompile: a respawned worker lost its cache, but the
        // install spec is still routed — rebuild the executable here.
        let Some(spec) = pool.route_spec(variant_id) else {
            return Err(Error::Coordinator(format!(
                "pool: {variant_id} is no longer installed"
            )));
        };
        let exe = match engine.compile(&spec.variant, &spec.hlo_text) {
            Ok(exe) => exe,
            Err(e) => {
                // A worker that cannot rebuild the variant must stop
                // being routed to, or every call would retry and fail.
                pool.deregister(variant_id, idx);
                return Err(e);
            }
        };
        slot.compiles.fetch_add(1, Ordering::Relaxed);
        cache.insert(variant_id.to_string(), exe);
    }
    let t0 = Instant::now();
    let output = cache[variant_id].execute(inputs)?;
    let exec = t0.elapsed();
    slot.executed.fetch_add(1, Ordering::Relaxed);
    slot.exec_nanos.fetch_add(exec.as_nanos() as u64, Ordering::Relaxed);
    Ok((output, exec))
}

/// Single-threaded queue-discipline tests, deliberately engine- and
/// thread-free so the nightly Miri CI job can interpret them in
/// seconds (`cargo miri test coordinator::pool::queue_tests`).
#[cfg(test)]
mod queue_tests {
    use super::*;

    fn exec_job(id: &str) -> (Job, mpsc::Receiver<Result<(HostTensor, Duration)>>) {
        let (reply, rx) = mpsc::sync_channel(1);
        (Job::Exec { variant_id: id.to_string(), inputs: Vec::new(), reply }, rx)
    }

    fn queued_id(job: &Job) -> String {
        match job {
            Job::Exec { variant_id, .. } => variant_id.clone(),
            Job::Evict { variant_ids } => format!("evict:{}", variant_ids.join(",")),
            _ => "other".into(),
        }
    }

    #[test]
    fn main_lane_overtakes_background() {
        let mut q = ShardQueues::default();
        assert!(q.is_empty());
        q.bg.push_back(Job::Evict { variant_ids: vec!["bg1".into()] });
        let (main_job, _rx) = exec_job("m1");
        q.main.push_back(main_job);
        assert!(!q.is_empty());
        // pop order mirrors `WorkerPool::pop`: main first, then bg
        let first = q.main.pop_front().or_else(|| q.bg.pop_front()).unwrap();
        assert_eq!(queued_id(&first), "m1");
        let second = q.main.pop_front().or_else(|| q.bg.pop_front()).unwrap();
        assert_eq!(queued_id(&second), "evict:bg1");
        assert!(q.is_empty());
    }

    #[test]
    fn main_lane_is_fifo() {
        let mut q = ShardQueues::default();
        let mut rxs = Vec::new();
        for id in ["a", "b", "c"] {
            let (job, rx) = exec_job(id);
            q.main.push_back(job);
            rxs.push(rx);
        }
        for expected in ["a", "b", "c"] {
            let job = q.main.pop_front().unwrap();
            assert_eq!(queued_id(&job), expected);
        }
        assert!(q.is_empty());
    }

    #[test]
    fn dropping_exec_job_closes_its_reply_channel() {
        // The death path (`drain_shard`) clears queues wholesale; the
        // caller blocked on `rx.recv()` must observe a disconnect, not
        // a hang.
        let mut q = ShardQueues::default();
        let (job, rx) = exec_job("m1");
        q.main.push_back(job);
        q.main.clear();
        assert!(rx.recv().is_err(), "dropped job closes the reply channel");
    }

    #[test]
    fn shard_lock_roundtrip() {
        let shard = Shard::new();
        {
            let mut q = shard.queue.lock();
            q.bg.push_back(Job::Evict { variant_ids: vec!["x".into()] });
            assert!(!q.is_empty());
        }
        let mut q = shard.queue.lock();
        assert!(q.main.pop_front().is_none());
        assert!(q.bg.pop_front().is_some());
        assert!(q.is_empty());
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::mock::{MockEngineFactory, MockSpec};
    use std::time::Duration;

    fn sample_variant(id: &str) -> Variant {
        crate::manifest::tests::sample_manifest()
            .unwrap()
            .variant(id)
            .unwrap()
            .clone()
    }

    fn spawn_mock_pool(spec: MockSpec, workers: usize) -> Arc<WorkerPool> {
        WorkerPool::spawn(
            PoolOptions::new(Arc::new(MockEngineFactory::new(spec)))
                .with_workers(workers)
                .with_queue_depth(8),
        )
        .unwrap()
    }

    fn inputs8() -> Vec<HostTensor> {
        vec![HostTensor::zeros(&[8, 8])]
    }

    #[test]
    fn install_execute_and_per_worker_stats() {
        let pool = spawn_mock_pool(MockSpec::default(), 2);
        let v = sample_variant("k.b.n8");
        assert_eq!(pool.install(v.clone(), "hlo".into()), 2, "both workers compile");
        assert_eq!(pool.installed(), 1);
        // idempotent re-install skips the broadcast
        assert_eq!(pool.install(v.clone(), "hlo".into()), 2);
        let exe = pool.handle_for(v.id.clone());
        assert_eq!(exe.variant_id(), "k.b.n8");
        for _ in 0..10 {
            let out = exe.execute(&inputs8()).unwrap();
            assert!(out.data().iter().all(|&x| x == 2.0));
        }
        let snap = pool.snapshot();
        assert_eq!(snap.total_executed(), 10, "every call counted on some worker");
        assert!(snap.workers.iter().all(|w| w.alive));
        assert_eq!(snap.respawns, 0);
        let json = pool.to_json();
        assert_eq!(json.get("workers").unwrap().as_i64(), Some(2));
        assert_eq!(json.get("executed").unwrap().as_i64(), Some(10));
        assert!(pool.render().contains("worker 0:"), "{}", pool.render());
        pool.stop();
    }

    #[test]
    fn submit_unknown_variant_errors_fast() {
        let pool = spawn_mock_pool(MockSpec::default(), 1);
        let err = pool.submit("nope", &inputs8()).expect_err("not installed");
        assert!(err.to_string().contains("not installed"), "{err}");
        pool.stop();
    }

    #[test]
    fn stopped_pool_errors_instead_of_hanging() {
        let pool = spawn_mock_pool(MockSpec::default(), 2);
        let v = sample_variant("k.a.n8");
        assert_eq!(pool.install(v.clone(), "hlo".into()), 2);
        let exe = pool.handle_for(v.id.clone());
        pool.stop();
        assert!(exe.execute(&inputs8()).is_err(), "submit after stop errors");
        assert_eq!(pool.install(sample_variant("k.b.n8"), "hlo".into()), 0);
        pool.stop(); // idempotent
    }

    #[test]
    fn failed_install_is_memoized_until_evicted() {
        let mut spec = MockSpec::default();
        spec.fail_compile.insert("k.a.n8".into());
        let pool = spawn_mock_pool(spec, 2);
        let v = sample_variant("k.a.n8");
        assert_eq!(pool.install(v.clone(), "hlo".into()), 0, "every worker fails");
        assert_eq!(pool.installed(), 0);
        // memoized: the retry is a lookup, not a broadcast
        assert_eq!(pool.install(v.clone(), "hlo".into()), 0);
        // evict clears the memo so a fresh finalization retries (and
        // fails again here — the engine still rejects the variant)
        pool.evict(std::slice::from_ref(&v.id));
        assert_eq!(pool.install(v, "hlo".into()), 0);
        pool.stop();
    }

    #[test]
    fn evicted_variant_stops_routing() {
        let pool = spawn_mock_pool(MockSpec::default(), 1);
        let v = sample_variant("k.b.n8");
        assert_eq!(pool.install(v.clone(), "hlo".into()), 1);
        let exe = pool.handle_for(v.id.clone());
        exe.execute(&inputs8()).unwrap();
        pool.evict(std::slice::from_ref(&v.id));
        assert_eq!(pool.installed(), 0);
        let err = exe.execute(&inputs8()).expect_err("route dropped");
        assert!(err.to_string().contains("not installed"), "{err}");
        pool.stop();
    }

    #[test]
    fn concurrent_submits_spread_across_workers() {
        let spec = MockSpec {
            default_exec_cost: Duration::from_micros(200),
            exec_sleep: true,
            ..MockSpec::default()
        };
        let pool = spawn_mock_pool(spec, 4);
        let v = sample_variant("k.b.n8");
        assert_eq!(pool.install(v.clone(), "hlo".into()), 4);
        let mut joins = Vec::new();
        for _ in 0..4 {
            let exe = pool.handle_for(v.id.clone());
            joins.push(std::thread::spawn(move || {
                for _ in 0..50 {
                    let out = exe.execute(&[HostTensor::zeros(&[8, 8])]).unwrap();
                    assert!(out.data().iter().all(|&x| x == 2.0));
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        let snap = pool.snapshot();
        assert_eq!(snap.total_executed(), 200, "no call lost or double-counted");
        let busy = snap.workers.iter().filter(|w| w.executed > 0).count();
        assert!(busy >= 2, "round-robin spreads load: {snap:?}");
        pool.stop();
    }

    #[test]
    fn deregister_last_worker_memoizes_failure() {
        let pool = spawn_mock_pool(MockSpec::default(), 2);
        let v = sample_variant("k.b.n8");
        assert_eq!(pool.install(v.clone(), "hlo".into()), 2);
        // worker 0 can no longer serve the variant (failed recompile)
        pool.deregister(&v.id, 0);
        let exe = pool.handle_for(v.id.clone());
        exe.execute(&inputs8()).unwrap();
        assert_eq!(pool.snapshot().workers[1].executed, 1, "routing shrank to worker 1");
        // the last worker deregistering memoizes the failure: the
        // republish probe goes quiet instead of churning
        pool.deregister(&v.id, 1);
        assert_eq!(pool.installed(), 0);
        assert!(pool.install_failed(&v.id));
        assert!(exe.execute(&inputs8()).is_err());
        assert_eq!(pool.install(v.clone(), "hlo".into()), 0, "memo gates re-install");
        // a retune's evict clears the memo and the re-broadcast succeeds
        pool.evict(std::slice::from_ref(&v.id));
        assert_eq!(pool.install(v, "hlo".into()), 2);
        pool.stop();
    }

    #[test]
    fn panicked_worker_respawns_and_recovers() {
        let spec = MockSpec::default();
        let fault = spec.latency_fault.clone();
        let pool = spawn_mock_pool(spec, 1);
        let v = sample_variant("k.b.n8");
        assert_eq!(pool.install(v.clone(), "hlo".into()), 1);
        let exe = pool.handle_for(v.id.clone());
        exe.execute(&inputs8()).unwrap();

        fault.panic_once("k.b.n8");
        let err = exe.execute(&inputs8()).expect_err("worker died mid-call");
        assert!(err.to_string().contains("died"), "{err}");

        // the respawned worker lazily recompiles from the install spec
        let out = exe.execute(&inputs8()).unwrap();
        assert!(out.data().iter().all(|&x| x == 2.0));
        assert_eq!(pool.respawns(), 1);
        let snap = pool.snapshot();
        assert!(snap.workers[0].alive);
        assert!(snap.workers[0].compiles >= 2, "install + lazy recompile: {snap:?}");
        pool.stop();
    }

    #[test]
    fn deadline_exceeded_releases_caller_and_keeps_worker_alive() {
        let spec = MockSpec {
            default_exec_cost: Duration::from_millis(60),
            exec_sleep: true,
            ..MockSpec::default()
        };
        let pool = spawn_mock_pool(spec, 1);
        let v = sample_variant("k.b.n8");
        assert_eq!(pool.install(v.clone(), "hlo".into()), 1);
        let t0 = Instant::now();
        let err = pool
            .submit_deadline(&v.id, &inputs8(), Some(t0 + Duration::from_millis(10)))
            .expect_err("wedged variant cannot meet a 10ms budget");
        assert!(matches!(err, Error::DeadlineExceeded { .. }), "wrong error: {err}");
        assert!(
            t0.elapsed() < Duration::from_millis(55),
            "caller released before the 60ms execution finished"
        );
        // The discarded result does not kill the worker: it serves the
        // next (undeadlined) call normally.
        let (out, _) = pool.submit(&v.id, &inputs8()).unwrap();
        assert!(out.data().iter().all(|&x| x == 2.0));
        assert!(pool.snapshot().workers[0].alive);
        pool.stop();
    }

    #[test]
    fn deadline_bounds_backpressure_wait_for_queue_space() {
        let spec = MockSpec {
            default_exec_cost: Duration::from_millis(50),
            exec_sleep: true,
            ..MockSpec::default()
        };
        let pool = WorkerPool::spawn(
            PoolOptions::new(Arc::new(MockEngineFactory::new(spec)))
                .with_workers(1)
                .with_queue_depth(1),
        )
        .unwrap();
        let v = sample_variant("k.b.n8");
        assert_eq!(pool.install(v.clone(), "hlo".into()), 1);
        let mut joins = Vec::new();
        for _ in 0..2 {
            let pool = pool.clone();
            let id = v.id.clone();
            joins.push(std::thread::spawn(move || {
                pool.submit(&id, &inputs8()).unwrap();
            }));
        }
        std::thread::sleep(Duration::from_millis(10));
        // One job executing, one queued: the shard is full, so this call
        // dies waiting for queue space — the wait counts against the
        // budget instead of parking the caller behind the wedge.
        let err = pool
            .submit_deadline(&v.id, &inputs8(), Some(Instant::now() + Duration::from_millis(5)))
            .expect_err("no queue space inside the budget");
        assert!(matches!(err, Error::DeadlineExceeded { .. }), "wrong error: {err}");
        for j in joins {
            j.join().unwrap();
        }
        pool.stop();
    }
}
