//! Performance measurement — the paper's §3.2 *Performance measurement*.
//!
//! The paper counts CPU cycles with `rdtsc` but notes the measurement
//! function "can be overloaded and any other measurement function can be
//! used to count any other metric, such as energy consumption". [`Metric`]
//! is that overload point; three implementations ship.

use std::time::Instant;

/// A cost metric the tuner minimizes. Object-safe so the dispatcher can
/// hold `Box<dyn Metric>`.
pub trait Metric: Send {
    /// Metric name for reports.
    fn name(&self) -> &'static str;
    /// Unit string for reports ("s", "cycles", "J").
    fn unit(&self) -> &'static str;
    /// Opaque begin token.
    fn begin(&self) -> u64;
    /// Cost since `begin`, in metric units.
    fn end(&self, begin: u64) -> f64;
}

/// Monotonic wall-clock seconds.
pub struct WallClock {
    epoch: Instant,
}

impl WallClock {
    /// New wall-clock metric.
    pub fn new() -> WallClock {
        WallClock { epoch: Instant::now() }
    }
}

impl Default for WallClock {
    fn default() -> Self {
        Self::new()
    }
}

impl Metric for WallClock {
    fn name(&self) -> &'static str {
        "wall_clock"
    }

    fn unit(&self) -> &'static str {
        "s"
    }

    fn begin(&self) -> u64 {
        self.epoch.elapsed().as_nanos() as u64
    }

    fn end(&self, begin: u64) -> f64 {
        (self.epoch.elapsed().as_nanos() as u64).saturating_sub(begin) as f64 * 1e-9
    }
}

/// CPU cycle counter — the paper's default (`rdtsc`). Falls back to
/// nanosecond wall time on non-x86_64 targets.
pub struct Rdtsc;

impl Rdtsc {
    #[cfg(target_arch = "x86_64")]
    fn read() -> u64 {
        // SAFETY: RDTSC is unprivileged and side-effect free.
        unsafe { core::arch::x86_64::_rdtsc() }
    }

    #[cfg(not(target_arch = "x86_64"))]
    fn read() -> u64 {
        use std::sync::OnceLock;
        static EPOCH: OnceLock<Instant> = OnceLock::new();
        EPOCH.get_or_init(Instant::now).elapsed().as_nanos() as u64
    }
}

impl Metric for Rdtsc {
    fn name(&self) -> &'static str {
        "rdtsc"
    }

    fn unit(&self) -> &'static str {
        "cycles"
    }

    fn begin(&self) -> u64 {
        Self::read()
    }

    fn end(&self, begin: u64) -> f64 {
        Self::read().saturating_sub(begin) as f64
    }
}

/// Simulated energy metric: joules ≈ wall time × active power. The paper
/// mentions energy as an alternative objective without evaluating it;
/// this model exercises the same code path (see DESIGN.md §Substitutions).
pub struct EnergyModel {
    clock: WallClock,
    /// Modelled active power draw in watts.
    pub active_watts: f64,
}

impl EnergyModel {
    /// Energy model with the given active power.
    pub fn new(active_watts: f64) -> EnergyModel {
        EnergyModel { clock: WallClock::new(), active_watts }
    }
}

impl Metric for EnergyModel {
    fn name(&self) -> &'static str {
        "energy_model"
    }

    fn unit(&self) -> &'static str {
        "J"
    }

    fn begin(&self) -> u64 {
        self.clock.begin()
    }

    fn end(&self, begin: u64) -> f64 {
        self.clock.end(begin) * self.active_watts
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::mock::spin_for;
    use std::time::Duration;

    #[test]
    fn wall_clock_measures_spin() {
        let m = WallClock::new();
        let b = m.begin();
        spin_for(Duration::from_millis(2));
        let cost = m.end(b);
        assert!(cost >= 0.002, "cost={cost}");
        assert!(cost < 0.2, "cost={cost}");
    }

    #[test]
    fn rdtsc_monotone_and_positive() {
        let m = Rdtsc;
        let b = m.begin();
        spin_for(Duration::from_micros(100));
        let cost = m.end(b);
        assert!(cost > 0.0);
        // a longer spin must cost more
        let b2 = m.begin();
        spin_for(Duration::from_millis(2));
        let cost2 = m.end(b2);
        assert!(cost2 > cost, "cost2={cost2} cost={cost}");
    }

    #[test]
    fn energy_scales_with_power() {
        let lo = EnergyModel::new(10.0);
        let hi = EnergyModel::new(100.0);
        let bl = lo.begin();
        spin_for(Duration::from_millis(1));
        let jl = lo.end(bl);
        let bh = hi.begin();
        spin_for(Duration::from_millis(1));
        let jh = hi.end(bh);
        // same duration, 10x the power → roughly 10x the joules
        let ratio = jh / jl;
        assert!(ratio > 5.0 && ratio < 20.0, "ratio={ratio}");
    }

    #[test]
    fn metric_is_object_safe() {
        let metrics: Vec<Box<dyn Metric>> =
            vec![Box::new(WallClock::new()), Box::new(Rdtsc), Box::new(EnergyModel::new(65.0))];
        for m in &metrics {
            let b = m.begin();
            let c = m.end(b);
            assert!(c >= 0.0, "{} went negative", m.name());
        }
    }
}
