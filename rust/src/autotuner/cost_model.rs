//! The paper's analytical overhead model (§3.3, Equations 1 and 2).
//!
//! With `k` variants of compile cost `C` each and execution times
//! `E_0 ≤ E_1 ≤ … ≤ E_{k-1}`, `N` total calls, and a programmer-picked
//! baseline variant with execution time `E_p`:
//!
//! **Eq. 1** — total autotuned cost:
//! ```text
//! E_auto = k·C + Σ_{i<k} E_i + C + (N − k − 1)·E_0
//! ```
//! (k tuning iterations each paying compile+run, one final compilation of
//! the winner — whose call also runs, hence the extra `E_0` — and the
//! remaining `N−k−1` calls at the optimal time.)
//!
//! **Eq. 2** — autotuning pays off when:
//! ```text
//! (N − k)(E_p − E_0) ≥ (k+1)·C + Σ_{i<k} E_i − k·E_p
//! ```
//!
//! `benches/costmodel_validation.rs` plugs measured `C` and `E_i` in and
//! checks the predicted crossover against the measured cumulative curves.

/// Inputs to the model: one tuning problem's measured constants.
#[derive(Debug, Clone, PartialEq)]
pub struct CostModel {
    /// Per-variant compile cost `C` (the paper assumes it equal across
    /// variants).
    pub compile_cost: f64,
    /// Execution times of all k variants, any order (`E_i`).
    pub exec_times: Vec<f64>,
}

impl CostModel {
    /// Build a model; `exec_times` must be non-empty and positive.
    pub fn new(compile_cost: f64, exec_times: Vec<f64>) -> CostModel {
        assert!(!exec_times.is_empty(), "need at least one variant");
        CostModel { compile_cost, exec_times }
    }

    /// Number of variants `k`.
    pub fn k(&self) -> usize {
        self.exec_times.len()
    }

    /// Best execution time `E_0`.
    pub fn e0(&self) -> f64 {
        self.exec_times.iter().copied().fold(f64::INFINITY, f64::min)
    }

    /// Sum of all variant execution times `Σ E_i`.
    pub fn sum_e(&self) -> f64 {
        self.exec_times.iter().sum()
    }

    /// **Eq. 1**: total cost of `n` calls under JIT autotuning.
    /// For `n ≤ k` the schedule is truncated: only the first `n` tuning
    /// iterations happen.
    pub fn e_auto(&self, n: usize) -> f64 {
        let k = self.k();
        let c = self.compile_cost;
        if n == 0 {
            return 0.0;
        }
        if n <= k {
            // truncated: n tuning iterations, no finalization yet
            return n as f64 * c + self.exec_times[..n].iter().sum::<f64>();
        }
        // k·C + Σ E_i  (tuning iterations)
        // + C + E_0    (finalization call: winner recompiled and run)
        // + (N−k−1)·E_0 (steady state)
        // = k·C + Σ E_i + C + (N−k)·E_0
        // (the paper's Eq. 1 second line drops the finalization call's
        // E_0 that its first line includes; we keep the exact total,
        // verified call-by-call by `simulate_schedule`.)
        k as f64 * c + self.sum_e() + c + (n as f64 - k as f64) * self.e0()
    }

    /// Total cost of `n` calls when the programmer fixed variant `p`
    /// (AOT baseline: no JIT compile on the request path).
    pub fn e_fixed(&self, p: usize, n: usize) -> f64 {
        n as f64 * self.exec_times[p]
    }

    /// **Eq. 2** left side: gain over the last `n−k` calls.
    pub fn gain(&self, p: usize, n: usize) -> f64 {
        (n as f64 - self.k() as f64) * (self.exec_times[p] - self.e0())
    }

    /// **Eq. 2** right side: tuning overhead vs the fixed baseline.
    pub fn overhead(&self, p: usize) -> f64 {
        let k = self.k() as f64;
        (k + 1.0) * self.compile_cost + self.sum_e() - k * self.exec_times[p]
    }

    /// Does autotuning pay off within `n` calls against baseline `p`?
    pub fn pays_off(&self, p: usize, n: usize) -> bool {
        self.gain(p, n) >= self.overhead(p)
    }

    /// Crossover call count `N*`: the smallest `n` for which autotuning
    /// beats baseline `p`. `None` if it never does (baseline is already
    /// optimal or better).
    pub fn crossover(&self, p: usize) -> Option<u64> {
        let ep = self.exec_times[p];
        let e0 = self.e0();
        if ep <= e0 {
            // no gain per call: pays off only if overhead ≤ 0 (impossible
            // with positive compile cost)
            return if self.overhead(p) <= 0.0 { Some(0) } else { None };
        }
        let k = self.k() as f64;
        let n = k + self.overhead(p) / (ep - e0);
        Some(n.max(0.0).ceil() as u64)
    }

    /// Simulate the exact call-by-call schedule (for property-testing
    /// Eq. 1 against the telescoped closed form): returns per-call costs.
    pub fn simulate_schedule(&self, n: usize) -> Vec<f64> {
        let k = self.k();
        let mut costs = Vec::with_capacity(n);
        for call in 0..n {
            if call < k {
                // tuning iteration: compile variant `call` + run it
                costs.push(self.compile_cost + self.exec_times[call]);
            } else if call == k {
                // finalization: compile winner again + run it
                costs.push(self.compile_cost + self.e0());
            } else {
                costs.push(self.e0());
            }
        }
        costs
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> CostModel {
        CostModel::new(10.0, vec![1.0, 4.0, 2.0])
    }

    #[test]
    fn eq1_matches_simulated_schedule() {
        let m = model();
        for n in [0usize, 1, 2, 3, 4, 5, 10, 100] {
            let sim: f64 = m.simulate_schedule(n).iter().sum();
            let closed = m.e_auto(n);
            assert!((sim - closed).abs() < 1e-9, "n={n}: sim={sim} closed={closed}");
        }
    }

    #[test]
    fn e0_and_sums() {
        let m = model();
        assert_eq!(m.e0(), 1.0);
        assert_eq!(m.sum_e(), 7.0);
        assert_eq!(m.k(), 3);
    }

    #[test]
    fn eq2_consistency_with_curves() {
        // pays_off(p, n) must agree with comparing the cumulative curves
        let m = model();
        for p in 0..3 {
            for n in 4..200 {
                let curves_say = m.e_auto(n) <= m.e_fixed(p, n);
                let eq2_says = m.pays_off(p, n);
                assert_eq!(curves_say, eq2_says, "p={p} n={n}");
            }
        }
    }

    #[test]
    fn crossover_is_tight() {
        let m = model();
        // baseline p=1 (E_p=4): gain 3/call after tuning
        let n_star = m.crossover(1).unwrap();
        assert!(m.pays_off(1, n_star as usize));
        assert!(!m.pays_off(1, n_star as usize - 1));
    }

    #[test]
    fn no_crossover_when_baseline_optimal() {
        let m = model();
        // baseline p=0 is already the best variant: compile cost never
        // amortizes
        assert_eq!(m.crossover(0), None);
        assert!(!m.pays_off(0, 1_000_000));
    }

    #[test]
    fn small_matrix_regime_large_crossover() {
        // Fig 3 regime: compile cost dwarfs per-call gain → huge N*
        let m = CostModel::new(100.0, vec![1.0, 1.2, 1.1]);
        let n_star = m.crossover(1).unwrap();
        assert!(n_star > 1000, "n_star={n_star}");
    }

    #[test]
    fn large_matrix_regime_small_crossover() {
        // Fig 5 regime: compile cost small vs exec gain → crossover in a
        // few iterations
        let m = CostModel::new(0.5, vec![10.0, 30.0, 20.0]);
        let n_star = m.crossover(1).unwrap();
        assert!(n_star <= 10, "n_star={n_star}");
    }

    #[test]
    fn truncated_schedule_below_k() {
        let m = model();
        assert_eq!(m.e_auto(2), 2.0 * 10.0 + 1.0 + 4.0);
    }
}
