//! Tuning-problem identity.

use std::fmt;

/// Identifies one tuning problem: a kernel, its autotune-parameter name
/// and the argument signature it is being called with.
///
/// The paper keys tuner state on the autotune parameter's *name* and
/// restarts tuning when it changes; calls with different argument sizes
/// are "another autotuning problem". Folding the signature into the key
/// implements exactly that: a mid-run shape change starts a fresh tuner
/// (exercised by `benches/ablation_retune.rs`).
#[derive(Debug, Clone, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ProblemKey {
    /// Kernel family name.
    pub kernel: String,
    /// Autotune parameter name (`block`, `order`, `chunk`, ...).
    pub param: String,
    /// Argument signature, e.g. `f32[128,128],f32[128,128]`.
    pub signature: String,
}

impl ProblemKey {
    /// Build a key.
    pub fn new(
        kernel: impl Into<String>,
        param: impl Into<String>,
        signature: impl Into<String>,
    ) -> ProblemKey {
        ProblemKey { kernel: kernel.into(), param: param.into(), signature: signature.into() }
    }

    /// Key for a manifest problem (kernel + param + joined input sigs).
    pub fn for_problem(p: &crate::manifest::Problem) -> ProblemKey {
        ProblemKey::new(&p.kernel, &p.param, p.variants[0].inputs.join(","))
    }
}

impl fmt::Display for ProblemKey {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}[{}]({})", self.kernel, self.param, self.signature)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn equality_and_hash_key_on_all_fields() {
        use std::collections::HashSet;
        let a = ProblemKey::new("k", "block", "f32[8,8]");
        let b = ProblemKey::new("k", "block", "f32[8,8]");
        let c = ProblemKey::new("k", "block", "f32[16,16]"); // new shape → new problem
        let d = ProblemKey::new("k", "unroll", "f32[8,8]"); // new param name → new problem
        assert_eq!(a, b);
        assert_ne!(a, c);
        assert_ne!(a, d);
        let set: HashSet<_> = [a, b, c, d].into_iter().collect();
        assert_eq!(set.len(), 3);
    }

    #[test]
    fn display_format() {
        let k = ProblemKey::new("matmul", "block", "f32[8,8],f32[8,8]");
        assert_eq!(k.to_string(), "matmul[block](f32[8,8],f32[8,8])");
    }
}
