//! The per-problem tuning state machine (§3.2 of the paper).
//!
//! ```text
//!   Exploring ──(strategy exhausted)──▶ Finalizing ──▶ Tuned
//!       │                                                ▲
//!       └––(every candidate failed)──▶ Failed            │
//!                         (winner recompiled one last time)
//! ```
//!
//! The dispatcher calls [`TuningState::decide`] before each kernel call:
//!
//! * [`Decision::Explore(i)`] — JIT-compile + run candidate `i`, measure
//!   it, and feed the cost back via [`TuningState::report`] (or
//!   [`TuningState::report_failure`]).
//! * [`Decision::Finalize(i)`] — compile the winner `i` into the
//!   instantiation cache (the paper's extra final compilation: "we can
//!   only keep ASTs ... and not the binary compiled by LLVM"), run it,
//!   then acknowledge with [`TuningState::confirm_finalized`].
//! * [`Decision::Use(i)`] — steady state: run the cached winner.
//! * [`Decision::Failed`] — every candidate is dead; nothing can run.
//!   Callers surface this as an error instead of indexing anything.

use super::record::{History, TuningReport};
use super::search::SearchStrategy;

/// What the dispatcher should do for the next call of this problem.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Decision {
    /// Run candidate `i` as a tuning iteration and report its cost.
    Explore(usize),
    /// Tuning finished: recompile winner `i` (final compilation), then
    /// `confirm_finalized(i)`.
    Finalize(usize),
    /// Steady state: use tuned winner `i`.
    Use(usize),
    /// Every candidate failed (or none exist): the problem cannot be
    /// executed. First-class so callers never receive an index into an
    /// empty or fully-failed candidate set.
    Failed,
}

/// What a fused scheduling round should do for a group of co-scheduled
/// calls of the same problem — the multi-candidate face of [`Decision`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum BatchDecision {
    /// Run each listed candidate once (distinct indices, declaration
    /// order per strategy); report the whole round back through
    /// [`TuningState::report_batch`]. Surplus co-scheduled calls
    /// replicate candidates and their median denoises the measurement.
    Explore(Vec<usize>),
    /// Tuning finished: recompile winner `i`, then `confirm_finalized`.
    Finalize(usize),
    /// Steady state: use tuned winner `i`.
    Use(usize),
    /// Every candidate failed; nothing can run.
    Failed,
}

/// Publishable snapshot of a tuned problem's winner — what the
/// coordinator's fast lane needs to publish an immutable `TunedEntry`
/// without reaching back into mutable tuner state.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WinnerSnapshot {
    /// Candidate index of the winner (into the parameter-value array).
    pub index: usize,
    /// Winning parameter value.
    pub value: i64,
}

/// Lifecycle phase of a tuning problem.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    /// Tuning iterations in progress.
    Exploring,
    /// Winner picked; awaiting its final compilation.
    Finalizing,
    /// Winner in use.
    Tuned,
    /// Every candidate failed; the problem cannot be executed.
    Failed,
}

/// State machine for one tuning problem.
pub struct TuningState {
    values: Vec<i64>,
    history: History,
    strategy: Box<dyn SearchStrategy>,
    phase: Phase,
    winner: Option<usize>,
    /// Candidates awaiting a report (catches protocol misuse, and lets a
    /// dropped fused round re-issue its whole batch). Serial callers
    /// keep at most one entry here.
    outstanding: Vec<usize>,
}

impl TuningState {
    /// New state over the candidate parameter values.
    pub fn new(values: Vec<i64>, strategy: Box<dyn SearchStrategy>) -> TuningState {
        let history = History::new(&values);
        let phase = if values.is_empty() { Phase::Failed } else { Phase::Exploring };
        TuningState { values, history, strategy, phase, winner: None, outstanding: Vec::new() }
    }

    /// A state pre-tuned to `winner_idx` — used when importing persisted
    /// tuning results (warm start: no tuning iterations, the winner still
    /// pays its one JIT compilation on first use via the normal
    /// `Finalizing` path, since only HLO text persists across runs).
    ///
    /// An out-of-range winner index — a stale or corrupt state file —
    /// returns [`crate::Error::Autotune`] so imports fail cleanly instead
    /// of crashing the process.
    pub fn pre_tuned(
        values: Vec<i64>,
        winner_idx: usize,
        strategy: Box<dyn SearchStrategy>,
    ) -> crate::Result<TuningState> {
        if winner_idx >= values.len() {
            return Err(crate::Error::Autotune(format!(
                "pre-tuned winner index {winner_idx} out of range for {} candidate(s)",
                values.len()
            )));
        }
        let history = History::new(&values);
        Ok(TuningState {
            values,
            history,
            strategy,
            phase: Phase::Finalizing,
            winner: Some(winner_idx),
            outstanding: Vec::new(),
        })
    }

    /// Decide what the next call should run (the serial face of
    /// [`TuningState::decide_batch`] — one candidate per round).
    pub fn decide(&mut self) -> Decision {
        match self.decide_batch(1) {
            BatchDecision::Explore(batch) => {
                Decision::Explore(*batch.first().expect("non-empty explore batch"))
            }
            BatchDecision::Finalize(i) => Decision::Finalize(i),
            BatchDecision::Use(i) => Decision::Use(i),
            BatchDecision::Failed => Decision::Failed,
        }
    }

    /// Decide what one fused scheduling round of up to `max` co-scheduled
    /// calls should run. While exploring, draws up to `max` distinct
    /// pending candidates from the strategy in one shot and marks them
    /// all outstanding; the round reports them back together via
    /// [`TuningState::report_batch`]. A round that was dropped before
    /// reporting is re-issued wholesale on the next decision.
    pub fn decide_batch(&mut self, max: usize) -> BatchDecision {
        match self.phase {
            Phase::Exploring => {
                if !self.outstanding.is_empty() {
                    // A previous round was never reported (e.g. the
                    // caller dropped the calls). Re-issue it.
                    return BatchDecision::Explore(self.outstanding.clone());
                }
                let mut batch = self.strategy.propose_batch(&self.history, max.max(1));
                batch.truncate(max.max(1));
                // Defensive dedup: a duplicate would leave a phantom
                // outstanding entry after its single report.
                let mut seen = Vec::with_capacity(batch.len());
                batch.retain(|&i| {
                    let fresh = !seen.contains(&i);
                    if fresh {
                        seen.push(i);
                    }
                    fresh
                });
                debug_assert!(
                    batch.iter().all(|&i| i < self.values.len()),
                    "strategy oob"
                );
                if batch.is_empty() {
                    match self.history.best_index() {
                        Some(best) => {
                            self.phase = Phase::Finalizing;
                            self.winner = Some(best);
                            BatchDecision::Finalize(best)
                        }
                        None => {
                            // Nothing runnable: strategy exhausted with no
                            // surviving measurement.
                            self.phase = Phase::Failed;
                            BatchDecision::Failed
                        }
                    }
                } else {
                    self.outstanding = batch.clone();
                    BatchDecision::Explore(batch)
                }
            }
            Phase::Finalizing => {
                BatchDecision::Finalize(self.winner.expect("finalizing has winner"))
            }
            Phase::Tuned => BatchDecision::Use(self.winner.expect("tuned has winner")),
            Phase::Failed => BatchDecision::Failed,
        }
    }

    /// Decide what a *background* explore scheduler should launch next —
    /// the zero-inflight-callers face of [`TuningState::decide_batch`].
    ///
    /// Callers never arrive here: the scheduler polls on its own clock,
    /// so candidates already in flight must not be re-issued (they are
    /// still awaiting asynchronous reports). While exploring, this draws
    /// proposals from the strategy, subtracts the in-flight set, and
    /// returns up to `max` *fresh* candidates — which join `outstanding`
    /// until their [`TuningState::report`] /
    /// [`TuningState::report_failure`] lands. `Explore(vec![])` is a
    /// first-class answer meaning "nothing new to launch; measurements
    /// are in flight" — unlike `decide_batch`, which re-issues the
    /// outstanding round wholesale.
    ///
    /// The phase transitions are identical to the caller-driven path:
    /// when the strategy is exhausted and nothing is in flight, the best
    /// measured candidate moves to `Finalizing` (or the problem fails).
    pub fn decide_background(&mut self, max: usize) -> BatchDecision {
        match self.phase {
            Phase::Exploring => {
                let want = self.outstanding.len() + max.max(1);
                let mut batch = self.strategy.propose_batch(&self.history, want);
                batch.retain(|i| !self.outstanding.contains(i));
                let mut seen = Vec::with_capacity(batch.len());
                batch.retain(|&i| {
                    let fresh = !seen.contains(&i);
                    if fresh {
                        seen.push(i);
                    }
                    fresh
                });
                batch.truncate(max);
                debug_assert!(batch.iter().all(|&i| i < self.values.len()), "strategy oob");
                if batch.is_empty() {
                    if !self.outstanding.is_empty() {
                        // In-flight measurements must land before the
                        // phase can advance.
                        return BatchDecision::Explore(Vec::new());
                    }
                    return match self.history.best_index() {
                        Some(best) => {
                            self.phase = Phase::Finalizing;
                            self.winner = Some(best);
                            BatchDecision::Finalize(best)
                        }
                        None => {
                            self.phase = Phase::Failed;
                            BatchDecision::Failed
                        }
                    };
                }
                self.outstanding.extend(batch.iter().copied());
                BatchDecision::Explore(batch)
            }
            Phase::Finalizing => {
                BatchDecision::Finalize(self.winner.expect("finalizing has winner"))
            }
            Phase::Tuned => BatchDecision::Use(self.winner.expect("tuned has winner")),
            Phase::Failed => BatchDecision::Failed,
        }
    }

    /// Report a successful measurement for an explored candidate.
    pub fn report(&mut self, idx: usize, cost: f64) {
        debug_assert!(self.outstanding.contains(&idx), "report for unexpected candidate");
        self.outstanding.retain(|&i| i != idx);
        self.history.record(idx, cost);
    }

    /// Report one fused round's results in a single batch: `Some(cost)`
    /// records a (replica-denoised) measurement, `None` marks the
    /// candidate failed. Candidates of the round that got no attempt
    /// (more proposals than co-scheduled calls) stay outstanding and are
    /// re-issued by the next decision.
    pub fn report_batch(&mut self, results: &[(usize, Option<f64>)]) {
        for &(idx, cost) in results {
            match cost {
                Some(cost) => self.report(idx, cost),
                None => self.report_failure(idx),
            }
        }
    }

    /// Report that a candidate failed to compile or execute; it is
    /// excluded and tuning continues with the rest (failure injection
    /// tests drive this path).
    pub fn report_failure(&mut self, idx: usize) {
        self.outstanding.retain(|&i| i != idx);
        self.history.mark_failed(idx);
        // A winner that fails its final compilation is demoted and the
        // tuner re-selects among the remaining candidates.
        if self.phase == Phase::Finalizing && self.winner == Some(idx) {
            self.winner = None;
            self.phase = Phase::Exploring;
        }
        if self.history.all_failed() {
            self.phase = Phase::Failed;
        }
    }

    /// Release an outstanding candidate without judging it — the
    /// *transient*-failure face of [`TuningState::report_failure`].
    ///
    /// A hedged background measurement that timed out tells us nothing
    /// about the candidate itself (the worker may have been wedged by a
    /// co-tenant, the queue may have backed up): the candidate's history
    /// is left untouched so the strategy can re-propose it later, and
    /// only its in-flight reservation is dropped. Repeated timeouts are
    /// escalated to [`report_failure`](TuningState::report_failure) by
    /// the dispatcher so a genuinely wedged variant cannot retry forever.
    pub fn release_outstanding(&mut self, idx: usize) {
        self.outstanding.retain(|&i| i != idx);
    }

    /// Demote a *tuned* winner whose runtime error rate tripped the
    /// quarantine breaker: mark it failed and fall back to the next-best
    /// measured candidate from the tuning history.
    ///
    /// `report_failure` deliberately leaves `Tuned` states alone (a
    /// single failed call must not unseat a winner); this is the
    /// breaker-driven path that *does*. Returns the fallback candidate
    /// now `Finalizing` (its compilation flows through the normal
    /// finalize path, so fast-lane publication and hub propagation of
    /// the demotion come for free), or `None` when no measured candidate
    /// survives and the problem moves to `Failed`.
    pub fn demote_winner(&mut self, idx: usize) -> Option<usize> {
        if self.phase != Phase::Tuned || self.winner != Some(idx) {
            // Already demoted/retuned concurrently — nothing to do.
            return self.pending_winner();
        }
        self.history.mark_failed(idx);
        self.winner = None;
        match self.history.best_index() {
            Some(next) => {
                self.phase = Phase::Finalizing;
                self.winner = Some(next);
                Some(next)
            }
            None => {
                self.phase = Phase::Failed;
                None
            }
        }
    }

    /// Acknowledge that the winner's final compilation happened.
    pub fn confirm_finalized(&mut self, idx: usize) {
        debug_assert_eq!(self.winner, Some(idx));
        debug_assert_eq!(self.phase, Phase::Finalizing);
        self.phase = Phase::Tuned;
    }

    /// Current phase.
    pub fn phase(&self) -> Phase {
        self.phase
    }

    /// Winning candidate index, once decided.
    pub fn winner(&self) -> Option<usize> {
        self.winner
    }

    /// Winning parameter value, once tuned (Listing 6 reuse).
    pub fn tuned_value(&self) -> Option<i64> {
        match self.phase {
            Phase::Tuned => self.winner.map(|i| self.values[i]),
            _ => None,
        }
    }

    /// Winner awaiting its final compilation (`Finalizing` only) — what
    /// a serve-current-best path should execute while the caller-less
    /// finalization is pending.
    pub fn pending_winner(&self) -> Option<usize> {
        match self.phase {
            Phase::Finalizing => self.winner,
            _ => None,
        }
    }

    /// Immutable winner snapshot, available once `Tuned` — the fast
    /// lane's publication source.
    pub fn winner_snapshot(&self) -> Option<WinnerSnapshot> {
        match self.phase {
            Phase::Tuned => {
                self.winner.map(|i| WinnerSnapshot { index: i, value: self.values[i] })
            }
            _ => None,
        }
    }

    /// Candidate parameter values, declaration order.
    pub fn values(&self) -> &[i64] {
        &self.values
    }

    /// Parameter value of candidate `idx`.
    pub fn value_of(&self, idx: usize) -> i64 {
        self.values[idx]
    }

    /// Measurement history (benches/reports).
    pub fn history(&self) -> &History {
        &self.history
    }

    /// Snapshot report.
    pub fn snapshot(&self) -> TuningReport {
        TuningReport {
            phase: match self.phase {
                Phase::Exploring => "exploring",
                Phase::Finalizing => "finalizing",
                Phase::Tuned => "tuned",
                Phase::Failed => "failed",
            }
            .to_string(),
            tuned_value: self.tuned_value(),
            variants: self
                .history
                .records
                .iter()
                .map(|r| (r.value, r.best(), r.count(), r.failed))
                .collect(),
            explore_calls: self.history.explore_calls,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::search::Sweep;
    use super::*;

    fn sweep_state(values: &[i64]) -> TuningState {
        TuningState::new(values.to_vec(), Box::new(Sweep::new(values.len())))
    }

    /// Drive a state machine with a synthetic cost table; returns the
    /// sequence of decisions taken.
    fn drive(state: &mut TuningState, costs: &[f64], calls: usize) -> Vec<Decision> {
        let mut decisions = Vec::new();
        for _ in 0..calls {
            let d = state.decide();
            decisions.push(d);
            match d {
                Decision::Explore(i) => state.report(i, costs[i]),
                Decision::Finalize(i) => state.confirm_finalized(i),
                Decision::Use(_) => {}
                Decision::Failed => break,
            }
        }
        decisions
    }

    #[test]
    fn paper_schedule_n_variants_then_finalize_then_use() {
        // The paper: k tuning iterations, one finalize compile, then use.
        let mut st = sweep_state(&[2, 4, 8]);
        let costs = [3.0, 1.0, 2.0];
        let ds = drive(&mut st, &costs, 6);
        assert_eq!(
            ds,
            vec![
                Decision::Explore(0),
                Decision::Explore(1),
                Decision::Explore(2),
                Decision::Finalize(1),
                Decision::Use(1),
                Decision::Use(1),
            ]
        );
        assert_eq!(st.tuned_value(), Some(4));
        assert_eq!(st.phase(), Phase::Tuned);
    }

    #[test]
    fn winner_is_argmin() {
        for (costs, want) in
            [([5.0, 6.0, 1.0], 2usize), ([0.1, 6.0, 1.0], 0), ([5.0, 0.2, 1.0], 1)]
        {
            let mut st = sweep_state(&[10, 20, 30]);
            drive(&mut st, &costs, 5);
            assert_eq!(st.winner(), Some(want), "costs {costs:?}");
        }
    }

    #[test]
    fn failures_are_skipped() {
        let mut st = sweep_state(&[10, 20, 30]);
        // candidate 0 fails, 1 and 2 measured; 2 is fastest
        match st.decide() {
            Decision::Explore(0) => st.report_failure(0),
            d => panic!("unexpected {d:?}"),
        }
        let ds = drive(&mut st, &[99.0, 2.0, 1.0], 4);
        assert_eq!(st.phase(), Phase::Tuned);
        assert_eq!(st.tuned_value(), Some(30));
        assert!(ds.contains(&Decision::Finalize(2)));
    }

    #[test]
    fn all_failed_goes_to_failed_phase() {
        let mut st = sweep_state(&[1, 2]);
        for _ in 0..2 {
            match st.decide() {
                Decision::Explore(i) => st.report_failure(i),
                d => panic!("unexpected {d:?}"),
            }
        }
        assert_eq!(st.phase(), Phase::Failed);
        assert_eq!(st.tuned_value(), None);
        // a failed problem keeps deciding Failed — never an index
        assert_eq!(st.decide(), Decision::Failed);
        assert_eq!(st.decide(), Decision::Failed);
    }

    #[test]
    fn unreported_explore_is_reissued() {
        let mut st = sweep_state(&[1, 2]);
        let d1 = st.decide();
        let d2 = st.decide(); // caller "dropped" the first call
        assert_eq!(d1, d2);
    }

    #[test]
    fn empty_values_is_failed() {
        let mut st = sweep_state(&[]);
        assert_eq!(st.phase(), Phase::Failed);
        assert_eq!(st.decide(), Decision::Failed);
    }

    #[test]
    fn pre_tuned_rejects_out_of_range_winner() {
        let err = TuningState::pre_tuned(vec![1, 2], 5, Box::new(Sweep::new(2)))
            .err()
            .expect("out-of-range winner must not construct");
        assert!(err.to_string().contains("out of range"), "{err}");
        // empty candidate set: any index is out of range
        assert!(TuningState::pre_tuned(Vec::new(), 0, Box::new(Sweep::new(0))).is_err());
    }

    #[test]
    fn pre_tuned_in_range_finalizes_then_serves() {
        let mut st = TuningState::pre_tuned(vec![7, 9], 1, Box::new(Sweep::new(2))).unwrap();
        assert_eq!(st.phase(), Phase::Finalizing);
        match st.decide() {
            Decision::Finalize(i) => {
                assert_eq!(i, 1);
                st.confirm_finalized(i);
            }
            d => panic!("{d:?}"),
        }
        assert_eq!(st.tuned_value(), Some(9));
    }

    #[test]
    fn winner_snapshot_only_when_tuned() {
        let mut st = sweep_state(&[2, 4, 8]);
        assert_eq!(st.winner_snapshot(), None);
        drive(&mut st, &[3.0, 1.0, 2.0], 4); // 3 explores + finalize
        assert_eq!(st.winner_snapshot(), Some(WinnerSnapshot { index: 1, value: 4 }));
        assert_eq!(st.values(), &[2, 4, 8]);
    }

    #[test]
    fn batch_sweep_explores_all_candidates_in_one_round() {
        let mut st = sweep_state(&[2, 4, 8]);
        match st.decide_batch(4) {
            BatchDecision::Explore(batch) => {
                assert_eq!(batch, vec![0, 1, 2]);
                st.report_batch(&[(0, Some(3.0)), (1, Some(1.0)), (2, Some(2.0))]);
            }
            d => panic!("{d:?}"),
        }
        // strategy exhausted: the very next decision finalizes
        assert_eq!(st.decide_batch(4), BatchDecision::Finalize(1));
        st.confirm_finalized(1);
        assert_eq!(st.tuned_value(), Some(4));
    }

    #[test]
    fn dropped_batch_round_is_reissued() {
        let mut st = sweep_state(&[1, 2, 3, 4]);
        let first = st.decide_batch(3);
        let second = st.decide_batch(3); // round dropped before reporting
        assert_eq!(first, second);
        // a serial decision after a dropped batch re-issues its head
        match (first, st.decide()) {
            (BatchDecision::Explore(batch), Decision::Explore(i)) => assert_eq!(i, batch[0]),
            (a, b) => panic!("{a:?} / {b:?}"),
        }
    }

    #[test]
    fn batch_failure_reports_exclude_candidates() {
        let mut st = sweep_state(&[1, 2, 3]);
        match st.decide_batch(3) {
            BatchDecision::Explore(batch) => {
                assert_eq!(batch.len(), 3);
                st.report_batch(&[(0, Some(2.0)), (1, None), (2, Some(1.0))]);
            }
            d => panic!("{d:?}"),
        }
        assert_eq!(st.decide_batch(3), BatchDecision::Finalize(2));
        st.confirm_finalized(2);
        assert_eq!(st.tuned_value(), Some(3), "failed candidate cannot win");
    }

    #[test]
    fn partial_batch_report_keeps_rest_outstanding() {
        // 4 proposals, but only 2 measured (fewer co-scheduled calls than
        // candidates): the unreported pair must be re-issued.
        let mut st = sweep_state(&[1, 2, 3, 4]);
        match st.decide_batch(4) {
            BatchDecision::Explore(batch) => {
                assert_eq!(batch, vec![0, 1, 2, 3]);
                st.report_batch(&[(0, Some(2.0)), (1, Some(1.0))]);
            }
            d => panic!("{d:?}"),
        }
        assert_eq!(st.decide_batch(4), BatchDecision::Explore(vec![2, 3]));
    }

    #[test]
    fn background_decisions_never_reissue_inflight_candidates() {
        let mut st = sweep_state(&[1, 2, 3]);
        match st.decide_background(2) {
            BatchDecision::Explore(batch) => assert_eq!(batch, vec![0, 1]),
            d => panic!("{d:?}"),
        }
        // nothing reported yet: only the remaining candidate is fresh
        match st.decide_background(2) {
            BatchDecision::Explore(batch) => assert_eq!(batch, vec![2]),
            d => panic!("{d:?}"),
        }
        // all candidates in flight: explicit "wait" answer
        assert_eq!(st.decide_background(2), BatchDecision::Explore(Vec::new()));
        st.report(0, 3.0);
        st.report(1, 1.0);
        // one measurement still in flight: cannot finalize yet
        assert_eq!(st.decide_background(2), BatchDecision::Explore(Vec::new()));
        st.report(2, 2.0);
        assert_eq!(st.decide_background(2), BatchDecision::Finalize(1));
        st.confirm_finalized(1);
        assert_eq!(st.decide_background(2), BatchDecision::Use(1));
        assert_eq!(st.tuned_value(), Some(2));
    }

    #[test]
    fn background_failure_reports_advance_the_phase() {
        let mut st = sweep_state(&[1, 2]);
        match st.decide_background(4) {
            BatchDecision::Explore(batch) => assert_eq!(batch, vec![0, 1]),
            d => panic!("{d:?}"),
        }
        st.report_failure(0);
        st.report(1, 1.0);
        assert_eq!(st.decide_background(4), BatchDecision::Finalize(1));
        // every candidate failing moves the problem to Failed
        let mut dead = sweep_state(&[1, 2]);
        match dead.decide_background(4) {
            BatchDecision::Explore(batch) => {
                for i in batch {
                    dead.report_failure(i);
                }
            }
            d => panic!("{d:?}"),
        }
        assert_eq!(dead.decide_background(4), BatchDecision::Failed);
        assert_eq!(dead.phase(), Phase::Failed);
    }

    #[test]
    fn demote_winner_falls_back_to_next_best() {
        let mut st = sweep_state(&[2, 4, 8]);
        drive(&mut st, &[3.0, 1.0, 2.0], 4); // tuned on candidate 1
        assert_eq!(st.phase(), Phase::Tuned);
        // breaker trips on the winner: next-best (candidate 2, cost 2.0)
        // becomes the Finalizing fallback
        assert_eq!(st.demote_winner(1), Some(2));
        assert_eq!(st.phase(), Phase::Finalizing);
        assert_eq!(st.pending_winner(), Some(2));
        match st.decide() {
            Decision::Finalize(i) => {
                assert_eq!(i, 2);
                st.confirm_finalized(i);
            }
            d => panic!("{d:?}"),
        }
        assert_eq!(st.tuned_value(), Some(8), "demoted winner cannot be re-picked");
    }

    #[test]
    fn demote_winner_with_no_survivors_fails_the_problem() {
        let mut st = sweep_state(&[2, 4]);
        match st.decide() {
            Decision::Explore(0) => st.report_failure(0),
            d => panic!("{d:?}"),
        }
        drive(&mut st, &[9.0, 1.0], 3); // only candidate 1 survives, tuned
        assert_eq!(st.phase(), Phase::Tuned);
        assert_eq!(st.demote_winner(1), None);
        assert_eq!(st.phase(), Phase::Failed);
    }

    #[test]
    fn demote_winner_ignores_stale_index() {
        let mut st = sweep_state(&[2, 4, 8]);
        drive(&mut st, &[3.0, 1.0, 2.0], 4);
        // a stale demotion for a non-winner leaves the state untouched
        assert_eq!(st.demote_winner(0), None);
        assert_eq!(st.phase(), Phase::Tuned);
        assert_eq!(st.winner(), Some(1));
    }

    #[test]
    fn release_outstanding_keeps_candidate_proposable() {
        let mut st = sweep_state(&[1, 2, 3]);
        match st.decide_background(1) {
            BatchDecision::Explore(batch) => assert_eq!(batch, vec![0]),
            d => panic!("{d:?}"),
        }
        // transient timeout: release without judging
        st.release_outstanding(0);
        // the sweep strategy proposes unmeasured candidates — 0 is still
        // unmeasured and un-failed, so it reappears
        match st.decide_background(3) {
            BatchDecision::Explore(batch) => {
                assert!(batch.contains(&0), "released candidate is re-proposable: {batch:?}");
            }
            d => panic!("{d:?}"),
        }
    }

    #[test]
    fn tuned_value_absent_until_finalized() {
        let mut st = sweep_state(&[7, 9]);
        assert_eq!(st.tuned_value(), None);
        match st.decide() {
            Decision::Explore(i) => st.report(i, 1.0),
            d => panic!("{d:?}"),
        }
        assert_eq!(st.tuned_value(), None);
        match st.decide() {
            Decision::Explore(i) => st.report(i, 2.0),
            d => panic!("{d:?}"),
        }
        match st.decide() {
            Decision::Finalize(i) => {
                assert_eq!(st.tuned_value(), None); // still finalizing
                st.confirm_finalized(i);
            }
            d => panic!("{d:?}"),
        }
        assert_eq!(st.tuned_value(), Some(7));
    }
}
