//! The paper's contribution: just-in-time autotuning (§3.2).
//!
//! A *tuning problem* is one JIT-compiled function with one autotune
//! parameter and one argument signature ([`ProblemKey`]). For each
//! problem the tuner walks a [`TuningState`] machine:
//!
//! 1. **Exploring** — each call runs the next candidate variant chosen by
//!    the [`search::SearchStrategy`] (the paper sweeps the parameter
//!    array in order); the call is JIT-compiled and measured with the
//!    configured [`Metric`].
//! 2. **Finalizing** — when the strategy is exhausted, the best variant
//!    is compiled *one last time* (the paper keeps only ASTs — we keep
//!    only HLO text — so the winner needs a final compilation into the
//!    instantiation cache) and losing executables are evicted.
//! 3. **Tuned** — every subsequent call uses the cached winner, and the
//!    winning parameter value is exposed for reuse by other kernels
//!    (the paper's Listing 6 workflow).
//!
//! The tuner is engine-agnostic: the coordinator's dispatcher drives it
//! and performs the actual compilation/execution.

pub mod cost_model;
mod key;
mod measurement;
mod record;
pub mod search;
mod state;

use std::collections::HashMap;

pub use key::ProblemKey;
pub use measurement::{EnergyModel, Metric, Rdtsc, WallClock};
pub use record::{History, TuningReport, VariantRecord};
pub use search::{Anneal, HillClimb, RandomSearch, SearchStrategy, Sweep};
pub use state::{BatchDecision, Decision, Phase, TuningState, WinnerSnapshot};

use crate::util::json::Value;

/// Factory producing a fresh search strategy for a new tuning problem,
/// given the candidate parameter values in declaration order.
pub type StrategyFactory = Box<dyn Fn(&[i64]) -> Box<dyn SearchStrategy> + Send>;

/// The autotuner: a map of tuning problems to their state machines.
///
/// Mirrors the paper's design: "another DenseMap" next to the JIT
/// instantiation cache, keyed by function + autotune-parameter name (we
/// add the argument signature, which the paper handles by restarting the
/// tuner when the parameter name changes — see §3.2 *Handling calls with
/// different arguments*).
pub struct Autotuner {
    states: HashMap<ProblemKey, TuningState>,
    factory: StrategyFactory,
}

impl Autotuner {
    /// Autotuner using the paper's exhaustive in-order sweep.
    pub fn sweep() -> Autotuner {
        Autotuner::with_factory(Box::new(|values| Box::new(Sweep::new(values.len()))))
    }

    /// Autotuner with a custom strategy factory.
    pub fn with_factory(factory: StrategyFactory) -> Autotuner {
        Autotuner { states: HashMap::new(), factory }
    }

    /// Get (or create) the state machine for a problem. `values` are the
    /// candidate parameter values in declaration order — the paper's
    /// `__autotune__` array.
    pub fn state(&mut self, key: &ProblemKey, values: &[i64]) -> &mut TuningState {
        if !self.states.contains_key(key) {
            let strategy = (self.factory)(values);
            self.states.insert(key.clone(), TuningState::new(values.to_vec(), strategy));
        }
        self.states.get_mut(key).unwrap()
    }

    /// Peek at a problem's state without creating it.
    pub fn peek(&self, key: &ProblemKey) -> Option<&TuningState> {
        self.states.get(key)
    }

    /// The tuned parameter value for a problem, once tuning completed —
    /// the paper's "the programmer can obtain the optimal parameters and
    /// use them for other kernels".
    pub fn tuned_value(&self, key: &ProblemKey) -> Option<i64> {
        self.states.get(key).and_then(|s| s.tuned_value())
    }

    /// Discard a problem's tuning results and start a fresh exploration on
    /// its next call — the serving layer's retune/demotion hook (callers
    /// must also invalidate any published fast-lane entry). Returns
    /// whether state existed.
    pub fn retune(&mut self, key: &ProblemKey) -> bool {
        match self.states.remove(key) {
            Some(old) => {
                let values = old.values().to_vec();
                let strategy = (self.factory)(&values);
                self.states.insert(key.clone(), TuningState::new(values, strategy));
                true
            }
            None => false,
        }
    }

    /// Number of problems with tuner state.
    pub fn problems(&self) -> usize {
        self.states.len()
    }

    /// Export a JSON report of every problem's history (CLI `inspect`).
    pub fn report(&self) -> Value {
        let mut problems: Vec<(String, Value)> = self
            .states
            .iter()
            .map(|(k, s)| (k.to_string(), s.snapshot().to_json_value()))
            .collect();
        problems.sort_by(|a, b| a.0.cmp(&b.0));
        Value::Obj(problems)
    }

    /// Export tuned results as persistable state.
    ///
    /// The paper contrasts offline tuning ("the optimal parameters found
    /// ... can be used for any program") with online tuning (results die
    /// with the execution). Exporting the tuned map bridges the two: a
    /// later run imports it and warm-starts without tuning iterations.
    /// Only `Tuned` problems are exported — in-flight exploration is
    /// execution-specific by design.
    pub fn export_state(&self) -> Value {
        let mut entries: Vec<(ProblemKey, &TuningState)> = self
            .states
            .iter()
            .filter(|(_, s)| s.phase() == Phase::Tuned)
            .map(|(k, s)| (k.clone(), s))
            .collect();
        entries.sort_by(|a, b| a.0.cmp(&b.0));
        Value::Arr(
            entries
                .into_iter()
                .map(|(k, s)| {
                    let winner = s.winner().expect("tuned state has winner");
                    Value::Obj(vec![
                        ("kernel".into(), crate::util::json::s(k.kernel)),
                        ("param".into(), crate::util::json::s(k.param)),
                        ("signature".into(), crate::util::json::s(k.signature)),
                        (
                            "values".into(),
                            Value::Arr(
                                (0..s.history().len())
                                    .map(|i| crate::util::json::n(s.value_of(i) as f64))
                                    .collect(),
                            ),
                        ),
                        ("winner_value".into(), crate::util::json::n(s.value_of(winner) as f64)),
                    ])
                })
                .collect(),
        )
    }

    /// Warm-start a single problem at a known winner (hub adoption).
    /// The state lands in `Finalizing`: the winner is trusted but still
    /// pays its one JIT compilation on first use, exactly like a
    /// file-based import. Replaces any existing state for the key.
    pub fn warm_start(
        &mut self,
        key: ProblemKey,
        values: Vec<i64>,
        winner_idx: usize,
    ) -> crate::Result<()> {
        let strategy = (self.factory)(&values);
        let state = TuningState::pre_tuned(values, winner_idx, strategy)?;
        self.states.insert(key, state);
        Ok(())
    }

    /// Import previously exported state; returns how many problems were
    /// warm-started. Entries whose candidate values no longer match the
    /// current manifest are rejected (the artifact set changed — stale
    /// tuning results must not be trusted).
    ///
    /// The import is all-or-nothing: every entry is validated and staged
    /// before anything is merged, so a corrupt entry anywhere in the
    /// array leaves the tuner untouched.
    pub fn import_state(&mut self, state: &Value) -> crate::Result<usize> {
        let arr = state
            .as_arr()
            .ok_or_else(|| crate::Error::Autotune("state: expected array".into()))?;
        let mut staged = Vec::new();
        for entry in arr {
            let kernel = entry.req_str("kernel")?;
            let param = entry.req_str("param")?;
            let signature = entry.req_str("signature")?;
            let values: Vec<i64> = entry
                .req_arr("values")?
                .iter()
                .map(|v| {
                    v.as_i64()
                        .ok_or_else(|| crate::Error::Autotune("state: non-integer value".into()))
                })
                .collect::<crate::Result<_>>()?;
            let winner_value = entry.req_i64("winner_value")?;
            let winner_idx = values.iter().position(|&v| v == winner_value).ok_or_else(|| {
                crate::Error::Autotune(format!(
                    "state: winner {winner_value} not among candidates for {kernel}/{param}"
                ))
            })?;
            let key = ProblemKey::new(kernel, param, signature);
            let strategy = (self.factory)(&values);
            // A corrupt entry (out-of-range winner) aborts the whole
            // import with Error::Autotune instead of panicking — and
            // because nothing was merged yet, aborts it cleanly.
            staged.push((key, TuningState::pre_tuned(values, winner_idx, strategy)?));
        }
        let imported = staged.len();
        for (key, state) in staged {
            self.states.insert(key, state);
        }
        Ok(imported)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(n: i64) -> ProblemKey {
        ProblemKey::new("k", "p", format!("f32[{n},{n}]"))
    }

    #[test]
    fn state_created_on_demand_and_keyed() {
        let mut t = Autotuner::sweep();
        t.state(&key(8), &[1, 2, 3]);
        t.state(&key(16), &[1, 2, 3]);
        t.state(&key(8), &[1, 2, 3]); // same key, no new state
        assert_eq!(t.problems(), 2);
    }

    #[test]
    fn tuned_value_flows_through() {
        let mut t = Autotuner::sweep();
        let k = key(8);
        // run the sweep: 3 variants, variant 1 fastest
        let costs = [3.0, 1.0, 2.0];
        loop {
            let st = t.state(&k, &[10, 20, 30]);
            match st.decide() {
                Decision::Explore(i) => st.report(i, costs[i]),
                Decision::Finalize(i) => st.confirm_finalized(i),
                Decision::Use(_) | Decision::Failed => break,
            }
        }
        assert_eq!(t.tuned_value(&k), Some(20));
        assert_eq!(t.peek(&k).unwrap().phase(), Phase::Tuned);
    }

    #[test]
    fn retune_resets_to_exploring() {
        let mut t = Autotuner::sweep();
        let k = key(8);
        let costs = [3.0, 1.0];
        loop {
            let st = t.state(&k, &[10, 20]);
            match st.decide() {
                Decision::Explore(i) => st.report(i, costs[i]),
                Decision::Finalize(i) => st.confirm_finalized(i),
                Decision::Use(_) | Decision::Failed => break,
            }
        }
        assert_eq!(t.tuned_value(&k), Some(20));
        assert!(t.retune(&k));
        assert_eq!(t.tuned_value(&k), None);
        assert_eq!(t.peek(&k).unwrap().phase(), Phase::Exploring);
        // values survive the reset; the sweep starts over
        assert_eq!(t.peek(&k).unwrap().values(), &[10, 20]);
        assert!(!t.retune(&ProblemKey::new("other", "p", "f32[1]")));
    }

    #[test]
    fn corrupt_import_winner_is_an_error_not_a_panic() {
        fn entry(kernel: &str, winner: f64) -> Value {
            Value::Obj(vec![
                ("kernel".into(), crate::util::json::s(kernel)),
                ("param".into(), crate::util::json::s("p")),
                ("signature".into(), crate::util::json::s("f32[8,8]")),
                (
                    "values".into(),
                    Value::Arr(vec![crate::util::json::n(1.0), crate::util::json::n(2.0)]),
                ),
                ("winner_value".into(), crate::util::json::n(winner)),
            ])
        }
        let mut t = Autotuner::sweep();
        // a valid entry followed by one whose winner 99 is not among the
        // candidates: the import must fail atomically
        let state = Value::Arr(vec![entry("good", 2.0), entry("bad", 99.0)]);
        let err = t.import_state(&state).unwrap_err();
        assert!(err.to_string().contains("winner"), "{err}");
        assert_eq!(t.problems(), 0, "corrupt state imports nothing, not even valid entries");
        // the same valid entry alone imports fine
        assert_eq!(t.import_state(&Value::Arr(vec![entry("good", 2.0)])).unwrap(), 1);
        assert_eq!(t.problems(), 1);
    }

    #[test]
    fn report_is_json_object() {
        let mut t = Autotuner::sweep();
        t.state(&key(8), &[1, 2]);
        let v = t.report();
        assert!(v.as_obj().is_some());
        assert_eq!(v.as_obj().unwrap().len(), 1);
    }
}
