//! Per-problem measurement history and reporting.

use crate::util::json::{n, s, Value};
use crate::util::stats::Summary;

/// Samples collected for one candidate variant.
#[derive(Debug, Clone, Default)]
pub struct VariantRecord {
    /// Parameter value this variant embodies.
    pub value: i64,
    /// Measured costs (metric units), in collection order.
    pub samples: Vec<f64>,
    /// Whether the variant failed (compile or execute) and is excluded.
    pub failed: bool,
}

impl VariantRecord {
    /// Best (minimum) observed cost — the paper keeps "the execution
    /// time of the best execution".
    pub fn best(&self) -> Option<f64> {
        self.samples.iter().copied().reduce(f64::min)
    }

    /// Number of samples.
    pub fn count(&self) -> usize {
        self.samples.len()
    }

    /// Mean observed cost — steadier than [`best`](VariantRecord::best)
    /// when used as a serving-latency baseline (drift detection), since
    /// a single anomalously fast sample cannot skew it as far.
    pub fn mean(&self) -> Option<f64> {
        if self.samples.is_empty() {
            None
        } else {
            Some(self.samples.iter().sum::<f64>() / self.samples.len() as f64)
        }
    }
}

/// Measurement history for one tuning problem — what search strategies
/// consult to decide the next candidate.
#[derive(Debug, Clone, Default)]
pub struct History {
    /// One record per candidate, index-aligned with the parameter array.
    pub records: Vec<VariantRecord>,
    /// Total explore calls (successful measurements).
    pub explore_calls: usize,
}

impl History {
    /// Fresh history over the candidate parameter values.
    pub fn new(values: &[i64]) -> History {
        History {
            records: values
                .iter()
                .map(|&value| VariantRecord { value, ..VariantRecord::default() })
                .collect(),
            explore_calls: 0,
        }
    }

    /// Number of candidates.
    pub fn len(&self) -> usize {
        self.records.len()
    }

    /// True when no candidates exist.
    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    /// Record a measurement for candidate `idx`.
    pub fn record(&mut self, idx: usize, cost: f64) {
        self.records[idx].samples.push(cost);
        self.explore_calls += 1;
    }

    /// Mark candidate `idx` failed.
    pub fn mark_failed(&mut self, idx: usize) {
        self.records[idx].failed = true;
    }

    /// Indices not yet measured and not failed.
    pub fn untried(&self) -> Vec<usize> {
        self.records
            .iter()
            .enumerate()
            .filter(|(_, r)| !r.failed && r.samples.is_empty())
            .map(|(i, _)| i)
            .collect()
    }

    /// Index of the best (minimum best-sample) non-failed candidate.
    pub fn best_index(&self) -> Option<usize> {
        self.records
            .iter()
            .enumerate()
            .filter(|(_, r)| !r.failed)
            .filter_map(|(i, r)| r.best().map(|b| (i, b)))
            // total_cmp: a NaN measurement must not panic winner selection
            .min_by(|a, b| a.1.total_cmp(&b.1))
            .map(|(i, _)| i)
    }

    /// Best cost observed for candidate `idx`, if measured.
    pub fn best_of(&self, idx: usize) -> Option<f64> {
        self.records.get(idx).and_then(|r| r.best())
    }

    /// Mean cost observed for candidate `idx`, if measured.
    pub fn mean_of(&self, idx: usize) -> Option<f64> {
        self.records.get(idx).and_then(VariantRecord::mean)
    }

    /// True when every candidate has failed.
    pub fn all_failed(&self) -> bool {
        self.records.iter().all(|r| r.failed)
    }
}

/// Immutable report of a finished (or in-flight) tuning problem.
#[derive(Debug, Clone)]
pub struct TuningReport {
    /// Phase name ("exploring", "finalizing", "tuned", "failed").
    pub phase: String,
    /// Winning value, when decided.
    pub tuned_value: Option<i64>,
    /// Per-variant (value, best cost, sample count, failed).
    pub variants: Vec<(i64, Option<f64>, usize, bool)>,
    /// Total explore calls.
    pub explore_calls: usize,
}

impl TuningReport {
    /// Render as JSON for the CLI / state export.
    pub fn to_json_value(&self) -> Value {
        let variants: Vec<Value> = self
            .variants
            .iter()
            .map(|(value, best, count, failed)| {
                Value::Obj(vec![
                    ("value".into(), n(*value as f64)),
                    ("best".into(), best.map(Value::Num).unwrap_or(Value::Null)),
                    ("samples".into(), n(*count as f64)),
                    ("failed".into(), Value::Bool(*failed)),
                ])
            })
            .collect();
        Value::Obj(vec![
            ("phase".into(), s(self.phase.clone())),
            (
                "tuned_value".into(),
                self.tuned_value.map(|v| n(v as f64)).unwrap_or(Value::Null),
            ),
            ("explore_calls".into(), n(self.explore_calls as f64)),
            ("variants".into(), Value::Arr(variants)),
        ])
    }

    /// Human-readable multi-line summary.
    pub fn render(&self) -> String {
        let mut out = format!(
            "phase={} tuned_value={:?} explore_calls={}\n",
            self.phase, self.tuned_value, self.explore_calls
        );
        for (value, best, count, failed) in &self.variants {
            let best_s = best.map(|b| format!("{b:.6}")).unwrap_or_else(|| "-".into());
            out.push_str(&format!(
                "  value={value:<8} best={best_s:<12} samples={count}{}\n",
                if *failed { " FAILED" } else { "" }
            ));
        }
        out
    }

    /// Summary stats over one variant's samples (bench reporting).
    pub fn summary_of(history: &History, idx: usize) -> Summary {
        Summary::of(&history.records[idx].samples)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn best_index_is_argmin_of_best_samples() {
        let mut h = History::new(&[10, 20, 30]);
        h.record(0, 5.0);
        h.record(0, 3.0); // best of 0 = 3
        h.record(1, 2.5); // best of 1 = 2.5  ← winner
        h.record(2, 2.6);
        assert_eq!(h.best_index(), Some(1));
        assert_eq!(h.best_of(1), Some(2.5));
        assert_eq!(h.explore_calls, 4);
    }

    #[test]
    fn failed_candidates_excluded() {
        let mut h = History::new(&[1, 2]);
        h.record(0, 1.0);
        h.record(1, 0.5);
        h.mark_failed(1);
        assert_eq!(h.best_index(), Some(0));
        assert!(!h.all_failed());
        h.mark_failed(0);
        assert!(h.all_failed());
        assert_eq!(h.best_index(), None);
    }

    #[test]
    fn untried_shrinks_as_measured() {
        let mut h = History::new(&[1, 2, 3]);
        assert_eq!(h.untried(), vec![0, 1, 2]);
        h.record(1, 1.0);
        assert_eq!(h.untried(), vec![0, 2]);
        h.mark_failed(0);
        assert_eq!(h.untried(), vec![2]);
    }

    #[test]
    fn empty_history_has_no_best() {
        let h = History::new(&[]);
        assert!(h.is_empty());
        assert_eq!(h.best_index(), None);
    }

    #[test]
    fn report_json_shape() {
        let r = TuningReport {
            phase: "tuned".into(),
            tuned_value: Some(64),
            variants: vec![(32, Some(1.5), 1, false), (64, Some(1.0), 1, false)],
            explore_calls: 2,
        };
        let v = r.to_json_value();
        assert_eq!(v.get("phase").unwrap().as_str(), Some("tuned"));
        assert_eq!(v.get("tuned_value").unwrap().as_i64(), Some(64));
        assert_eq!(v.get("variants").unwrap().as_arr().unwrap().len(), 2);
        assert!(r.render().contains("value=64"));
    }
}
