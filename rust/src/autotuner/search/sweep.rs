//! The paper's strategy: try every candidate once, in declaration order.

use super::{History, SearchStrategy};

/// Exhaustive in-order sweep — "the first time the function is called,
/// it is generated and executed with the first autotuning parameter, and
/// so on for each parameter" (§3.2).
pub struct Sweep {
    n: usize,
}

impl Sweep {
    /// Sweep over `n` candidates.
    pub fn new(n: usize) -> Sweep {
        Sweep { n }
    }
}

impl SearchStrategy for Sweep {
    fn name(&self) -> &'static str {
        "sweep"
    }

    fn next(&mut self, history: &History) -> Option<usize> {
        debug_assert_eq!(history.len(), self.n);
        // First untried, non-failed candidate in declaration order.
        history.untried().into_iter().next()
    }

    fn propose_batch(&mut self, history: &History, max: usize) -> Vec<usize> {
        debug_assert_eq!(history.len(), self.n);
        // The sweep visits candidates in declaration order and never
        // consults costs, so a fused round can draw the next `max`
        // untried candidates in one shot.
        history.untried().into_iter().take(max.max(1)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::super::testsupport::run_to_completion;
    use super::*;

    #[test]
    fn visits_each_candidate_exactly_once_in_order() {
        let mut s = Sweep::new(4);
        let mut h = History::new(&[10, 20, 30, 40]);
        let mut order = Vec::new();
        while let Some(i) = s.next(&h) {
            order.push(i);
            h.record(i, 1.0 + i as f64);
        }
        assert_eq!(order, vec![0, 1, 2, 3]);
        assert_eq!(s.next(&h), None);
    }

    #[test]
    fn skips_failed_candidates() {
        let mut s = Sweep::new(3);
        let mut h = History::new(&[1, 2, 3]);
        h.mark_failed(0);
        assert_eq!(s.next(&h), Some(1));
        h.record(1, 1.0);
        h.mark_failed(2);
        assert_eq!(s.next(&h), None);
    }

    #[test]
    fn finds_global_optimum() {
        let values = [8i64, 16, 32, 64, 128];
        // cost minimized at 32
        let (best, iters) =
            run_to_completion(Box::new(Sweep::new(5)), &values, |v| ((v - 32).abs() as f64) + 1.0, 100);
        assert_eq!(best, Some(2));
        assert_eq!(iters, 5); // exactly k iterations, as the paper schedules
    }
}
