//! Simulated annealing on the candidate index axis (paper §5 heuristic).

use super::{History, SearchStrategy};
use crate::util::prng::Rng;

/// Simulated annealing: random neighborhood moves accepted when better,
/// or probabilistically when worse, with a geometric cooling schedule.
/// Robust on non-unimodal cost surfaces where hill climbing stalls.
pub struct Anneal {
    budget: usize,
    used: usize,
    rng: Rng,
    current: Option<usize>,
    pending: Option<usize>,
    temperature: f64,
    cooling: f64,
}

impl Anneal {
    /// Annealer with a measurement budget.
    pub fn new(budget: usize, seed: u64) -> Anneal {
        Anneal {
            budget,
            used: 0,
            rng: Rng::seed(seed),
            current: None,
            pending: None,
            temperature: 1.5,
            cooling: 0.95,
        }
    }

    fn propose(&mut self, n: usize, history: &History) -> Option<usize> {
        let cur = self.current.unwrap_or(n / 2);
        // neighborhood radius shrinks with temperature
        let radius = ((n as f64 * self.temperature * 0.5).ceil() as i64).max(1);
        for _ in 0..16 {
            let step = self.rng.range_i64(-radius, radius);
            let cand = cur as i64 + step;
            if cand >= 0 && (cand as usize) < n && !history.records[cand as usize].failed {
                return Some(cand as usize);
            }
        }
        (0..n).find(|&i| !history.records[i].failed)
    }
}

impl SearchStrategy for Anneal {
    fn name(&self) -> &'static str {
        "anneal"
    }

    fn next(&mut self, history: &History) -> Option<usize> {
        if self.used >= self.budget || history.is_empty() || history.all_failed() {
            return None;
        }

        // Process the outcome of the previous proposal.
        if let Some(p) = self.pending.take() {
            let p_cost = history.best_of(p);
            let cur_cost = self.current.and_then(|c| history.best_of(c));
            match (p_cost, cur_cost) {
                (Some(pc), Some(cc)) => {
                    let accept = pc < cc || {
                        let delta = (pc - cc) / cc.max(1e-12);
                        self.rng.chance((-delta / self.temperature.max(1e-9)).exp().min(1.0))
                    };
                    if accept {
                        self.current = Some(p);
                    }
                }
                (Some(_), None) => self.current = Some(p),
                _ => {}
            }
            self.temperature *= self.cooling;
        }

        let n = history.len();
        let proposal = self.propose(n, history)?;
        self.pending = Some(proposal);
        self.used += 1;
        Some(proposal)
    }
}

#[cfg(test)]
mod tests {
    use super::super::testsupport::run_to_completion;
    use super::*;

    #[test]
    fn respects_budget() {
        let (_, iters) =
            run_to_completion(Box::new(Anneal::new(12, 5)), &[1, 2, 3, 4], |_| 1.0, 100);
        assert_eq!(iters, 12);
    }

    #[test]
    fn finds_optimum_on_unimodal_surface() {
        let values: Vec<i64> = (0..10).collect();
        let (best, _) = run_to_completion(
            Box::new(Anneal::new(30, 7)),
            &values,
            |v| ((v - 7).abs() as f64) + 1.0,
            100,
        );
        assert_eq!(best, Some(7));
    }

    #[test]
    fn escapes_local_minimum() {
        // W-shaped surface: local min at idx 1 (cost 2), global at idx 8 (cost 1)
        let values: Vec<i64> = (0..10).collect();
        let cost = |v: i64| match v {
            1 => 2.0,
            8 => 1.0,
            0 | 2 => 3.0,
            7 | 9 => 2.5,
            _ => 5.0,
        };
        // Annealing is stochastic: the property is that a clear majority
        // of seeds escape the local minimum within the budget.
        let escaped = (0..10u64)
            .filter(|&seed| {
                let (best, _) =
                    run_to_completion(Box::new(Anneal::new(40, seed)), &values, cost, 100);
                best == Some(8)
            })
            .count();
        assert!(escaped >= 6, "only {escaped}/10 seeds escaped the local minimum");
    }

    #[test]
    fn deterministic_for_seed() {
        let values = [1i64, 2, 3, 4, 5];
        let run = |seed| {
            let mut s = Anneal::new(15, seed);
            let mut h = History::new(&values);
            let mut order = Vec::new();
            while let Some(i) = s.next(&h) {
                order.push(i);
                h.record(i, (i as f64 - 2.0).abs() + 1.0);
            }
            order
        };
        assert_eq!(run(3), run(3));
    }

    #[test]
    fn all_failed_returns_none() {
        let mut s = Anneal::new(10, 0);
        let mut h = History::new(&[1, 2]);
        h.mark_failed(0);
        h.mark_failed(1);
        assert_eq!(s.next(&h), None);
    }
}
