//! Parameter-search strategies.
//!
//! The paper uses an exhaustive in-order [`Sweep`] of the `__autotune__`
//! array and names faster-convergence heuristics as future work (§5,
//! citing Bayesian-optimization autotuners). [`RandomSearch`],
//! [`HillClimb`] and [`Anneal`] implement that future work; the
//! `ablation_search` bench compares them on iterations-to-optimum and
//! regret.

mod anneal;
mod hillclimb;
mod random;
mod sweep;

pub use anneal::Anneal;
pub use hillclimb::HillClimb;
pub use random::RandomSearch;
pub use sweep::Sweep;

use super::record::History;

/// A strategy picks which candidate the next tuning iteration should
/// evaluate, based on the measurements so far. Returning `None` ends the
/// exploration phase (the tuner then finalizes the best candidate).
pub trait SearchStrategy: Send {
    /// Strategy name for reports.
    fn name(&self) -> &'static str;

    /// Index of the next candidate to measure, or `None` when done.
    /// Must never return a failed candidate's index.
    fn next(&mut self, history: &History) -> Option<usize>;

    /// Up to `max` *distinct* pending candidates for one fused
    /// exploration round — the measurements come back together via a
    /// single batch report, so every proposed candidate must be valid
    /// without seeing the others' costs first. Returning an empty vector
    /// ends exploration, exactly like `next` returning `None`.
    ///
    /// The default is the serial behaviour (at most one candidate), which
    /// keeps inherently sequential strategies — hill climbing and
    /// annealing consult the previous measurement before moving — exactly
    /// correct under fused rounds: their single candidate is replicated
    /// across the round's co-scheduled calls and the median is reported.
    /// Order-free strategies (sweep, random) override this to fill the
    /// round with distinct candidates.
    fn propose_batch(&mut self, history: &History, _max: usize) -> Vec<usize> {
        self.next(history).into_iter().collect()
    }
}

/// Parse a strategy spec string (CLI/config): `sweep`, `random:K`,
/// `hillclimb`, `anneal:K`.
pub fn from_spec(spec: &str, n_candidates: usize, seed: u64) -> crate::Result<Box<dyn SearchStrategy>> {
    let (name, arg) = match spec.split_once(':') {
        Some((n, a)) => (n, Some(a)),
        None => (spec, None),
    };
    let parse_budget = |default: usize| -> crate::Result<usize> {
        match arg {
            None => Ok(default),
            Some(a) => a
                .parse::<usize>()
                .map_err(|_| crate::Error::Config(format!("bad strategy budget `{a}`"))),
        }
    };
    match name {
        "sweep" => Ok(Box::new(Sweep::new(n_candidates))),
        "random" => Ok(Box::new(RandomSearch::new(parse_budget(n_candidates)?, seed))),
        "hillclimb" => Ok(Box::new(HillClimb::new())),
        "anneal" => Ok(Box::new(Anneal::new(parse_budget(2 * n_candidates)?, seed))),
        other => Err(crate::Error::Config(format!("unknown search strategy `{other}`"))),
    }
}

#[cfg(test)]
pub(crate) mod testsupport {
    use super::*;

    /// Run a strategy against a synthetic cost function until it stops or
    /// `max_iters` is hit; returns (chosen best index, iterations used).
    pub fn run_to_completion(
        mut strategy: Box<dyn SearchStrategy>,
        values: &[i64],
        cost_fn: impl Fn(i64) -> f64,
        max_iters: usize,
    ) -> (Option<usize>, usize) {
        let mut history = History::new(values);
        let mut iters = 0;
        while iters < max_iters {
            match strategy.next(&history) {
                Some(idx) => {
                    history.record(idx, cost_fn(values[idx]));
                    iters += 1;
                }
                None => break,
            }
        }
        (history.best_index(), iters)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_spec_parses_all() {
        assert_eq!(from_spec("sweep", 4, 0).unwrap().name(), "sweep");
        assert_eq!(from_spec("random:10", 4, 0).unwrap().name(), "random");
        assert_eq!(from_spec("hillclimb", 4, 0).unwrap().name(), "hillclimb");
        assert_eq!(from_spec("anneal:16", 4, 0).unwrap().name(), "anneal");
        assert!(from_spec("nope", 4, 0).is_err());
        assert!(from_spec("random:x", 4, 0).is_err());
    }
}
