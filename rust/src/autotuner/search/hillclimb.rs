//! Hill climbing over the (ordered) parameter axis.
//!
//! Tuning parameters like block sizes are ordered and their cost surface
//! is usually unimodal-ish; hill climbing starts in the middle and walks
//! toward lower cost, measuring only a fraction of the grid. One of the
//! paper's §5 faster-convergence heuristics.

use super::{History, SearchStrategy};

/// Greedy neighbor-descent on the candidate index axis.
pub struct HillClimb {
    /// Next index to evaluate, if already picked.
    pending: Option<usize>,
    /// Current position (best measured so far in the walk).
    current: Option<usize>,
    /// Direction of travel: +1 / -1; None while probing both neighbors.
    probing: Vec<usize>,
    done: bool,
}

impl HillClimb {
    /// New climber (starts at the middle candidate).
    pub fn new() -> HillClimb {
        HillClimb { pending: None, current: None, probing: Vec::new(), done: false }
    }

    fn cost(history: &History, idx: usize) -> Option<f64> {
        history.best_of(idx)
    }
}

impl Default for HillClimb {
    fn default() -> Self {
        Self::new()
    }
}

impl SearchStrategy for HillClimb {
    fn name(&self) -> &'static str {
        "hillclimb"
    }

    fn next(&mut self, history: &History) -> Option<usize> {
        if self.done || history.is_empty() || history.all_failed() {
            return None;
        }
        let n = history.len();
        let alive = |i: usize| !history.records[i].failed;

        // Start: measure the middle candidate.
        if self.current.is_none() {
            if let Some(p) = self.pending {
                if Self::cost(history, p).is_some() {
                    self.current = Some(p);
                    self.pending = None;
                    // queue both neighbors
                    self.probing.clear();
                    if p > 0 {
                        self.probing.push(p - 1);
                    }
                    if p + 1 < n {
                        self.probing.push(p + 1);
                    }
                } else if alive(p) {
                    return Some(p); // re-issue (previous failed to report)
                }
            }
            if self.current.is_none() {
                let mid = n / 2;
                let start = (0..n)
                    .min_by_key(|&i| (i as i64 - mid as i64).abs() + if alive(i) { 0 } else { n as i64 * 2 })?;
                if !alive(start) {
                    return None;
                }
                self.pending = Some(start);
                return Some(start);
            }
        }

        // Probe queued neighbors.
        while let Some(i) = self.probing.pop() {
            if alive(i) && Self::cost(history, i).is_none() {
                return Some(i);
            }
        }

        // All probes measured: move to the best neighbor if it improves.
        let cur = self.current.unwrap();
        let cur_cost = Self::cost(history, cur).unwrap_or(f64::INFINITY);
        let mut best = cur;
        let mut best_cost = cur_cost;
        for i in [cur.wrapping_sub(1), cur + 1] {
            if i < n && alive(i) {
                if let Some(c) = Self::cost(history, i) {
                    if c < best_cost {
                        best = i;
                        best_cost = c;
                    }
                }
            }
        }
        if best == cur {
            self.done = true; // local minimum
            return None;
        }
        self.current = Some(best);
        // queue unmeasured neighbors of the new position
        self.probing.clear();
        if best > 0 {
            self.probing.push(best - 1);
        }
        if best + 1 < n {
            self.probing.push(best + 1);
        }
        while let Some(i) = self.probing.pop() {
            if alive(i) && Self::cost(history, i).is_none() {
                return Some(i);
            }
        }
        self.done = true;
        None
    }
}

#[cfg(test)]
mod tests {
    use super::super::testsupport::run_to_completion;
    use super::*;

    #[test]
    fn descends_to_unimodal_minimum() {
        // costs over indices 0..8: V-shape with min at index 6
        let values: Vec<i64> = (0..8).collect();
        let (best, iters) = run_to_completion(
            Box::new(HillClimb::new()),
            &values,
            |v| ((v - 6).abs() as f64) + 1.0,
            100,
        );
        assert_eq!(best, Some(6));
        assert!(iters < 8, "should not exhaustively sweep (used {iters})");
    }

    #[test]
    fn stops_at_local_minimum_of_middle_start() {
        let values: Vec<i64> = (0..5).collect();
        // min at middle: immediate local stop after probing neighbors
        let (best, iters) = run_to_completion(
            Box::new(HillClimb::new()),
            &values,
            |v| ((v - 2).abs() as f64) + 1.0,
            100,
        );
        assert_eq!(best, Some(2));
        assert!(iters <= 3);
    }

    #[test]
    fn handles_single_candidate() {
        let (best, iters) =
            run_to_completion(Box::new(HillClimb::new()), &[42], |_| 1.0, 10);
        assert_eq!(best, Some(0));
        assert_eq!(iters, 1);
    }

    #[test]
    fn walks_to_edge() {
        let values: Vec<i64> = (0..6).collect();
        // monotone decreasing cost: min at last index
        let (best, _) = run_to_completion(
            Box::new(HillClimb::new()),
            &values,
            |v| (10 - v) as f64,
            100,
        );
        assert_eq!(best, Some(5));
    }

    #[test]
    fn all_failed_returns_none() {
        let mut s = HillClimb::new();
        let mut h = History::new(&[1, 2, 3]);
        for i in 0..3 {
            h.mark_failed(i);
        }
        assert_eq!(s.next(&h), None);
    }
}
