//! Budgeted random search (paper §5 future-work heuristic).

use super::{History, SearchStrategy};
use crate::util::prng::Rng;

/// Uniform random sampling of candidates under an iteration budget.
/// Guarantees every candidate is tried at least once if the budget
/// allows (first pass is a shuffled sweep), then re-samples randomly —
/// re-measurement sharpens the best-sample estimate under noise.
pub struct RandomSearch {
    budget: usize,
    used: usize,
    rng: Rng,
    first_pass: Vec<usize>,
}

impl RandomSearch {
    /// Random search with a total measurement budget.
    pub fn new(budget: usize, seed: u64) -> RandomSearch {
        RandomSearch { budget, used: 0, rng: Rng::seed(seed), first_pass: Vec::new() }
    }
}

impl SearchStrategy for RandomSearch {
    fn name(&self) -> &'static str {
        "random"
    }

    fn next(&mut self, history: &History) -> Option<usize> {
        if self.used >= self.budget || history.all_failed() {
            return None;
        }
        self.used += 1;
        // Shuffled first pass covering all candidates.
        if self.first_pass.is_empty() && self.used == 1 {
            self.first_pass = (0..history.len()).collect();
            self.rng.shuffle(&mut self.first_pass);
        }
        while let Some(idx) = self.first_pass.pop() {
            if !history.records[idx].failed {
                return Some(idx);
            }
        }
        // Random re-measurement among non-failed candidates.
        let alive: Vec<usize> =
            (0..history.len()).filter(|&i| !history.records[i].failed).collect();
        if alive.is_empty() {
            return None;
        }
        Some(alive[self.rng.below(alive.len())])
    }

    fn propose_batch(&mut self, history: &History, max: usize) -> Vec<usize> {
        // Sampling never consults costs, so a fused round can draw
        // several distinct candidates at once. A duplicate draw in the
        // re-measurement phase ends the batch (its budget is returned);
        // the duplicate's extra samples come from round replication
        // instead.
        let mut batch: Vec<usize> = Vec::new();
        while batch.len() < max.max(1) {
            match self.next(history) {
                Some(idx) if !batch.contains(&idx) => batch.push(idx),
                Some(_duplicate) => {
                    self.used -= 1;
                    break;
                }
                None => break,
            }
        }
        batch
    }
}

#[cfg(test)]
mod tests {
    use super::super::testsupport::run_to_completion;
    use super::*;

    #[test]
    fn covers_all_candidates_when_budget_allows() {
        let mut s = RandomSearch::new(8, 42);
        let mut h = History::new(&[1, 2, 3, 4]);
        let mut seen = std::collections::HashSet::new();
        for _ in 0..4 {
            let i = s.next(&h).unwrap();
            seen.insert(i);
            h.record(i, 1.0);
        }
        assert_eq!(seen.len(), 4, "first pass must cover all candidates");
    }

    #[test]
    fn respects_budget() {
        let (_, iters) =
            run_to_completion(Box::new(RandomSearch::new(6, 1)), &[1, 2, 3], |_| 1.0, 100);
        assert_eq!(iters, 6);
    }

    #[test]
    fn deterministic_for_seed() {
        for seed in [0u64, 7, 99] {
            let mut a = RandomSearch::new(10, seed);
            let mut b = RandomSearch::new(10, seed);
            let mut ha = History::new(&[1, 2, 3, 4, 5]);
            let mut hb = History::new(&[1, 2, 3, 4, 5]);
            for _ in 0..10 {
                let ia = a.next(&ha).unwrap();
                let ib = b.next(&hb).unwrap();
                assert_eq!(ia, ib);
                ha.record(ia, 1.0);
                hb.record(ib, 1.0);
            }
        }
    }

    #[test]
    fn finds_optimum_with_enough_budget() {
        let values = [8i64, 16, 32, 64, 128];
        let (best, _) = run_to_completion(
            Box::new(RandomSearch::new(10, 3)),
            &values,
            |v| ((v - 64).abs() as f64) + 1.0,
            100,
        );
        assert_eq!(best, Some(3));
    }

    #[test]
    fn stops_when_all_failed() {
        let mut s = RandomSearch::new(10, 0);
        let mut h = History::new(&[1, 2]);
        h.mark_failed(0);
        h.mark_failed(1);
        assert_eq!(s.next(&h), None);
    }
}
