//! Workload generation: seeded inputs and call traces.
//!
//! Every benchmark and example drives the system through these
//! generators, so runs are reproducible from the seed alone.

use crate::manifest::{Problem, Variant};
use crate::tensor::HostTensor;

/// Build the input tensors for one problem from its manifest signature.
///
/// Inputs are uniform in [-1, 1) except shape-`[1]` scalars (saxpy's `a`),
/// which get a fixed 2.5 so results stay comparable across variants.
pub fn inputs_for(problem: &Problem, seed: u64) -> Vec<HostTensor> {
    inputs_for_variant(&problem.variants[0], seed)
}

/// Same, from a single variant's signature.
pub fn inputs_for_variant(variant: &Variant, seed: u64) -> Vec<HostTensor> {
    variant
        .input_shapes()
        .expect("manifest signatures validated at load")
        .iter()
        .enumerate()
        .map(|(i, shape)| {
            if shape == &[1usize] {
                HostTensor::from_vec(&[1], vec![2.5]).unwrap()
            } else {
                HostTensor::random(shape, seed.wrapping_add(i as u64 * 0x9E37))
            }
        })
        .collect()
}

/// One entry of a call trace.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CallSpec {
    /// Kernel family to invoke.
    pub kernel: String,
    /// Problem size to invoke it at.
    pub size: i64,
}

/// A sequence of kernel calls — the "program" driving the coordinator.
#[derive(Debug, Clone, Default)]
pub struct CallTrace {
    /// Calls in order.
    pub calls: Vec<CallSpec>,
}

impl CallTrace {
    /// `iters` calls of one kernel at one size (the paper's benchmark
    /// loop).
    pub fn uniform(kernel: &str, size: i64, iters: usize) -> CallTrace {
        CallTrace {
            calls: (0..iters)
                .map(|_| CallSpec { kernel: kernel.to_string(), size })
                .collect(),
        }
    }

    /// A trace that switches problem size mid-run (paper §3.2: a call
    /// with different arguments is a new tuning problem — used by the
    /// re-tuning ablation).
    pub fn with_size_switch(
        kernel: &str,
        first: i64,
        second: i64,
        at: usize,
        total: usize,
    ) -> CallTrace {
        assert!(at <= total);
        let mut calls = Vec::with_capacity(total);
        for i in 0..total {
            calls.push(CallSpec {
                kernel: kernel.to_string(),
                size: if i < at { first } else { second },
            });
        }
        CallTrace { calls }
    }

    /// Interleave several (kernel, size) streams round-robin — the
    /// multi-kernel service mix of the serving example.
    pub fn interleaved(streams: &[(&str, i64)], rounds: usize) -> CallTrace {
        let mut calls = Vec::with_capacity(streams.len() * rounds);
        for _ in 0..rounds {
            for &(kernel, size) in streams {
                calls.push(CallSpec { kernel: kernel.to_string(), size });
            }
        }
        CallTrace { calls }
    }

    /// Number of calls.
    pub fn len(&self) -> usize {
        self.calls.len()
    }

    /// True when the trace is empty.
    pub fn is_empty(&self) -> bool {
        self.calls.is_empty()
    }
}

/// One arrival of an open-loop trace: *what* to call and *when*,
/// relative to replay start. The timed generalization of [`CallSpec`] —
/// [`crate::traffic`] generates these (Zipfian popularity, churn,
/// bursts) and replays them against a live coordinator.
#[derive(Debug, Clone, PartialEq)]
pub struct TimedCall {
    /// Scheduled arrival offset from replay start.
    pub at: std::time::Duration,
    /// The call itself.
    pub spec: CallSpec,
}

/// An arrival-timed call sequence (open loop: arrivals do not wait for
/// completions).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TimedTrace {
    /// Arrivals in schedule order.
    pub calls: Vec<TimedCall>,
}

impl TimedTrace {
    /// Time an untimed trace at a constant `rps` arrival rate.
    pub fn constant_rate(trace: &CallTrace, rps: f64) -> TimedTrace {
        let gap = 1.0 / rps.max(1e-9);
        TimedTrace {
            calls: trace
                .calls
                .iter()
                .enumerate()
                .map(|(i, spec)| TimedCall {
                    at: std::time::Duration::from_secs_f64(i as f64 * gap),
                    spec: spec.clone(),
                })
                .collect(),
        }
    }

    /// Number of arrivals.
    pub fn len(&self) -> usize {
        self.calls.len()
    }

    /// True when the trace is empty.
    pub fn is_empty(&self) -> bool {
        self.calls.is_empty()
    }

    /// Total scheduled duration (arrival offset of the last call).
    pub fn span(&self) -> std::time::Duration {
        self.calls.last().map(|c| c.at).unwrap_or_default()
    }

    /// The distinct problems appearing in the trace, in first-arrival
    /// order.
    pub fn problems(&self) -> Vec<CallSpec> {
        let mut seen = Vec::new();
        for c in &self.calls {
            if !seen.contains(&c.spec) {
                seen.push(c.spec.clone());
            }
        }
        seen
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_trace() {
        let t = CallTrace::uniform("matmul", 128, 10);
        assert_eq!(t.len(), 10);
        assert!(t.calls.iter().all(|c| c.kernel == "matmul" && c.size == 128));
    }

    #[test]
    fn size_switch_trace() {
        let t = CallTrace::with_size_switch("k", 8, 16, 3, 7);
        assert_eq!(t.len(), 7);
        assert_eq!(t.calls[2].size, 8);
        assert_eq!(t.calls[3].size, 16);
        assert_eq!(t.calls[6].size, 16);
    }

    #[test]
    fn interleaved_trace() {
        let t = CallTrace::interleaved(&[("a", 1), ("b", 2)], 3);
        assert_eq!(t.len(), 6);
        assert_eq!(t.calls[0].kernel, "a");
        assert_eq!(t.calls[1].kernel, "b");
        assert_eq!(t.calls[4].kernel, "a");
    }

    #[test]
    fn timed_trace_constant_rate_and_problems() {
        let t = TimedTrace::constant_rate(&CallTrace::interleaved(&[("a", 1), ("b", 2)], 3), 100.0);
        assert_eq!(t.len(), 6);
        assert_eq!(t.calls[0].at, std::time::Duration::ZERO);
        assert_eq!(t.calls[2].at, std::time::Duration::from_millis(20));
        assert_eq!(t.span(), std::time::Duration::from_millis(50));
        let probs = t.problems();
        assert_eq!(probs.len(), 2);
        assert_eq!(probs[0], CallSpec { kernel: "a".into(), size: 1 });
    }

    #[test]
    fn inputs_match_signature_and_seed() {
        let m = crate::manifest::tests::sample_manifest().unwrap();
        let p = m.problem("k", 8).unwrap();
        let a = inputs_for(p, 42);
        let b = inputs_for(p, 42);
        let c = inputs_for(p, 43);
        assert_eq!(a.len(), 1);
        assert_eq!(a[0].shape(), &[8, 8]);
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn scalar_inputs_fixed() {
        // fabricate a variant with a scalar input signature
        let m = crate::manifest::tests::sample_manifest().unwrap();
        let mut v = m.variants[0].clone();
        v.inputs = vec!["f32[1]".into(), "f32[8]".into()];
        let ins = inputs_for_variant(&v, 7);
        assert_eq!(ins[0].data(), &[2.5]);
        assert_eq!(ins[1].len(), 8);
    }
}
