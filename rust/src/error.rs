//! Crate-wide error type.
//!
//! Everything that can fail on the request path funnels into [`Error`] so
//! the coordinator can decide between retrying, skipping a variant (the
//! failure-injection path exercised in tests) and aborting.
//!
//! `Display`/`Error` are hand-implemented — `thiserror` is a proc-macro
//! crate and the build environment is fully offline.

use std::fmt;

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// All error conditions surfaced by the jitune runtime.
#[derive(Debug)]
pub enum Error {
    /// Error bubbled up from the PJRT / XLA runtime (compile or execute).
    Xla(String),

    /// Artifact or manifest I/O failure.
    Io {
        /// Path involved in the failed operation.
        path: String,
        /// Underlying OS error.
        source: std::io::Error,
    },

    /// Malformed JSON (manifest, config, tuning-state export).
    Json {
        /// Byte offset of the first offending character.
        offset: usize,
        /// Human-readable description.
        msg: String,
    },

    /// Manifest is syntactically valid JSON but semantically broken.
    Manifest(String),

    /// Configuration file / CLI error.
    Config(String),

    /// A kernel, variant or problem key that the registry does not know.
    Unknown {
        /// What category of entity was looked up ("kernel", "variant", ...).
        kind: &'static str,
        /// The name that failed to resolve.
        name: String,
    },

    /// Shape/dtype mismatch between caller-provided tensors and the
    /// artifact's expected signature.
    ShapeMismatch {
        /// Kernel being invoked.
        kernel: String,
        /// Signature recorded in the manifest.
        expected: String,
        /// Signature derived from the call's arguments.
        got: String,
    },

    /// JIT compilation of a variant failed (also produced by the
    /// failure-injecting mock engine in tests).
    CompileFailed {
        /// Variant id that failed to compile.
        variant: String,
        /// Reason.
        msg: String,
    },

    /// The autotuner was asked for a decision it cannot make yet or at all
    /// (e.g. every variant failed to compile).
    Autotune(String),

    /// Coordinator lifecycle error (server already stopped, queue closed...).
    Coordinator(String),

    /// The call's deadline elapsed before a result was produced. The work
    /// may still complete on a worker — its result is discarded on
    /// arrival — but the caller has already been released.
    DeadlineExceeded {
        /// Kernel being invoked.
        kernel: String,
        /// The budget that was exceeded.
        deadline: std::time::Duration,
    },

    /// The admission gate shed this call instead of queueing it without
    /// bound ([`ShedPolicy`](crate::coordinator::ShedPolicy)).
    Overloaded(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Xla(msg) => write!(f, "xla: {msg}"),
            Error::Io { path, source } => write!(f, "io: {path}: {source}"),
            Error::Json { offset, msg } => {
                write!(f, "json parse error at byte {offset}: {msg}")
            }
            Error::Manifest(msg) => write!(f, "manifest: {msg}"),
            Error::Config(msg) => write!(f, "config: {msg}"),
            Error::Unknown { kind, name } => write!(f, "unknown {kind}: {name}"),
            Error::ShapeMismatch { kernel, expected, got } => {
                write!(f, "shape mismatch for {kernel}: expected {expected}, got {got}")
            }
            Error::CompileFailed { variant, msg } => {
                write!(f, "compile failed for variant {variant}: {msg}")
            }
            Error::Autotune(msg) => write!(f, "autotuner: {msg}"),
            Error::Coordinator(msg) => write!(f, "coordinator: {msg}"),
            Error::DeadlineExceeded { kernel, deadline } => {
                write!(f, "deadline exceeded for {kernel}: budget {deadline:?} elapsed")
            }
            Error::Overloaded(msg) => write!(f, "overloaded: {msg}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io { source, .. } => Some(source),
            _ => None,
        }
    }
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

impl Error {
    /// Helper to build an [`Error::Io`] with path context.
    pub fn io(path: impl Into<String>, source: std::io::Error) -> Self {
        Error::Io { path: path.into(), source }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_includes_context() {
        let e = Error::Unknown { kind: "kernel", name: "nope".into() };
        assert_eq!(e.to_string(), "unknown kernel: nope");
        let e = Error::ShapeMismatch {
            kernel: "matmul".into(),
            expected: "f32[8,8]".into(),
            got: "f32[4,4]".into(),
        };
        assert!(e.to_string().contains("expected f32[8,8]"));
        let e = Error::DeadlineExceeded {
            kernel: "matmul".into(),
            deadline: std::time::Duration::from_millis(50),
        };
        assert!(e.to_string().contains("deadline exceeded for matmul"));
        let e = Error::Overloaded("1024 calls in flight".into());
        assert!(e.to_string().contains("overloaded"));
    }

    #[test]
    fn io_helper_keeps_path() {
        let e = Error::io("/tmp/x", std::io::Error::new(std::io::ErrorKind::NotFound, "gone"));
        assert!(e.to_string().contains("/tmp/x"));
        use std::error::Error as _;
        assert!(e.source().is_some());
    }
}
