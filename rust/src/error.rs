//! Crate-wide error type.
//!
//! Everything that can fail on the request path funnels into [`Error`] so
//! the coordinator can decide between retrying, skipping a variant (the
//! failure-injection path exercised in tests) and aborting.

use thiserror::Error;

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// All error conditions surfaced by the jitune runtime.
#[derive(Error, Debug)]
pub enum Error {
    /// Error bubbled up from the PJRT / XLA runtime (compile or execute).
    #[error("xla: {0}")]
    Xla(String),

    /// Artifact or manifest I/O failure.
    #[error("io: {path}: {source}")]
    Io {
        /// Path involved in the failed operation.
        path: String,
        /// Underlying OS error.
        #[source]
        source: std::io::Error,
    },

    /// Malformed JSON (manifest, config, tuning-state export).
    #[error("json parse error at byte {offset}: {msg}")]
    Json {
        /// Byte offset of the first offending character.
        offset: usize,
        /// Human-readable description.
        msg: String,
    },

    /// Manifest is syntactically valid JSON but semantically broken.
    #[error("manifest: {0}")]
    Manifest(String),

    /// Configuration file / CLI error.
    #[error("config: {0}")]
    Config(String),

    /// A kernel, variant or problem key that the registry does not know.
    #[error("unknown {kind}: {name}")]
    Unknown {
        /// What category of entity was looked up ("kernel", "variant", ...).
        kind: &'static str,
        /// The name that failed to resolve.
        name: String,
    },

    /// Shape/dtype mismatch between caller-provided tensors and the
    /// artifact's expected signature.
    #[error("shape mismatch for {kernel}: expected {expected}, got {got}")]
    ShapeMismatch {
        /// Kernel being invoked.
        kernel: String,
        /// Signature recorded in the manifest.
        expected: String,
        /// Signature derived from the call's arguments.
        got: String,
    },

    /// JIT compilation of a variant failed (also produced by the
    /// failure-injecting mock engine in tests).
    #[error("compile failed for variant {variant}: {msg}")]
    CompileFailed {
        /// Variant id that failed to compile.
        variant: String,
        /// Reason.
        msg: String,
    },

    /// The autotuner was asked for a decision it cannot make yet or at all
    /// (e.g. every variant failed to compile).
    #[error("autotuner: {0}")]
    Autotune(String),

    /// Coordinator lifecycle error (server already stopped, queue closed...).
    #[error("coordinator: {0}")]
    Coordinator(String),
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

impl Error {
    /// Helper to build an [`Error::Io`] with path context.
    pub fn io(path: impl Into<String>, source: std::io::Error) -> Self {
        Error::Io { path: path.into(), source }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_includes_context() {
        let e = Error::Unknown { kind: "kernel", name: "nope".into() };
        assert_eq!(e.to_string(), "unknown kernel: nope");
        let e = Error::ShapeMismatch {
            kernel: "matmul".into(),
            expected: "f32[8,8]".into(),
            got: "f32[4,4]".into(),
        };
        assert!(e.to_string().contains("expected f32[8,8]"));
    }

    #[test]
    fn io_helper_keeps_path() {
        let e = Error::io("/tmp/x", std::io::Error::new(std::io::ErrorKind::NotFound, "gone"));
        assert!(e.to_string().contains("/tmp/x"));
    }
}
