//! # jitune — Just-in-Time autotuning
//!
//! Reproduction of *Just-in-Time autotuning* (Morel & Coti, 2023) as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! * **Layer 1 (build time)** — Pallas kernels (`python/compile/kernels/`)
//!   parameterized by the paper's tuning axes (block size, loop order,
//!   unroll factor).
//! * **Layer 2 (build time)** — JAX entry points lowered per variant to HLO
//!   text artifacts plus a manifest (`python/compile/aot.py`).
//! * **Layer 3 (run time, this crate)** — the paper's contribution: a
//!   just-in-time autotuning runtime. The first *k* calls of a kernel
//!   JIT-compile (PJRT `compile`) and measure each variant; the winner is
//!   then recompiled into the instantiation cache and used for every
//!   subsequent call ([`autotuner`], [`runtime::CompileCache`],
//!   [`coordinator`]).
//!
//! Python never runs on the request path: `make artifacts` is the only
//! Python invocation, and the resulting binary is self-contained.
//!
//! See `DESIGN.md` for the paper→system mapping and the experiment index.

pub mod autotuner;
pub mod baseline;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod error;
pub mod hub;
pub mod manifest;
pub mod report;
pub mod runtime;
pub mod sync;
pub mod tensor;
pub mod testutil;
pub mod traffic;
pub mod util;
pub mod workload;

pub use error::{Error, Result};
