//! Artifact manifest: the Rust-side view of what `make artifacts` built.
//!
//! The manifest is the analog of ClangJIT's serialized-AST store: it tells
//! the runtime which kernel variants exist, which tuning-parameter value
//! each one embodies, and where the HLO text lives. The coordinator's
//! [`crate::coordinator::KernelRegistry`] is built from this.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::error::{Error, Result};
use crate::util::json::{self, Value};

/// Schema version this loader understands (bump with `aot.py`).
pub const SCHEMA_VERSION: i64 = 1;

/// One lowered artifact: a (kernel, tuning-parameter value, problem size)
/// point of the variant grid.
#[derive(Debug, Clone, PartialEq)]
pub struct Variant {
    /// Globally unique id, e.g. `matmul_tiled.b8.n128`.
    pub id: String,
    /// Kernel family name.
    pub kernel: String,
    /// Tuning-parameter name (the paper keys tuner state on this).
    pub param: String,
    /// Tuning-parameter value (e.g. block size, or implementation index).
    pub value: i64,
    /// Human label (`b8`, `ijk`, ...).
    pub label: String,
    /// Problem-size scalar (matrix edge / vector length / batch).
    pub size: i64,
    /// Input signatures, e.g. `["f32[128,128]", "f32[128,128]"]`.
    pub inputs: Vec<String>,
    /// Output signature.
    pub output: String,
    /// HLO text file, relative to the artifacts dir.
    pub path: String,
    /// Nominal FLOP count of one execution (throughput reporting).
    pub flops: i64,
}

impl Variant {
    /// Parse one manifest entry.
    fn from_json(v: &Value) -> Result<Variant> {
        Ok(Variant {
            id: v.req_str("id")?.to_string(),
            kernel: v.req_str("kernel")?.to_string(),
            param: v.req_str("param")?.to_string(),
            value: v.req_i64("value")?,
            label: v.req_str("label")?.to_string(),
            size: v.req_i64("size")?,
            inputs: v
                .req_arr("inputs")?
                .iter()
                .map(|s| {
                    s.as_str()
                        .map(str::to_string)
                        .ok_or_else(|| Error::Manifest("non-string input signature".into()))
                })
                .collect::<Result<_>>()?,
            output: v.req_str("output")?.to_string(),
            path: v.req_str("path")?.to_string(),
            flops: v.req_i64("flops")?,
        })
    }

    /// Parse dims out of a signature like `f32[128,64]`.
    pub fn parse_sig(sig: &str) -> Result<Vec<usize>> {
        let inner = sig
            .strip_prefix("f32[")
            .and_then(|s| s.strip_suffix(']'))
            .ok_or_else(|| Error::Manifest(format!("bad signature `{sig}`")))?;
        inner
            .split(',')
            .map(|d| {
                d.trim()
                    .parse::<usize>()
                    .map_err(|_| Error::Manifest(format!("bad dim in `{sig}`")))
            })
            .collect()
    }

    /// Input shapes as dim vectors.
    pub fn input_shapes(&self) -> Result<Vec<Vec<usize>>> {
        self.inputs.iter().map(|s| Variant::parse_sig(s)).collect()
    }

    /// Output shape as a dim vector.
    pub fn output_shape(&self) -> Result<Vec<usize>> {
        Variant::parse_sig(&self.output)
    }
}

/// A *tuning problem*: one kernel at one problem size — the unit the
/// autotuner optimizes (the paper's "function + autotune parameter +
/// argument set"). Holds the candidate variants in manifest order (the
/// order the sweep tries them, like the paper's parameter array).
#[derive(Debug, Clone)]
pub struct Problem {
    /// Kernel family.
    pub kernel: String,
    /// Tuning-parameter name.
    pub param: String,
    /// Problem-size scalar.
    pub size: i64,
    /// Candidate variants, in declaration order.
    pub variants: Vec<Variant>,
}

impl Problem {
    /// Unique key string for maps/logs: `kernel/param/size`.
    pub fn key(&self) -> String {
        format!("{}/{}/n{}", self.kernel, self.param, self.size)
    }
}

/// The whole loaded manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// Directory the artifact paths are relative to.
    pub dir: PathBuf,
    /// All variants, manifest order.
    pub variants: Vec<Variant>,
    /// Problems grouped from the variants, ordered by (kernel, param, size).
    pub problems: Vec<Problem>,
    /// JAX version recorded by the generator (provenance).
    pub jax_version: String,
}

impl Manifest {
    /// Load and validate `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| Error::io(path.display().to_string(), e))?;
        Manifest::from_json_str(&text, dir)
    }

    /// Parse from a JSON string (tests use this directly).
    pub fn from_json_str(text: &str, dir: PathBuf) -> Result<Manifest> {
        let root = json::parse(text)?;
        let schema = root.req_i64("schema")?;
        if schema != SCHEMA_VERSION {
            return Err(Error::Manifest(format!(
                "schema {schema} unsupported (want {SCHEMA_VERSION})"
            )));
        }
        let jax_version =
            root.get("jax_version").and_then(Value::as_str).unwrap_or("?").to_string();
        let variants: Vec<Variant> = root
            .req_arr("entries")?
            .iter()
            .map(Variant::from_json)
            .collect::<Result<_>>()?;
        if variants.is_empty() {
            return Err(Error::Manifest("no entries".into()));
        }
        // uniqueness of ids
        let mut seen = std::collections::HashSet::new();
        for v in &variants {
            if !seen.insert(&v.id) {
                return Err(Error::Manifest(format!("duplicate variant id `{}`", v.id)));
            }
        }
        let problems = group_problems(&variants)?;
        Ok(Manifest { dir, variants, problems, jax_version })
    }

    /// Absolute path of a variant's HLO file.
    pub fn artifact_path(&self, v: &Variant) -> PathBuf {
        self.dir.join(&v.path)
    }

    /// Find a problem by kernel + size.
    pub fn problem(&self, kernel: &str, size: i64) -> Result<&Problem> {
        self.problems
            .iter()
            .find(|p| p.kernel == kernel && p.size == size)
            .ok_or_else(|| Error::Unknown { kind: "problem", name: format!("{kernel}/n{size}") })
    }

    /// Find a variant by id.
    pub fn variant(&self, id: &str) -> Result<&Variant> {
        self.variants
            .iter()
            .find(|v| v.id == id)
            .ok_or_else(|| Error::Unknown { kind: "variant", name: id.to_string() })
    }

    /// Kernel family names, sorted and deduplicated.
    pub fn kernels(&self) -> Vec<String> {
        let mut names: Vec<String> = self.variants.iter().map(|v| v.kernel.clone()).collect();
        names.sort();
        names.dedup();
        names
    }

    /// Sizes available for a kernel family, ascending.
    pub fn sizes(&self, kernel: &str) -> Vec<i64> {
        let mut sizes: Vec<i64> =
            self.problems.iter().filter(|p| p.kernel == kernel).map(|p| p.size).collect();
        sizes.sort_unstable();
        sizes
    }
}

fn group_problems(variants: &[Variant]) -> Result<Vec<Problem>> {
    let mut map: BTreeMap<(String, String, i64), Vec<Variant>> = BTreeMap::new();
    for v in variants {
        let key = (v.kernel.clone(), v.param.clone(), v.size);
        map.entry(key).or_default().push(v.clone());
    }
    let mut problems = Vec::new();
    for ((kernel, param, size), vs) in map {
        // A problem must have consistent signatures across its variants —
        // they are interchangeable implementations of the same call.
        let sig0 = (vs[0].inputs.clone(), vs[0].output.clone());
        for v in &vs[1..] {
            if (v.inputs.clone(), v.output.clone()) != sig0 {
                return Err(Error::Manifest(format!(
                    "variant `{}` signature differs within problem {kernel}/{param}/n{size}",
                    v.id
                )));
            }
        }
        problems.push(Problem { kernel, param, size, variants: vs });
    }
    Ok(problems)
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};

    /// Shared fixture: a manifest whose artifact files actually exist
    /// (dummy HLO text in a unique temp dir), for CompileCache and
    /// coordinator tests running against the mock engine.
    pub(crate) fn sample_manifest() -> Result<Manifest> {
        // relaxed-counter: unique-suffix sequence, never synchronizes
        static COUNTER: AtomicU64 = AtomicU64::new(0);
        let dir = std::env::temp_dir().join(format!(
            "jitune-test-{}-{}",
            std::process::id(),
            COUNTER.fetch_add(1, Ordering::Relaxed)
        ));
        std::fs::create_dir_all(&dir).map_err(|e| Error::io(dir.display().to_string(), e))?;
        let m = Manifest::from_json_str(&sample_manifest_json(), dir.clone())?;
        for v in &m.variants {
            std::fs::write(dir.join(&v.path), format!("HloModule dummy_{}\n", v.id))
                .map_err(|e| Error::io(v.path.clone(), e))?;
        }
        Ok(m)
    }

    /// Shared fixture for other test modules.
    pub(crate) fn sample_manifest_json() -> String {
        r#"{
          "schema": 1,
          "generated_by": "test",
          "jax_version": "0.8.2",
          "entries": [
            {"id": "k.a.n8", "kernel": "k", "param": "p", "value": 1, "label": "a",
             "size": 8, "inputs": ["f32[8,8]"], "output": "f32[8,8]",
             "path": "k.a.n8.hlo.txt", "flops": 1024},
            {"id": "k.b.n8", "kernel": "k", "param": "p", "value": 2, "label": "b",
             "size": 8, "inputs": ["f32[8,8]"], "output": "f32[8,8]",
             "path": "k.b.n8.hlo.txt", "flops": 1024},
            {"id": "k.a.n16", "kernel": "k", "param": "p", "value": 1, "label": "a",
             "size": 16, "inputs": ["f32[16,16]"], "output": "f32[16,16]",
             "path": "k.a.n16.hlo.txt", "flops": 8192}
          ]
        }"#
        .to_string()
    }

    #[test]
    fn loads_and_groups() {
        let m = Manifest::from_json_str(&sample_manifest_json(), PathBuf::from("/tmp")).unwrap();
        assert_eq!(m.variants.len(), 3);
        assert_eq!(m.problems.len(), 2);
        let p = m.problem("k", 8).unwrap();
        assert_eq!(p.variants.len(), 2);
        assert_eq!(p.key(), "k/p/n8");
        assert_eq!(m.sizes("k"), vec![8, 16]);
        assert_eq!(m.kernels(), vec!["k".to_string()]);
    }

    #[test]
    fn variant_order_preserved_within_problem() {
        let m = Manifest::from_json_str(&sample_manifest_json(), PathBuf::from("/tmp")).unwrap();
        let p = m.problem("k", 8).unwrap();
        assert_eq!(p.variants[0].label, "a");
        assert_eq!(p.variants[1].label, "b");
    }

    #[test]
    fn rejects_duplicate_ids() {
        let text = sample_manifest_json().replace("k.b.n8", "k.a.n8");
        assert!(Manifest::from_json_str(&text, PathBuf::from("/tmp")).is_err());
    }

    #[test]
    fn rejects_wrong_schema() {
        let text = sample_manifest_json().replace("\"schema\": 1", "\"schema\": 99");
        assert!(Manifest::from_json_str(&text, PathBuf::from("/tmp")).is_err());
    }

    #[test]
    fn rejects_inconsistent_signatures() {
        let text = sample_manifest_json().replace(
            r#""size": 8, "inputs": ["f32[8,8]"], "output": "f32[8,8]",
             "path": "k.b.n8.hlo.txt""#,
            r#""size": 8, "inputs": ["f32[4,4]"], "output": "f32[4,4]",
             "path": "k.b.n8.hlo.txt""#,
        );
        assert!(Manifest::from_json_str(&text, PathBuf::from("/tmp")).is_err());
    }

    #[test]
    fn parse_sig_roundtrip() {
        assert_eq!(Variant::parse_sig("f32[128,64]").unwrap(), vec![128, 64]);
        assert_eq!(Variant::parse_sig("f32[5]").unwrap(), vec![5]);
        assert!(Variant::parse_sig("i32[5]").is_err());
        assert!(Variant::parse_sig("f32[a]").is_err());
    }

    #[test]
    fn unknown_lookups_error() {
        let m = Manifest::from_json_str(&sample_manifest_json(), PathBuf::from("/tmp")).unwrap();
        assert!(m.problem("nope", 8).is_err());
        assert!(m.variant("nope").is_err());
    }

    #[test]
    fn input_shapes_parsed() {
        let m = Manifest::from_json_str(&sample_manifest_json(), PathBuf::from("/tmp")).unwrap();
        let v = m.variant("k.a.n16").unwrap();
        assert_eq!(v.input_shapes().unwrap(), vec![vec![16, 16]]);
        assert_eq!(v.output_shape().unwrap(), vec![16, 16]);
    }
}
