//! Open-loop trace replay against a live coordinator.
//!
//! N named client threads walk one shared arrival schedule: each claims
//! the next arrival index, sleeps until its scheduled offset (open loop:
//! a late arrival is issued immediately — queueing shows up as latency,
//! exactly like a real service under burst), issues the call, and
//! records scheduled/actual/latency/route. A sampler thread polls the
//! fast lane's published-entry count into a time series, so the report
//! shows tuned-state growth *during* the run, not just its end state.
//!
//! The report answers the paper's questions under realistic traffic:
//! what did callers pay while tuning was in flight (cold vs. steady
//! p50/p99), how long until each problem was served by its tuned winner
//! (time-to-good), how much serving capacity exploration consumed
//! (duty cycle), and how much tuned state the shape churn accumulated.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::coordinator::{CallRoute, Coordinator};
use crate::error::{Error, Result};
use crate::manifest::Manifest;
use crate::sync::TrackedMutex;
use crate::tensor::HostTensor;
use crate::util::json::{n, obj, s, Value};
use crate::util::stats::percentile;
use crate::workload::{inputs_for, CallSpec, TimedTrace};

use super::{generate, TrafficSpec};

/// Replay tuning knobs (separate from [`TrafficSpec`] because they do
/// not change the generated workload, only how it is replayed and
/// observed).
#[derive(Clone)]
pub struct ReplayOptions {
    /// Multiplier on every scheduled arrival offset (1.0 = replay in
    /// trace time; tests use small values to replay faster).
    pub time_scale: f64,
    /// Cadence of the tuned-state time series sampler.
    pub sample_every: Duration,
    /// Fired exactly once, by the client that claims the trace's
    /// drift-injection index (see [`TrafficSpec::drift_at`]) — wire it
    /// to a [`NativeFault`](crate::runtime::native::NativeFault) or
    /// [`LatencyFault`](crate::runtime::mock::LatencyFault) handle.
    pub drift_inject: Option<Arc<dyn Fn() + Send + Sync>>,
    /// Chaos injections fired mid-replay (see [`FaultInjection`]); the
    /// schedule typically comes from a
    /// [`FaultPlan`](super::FaultPlan).
    pub faults: Vec<FaultInjection>,
}

impl Default for ReplayOptions {
    fn default() -> Self {
        ReplayOptions {
            time_scale: 1.0,
            sample_every: Duration::from_millis(25),
            drift_inject: None,
            faults: Vec::new(),
        }
    }
}

impl std::fmt::Debug for ReplayOptions {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ReplayOptions")
            .field("time_scale", &self.time_scale)
            .field("sample_every", &self.sample_every)
            .field("drift_inject", &self.drift_inject.is_some())
            .field("faults", &self.faults.iter().map(|f| f.label.clone()).collect::<Vec<_>>())
            .finish()
    }
}

/// One scheduled chaos injection: `fire` runs on the client that claims
/// call index `at` (before that call issues); `clear`, when set, runs at
/// `clear_at`. The timing typically comes from a
/// [`FaultPlan`](super::FaultPlan)'s `fire_index`/`clear_index`; the
/// closures bind it to a concrete handle — a
/// [`LatencyFault`](crate::runtime::mock::LatencyFault) or
/// [`NativeFault`](crate::runtime::native::NativeFault), a pool-worker
/// panic, a broker shutdown, an overload burst.
#[derive(Clone)]
pub struct FaultInjection {
    /// Report label, e.g. `error:k.b.n8` (see `FaultPlan::label`).
    pub label: String,
    /// Call index at which `fire` runs.
    pub at: usize,
    /// Call index at which `clear` runs; `None` = the fault persists.
    pub clear_at: Option<usize>,
    /// Injects the fault.
    pub fire: Arc<dyn Fn() + Send + Sync>,
    /// Removes the fault.
    pub clear: Option<Arc<dyn Fn() + Send + Sync>>,
}

impl std::fmt::Debug for FaultInjection {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("FaultInjection")
            .field("label", &self.label)
            .field("at", &self.at)
            .field("clear_at", &self.clear_at)
            .finish()
    }
}

/// How a failed call failed — the resilience mechanisms answer
/// differently and the report counts them apart.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum ErrorClass {
    /// [`Error::Overloaded`]: shed by the admission gate or queue-wait
    /// bound.
    Shed,
    /// [`Error::DeadlineExceeded`]: the call's budget elapsed.
    Deadline,
    /// A genuine execution/compile error.
    Other,
}

/// What one replayed call observed.
#[derive(Debug, Clone)]
struct CallRecord {
    idx: usize,
    spec: CallSpec,
    /// Scheduled offset (after time scaling).
    sched: Duration,
    /// Actual issue offset from replay start.
    start: Duration,
    latency: Duration,
    /// `None` when the call errored.
    route: Option<CallRoute>,
    /// `Some` exactly when `route` is `None`.
    error: Option<ErrorClass>,
}

/// A generated trace plus pre-built inputs, ready to replay any number
/// of times (A/B runs replay the identical workload).
pub struct TrafficHarness {
    spec: TrafficSpec,
    trace: Arc<TimedTrace>,
    /// Per-problem input tensors, keyed by `kernel/n{size}`. Built once
    /// up front — input synthesis must not pollute serve latency.
    inputs: Arc<HashMap<String, Vec<HostTensor>>>,
}

fn problem_key(spec: &CallSpec) -> String {
    format!("{}/n{}", spec.kernel, spec.size)
}

impl TrafficHarness {
    /// Generate the trace for `spec` over every problem of `manifest`
    /// (declaration order = popularity rank) and pre-build each
    /// problem's input tensors.
    pub fn new(manifest: &Manifest, spec: TrafficSpec, input_seed: u64) -> Result<TrafficHarness> {
        spec.validate()?;
        let catalog: Vec<CallSpec> = manifest
            .problems
            .iter()
            .map(|p| CallSpec { kernel: p.kernel.clone(), size: p.size })
            .collect();
        if catalog.is_empty() {
            return Err(Error::Config("traffic harness: manifest has no problems".into()));
        }
        let trace = generate(&spec, &catalog);
        let mut inputs = HashMap::new();
        for call in trace.problems() {
            let problem = manifest.problem(&call.kernel, call.size)?;
            inputs.insert(problem_key(&call), inputs_for(problem, input_seed));
        }
        Ok(TrafficHarness { spec, trace: Arc::new(trace), inputs: Arc::new(inputs) })
    }

    /// The generated arrival schedule.
    pub fn trace(&self) -> &TimedTrace {
        &self.trace
    }

    /// Replay the trace against `coord` and assemble the report.
    pub fn run(&self, coord: &Coordinator, opts: &ReplayOptions) -> Result<TrafficReport> {
        let next = Arc::new(AtomicUsize::new(0));
        let done = Arc::new(AtomicBool::new(false));
        let records: Arc<TrackedMutex<Vec<CallRecord>>> =
            Arc::new(TrackedMutex::new("traffic.harness.records", Vec::new()));
        let drift_fired: Arc<TrackedMutex<Option<Duration>>> =
            Arc::new(TrackedMutex::new("traffic.harness.drift_fired", None));
        let drift_call = self.spec.drift_call();
        // Per-fault (fired, cleared) offsets, filled by whichever client
        // claims the fault's call index.
        let fault_times: Arc<TrackedMutex<Vec<(Option<Duration>, Option<Duration>)>>> =
            Arc::new(TrackedMutex::new(
                "traffic.harness.fault_times",
                vec![(None, None); opts.faults.len()],
            ));
        let t0 = Instant::now();

        // Tuned-state sampler: published fast-lane entries over time
        // (reads a shared map — no leader round-trip, no serve impact).
        let sampler = {
            let h = coord.handle();
            let done = done.clone();
            let every = opts.sample_every;
            std::thread::Builder::new()
                .name("jitune-traffic-sampler".into())
                .spawn(move || {
                    let mut series: Vec<(f64, usize)> = vec![(0.0, h.fast_lane_published())];
                    while !done.load(Ordering::Acquire) {
                        std::thread::sleep(every);
                        series.push((t0.elapsed().as_secs_f64() * 1e3, h.fast_lane_published()));
                    }
                    // Final sample after the replay ends, so the series
                    // always closes on the end-of-run state.
                    series.push((t0.elapsed().as_secs_f64() * 1e3, h.fast_lane_published()));
                    series
                })
                .map_err(|e| Error::Coordinator(format!("traffic sampler spawn: {e}")))?
        };

        let mut clients = Vec::new();
        for c in 0..self.spec.clients {
            let h = coord.handle();
            let trace = self.trace.clone();
            let inputs = self.inputs.clone();
            let next = next.clone();
            let records = records.clone();
            let drift_fired = drift_fired.clone();
            let drift_inject = opts.drift_inject.clone();
            let faults = opts.faults.clone();
            let fault_times = fault_times.clone();
            let time_scale = opts.time_scale;
            let join = std::thread::Builder::new()
                .name(format!("jitune-traffic-{c}"))
                .spawn(move || {
                    let mut local: Vec<CallRecord> = Vec::new();
                    loop {
                        let idx = next.fetch_add(1, Ordering::AcqRel);
                        if idx >= trace.calls.len() {
                            break;
                        }
                        let call = &trace.calls[idx];
                        if drift_call == Some(idx) {
                            if let Some(inject) = &drift_inject {
                                inject();
                                *drift_fired.lock() = Some(t0.elapsed());
                            }
                        }
                        for (fi, fault) in faults.iter().enumerate() {
                            if fault.at == idx {
                                (fault.fire)();
                                fault_times.lock()[fi].0 = Some(t0.elapsed());
                            }
                            if fault.clear_at == Some(idx) {
                                if let Some(clear) = &fault.clear {
                                    clear();
                                }
                                fault_times.lock()[fi].1 = Some(t0.elapsed());
                            }
                        }
                        let sched = call.at.mul_f64(time_scale);
                        let now = t0.elapsed();
                        if sched > now {
                            std::thread::sleep(sched - now);
                        }
                        let args = inputs[&problem_key(&call.spec)].clone();
                        let start = t0.elapsed();
                        let issued = Instant::now();
                        let (route, error) = match h.call(&call.spec.kernel, args) {
                            Ok(outcome) => (Some(outcome.route), None),
                            Err(e) => {
                                let class = match &e {
                                    Error::Overloaded(_) => ErrorClass::Shed,
                                    Error::DeadlineExceeded { .. } => ErrorClass::Deadline,
                                    _ => ErrorClass::Other,
                                };
                                // sheds and deadline misses are the
                                // resilience layer working as designed
                                // under chaos — only genuine errors warn
                                if class == ErrorClass::Other {
                                    log::warn!(
                                        "traffic call {idx} ({}) failed: {e}",
                                        call.spec.kernel
                                    );
                                } else {
                                    log::debug!(
                                        "traffic call {idx} ({}): {e}",
                                        call.spec.kernel
                                    );
                                }
                                (None, Some(class))
                            }
                        };
                        local.push(CallRecord {
                            idx,
                            spec: call.spec.clone(),
                            sched,
                            start,
                            latency: issued.elapsed(),
                            route,
                            error,
                        });
                    }
                    records.lock().append(&mut local);
                })
                .map_err(|e| Error::Coordinator(format!("traffic client spawn: {e}")))?;
            clients.push(join);
        }
        for join in clients {
            join.join()
                .map_err(|_| Error::Coordinator("traffic client panicked".into()))?;
        }
        let wall = t0.elapsed();
        done.store(true, Ordering::Release);
        let tuned_series = sampler
            .join()
            .map_err(|_| Error::Coordinator("traffic sampler panicked".into()))?;

        let mut records = std::mem::take(&mut *records.lock());
        records.sort_by_key(|r| r.idx);
        let drift_fired_ms = drift_fired.lock().map(|d| d.as_secs_f64() * 1e3);
        let fault_events: Vec<FaultEvent> = opts
            .faults
            .iter()
            .zip(fault_times.lock().iter())
            .map(|(fault, &(fired, cleared))| FaultEvent {
                label: fault.label.clone(),
                fired_ms: fired.map(|d| d.as_secs_f64() * 1e3),
                cleared_ms: cleared.map(|d| d.as_secs_f64() * 1e3),
            })
            .collect();
        // Recovery window: everything after the *last* fault clears.
        let last_clear = opts.faults.iter().filter_map(|f| f.clear_at).max();
        self.assemble(coord, records, tuned_series, wall, drift_fired_ms, fault_events, last_clear)
    }

    #[allow(clippy::too_many_arguments)]
    fn assemble(
        &self,
        coord: &Coordinator,
        records: Vec<CallRecord>,
        tuned_series: Vec<(f64, usize)>,
        wall: Duration,
        drift_fired_ms: Option<f64>,
        faults: Vec<FaultEvent>,
        last_fault_clear: Option<usize>,
    ) -> Result<TrafficReport> {
        let h = coord.handle();
        let lat_us: Vec<f64> =
            records.iter().map(|r| r.latency.as_secs_f64() * 1e6).collect();
        let cold_end = records.len() / 5;
        let steady_start = records.len() / 2;
        let errors = records.iter().filter(|r| r.route.is_none()).count();
        let shed = records.iter().filter(|r| r.error == Some(ErrorClass::Shed)).count();
        let deadline_exceeded =
            records.iter().filter(|r| r.error == Some(ErrorClass::Deadline)).count();
        // Post-recovery tail: successful calls after the last fault
        // cleared (the chaos gate: p99 must come back down).
        let recovery_p99_us = last_fault_clear.map(|clear| {
            let post: Vec<f64> = records
                .iter()
                .filter(|r| r.idx > clear && r.route.is_some())
                .map(|r| r.latency.as_secs_f64() * 1e6)
                .collect();
            pct(&post, 99.0)
        });

        // Per-problem stats, in first-arrival order.
        let mut order: Vec<String> = Vec::new();
        let mut by_problem: HashMap<String, Vec<&CallRecord>> = HashMap::new();
        for r in &records {
            let key = problem_key(&r.spec);
            if !by_problem.contains_key(&key) {
                order.push(key.clone());
            }
            by_problem.entry(key).or_default().push(r);
        }
        let mut problems = Vec::new();
        for key in &order {
            let rs = &by_problem[key];
            let first_arrival = rs[0].sched;
            // Time-to-good: first serve by the *tuned winner* relative to
            // the problem's first arrival. Explored/Finalized/Default
            // routes are the cold phase being bridged.
            let time_to_good_ms = rs
                .iter()
                .find(|r| r.route == Some(CallRoute::Tuned))
                .map(|r| ((r.start + r.latency) - first_arrival).as_secs_f64() * 1e3);
            let us: Vec<f64> = rs.iter().map(|r| r.latency.as_secs_f64() * 1e6).collect();
            problems.push(ProblemStats {
                kernel: rs[0].spec.kernel.clone(),
                size: rs[0].spec.size,
                calls: rs.len(),
                errors: rs.iter().filter(|r| r.route.is_none()).count(),
                shed: rs.iter().filter(|r| r.error == Some(ErrorClass::Shed)).count(),
                deadline_exceeded: rs
                    .iter()
                    .filter(|r| r.error == Some(ErrorClass::Deadline))
                    .count(),
                first_arrival_ms: first_arrival.as_secs_f64() * 1e3,
                time_to_good_ms,
                p50_us: pct(&us, 50.0),
                p99_us: pct(&us, 99.0),
            });
        }
        let ttg: Vec<f64> = problems.iter().filter_map(|p| p.time_to_good_ms).collect();
        let untuned_problems = problems.len() - ttg.len();

        // Tuned-state size: serialize the tuner's exported state to a
        // scratch file and measure it (the deployable-cache footprint).
        let state_path = crate::testutil::temp_path("traffic-state", "json");
        let tuned_problems = h.save_state(&state_path)?;
        let tuned_state_bytes = std::fs::metadata(&state_path).map(|m| m.len()).unwrap_or(0);
        let _ = std::fs::remove_file(&state_path);

        let stats = h.stats_json()?;
        let duty_cycle_pct = stats
            .get("background")
            .and_then(|b| b.get("duty_cycle_pct"))
            .and_then(Value::as_f64);
        let drift_retunes = stats
            .get("kernels")
            .and_then(Value::as_obj)
            .map(|kernels| {
                kernels
                    .iter()
                    .filter_map(|(_, v)| v.get("drift_retunes").and_then(Value::as_i64))
                    .sum()
            })
            .unwrap_or(0);

        Ok(TrafficReport {
            spec: self.spec.clone(),
            calls: records.len(),
            errors,
            shed,
            deadline_exceeded,
            recovery_p99_us,
            faults,
            wall_ms: wall.as_secs_f64() * 1e3,
            p50_us: pct(&lat_us, 50.0),
            p99_us: pct(&lat_us, 99.0),
            cold_p50_us: pct(&lat_us[..cold_end], 50.0),
            cold_p99_us: pct(&lat_us[..cold_end], 99.0),
            steady_p50_us: pct(&lat_us[steady_start..], 50.0),
            steady_p99_us: pct(&lat_us[steady_start..], 99.0),
            problems,
            ttg_median_ms: if ttg.is_empty() { None } else { Some(pct(&ttg, 50.0)) },
            ttg_max_ms: ttg.iter().cloned().fold(None, |acc: Option<f64>, v| {
                Some(acc.map_or(v, |a| a.max(v)))
            }),
            untuned_problems,
            tuned_series,
            tuned_problems,
            tuned_state_bytes,
            duty_cycle_pct,
            drift_retunes,
            drift_fired_ms,
        })
    }
}

fn pct(samples: &[f64], p: f64) -> f64 {
    if samples.is_empty() {
        0.0
    } else {
        percentile(samples, p)
    }
}

/// One chaos injection as it actually landed during replay.
#[derive(Debug, Clone)]
pub struct FaultEvent {
    /// The injection's label (see `FaultPlan::label`).
    pub label: String,
    /// When the fault fired, ms from replay start (`None`: its call
    /// index was never reached).
    pub fired_ms: Option<f64>,
    /// When it cleared (`None`: persisted to end of trace).
    pub cleared_ms: Option<f64>,
}

/// Per-problem slice of a [`TrafficReport`].
#[derive(Debug, Clone)]
pub struct ProblemStats {
    /// Kernel family.
    pub kernel: String,
    /// Problem size.
    pub size: i64,
    /// Calls replayed for this problem.
    pub calls: usize,
    /// Calls that errored (any class, including shed/deadline).
    pub errors: usize,
    /// Calls shed with [`Error::Overloaded`].
    pub shed: usize,
    /// Calls that exceeded their deadline.
    pub deadline_exceeded: usize,
    /// Scheduled offset of the problem's first arrival.
    pub first_arrival_ms: f64,
    /// First tuned-winner serve relative to first arrival (`None`: the
    /// problem never reached its tuned winner within the trace).
    pub time_to_good_ms: Option<f64>,
    /// Median serve latency.
    pub p50_us: f64,
    /// Tail serve latency.
    pub p99_us: f64,
}

/// Everything a replay observed. `to_json` is the `BENCH_TRAFFIC.json`
/// payload; `render` is the human CLI summary.
#[derive(Debug, Clone)]
pub struct TrafficReport {
    /// The spec that generated the workload.
    pub spec: TrafficSpec,
    /// Calls replayed.
    pub calls: usize,
    /// Calls that errored (any class, including shed/deadline).
    pub errors: usize,
    /// Calls shed with [`Error::Overloaded`] (admission gate or
    /// queue-wait bound).
    pub shed: usize,
    /// Calls that returned [`Error::DeadlineExceeded`].
    pub deadline_exceeded: usize,
    /// p99 over successful calls issued after the last fault cleared
    /// (`None` when no fault was scheduled to clear).
    pub recovery_p99_us: Option<f64>,
    /// Chaos injections as they actually landed.
    pub faults: Vec<FaultEvent>,
    /// Wall time of the replay.
    pub wall_ms: f64,
    /// Overall median serve latency (µs).
    pub p50_us: f64,
    /// Overall tail serve latency (µs).
    pub p99_us: f64,
    /// Median over the first 20% of arrivals (tuning in flight).
    pub cold_p50_us: f64,
    /// Tail over the first 20% of arrivals.
    pub cold_p99_us: f64,
    /// Median over the last 50% of arrivals.
    pub steady_p50_us: f64,
    /// Tail over the last 50% of arrivals.
    pub steady_p99_us: f64,
    /// Per-problem stats, first-arrival order.
    pub problems: Vec<ProblemStats>,
    /// Median time-to-good over problems that tuned.
    pub ttg_median_ms: Option<f64>,
    /// Worst time-to-good.
    pub ttg_max_ms: Option<f64>,
    /// Problems that never reached their tuned winner in-trace.
    pub untuned_problems: usize,
    /// `(ms since start, fast-lane entries)` samples.
    pub tuned_series: Vec<(f64, usize)>,
    /// Tuned problems in the exported state.
    pub tuned_problems: usize,
    /// Size of the exported tuned state (deployable-cache footprint).
    pub tuned_state_bytes: u64,
    /// Background-explore duty cycle over the run, when enabled.
    pub duty_cycle_pct: Option<f64>,
    /// Drift-triggered retunes observed.
    pub drift_retunes: i64,
    /// When the drift injection actually fired.
    pub drift_fired_ms: Option<f64>,
}

impl TrafficReport {
    /// Machine-readable export (the `BENCH_TRAFFIC.json` schema).
    pub fn to_json(&self) -> Value {
        let spec = &self.spec;
        obj(vec![
            (
                "spec",
                obj(vec![
                    ("calls", n(spec.calls as f64)),
                    ("rps", n(spec.rps)),
                    ("zipf_s", n(spec.zipf_s)),
                    ("initial", n(spec.initial as f64)),
                    ("churn_every", n(spec.churn_every as f64)),
                    ("burst", n(spec.burst)),
                    ("burst_len", n(spec.burst_len as f64)),
                    ("drift_at", n(spec.drift_at)),
                    ("seed", n(spec.seed as f64)),
                    ("clients", n(spec.clients as f64)),
                ]),
            ),
            ("calls", n(self.calls as f64)),
            ("errors", n(self.errors as f64)),
            ("shed", n(self.shed as f64)),
            ("deadline_exceeded", n(self.deadline_exceeded as f64)),
            ("wall_ms", n(self.wall_ms)),
            (
                "latency_us",
                obj(vec![
                    ("p50", n(self.p50_us)),
                    ("p99", n(self.p99_us)),
                    ("cold_p50", n(self.cold_p50_us)),
                    ("cold_p99", n(self.cold_p99_us)),
                    ("steady_p50", n(self.steady_p50_us)),
                    ("steady_p99", n(self.steady_p99_us)),
                    ("recovery_p99", self.recovery_p99_us.map(n).unwrap_or(Value::Null)),
                ]),
            ),
            (
                "faults",
                Value::Arr(
                    self.faults
                        .iter()
                        .map(|f| {
                            obj(vec![
                                ("label", s(f.label.clone())),
                                ("fired_ms", f.fired_ms.map(n).unwrap_or(Value::Null)),
                                ("cleared_ms", f.cleared_ms.map(n).unwrap_or(Value::Null)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "time_to_good_ms",
                obj(vec![
                    ("median", self.ttg_median_ms.map(n).unwrap_or(Value::Null)),
                    ("max", self.ttg_max_ms.map(n).unwrap_or(Value::Null)),
                    ("untuned_problems", n(self.untuned_problems as f64)),
                ]),
            ),
            (
                "problems",
                Value::Arr(
                    self.problems
                        .iter()
                        .map(|p| {
                            obj(vec![
                                ("kernel", s(p.kernel.clone())),
                                ("size", n(p.size as f64)),
                                ("calls", n(p.calls as f64)),
                                ("errors", n(p.errors as f64)),
                                ("shed", n(p.shed as f64)),
                                ("deadline_exceeded", n(p.deadline_exceeded as f64)),
                                ("first_arrival_ms", n(p.first_arrival_ms)),
                                (
                                    "time_to_good_ms",
                                    p.time_to_good_ms.map(n).unwrap_or(Value::Null),
                                ),
                                ("p50_us", n(p.p50_us)),
                                ("p99_us", n(p.p99_us)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "tuned_state",
                obj(vec![
                    (
                        "series",
                        Value::Arr(
                            self.tuned_series
                                .iter()
                                .map(|&(ms, count)| {
                                    Value::Arr(vec![n(ms), n(count as f64)])
                                })
                                .collect(),
                        ),
                    ),
                    ("problems", n(self.tuned_problems as f64)),
                    ("bytes", n(self.tuned_state_bytes as f64)),
                ]),
            ),
            (
                "background",
                obj(vec![(
                    "duty_cycle_pct",
                    self.duty_cycle_pct.map(n).unwrap_or(Value::Null),
                )]),
            ),
            (
                "drift",
                obj(vec![
                    ("retunes", n(self.drift_retunes as f64)),
                    ("fired_ms", self.drift_fired_ms.map(n).unwrap_or(Value::Null)),
                ]),
            ),
        ])
    }

    /// Human-readable multi-line summary.
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "traffic: {} calls ({} errors) in {:.0}ms across {} clients\n",
            self.calls, self.errors, self.wall_ms, self.spec.clients
        ));
        out.push_str(&format!(
            "latency: p50 {:.0}us p99 {:.0}us (cold p99 {:.0}us -> steady p99 {:.0}us)\n",
            self.p50_us, self.p99_us, self.cold_p99_us, self.steady_p99_us
        ));
        if self.shed + self.deadline_exceeded > 0 {
            out.push_str(&format!(
                "resilience: {} shed, {} deadline-exceeded\n",
                self.shed, self.deadline_exceeded
            ));
        }
        for f in &self.faults {
            let fired = f.fired_ms.map(|ms| format!("{ms:.0}ms")).unwrap_or_else(|| "-".into());
            let cleared =
                f.cleared_ms.map(|ms| format!("{ms:.0}ms")).unwrap_or_else(|| "never".into());
            out.push_str(&format!("fault {}: fired {fired}, cleared {cleared}", f.label));
            if let Some(p99) = self.recovery_p99_us {
                out.push_str(&format!(" (post-clear p99 {p99:.0}us)"));
            }
            out.push('\n');
        }
        match self.ttg_median_ms {
            Some(median) => out.push_str(&format!(
                "time-to-good: median {median:.0}ms max {:.0}ms ({} problem(s) untuned)\n",
                self.ttg_max_ms.unwrap_or(median),
                self.untuned_problems
            )),
            None => out.push_str("time-to-good: no problem reached its tuned winner\n"),
        }
        out.push_str(&format!(
            "tuned state: {} problem(s), {} bytes exported\n",
            self.tuned_problems, self.tuned_state_bytes
        ));
        if let Some(duty) = self.duty_cycle_pct {
            out.push_str(&format!("background explore duty cycle: {duty:.2}%\n"));
        }
        if self.drift_retunes > 0 || self.drift_fired_ms.is_some() {
            out.push_str(&format!(
                "drift: injection at {} -> {} retune(s)\n",
                self.drift_fired_ms
                    .map(|ms| format!("{ms:.0}ms"))
                    .unwrap_or_else(|| "-".into()),
                self.drift_retunes
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::ServerOptions;
    use crate::runtime::mock::MockSpec;
    use crate::testutil::spawn_pooled_mock;

    fn mock_coord() -> Coordinator {
        spawn_pooled_mock("kern", 2, &[8, 16], MockSpec::default(), 2, ServerOptions::default())
            .unwrap()
    }

    fn quick_spec() -> TrafficSpec {
        TrafficSpec {
            calls: 120,
            rps: 4000.0,
            initial: 2,
            churn_every: 0,
            clients: 3,
            ..TrafficSpec::default()
        }
    }

    #[test]
    fn replays_every_call_and_reports() {
        let coord = mock_coord();
        let manifest = crate::testutil::synthetic_manifest("kern", 2, &[8, 16]).unwrap();
        let harness = TrafficHarness::new(&manifest, quick_spec(), 7).unwrap();
        let report = harness.run(&coord, &ReplayOptions::default()).unwrap();
        assert_eq!(report.calls, 120);
        assert_eq!(report.errors, 0);
        assert!(report.p50_us > 0.0);
        assert!(report.p99_us >= report.p50_us);
        assert_eq!(report.problems.len(), 2);
        assert_eq!(report.problems.iter().map(|p| p.calls).sum::<usize>(), 120);
        // both problems see enough traffic to tune (sweep needs
        // 2 explores + 1 finalize each)
        assert!(report.ttg_median_ms.is_some(), "problems tuned: {report:?}");
        assert_eq!(report.untuned_problems, 0);
        assert_eq!(report.tuned_problems, 2);
        assert!(report.tuned_state_bytes > 0);
        // the sampler saw the lane fill up
        assert_eq!(report.tuned_series.last().unwrap().1, 2);
        // JSON export parses back
        let text = report.to_json().to_json_pretty();
        let parsed = crate::util::json::parse(&text).unwrap();
        assert_eq!(parsed.get("calls").unwrap().as_i64(), Some(120));
        assert!(parsed.get("latency_us").unwrap().get("p99").is_some());
        assert!(!report.render().is_empty());
    }

    #[test]
    fn drift_injection_fires_once_at_fraction() {
        let coord = mock_coord();
        let manifest = crate::testutil::synthetic_manifest("kern", 2, &[8, 16]).unwrap();
        let spec = TrafficSpec { drift_at: 0.5, ..quick_spec() };
        let harness = TrafficHarness::new(&manifest, spec, 7).unwrap();
        let fired = Arc::new(AtomicUsize::new(0));
        let counter = fired.clone();
        let opts = ReplayOptions {
            drift_inject: Some(Arc::new(move || {
                counter.fetch_add(1, Ordering::AcqRel);
            })),
            ..ReplayOptions::default()
        };
        let report = harness.run(&coord, &opts).unwrap();
        assert_eq!(fired.load(Ordering::Acquire), 1, "exactly one injection");
        assert!(report.drift_fired_ms.is_some());
    }

    #[test]
    fn identical_spec_replays_identical_workload() {
        let manifest = crate::testutil::synthetic_manifest("kern", 2, &[8, 16]).unwrap();
        let a = TrafficHarness::new(&manifest, quick_spec(), 7).unwrap();
        let b = TrafficHarness::new(&manifest, quick_spec(), 7).unwrap();
        assert_eq!(a.trace(), b.trace());
    }

    #[test]
    fn fault_injections_fire_and_clear_on_schedule() {
        use super::super::FaultPlan;
        let coord = mock_coord();
        let manifest = crate::testutil::synthetic_manifest("kern", 2, &[8, 16]).unwrap();
        let harness = TrafficHarness::new(&manifest, quick_spec(), 7).unwrap();
        let plan = FaultPlan::parse("kind=error, at=0.25, clear=0.75, target=x").unwrap();
        let fired = Arc::new(AtomicUsize::new(0));
        let cleared = Arc::new(AtomicUsize::new(0));
        let (f, c) = (fired.clone(), cleared.clone());
        let opts = ReplayOptions {
            faults: vec![FaultInjection {
                label: plan.label(),
                at: plan.fire_index(120),
                clear_at: plan.clear_index(120),
                fire: Arc::new(move || {
                    f.fetch_add(1, Ordering::AcqRel);
                }),
                clear: Some(Arc::new(move || {
                    c.fetch_add(1, Ordering::AcqRel);
                })),
            }],
            ..ReplayOptions::default()
        };
        let report = harness.run(&coord, &opts).unwrap();
        assert_eq!(fired.load(Ordering::Acquire), 1, "fault fired exactly once");
        assert_eq!(cleared.load(Ordering::Acquire), 1, "fault cleared exactly once");
        assert_eq!(report.faults.len(), 1);
        assert_eq!(report.faults[0].label, "error:x");
        let (fired_ms, cleared_ms) =
            (report.faults[0].fired_ms.unwrap(), report.faults[0].cleared_ms.unwrap());
        assert!(fired_ms <= cleared_ms, "fired before cleared");
        assert!(report.recovery_p99_us.is_some(), "post-clear tail reported");
        // a benign injection breaks nothing
        assert_eq!(report.errors, 0);
        assert_eq!(report.shed, 0);
        assert_eq!(report.deadline_exceeded, 0);
        // the new counters survive the JSON round trip
        let parsed = crate::util::json::parse(&report.to_json().to_json_pretty()).unwrap();
        assert_eq!(parsed.get("shed").unwrap().as_i64(), Some(0));
        assert_eq!(
            parsed.get("faults").unwrap().as_arr().unwrap()[0]
                .get("label")
                .unwrap()
                .as_str(),
            Some("error:x")
        );
    }
}
