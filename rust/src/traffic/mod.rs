//! Production-traffic replay: seeded generators + a harness that drives
//! a full coordinator the way a real service would.
//!
//! Every scaling claim upstream (fast lane, worker pool, background
//! exploration, drift retuning) was demonstrated under uniform call
//! loops. Real services look nothing like that: a few kernels dominate
//! (Zipfian popularity), the shape catalog churns as new models roll
//! out, arrivals come in open-loop bursts, and machine behaviour drifts
//! mid-run. This module makes those conditions reproducible from a seed:
//!
//! - [`TrafficSpec`] — the knobs, parseable from a compact
//!   `k=v,k=v` string (`jitune run --traffic <spec>`).
//! - [`generate`](generate::generate) — spec + problem catalog →
//!   [`TimedTrace`](crate::workload::TimedTrace): Zipf-weighted problem
//!   choice over a churning active set, exponential inter-arrivals with
//!   a two-state (normal/burst) modulator.
//! - [`TrafficHarness`](harness::TrafficHarness) — open-loop replay of
//!   a trace against a live coordinator from N client threads,
//!   producing a [`TrafficReport`](harness::TrafficReport): p50/p99
//!   serve latency (overall, cold, steady), per-problem time-to-good,
//!   explore duty cycle, and a tuned-state-size time series.
//!
//! `benches/traffic_replay.rs` runs the harness over the native engine
//! ([`crate::runtime::native`]) and writes `BENCH_TRAFFIC.json` at the
//! repo root, extending the visible perf trajectory on every push to
//! main.

pub mod generate;
pub mod harness;

use crate::error::{Error, Result};

pub use generate::generate;
pub use harness::{ReplayOptions, TrafficHarness, TrafficReport};

/// Knobs of a synthetic traffic trace. All fields have serving-shaped
/// defaults; construct with `TrafficSpec::default()` and override, or
/// parse a `k=v,k=v` string (see [`TrafficSpec::parse`]).
#[derive(Debug, Clone, PartialEq)]
pub struct TrafficSpec {
    /// Total arrivals in the trace.
    pub calls: usize,
    /// Mean arrival rate (calls/second of trace time) outside bursts.
    pub rps: f64,
    /// Zipf popularity exponent over the active problem set (0 =
    /// uniform; ~1 = classic web-serving skew).
    pub zipf_s: f64,
    /// Problems active at trace start (the rest arrive via churn).
    pub initial: usize,
    /// Activate one more catalog problem every N calls (shape churn);
    /// 0 disables churn.
    pub churn_every: usize,
    /// Arrival-rate multiplier while the burst state is on.
    pub burst: f64,
    /// Mean burst episode length in calls (geometric); also sets the
    /// off-state length to ~3x this, so bursts cover ~25% of arrivals.
    pub burst_len: usize,
    /// Fraction of the trace (0..1] after which the harness fires its
    /// drift injection; 0 disables.
    pub drift_at: f64,
    /// Generator seed — the whole trace is a pure function of the spec.
    pub seed: u64,
    /// Replay client threads.
    pub clients: usize,
}

impl Default for TrafficSpec {
    fn default() -> Self {
        TrafficSpec {
            calls: 2000,
            rps: 1000.0,
            zipf_s: 1.1,
            initial: 3,
            churn_every: 250,
            burst: 4.0,
            burst_len: 50,
            drift_at: 0.0,
            seed: 42,
            clients: 4,
        }
    }
}

impl TrafficSpec {
    /// Parse a compact spec string: comma-separated `key=value` pairs
    /// over the struct's fields (`calls`, `rps`, `zipf`, `initial`,
    /// `churn`, `burst`, `burstlen`, `drift`, `seed`, `clients`).
    /// Omitted keys keep their defaults; `TrafficSpec::parse("")` is
    /// `TrafficSpec::default()`.
    pub fn parse(text: &str) -> Result<TrafficSpec> {
        let mut spec = TrafficSpec::default();
        for pair in text.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            let (key, value) = pair.split_once('=').ok_or_else(|| {
                Error::Config(format!("traffic spec: `{pair}` is not key=value"))
            })?;
            let bad = |what: &str| {
                Error::Config(format!("traffic spec: `{value}` is not a valid {what} for {key}"))
            };
            match key.trim() {
                "calls" => spec.calls = value.parse().map_err(|_| bad("count"))?,
                "rps" => spec.rps = value.parse().map_err(|_| bad("rate"))?,
                "zipf" => spec.zipf_s = value.parse().map_err(|_| bad("exponent"))?,
                "initial" => spec.initial = value.parse().map_err(|_| bad("count"))?,
                "churn" => spec.churn_every = value.parse().map_err(|_| bad("count"))?,
                "burst" => spec.burst = value.parse().map_err(|_| bad("factor"))?,
                "burstlen" => spec.burst_len = value.parse().map_err(|_| bad("count"))?,
                "drift" => spec.drift_at = value.parse().map_err(|_| bad("fraction"))?,
                "seed" => spec.seed = value.parse().map_err(|_| bad("seed"))?,
                "clients" => spec.clients = value.parse().map_err(|_| bad("count"))?,
                other => {
                    return Err(Error::Config(format!("traffic spec: unknown key `{other}`")))
                }
            }
        }
        spec.validate()?;
        Ok(spec)
    }

    /// Reject degenerate configurations early.
    pub fn validate(&self) -> Result<()> {
        if self.calls == 0 {
            return Err(Error::Config("traffic spec: calls must be > 0".into()));
        }
        if !self.rps.is_finite() || self.rps <= 0.0 {
            return Err(Error::Config("traffic spec: rps must be > 0".into()));
        }
        if !self.zipf_s.is_finite() || self.zipf_s < 0.0 {
            return Err(Error::Config("traffic spec: zipf must be >= 0".into()));
        }
        if !self.burst.is_finite() || self.burst < 1.0 {
            return Err(Error::Config("traffic spec: burst must be >= 1".into()));
        }
        if self.clients == 0 {
            return Err(Error::Config("traffic spec: clients must be > 0".into()));
        }
        if !(0.0..=1.0).contains(&self.drift_at) {
            return Err(Error::Config("traffic spec: drift must be in [0, 1]".into()));
        }
        Ok(())
    }

    /// The call index at which the harness fires drift injection
    /// (`None` when disabled).
    pub fn drift_call(&self) -> Option<usize> {
        if self.drift_at > 0.0 {
            Some(((self.calls as f64 * self.drift_at) as usize).min(self.calls - 1))
        } else {
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrips_defaults_and_overrides() {
        assert_eq!(TrafficSpec::parse("").unwrap(), TrafficSpec::default());
        let s =
            TrafficSpec::parse("calls=500, rps=250, zipf=0.9, churn=0, drift=0.5, seed=7").unwrap();
        assert_eq!(s.calls, 500);
        assert_eq!(s.rps, 250.0);
        assert_eq!(s.zipf_s, 0.9);
        assert_eq!(s.churn_every, 0);
        assert_eq!(s.drift_at, 0.5);
        assert_eq!(s.seed, 7);
        assert_eq!(s.clients, TrafficSpec::default().clients);
        assert_eq!(s.drift_call(), Some(250));
    }

    #[test]
    fn parse_rejects_bad_input() {
        assert!(TrafficSpec::parse("calls").is_err());
        assert!(TrafficSpec::parse("calls=zero").is_err());
        assert!(TrafficSpec::parse("warp=9").is_err());
        assert!(TrafficSpec::parse("calls=0").is_err());
        assert!(TrafficSpec::parse("burst=0.5").is_err());
        assert!(TrafficSpec::parse("drift=1.5").is_err());
    }
}
