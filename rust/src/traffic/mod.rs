//! Production-traffic replay: seeded generators + a harness that drives
//! a full coordinator the way a real service would.
//!
//! Every scaling claim upstream (fast lane, worker pool, background
//! exploration, drift retuning) was demonstrated under uniform call
//! loops. Real services look nothing like that: a few kernels dominate
//! (Zipfian popularity), the shape catalog churns as new models roll
//! out, arrivals come in open-loop bursts, and machine behaviour drifts
//! mid-run. This module makes those conditions reproducible from a seed:
//!
//! - [`TrafficSpec`] — the knobs, parseable from a compact
//!   `k=v,k=v` string (`jitune run --traffic <spec>`).
//! - [`generate`](generate::generate) — spec + problem catalog →
//!   [`TimedTrace`](crate::workload::TimedTrace): Zipf-weighted problem
//!   choice over a churning active set, exponential inter-arrivals with
//!   a two-state (normal/burst) modulator.
//! - [`TrafficHarness`](harness::TrafficHarness) — open-loop replay of
//!   a trace against a live coordinator from N client threads,
//!   producing a [`TrafficReport`](harness::TrafficReport): p50/p99
//!   serve latency (overall, cold, steady), per-problem time-to-good
//!   and error/shed/deadline counts, explore duty cycle, and a
//!   tuned-state-size time series.
//! - [`FaultPlan`] — a chaos schedule (`kind=error,at=0.3,clear=0.6,
//!   target=...`), parseable like a [`TrafficSpec`], that the replay
//!   fires mid-run: wedged variants, erroring winners, worker death,
//!   broker outage, overload bursts. The plan owns *when*; the caller
//!   wires *how* (a [`LatencyFault`](crate::runtime::mock::LatencyFault)
//!   or [`NativeFault`](crate::runtime::native::NativeFault) handle, a
//!   worker kill, a broker stop) into a
//!   [`FaultInjection`](harness::FaultInjection).
//!
//! `benches/traffic_replay.rs` runs the harness over the native engine
//! ([`crate::runtime::native`]) and writes `BENCH_TRAFFIC.json` at the
//! repo root, extending the visible perf trajectory on every push to
//! main; `benches/chaos_replay.rs` replays under [`FaultPlan`]s and
//! gates the resilience contract into `BENCH_CHAOS.json`.

pub mod generate;
pub mod harness;

use crate::error::{Error, Result};

pub use generate::generate;
pub use harness::{FaultEvent, FaultInjection, ReplayOptions, TrafficHarness, TrafficReport};

/// Knobs of a synthetic traffic trace. All fields have serving-shaped
/// defaults; construct with `TrafficSpec::default()` and override, or
/// parse a `k=v,k=v` string (see [`TrafficSpec::parse`]).
#[derive(Debug, Clone, PartialEq)]
pub struct TrafficSpec {
    /// Total arrivals in the trace.
    pub calls: usize,
    /// Mean arrival rate (calls/second of trace time) outside bursts.
    pub rps: f64,
    /// Zipf popularity exponent over the active problem set (0 =
    /// uniform; ~1 = classic web-serving skew).
    pub zipf_s: f64,
    /// Problems active at trace start (the rest arrive via churn).
    pub initial: usize,
    /// Activate one more catalog problem every N calls (shape churn);
    /// 0 disables churn.
    pub churn_every: usize,
    /// Arrival-rate multiplier while the burst state is on.
    pub burst: f64,
    /// Mean burst episode length in calls (geometric); also sets the
    /// off-state length to ~3x this, so bursts cover ~25% of arrivals.
    pub burst_len: usize,
    /// Fraction of the trace (0..1] after which the harness fires its
    /// drift injection; 0 disables.
    pub drift_at: f64,
    /// Generator seed — the whole trace is a pure function of the spec.
    pub seed: u64,
    /// Replay client threads.
    pub clients: usize,
}

impl Default for TrafficSpec {
    fn default() -> Self {
        TrafficSpec {
            calls: 2000,
            rps: 1000.0,
            zipf_s: 1.1,
            initial: 3,
            churn_every: 250,
            burst: 4.0,
            burst_len: 50,
            drift_at: 0.0,
            seed: 42,
            clients: 4,
        }
    }
}

impl TrafficSpec {
    /// Parse a compact spec string: comma-separated `key=value` pairs
    /// over the struct's fields (`calls`, `rps`, `zipf`, `initial`,
    /// `churn`, `burst`, `burstlen`, `drift`, `seed`, `clients`).
    /// Omitted keys keep their defaults; `TrafficSpec::parse("")` is
    /// `TrafficSpec::default()`.
    pub fn parse(text: &str) -> Result<TrafficSpec> {
        let mut spec = TrafficSpec::default();
        for pair in text.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            let (key, value) = pair.split_once('=').ok_or_else(|| {
                Error::Config(format!("traffic spec: `{pair}` is not key=value"))
            })?;
            let bad = |what: &str| {
                Error::Config(format!("traffic spec: `{value}` is not a valid {what} for {key}"))
            };
            match key.trim() {
                "calls" => spec.calls = value.parse().map_err(|_| bad("count"))?,
                "rps" => spec.rps = value.parse().map_err(|_| bad("rate"))?,
                "zipf" => spec.zipf_s = value.parse().map_err(|_| bad("exponent"))?,
                "initial" => spec.initial = value.parse().map_err(|_| bad("count"))?,
                "churn" => spec.churn_every = value.parse().map_err(|_| bad("count"))?,
                "burst" => spec.burst = value.parse().map_err(|_| bad("factor"))?,
                "burstlen" => spec.burst_len = value.parse().map_err(|_| bad("count"))?,
                "drift" => spec.drift_at = value.parse().map_err(|_| bad("fraction"))?,
                "seed" => spec.seed = value.parse().map_err(|_| bad("seed"))?,
                "clients" => spec.clients = value.parse().map_err(|_| bad("count"))?,
                other => {
                    return Err(Error::Config(format!("traffic spec: unknown key `{other}`")))
                }
            }
        }
        spec.validate()?;
        Ok(spec)
    }

    /// Reject degenerate configurations early.
    pub fn validate(&self) -> Result<()> {
        if self.calls == 0 {
            return Err(Error::Config("traffic spec: calls must be > 0".into()));
        }
        if !self.rps.is_finite() || self.rps <= 0.0 {
            return Err(Error::Config("traffic spec: rps must be > 0".into()));
        }
        if !self.zipf_s.is_finite() || self.zipf_s < 0.0 {
            return Err(Error::Config("traffic spec: zipf must be >= 0".into()));
        }
        if !self.burst.is_finite() || self.burst < 1.0 {
            return Err(Error::Config("traffic spec: burst must be >= 1".into()));
        }
        if self.clients == 0 {
            return Err(Error::Config("traffic spec: clients must be > 0".into()));
        }
        if !(0.0..=1.0).contains(&self.drift_at) {
            return Err(Error::Config("traffic spec: drift must be in [0, 1]".into()));
        }
        Ok(())
    }

    /// The call index at which the harness fires drift injection
    /// (`None` when disabled).
    pub fn drift_call(&self) -> Option<usize> {
        if self.drift_at > 0.0 {
            Some(((self.calls as f64 * self.drift_at) as usize).min(self.calls - 1))
        } else {
            None
        }
    }
}

/// What a [`FaultPlan`] breaks.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// A variant's execution slows by `factor` (wedged winner / stuck
    /// accelerator): deadlines must bound callers, drift may retune.
    Wedge,
    /// A variant's execution starts erroring (miscompiled winner): the
    /// quarantine breaker must demote it to the fallback.
    Error,
    /// A pool worker dies mid-run: respawn must absorb it, in-flight
    /// callers must not hang.
    WorkerDeath,
    /// The hub broker goes away: serving must continue unaffected.
    BrokerDown,
    /// An arrival burst beyond capacity: the admission gate must shed
    /// instead of queueing unboundedly.
    Overload,
}

impl std::fmt::Display for FaultKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            FaultKind::Wedge => "wedge",
            FaultKind::Error => "error",
            FaultKind::WorkerDeath => "worker_death",
            FaultKind::BrokerDown => "broker_down",
            FaultKind::Overload => "overload",
        })
    }
}

/// A chaos schedule: *which* fault, *when* it fires as a fraction of the
/// trace, and *when* it clears. Parsed from a compact `k=v,k=v` string
/// exactly like [`TrafficSpec`]. The plan is engine-agnostic — it only
/// owns timing and targeting; the chaos harness binds each kind to the
/// concrete injection handle and hands the pair to the replay as a
/// [`FaultInjection`](harness::FaultInjection).
#[derive(Debug, Clone, PartialEq)]
pub struct FaultPlan {
    /// What breaks.
    pub kind: FaultKind,
    /// Fraction of the trace (0..1) at which the fault fires.
    pub at: f64,
    /// Fraction of the trace at which it clears; 0 means it never does.
    pub clear: f64,
    /// Target id — a variant for wedge/error, free-form otherwise.
    pub target: String,
    /// Wedge slowdown multiplier (ignored by the other kinds).
    pub factor: f64,
}

impl Default for FaultPlan {
    fn default() -> Self {
        FaultPlan {
            kind: FaultKind::Error,
            at: 0.4,
            clear: 0.0,
            target: String::new(),
            factor: 20.0,
        }
    }
}

impl FaultPlan {
    /// Parse a compact plan: comma-separated `key=value` over `kind`
    /// (`wedge` | `error` | `worker_death` | `broker_down` |
    /// `overload`), `at`, `clear`, `target`, `factor`. Omitted keys keep
    /// their defaults.
    pub fn parse(text: &str) -> Result<FaultPlan> {
        let mut plan = FaultPlan::default();
        for pair in text.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            let (key, value) = pair
                .split_once('=')
                .ok_or_else(|| Error::Config(format!("fault plan: `{pair}` is not key=value")))?;
            let bad = |what: &str| {
                Error::Config(format!("fault plan: `{value}` is not a valid {what} for {key}"))
            };
            match key.trim() {
                "kind" => {
                    plan.kind = match value.trim() {
                        "wedge" => FaultKind::Wedge,
                        "error" => FaultKind::Error,
                        "worker_death" => FaultKind::WorkerDeath,
                        "broker_down" => FaultKind::BrokerDown,
                        "overload" => FaultKind::Overload,
                        _ => return Err(bad("fault kind")),
                    }
                }
                "at" => plan.at = value.parse().map_err(|_| bad("fraction"))?,
                "clear" => plan.clear = value.parse().map_err(|_| bad("fraction"))?,
                "target" => plan.target = value.trim().to_string(),
                "factor" => plan.factor = value.parse().map_err(|_| bad("factor"))?,
                other => return Err(Error::Config(format!("fault plan: unknown key `{other}`"))),
            }
        }
        plan.validate()?;
        Ok(plan)
    }

    /// Reject degenerate schedules early.
    pub fn validate(&self) -> Result<()> {
        if !self.at.is_finite() || !(0.0..1.0).contains(&self.at) {
            return Err(Error::Config("fault plan: at must be in [0, 1)".into()));
        }
        if !self.clear.is_finite() || !(0.0..=1.0).contains(&self.clear) {
            return Err(Error::Config("fault plan: clear must be in [0, 1]".into()));
        }
        if self.clear > 0.0 && self.clear <= self.at {
            return Err(Error::Config("fault plan: clear must be after at".into()));
        }
        if !self.factor.is_finite() || self.factor < 1.0 {
            return Err(Error::Config("fault plan: factor must be >= 1".into()));
        }
        Ok(())
    }

    /// Call index at which the fault fires for a trace of `calls`.
    pub fn fire_index(&self, calls: usize) -> usize {
        ((calls as f64 * self.at) as usize).min(calls.saturating_sub(1))
    }

    /// Call index at which the fault clears (`None`: never clears).
    pub fn clear_index(&self, calls: usize) -> Option<usize> {
        if self.clear > 0.0 {
            Some(((calls as f64 * self.clear) as usize).min(calls.saturating_sub(1)))
        } else {
            None
        }
    }

    /// Report label, e.g. `error:k.b.n8`.
    pub fn label(&self) -> String {
        if self.target.is_empty() {
            self.kind.to_string()
        } else {
            format!("{}:{}", self.kind, self.target)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrips_defaults_and_overrides() {
        assert_eq!(TrafficSpec::parse("").unwrap(), TrafficSpec::default());
        let s =
            TrafficSpec::parse("calls=500, rps=250, zipf=0.9, churn=0, drift=0.5, seed=7").unwrap();
        assert_eq!(s.calls, 500);
        assert_eq!(s.rps, 250.0);
        assert_eq!(s.zipf_s, 0.9);
        assert_eq!(s.churn_every, 0);
        assert_eq!(s.drift_at, 0.5);
        assert_eq!(s.seed, 7);
        assert_eq!(s.clients, TrafficSpec::default().clients);
        assert_eq!(s.drift_call(), Some(250));
    }

    #[test]
    fn parse_rejects_bad_input() {
        assert!(TrafficSpec::parse("calls").is_err());
        assert!(TrafficSpec::parse("calls=zero").is_err());
        assert!(TrafficSpec::parse("warp=9").is_err());
        assert!(TrafficSpec::parse("calls=0").is_err());
        assert!(TrafficSpec::parse("burst=0.5").is_err());
        assert!(TrafficSpec::parse("drift=1.5").is_err());
    }

    #[test]
    fn fault_plan_parses_and_schedules() {
        assert_eq!(FaultPlan::parse("").unwrap(), FaultPlan::default());
        let p = FaultPlan::parse("kind=wedge, at=0.25, clear=0.75, target=k.b.n8, factor=50")
            .unwrap();
        assert_eq!(p.kind, FaultKind::Wedge);
        assert_eq!(p.target, "k.b.n8");
        assert_eq!(p.factor, 50.0);
        assert_eq!(p.fire_index(200), 50);
        assert_eq!(p.clear_index(200), Some(150));
        assert_eq!(p.label(), "wedge:k.b.n8");
        let never = FaultPlan::parse("kind=broker_down, at=0.5").unwrap();
        assert_eq!(never.clear_index(200), None);
        assert_eq!(never.label(), "broker_down");
    }

    #[test]
    fn fault_plan_rejects_bad_schedules() {
        assert!(FaultPlan::parse("kind=meteor").is_err());
        assert!(FaultPlan::parse("at=1.5").is_err());
        assert!(FaultPlan::parse("at=0.6, clear=0.4").is_err());
        assert!(FaultPlan::parse("factor=0.5").is_err());
        assert!(FaultPlan::parse("when=now").is_err());
    }
}
