//! Trace generation: spec + problem catalog → arrival-timed call list.
//!
//! The trace is a pure function of ([`TrafficSpec`], catalog order):
//! one seeded PRNG drives problem choice, inter-arrival sampling and
//! burst transitions, so two runs with the same spec replay the exact
//! same workload — the property every A/B comparison in
//! `benches/traffic_replay.rs` rests on.

use std::time::Duration;

use crate::util::prng::Rng;
use crate::workload::{CallSpec, TimedCall, TimedTrace};

use super::TrafficSpec;

/// Generate the arrival-timed trace for `spec` over `catalog` (the
/// orderable universe of problems, e.g. every problem of a manifest in
/// declaration order).
///
/// - **Popularity**: problem `i` of the *active* prefix is drawn with
///   weight `1/(i+1)^zipf_s` — earlier catalog entries are the perennial
///   hot shapes, churned-in entries join the tail.
/// - **Churn**: the active prefix starts at `initial` problems and grows
///   by one every `churn_every` calls until the catalog is exhausted —
///   each growth step is a cold shape arriving mid-run.
/// - **Arrivals**: exponential inter-arrival times at `rps`, modulated
///   by a two-state (normal/burst) chain: bursts multiply the rate by
///   `burst` and last ~`burst_len` calls (geometric), with off periods
///   ~3x longer.
///
/// Panics if `catalog` is empty (a spec without problems is a caller
/// bug, not a runtime condition).
pub fn generate(spec: &TrafficSpec, catalog: &[CallSpec]) -> TimedTrace {
    assert!(!catalog.is_empty(), "traffic generation needs a non-empty problem catalog");
    let mut rng = Rng::seed(spec.seed);
    let mut active = spec.initial.clamp(1, catalog.len());
    let mut weights = zipf_weights(active, spec.zipf_s);
    let mut bursting = false;
    let mut clock = 0.0f64;
    let mut calls = Vec::with_capacity(spec.calls);
    for i in 0..spec.calls {
        // Shape churn: one more catalog problem goes live every
        // `churn_every` calls.
        if spec.churn_every > 0 && i > 0 && i % spec.churn_every == 0 && active < catalog.len() {
            active += 1;
            weights = zipf_weights(active, spec.zipf_s);
        }
        // Burst chain: geometric dwell times in each state.
        let mean_dwell = spec.burst_len.max(1) as f64;
        if bursting {
            if rng.chance(1.0 / mean_dwell) {
                bursting = false;
            }
        } else if rng.chance(1.0 / (3.0 * mean_dwell)) {
            bursting = true;
        }
        let rate = if bursting { spec.rps * spec.burst } else { spec.rps };
        // Exponential inter-arrival; f64() is in [0, 1) so 1-u is in
        // (0, 1] and the log is finite.
        clock += -(1.0 - rng.f64()).ln() / rate;
        let idx = pick_weighted(&mut rng, &weights);
        calls.push(TimedCall {
            at: Duration::from_secs_f64(clock),
            spec: catalog[idx].clone(),
        });
    }
    TimedTrace { calls }
}

/// Unnormalized Zipf weights for ranks `0..active`, prefix-summed into a
/// CDF for O(log n) sampling.
fn zipf_weights(active: usize, s: f64) -> Vec<f64> {
    let mut cdf = Vec::with_capacity(active);
    let mut total = 0.0;
    for rank in 0..active {
        total += 1.0 / ((rank + 1) as f64).powf(s);
        cdf.push(total);
    }
    cdf
}

/// Draw an index from the prefix-sum CDF.
fn pick_weighted(rng: &mut Rng, cdf: &[f64]) -> usize {
    let total = cdf[cdf.len() - 1];
    let u = rng.f64() * total;
    match cdf.binary_search_by(|probe| probe.partial_cmp(&u).unwrap_or(std::cmp::Ordering::Less)) {
        Ok(i) => (i + 1).min(cdf.len() - 1),
        Err(i) => i.min(cdf.len() - 1),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn catalog(n: usize) -> Vec<CallSpec> {
        (0..n).map(|i| CallSpec { kernel: format!("k{i}"), size: 8 }).collect()
    }

    fn counts(trace: &TimedTrace, catalog_len: usize) -> Vec<usize> {
        let mut c = vec![0usize; catalog_len];
        for call in &trace.calls {
            let idx: usize = call.spec.kernel[1..].parse().unwrap();
            c[idx] += 1;
        }
        c
    }

    #[test]
    fn deterministic_per_seed() {
        let spec = TrafficSpec { calls: 500, ..TrafficSpec::default() };
        let cat = catalog(6);
        assert_eq!(generate(&spec, &cat), generate(&spec, &cat));
        let other = TrafficSpec { seed: 43, ..spec };
        assert_ne!(generate(&other, &cat), generate(&spec, &cat));
    }

    #[test]
    fn zipf_skews_toward_head() {
        let spec = TrafficSpec {
            calls: 4000,
            zipf_s: 1.2,
            churn_every: 0,
            initial: 8,
            ..TrafficSpec::default()
        };
        let cat = catalog(8);
        let c = counts(&generate(&spec, &cat), 8);
        assert!(
            c[0] > 3 * c[7].max(1),
            "rank 0 should dominate rank 7: {c:?}"
        );
        assert!(c[0] > c[1], "monotone-ish head: {c:?}");
    }

    #[test]
    fn churn_activates_problems_over_time() {
        let spec = TrafficSpec {
            calls: 1000,
            initial: 2,
            churn_every: 100,
            ..TrafficSpec::default()
        };
        let cat = catalog(5);
        let trace = generate(&spec, &cat);
        // Problems beyond the initial 2 must not appear before their
        // activation call index.
        for (i, call) in trace.calls.iter().enumerate() {
            let idx: usize = call.spec.kernel[1..].parse().unwrap();
            if idx >= 2 {
                assert!(
                    i >= (idx - 1) * 100,
                    "problem {idx} arrived at call {i}, before activation"
                );
            }
        }
        // ... and the whole catalog is live by the end.
        let c = counts(&trace, 5);
        assert!(c.iter().all(|&n| n > 0), "all problems eventually seen: {c:?}");
    }

    #[test]
    fn arrivals_are_monotone_and_roughly_at_rate() {
        let spec = TrafficSpec {
            calls: 2000,
            rps: 1000.0,
            burst: 1.0, // burst state exists but does not change the rate
            ..TrafficSpec::default()
        };
        let trace = generate(&spec, &catalog(3));
        for w in trace.calls.windows(2) {
            assert!(w[1].at >= w[0].at, "arrival times are monotone");
        }
        let span = trace.span().as_secs_f64();
        // 2000 calls at 1000/s ≈ 2s of trace time; exponential noise is
        // ~±2*sqrt(2000)/1000 ≈ 0.09s at 2 sigma — use a wide band.
        assert!((1.5..2.6).contains(&span), "span {span:.3}s for 2s of traffic");
    }

    #[test]
    fn bursts_compress_interarrivals() {
        let base = TrafficSpec {
            calls: 3000,
            rps: 1000.0,
            burst: 1.0,
            churn_every: 0,
            ..TrafficSpec::default()
        };
        let bursty = TrafficSpec { burst: 8.0, ..base.clone() };
        let cat = catalog(3);
        let slow = generate(&base, &cat).span();
        let fast = generate(&bursty, &cat).span();
        assert!(
            fast < slow,
            "burst episodes shorten the trace: burst=8 {fast:?} vs burst=1 {slow:?}"
        );
    }
}
