//! Shared support for the figure benches (`rust/benches/*`).
//!
//! Benches are `harness = false` binaries (criterion is unavailable
//! offline); this module carries the common plumbing: artifact
//! discovery with graceful skip, fresh-dispatcher construction, and the
//! instrumented call loops whose outputs the figures plot.

use std::time::Duration;

use crate::autotuner::Autotuner;
use crate::coordinator::{CallOutcome, CallRoute, Dispatcher, KernelRegistry};
use crate::manifest::Manifest;
use crate::runtime::PjrtEngine;
use crate::tensor::HostTensor;
use crate::workload::inputs_for;
use crate::{Error, Result};

/// Locate the artifacts dir; `None` (with a notice) when not built, so
/// `cargo bench` degrades gracefully instead of failing.
pub fn artifacts_or_skip(bench: &str) -> Option<Manifest> {
    let dir = std::env::var("JITUNE_ARTIFACTS").unwrap_or_else(|_| {
        format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"))
    });
    match Manifest::load(&dir) {
        Ok(m) => Some(m),
        Err(e) => {
            println!("[{bench}] SKIP: {e} (run `make artifacts`)");
            None
        }
    }
}

/// A fresh PJRT-backed dispatcher with the paper's defaults (sweep +
/// wall clock). Each tuning experiment starts from a clean tuner state.
pub fn fresh_dispatcher(manifest: &Manifest) -> Result<Dispatcher> {
    let registry = KernelRegistry::new(manifest.clone());
    let engine = PjrtEngine::cpu()?;
    Ok(Dispatcher::new(registry, Box::new(engine)))
}

/// Same, with a custom strategy factory.
pub fn fresh_dispatcher_with(
    manifest: &Manifest,
    tuner: Autotuner,
) -> Result<Dispatcher> {
    let registry = KernelRegistry::new(manifest.clone());
    let engine = PjrtEngine::cpu()?;
    Ok(Dispatcher::with(
        registry,
        Box::new(engine),
        tuner,
        Box::new(crate::autotuner::WallClock::new()),
    ))
}

/// One instrumented autotuned run: `iters` calls of `kernel` at `size`,
/// returning every call's outcome (timings, routes, variants).
pub fn autotuned_run(
    dispatcher: &mut Dispatcher,
    kernel: &str,
    size: i64,
    iters: usize,
    seed: u64,
) -> Result<Vec<CallOutcome>> {
    let problem = dispatcher.registry().problem(kernel, size)?.clone();
    let inputs = inputs_for(&problem, seed);
    (0..iters).map(|_| dispatcher.call(kernel, &inputs)).collect()
}

/// One instrumented *fused* autotuned run: `rounds` scheduling rounds of
/// `width` co-scheduled calls each, dispatched through
/// [`Dispatcher::call_batch`] — the deterministic stand-in for `width`
/// application threads landing in the same leader round. Returns each
/// round's *wall time* (which, unlike summing the callers' outcomes,
/// includes the caller-less in-round finalize compile when the strategy
/// converges) alongside its outcomes (failures surface as errors in
/// place).
pub fn fused_autotuned_run(
    dispatcher: &mut Dispatcher,
    kernel: &str,
    size: i64,
    rounds: usize,
    width: usize,
    seed: u64,
) -> Result<Vec<(Duration, Vec<Result<CallOutcome>>)>> {
    let problem = dispatcher.registry().problem(kernel, size)?.clone();
    let inputs = inputs_for(&problem, seed);
    Ok((0..rounds)
        .map(|_| {
            let batch: Vec<_> = (0..width.max(1)).map(|_| inputs.clone()).collect();
            let t0 = std::time::Instant::now();
            let outcomes = dispatcher.call_batch(kernel, batch);
            (t0.elapsed(), outcomes)
        })
        .collect())
}

/// Cumulative per-call seconds from a run's outcomes.
pub fn cumulative(outcomes: &[CallOutcome]) -> Vec<f64> {
    let mut acc = 0.0;
    outcomes
        .iter()
        .map(|o| {
            acc += o.total.as_secs_f64();
            acc
        })
        .collect()
}

/// Index of the first call routed `Tuned` (steady state begins).
pub fn steady_start(outcomes: &[CallOutcome]) -> Option<usize> {
    outcomes.iter().position(|o| o.route == CallRoute::Tuned)
}

/// Measure one variant's steady execution time: compile (untimed), then
/// `reps` timed executions, returning the minimum (the paper keeps best
/// samples).
pub fn steady_exec_time(
    manifest: &Manifest,
    cache: &mut crate::runtime::CompileCache,
    variant: &crate::manifest::Variant,
    inputs: &[HostTensor],
    reps: usize,
) -> Result<Duration> {
    let (exe, _) = cache.get_or_compile(manifest, variant)?;
    let mut best = Duration::MAX;
    for _ in 0..reps {
        let t = std::time::Instant::now();
        exe.execute(inputs)?;
        best = best.min(t.elapsed());
    }
    if best == Duration::MAX {
        return Err(Error::Autotune("no reps".into()));
    }
    Ok(best)
}

/// Env-tunable repetition count (`JITUNE_BENCH_REPEATS`), default `d`.
pub fn repeats(d: usize) -> usize {
    std::env::var("JITUNE_BENCH_REPEATS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(d)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::CallRoute;
    use std::time::Duration;

    fn outcome(ms: u64, route: CallRoute) -> CallOutcome {
        CallOutcome {
            output: HostTensor::zeros(&[1]),
            variant_id: "v".into(),
            value: 0,
            route,
            compiled: false,
            exec_cost: 0.0,
            total: Duration::from_millis(ms),
        }
    }

    #[test]
    fn cumulative_and_steady_start() {
        let outcomes = vec![
            outcome(10, CallRoute::Explored),
            outcome(10, CallRoute::Finalized),
            outcome(1, CallRoute::Tuned),
        ];
        let cum = cumulative(&outcomes);
        assert_eq!(cum.len(), 3);
        assert!((cum[2] - 0.021).abs() < 1e-9);
        assert_eq!(steady_start(&outcomes), Some(2));
    }

    #[test]
    fn repeats_env_default() {
        assert_eq!(repeats(7), 7);
    }
}
