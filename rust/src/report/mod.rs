//! Figure/table emission: every bench writes a CSV (machine-readable)
//! and an ASCII chart (human-readable) under `target/figures/`.

pub mod bench;

use std::path::PathBuf;

use crate::error::{Error, Result};
use crate::util::chart;

/// Where figures land (`target/figures/` next to the workspace root).
pub fn figures_dir() -> PathBuf {
    let base = std::env::var("CARGO_MANIFEST_DIR")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("."));
    base.join("target").join("figures")
}

/// Write `content` to `target/figures/<name>` (creating directories).
pub fn write_figure_file(name: &str, content: &str) -> Result<PathBuf> {
    let dir = figures_dir();
    std::fs::create_dir_all(&dir).map_err(|e| Error::io(dir.display().to_string(), e))?;
    let path = dir.join(name);
    std::fs::write(&path, content).map_err(|e| Error::io(path.display().to_string(), e))?;
    Ok(path)
}

/// Emit one figure: CSV + ASCII chart, returning the rendered chart so
/// benches can also print it to stdout.
pub struct Figure {
    /// Stem for output files (`fig1`, `fig3_n128`, ...).
    pub stem: String,
    /// Chart title.
    pub title: String,
    /// CSV header.
    pub header: Vec<String>,
    /// CSV rows.
    pub rows: Vec<Vec<String>>,
    /// Chart series.
    pub series: Vec<chart::Series>,
    /// Log-scale y axis (the paper's Fig 2).
    pub log_y: bool,
}

impl Figure {
    /// Write the CSV and chart files; returns the rendered ASCII chart.
    pub fn emit(&self) -> Result<String> {
        let header: Vec<&str> = self.header.iter().map(String::as_str).collect();
        let csv = chart::csv(&header, &self.rows);
        write_figure_file(&format!("{}.csv", self.stem), &csv)?;
        let rendered = chart::render(&self.title, &self.series, 72, 20, self.log_y);
        write_figure_file(&format!("{}.txt", self.stem), &rendered)?;
        Ok(rendered)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn figure_emits_csv_and_chart() {
        let fig = Figure {
            stem: "zz_selftest".into(),
            title: "test".into(),
            header: vec!["x".into(), "y".into()],
            rows: vec![vec!["1".into(), "2".into()]],
            series: vec![chart::Series::new("s", vec![(1.0, 2.0), (2.0, 4.0)])],
            log_y: false,
        };
        let rendered = fig.emit().unwrap();
        assert!(rendered.contains("## test"));
        let csv_path = figures_dir().join("zz_selftest.csv");
        let content = std::fs::read_to_string(&csv_path).unwrap();
        assert_eq!(content, "x,y\n1,2\n");
        // clean up so bench figure listings stay tidy
        let _ = std::fs::remove_file(csv_path);
        let _ = std::fs::remove_file(figures_dir().join("zz_selftest.txt"));
    }
}
