//! Mini property-testing framework (proptest is unavailable offline).
//!
//! Provides seeded generators and a `forall` runner with shrinking for
//! integer-vector inputs. Deliberately small: enough to express the
//! repo's invariant suites (`rust/tests/autotuner_props.rs`), fully
//! deterministic, zero dependencies.

use std::sync::Arc;

use crate::coordinator::{Coordinator, Dispatcher, KernelRegistry, PoolOptions, ServerOptions};
use crate::manifest::Manifest;
use crate::runtime::mock::{MockEngineFactory, MockSpec};
use crate::runtime::EngineFactory;
use crate::util::prng::Rng;

/// Process-wide uniquifier for temp artifacts (sockets, state files,
/// synthetic-manifest dirs): pid gives cross-process uniqueness, the
/// counter intra-process uniqueness.
fn next_uniq() -> u64 {
    use std::sync::atomic::{AtomicU64, Ordering};
    // relaxed-counter: unique-suffix sequence, never synchronizes
    static COUNTER: AtomicU64 = AtomicU64::new(0);
    COUNTER.fetch_add(1, Ordering::Relaxed)
}

/// A unique scratch path under the system temp dir:
/// `jitune-<tag>-<pid>-<seq>.<ext>`. Shared by every test/bench/example
/// that needs a hub socket or scratch file, so naming (and its
/// collision-avoidance) lives in one place.
pub fn temp_path(tag: &str, ext: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!(
        "jitune-{tag}-{}-{}.{ext}",
        std::process::id(),
        next_uniq()
    ))
}

/// A synthetic manifest: `variants` interchangeable variants of one
/// kernel at each of `sizes`, backed by dummy HLO files in a unique temp
/// directory (the mock engine never parses them). Variant `i` carries
/// tuning value `i` and id `{kernel}.v{i}.n{size}` — shared by the
/// fast-lane stress tests, the throughput-scaling bench and the
/// mock-backed serving example.
pub fn synthetic_manifest(kernel: &str, variants: usize, sizes: &[i64]) -> crate::Result<Manifest> {
    let dir = std::env::temp_dir().join(format!(
        "jitune-synth-{}-{}",
        std::process::id(),
        next_uniq()
    ));
    std::fs::create_dir_all(&dir).map_err(|e| crate::Error::io(dir.display().to_string(), e))?;
    let mut entries = Vec::new();
    for &size in sizes {
        for i in 0..variants {
            let id = format!("{kernel}.v{i}.n{size}");
            std::fs::write(dir.join(format!("{id}.hlo.txt")), "HloModule dummy\n")
                .map_err(|e| crate::Error::io(id.clone(), e))?;
            entries.push(format!(
                r#"{{"id":"{id}","kernel":"{kernel}","param":"p","value":{i},"label":"v{i}","size":{size},"inputs":["f32[{size},{size}]"],"output":"f32[{size},{size}]","path":"{id}.hlo.txt","flops":100}}"#
            ));
        }
    }
    let text =
        format!(r#"{{"schema":1,"jax_version":"synthetic","entries":[{}]}}"#, entries.join(","));
    Manifest::from_json_str(&text, dir)
}

/// Spawn a coordinator over a synthetic manifest whose engines all come
/// from a *pinned* mock factory (kernels refuse `shared()`), with a
/// worker pool of `workers` attached — the standard fixture for forcing
/// tuned calls onto the pool path in tests and benches. The leader's
/// dispatcher engine comes from the same factory, so the shared fast
/// lane can never serve and every tuned call is pool-or-leader.
///
/// `opts.pool` is overwritten; customize other fields (drift, batching)
/// freely. Spawn manually for a custom queue depth or a non-pinned
/// factory.
pub fn spawn_pooled_mock(
    kernel: &str,
    variants: usize,
    sizes: &[i64],
    spec: MockSpec,
    workers: usize,
    mut opts: ServerOptions,
) -> crate::Result<Coordinator> {
    let factory = Arc::new(MockEngineFactory::pinned(spec));
    let leader_factory: Arc<dyn EngineFactory> = factory.clone();
    opts.pool = Some(PoolOptions::new(factory).with_workers(workers));
    let kernel = kernel.to_string();
    let sizes = sizes.to_vec();
    Coordinator::spawn_with_options(
        move || {
            let manifest = synthetic_manifest(&kernel, variants, &sizes)?;
            let registry = KernelRegistry::new(manifest);
            Ok(Dispatcher::new(registry, leader_factory.create()?))
        },
        opts,
    )
}

/// A generator of random values of `T`.
pub trait Gen<T> {
    /// Produce one value.
    fn generate(&self, rng: &mut Rng) -> T;
}

impl<T, F: Fn(&mut Rng) -> T> Gen<T> for F {
    fn generate(&self, rng: &mut Rng) -> T {
        self(rng)
    }
}

/// Uniform integer in [lo, hi].
pub fn int_range(lo: i64, hi: i64) -> impl Gen<i64> {
    move |rng: &mut Rng| rng.range_i64(lo, hi)
}

/// Uniform f64 in [lo, hi).
pub fn f64_range(lo: f64, hi: f64) -> impl Gen<f64> {
    move |rng: &mut Rng| lo + rng.f64() * (hi - lo)
}

/// Vector of `len ∈ [min_len, max_len]` values from `inner`.
pub fn vec_of<T, G: Gen<T>>(inner: G, min_len: usize, max_len: usize) -> impl Gen<Vec<T>> {
    move |rng: &mut Rng| {
        let len = min_len + rng.below(max_len - min_len + 1);
        (0..len).map(|_| inner.generate(rng)).collect()
    }
}

/// Configuration for a property run.
#[derive(Debug, Clone)]
pub struct PropConfig {
    /// Number of random cases.
    pub cases: usize,
    /// Base seed (each case derives `seed + case_index`).
    pub seed: u64,
}

impl Default for PropConfig {
    fn default() -> Self {
        PropConfig { cases: 100, seed: 0x1234_5678 }
    }
}

/// Run `prop` on `cases` generated inputs; panics with the seed and a
/// debug rendering of the (shrunk, when possible) counterexample.
pub fn forall<T: Clone + std::fmt::Debug, G: Gen<T>>(
    config: &PropConfig,
    gen: G,
    prop: impl Fn(&T) -> bool,
) {
    for case in 0..config.cases {
        let mut rng = Rng::seed(config.seed.wrapping_add(case as u64));
        let input = gen.generate(&mut rng);
        if !prop(&input) {
            panic!(
                "property failed at case {case} (seed {}):\n  input: {input:?}",
                config.seed.wrapping_add(case as u64)
            );
        }
    }
}

/// `forall` specialized to `Vec<i64>` with element-drop + value-halving
/// shrinking on failure: reports the smallest failing vector found.
pub fn forall_vec_i64(
    config: &PropConfig,
    gen: impl Gen<Vec<i64>>,
    prop: impl Fn(&[i64]) -> bool,
) {
    for case in 0..config.cases {
        let mut rng = Rng::seed(config.seed.wrapping_add(case as u64));
        let input = gen.generate(&mut rng);
        if !prop(&input) {
            let shrunk = shrink_vec(&input, &prop);
            panic!(
                "property failed at case {case} (seed {}):\n  original: {input:?}\n  shrunk:   {shrunk:?}",
                config.seed.wrapping_add(case as u64)
            );
        }
    }
}

/// Greedy shrink: repeatedly try dropping one element or halving one
/// value while the property still fails.
fn shrink_vec(failing: &[i64], prop: &impl Fn(&[i64]) -> bool) -> Vec<i64> {
    let mut current = failing.to_vec();
    let mut improved = true;
    while improved {
        improved = false;
        // try dropping each element
        for i in 0..current.len() {
            let mut candidate = current.clone();
            candidate.remove(i);
            if !candidate.is_empty() && !prop(&candidate) {
                current = candidate;
                improved = true;
                break;
            }
        }
        if improved {
            continue;
        }
        // try halving each element toward zero
        for i in 0..current.len() {
            if current[i].abs() > 1 {
                let mut candidate = current.clone();
                candidate[i] /= 2;
                if !prop(&candidate) {
                    current = candidate;
                    improved = true;
                    break;
                }
            }
        }
    }
    current
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_manifest_loads_and_groups() {
        let m = synthetic_manifest("kern", 3, &[8, 16]).unwrap();
        assert_eq!(m.variants.len(), 6);
        assert_eq!(m.problems.len(), 2);
        let p = m.problem("kern", 8).unwrap();
        assert_eq!(p.variants.len(), 3);
        assert_eq!(p.variants[1].value, 1);
        // artifact files exist so CompileCache can read them
        for v in &m.variants {
            assert!(m.artifact_path(v).exists(), "missing {}", v.path);
        }
    }

    #[test]
    fn forall_passes_true_property() {
        forall(&PropConfig::default(), int_range(0, 100), |&x| (0..=100).contains(&x));
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn forall_reports_failure() {
        forall(&PropConfig { cases: 200, seed: 1 }, int_range(0, 100), |&x| x < 90);
    }

    #[test]
    fn generators_are_deterministic_per_seed() {
        let g = vec_of(int_range(-5, 5), 1, 8);
        let mut a = Rng::seed(9);
        let mut b = Rng::seed(9);
        assert_eq!(g.generate(&mut a), g.generate(&mut b));
    }

    #[test]
    fn shrinking_finds_small_counterexample() {
        // property: no element is >= 50. Failing vectors shrink toward a
        // single offending element.
        let failing = vec![3, 120, 7, 64];
        let shrunk = shrink_vec(&failing, &|v: &[i64]| v.iter().all(|&x| x < 50));
        assert_eq!(shrunk.len(), 1);
        assert!(shrunk[0] >= 50);
        // halving shrinks the value close to the boundary
        assert!(shrunk[0] <= 120);
    }

    #[test]
    fn vec_gen_respects_bounds() {
        let g = vec_of(int_range(1, 3), 2, 5);
        let mut rng = Rng::seed(4);
        for _ in 0..100 {
            let v = g.generate(&mut rng);
            assert!((2..=5).contains(&v.len()));
            assert!(v.iter().all(|x| (1..=3).contains(x)));
        }
    }

    #[test]
    fn f64_range_bounds() {
        let g = f64_range(-2.0, 3.0);
        let mut rng = Rng::seed(5);
        for _ in 0..1000 {
            let x = g.generate(&mut rng);
            assert!((-2.0..3.0).contains(&x));
        }
    }
}
