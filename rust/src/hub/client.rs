//! Hub client: connect-with-retry plus a tiny request/reply layer with
//! one transparent reconnect per request.

use std::os::unix::net::UnixStream;
use std::path::{Path, PathBuf};
use std::time::Duration;

use crate::error::Result;

use super::protocol::{proto_err, read_frame, write_frame, Frame, HubEntry, PROTOCOL_VERSION};

/// Hub connection configuration (`ServerOptions { hub: Some(..) }`).
#[derive(Debug, Clone)]
pub struct HubOptions {
    /// Unix-domain socket the broker listens on.
    pub socket: PathBuf,
    /// Connection attempts before giving up (covers the race of a fleet
    /// starting alongside its broker).
    pub connect_retries: u32,
    /// Delay between connection attempts.
    pub retry_delay: Duration,
    /// Per-request read/write timeout — a wedged broker must not hang
    /// the leader thread.
    pub io_timeout: Duration,
    /// Periodically pull the tuned map and adopt newer winners while
    /// serving. `None` pulls only at startup (plus explicit
    /// `hub_pull` calls).
    pub pull_interval: Option<Duration>,
    /// Peer name sent in `Hello` (diagnostics only).
    pub peer: String,
}

impl HubOptions {
    /// Defaults for a broker at `socket`: 40 × 25ms connect budget
    /// (~1s), 5s io timeout, no periodic pull.
    pub fn at(socket: impl AsRef<Path>) -> HubOptions {
        HubOptions {
            socket: socket.as_ref().to_path_buf(),
            connect_retries: 40,
            retry_delay: Duration::from_millis(25),
            io_timeout: Duration::from_secs(5),
            pull_interval: None,
            peer: format!("jitune-{}", std::process::id()),
        }
    }
}

/// Publish outcome as acknowledged by the broker.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PublishAck {
    /// Version the entry is stored under.
    pub version: u64,
    /// Whether the broker resolved a version conflict (another process
    /// published the same problem concurrently).
    pub conflict: bool,
}

/// A connected hub client.
pub struct HubClient {
    opts: HubOptions,
    stream: UnixStream,
    generation: u64,
}

impl HubClient {
    /// Connect (with retry) and complete the `Hello` handshake.
    pub fn connect(opts: HubOptions) -> Result<HubClient> {
        let stream = dial(&opts, opts.connect_retries)?;
        Ok(HubClient { opts, stream, generation: 0 })
    }

    /// Options this client was built with.
    pub fn options(&self) -> &HubOptions {
        &self.opts
    }

    /// Connection generation: bumped every time the client had to redial
    /// after a dead stream. A change signals the broker may have
    /// restarted (and, being in-memory, lost its map) — callers caching
    /// per-entry versions must drop that cache and resynchronize.
    pub fn generation(&self) -> u64 {
        self.generation
    }

    /// Fetch the broker's full tuned map.
    pub fn pull_all(&mut self) -> Result<Vec<HubEntry>> {
        match self.request(&Frame::PullAll)? {
            Frame::Update { entries } => Ok(entries),
            other => Err(proto_err(format!("expected update, got {other:?}"))),
        }
    }

    /// Publish one winner; returns the broker's merge acknowledgement.
    pub fn publish(&mut self, entry: &HubEntry) -> Result<PublishAck> {
        match self.request(&Frame::Publish { entry: entry.clone() })? {
            Frame::Ack { version, conflict } => Ok(PublishAck { version, conflict }),
            other => Err(proto_err(format!("expected ack, got {other:?}"))),
        }
    }

    /// One request/reply round-trip. A dead stream (broker restarted,
    /// socket dropped) gets one transparent redial before the error
    /// surfaces — a *single* immediate attempt, not the full startup
    /// retry budget: requests run on the coordinator's leader thread,
    /// and a down broker must degrade serving to a warning, not stall
    /// every queued call behind a retry sleep loop. A *timeout* is not
    /// redialed at all: the broker is wedged, not gone, and a redial
    /// would both double the stall (another `io_timeout` on the
    /// handshake) and re-send a request that may already have applied.
    fn request(&mut self, frame: &Frame) -> Result<Frame> {
        match round_trip(&mut self.stream, frame) {
            Ok(reply) => Ok(reply),
            Err(e) if is_timeout(&e) => {
                // the reply may still arrive late and would desynchronize
                // the stream (the next request would read *this* one's
                // answer): kill the stream so the next request starts
                // from a clean redial instead of a stale frame
                let _ = self.stream.shutdown(std::net::Shutdown::Both);
                Err(e)
            }
            Err(first) => {
                log::debug!("hub: request failed ({first}); redialing");
                self.stream = dial(&self.opts, 0)?;
                self.generation = self.generation.wrapping_add(1);
                round_trip(&mut self.stream, frame)
            }
        }
    }

    /// Test hook: kill the live stream to exercise the redial path.
    #[cfg(test)]
    pub(crate) fn shutdown_stream_for_test(&mut self) {
        let _ = self.stream.shutdown(std::net::Shutdown::Both);
    }
}

fn round_trip(stream: &mut UnixStream, frame: &Frame) -> Result<Frame> {
    write_frame(stream, frame)?;
    read_frame(stream)
}

/// Whether a request failure was the io-timeout set on the stream
/// (`SO_RCVTIMEO`/`SO_SNDTIMEO` surface as `WouldBlock` or `TimedOut`).
fn is_timeout(e: &crate::Error) -> bool {
    use std::io::ErrorKind;
    matches!(e, crate::Error::Io { source, .. }
        if matches!(source.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut))
}

/// Connect (with up to `retries` re-attempts) and shake hands.
fn dial(opts: &HubOptions, retries: u32) -> Result<UnixStream> {
    let mut last: Option<std::io::Error> = None;
    for attempt in 0..=retries {
        if attempt > 0 {
            std::thread::sleep(opts.retry_delay);
        }
        match UnixStream::connect(&opts.socket) {
            Ok(mut stream) => {
                stream
                    .set_read_timeout(Some(opts.io_timeout))
                    .and_then(|()| stream.set_write_timeout(Some(opts.io_timeout)))
                    .map_err(|e| proto_err(format!("set timeout: {e}")))?;
                let hello = Frame::Hello { protocol: PROTOCOL_VERSION, peer: opts.peer.clone() };
                match round_trip(&mut stream, &hello)? {
                    Frame::HelloAck { protocol, entries } => {
                        if protocol != PROTOCOL_VERSION {
                            return Err(proto_err(format!(
                                "protocol mismatch: broker v{protocol}, client v{PROTOCOL_VERSION}"
                            )));
                        }
                        log::debug!(
                            "hub: connected to {} ({entries} entries held)",
                            opts.socket.display()
                        );
                        return Ok(stream);
                    }
                    other => return Err(proto_err(format!("expected hello_ack, got {other:?}"))),
                }
            }
            Err(e) => last = Some(e),
        }
    }
    Err(proto_err(format!(
        "cannot reach broker at {} after {} attempt(s): {}",
        opts.socket.display(),
        retries + 1,
        last.map(|e| e.to_string()).unwrap_or_else(|| "no attempt made".into()),
    )))
}
