//! Hub client: connect-with-retry plus a tiny request/reply layer with
//! one transparent reconnect per request, and a push subscriber
//! ([`HubSubscriber`]) that receives broker updates without polling.

use std::path::Path;
use std::time::Duration;

use crate::error::Result;

use super::protocol::{
    proto_err, read_frame, write_frame, Frame, HubEntry, MAX_FRAME_BYTES, PROTOCOL_VERSION,
};
use super::transport::{HubAddr, HubStream};

/// Hub connection configuration (`ServerOptions { hub: Some(..) }`).
#[derive(Debug, Clone)]
pub struct HubOptions {
    /// Broker address: Unix socket ([`HubOptions::at`]) or TCP
    /// ([`HubOptions::tcp`]).
    pub addr: HubAddr,
    /// Connection attempts before giving up (covers the race of a fleet
    /// starting alongside its broker).
    pub connect_retries: u32,
    /// Delay between connection attempts.
    pub retry_delay: Duration,
    /// Per-request read/write timeout — a wedged broker must not hang
    /// the leader thread.
    pub io_timeout: Duration,
    /// Periodically pull the tuned map and adopt newer winners while
    /// serving. With push-notify subscribed this is the *fallback*
    /// propagation path; `None` pulls only at startup (plus explicit
    /// `hub_pull` calls).
    pub pull_interval: Option<Duration>,
    /// Subscribe a push channel: the broker pushes every accepted
    /// publish, and the coordinator pulls on each push instead of
    /// waiting for the next `pull_interval` tick.
    pub subscribe: bool,
    /// Peer name sent in `Hello` (diagnostics only).
    pub peer: String,
}

impl HubOptions {
    /// Defaults for a broker at a Unix socket: 40 × 25ms connect budget
    /// (~1s), 5s io timeout, no periodic pull, no push subscription.
    pub fn at(socket: impl AsRef<Path>) -> HubOptions {
        HubOptions::for_addr(HubAddr::Unix(socket.as_ref().to_path_buf()))
    }

    /// Same defaults for a broker at a TCP `host:port`.
    pub fn tcp(addr: impl Into<String>) -> HubOptions {
        HubOptions::for_addr(HubAddr::Tcp(addr.into()))
    }

    /// Defaults for an already-parsed address.
    pub fn for_addr(addr: HubAddr) -> HubOptions {
        HubOptions {
            addr,
            connect_retries: 40,
            retry_delay: Duration::from_millis(25),
            io_timeout: Duration::from_secs(5),
            pull_interval: None,
            subscribe: false,
            peer: format!("jitune-{}", std::process::id()),
        }
    }
}

/// Publish outcome as acknowledged by the broker.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PublishAck {
    /// Version the entry is stored under.
    pub version: u64,
    /// Whether the broker resolved a version conflict (another process
    /// published the same problem concurrently).
    pub conflict: bool,
}

/// A connected hub client.
pub struct HubClient {
    opts: HubOptions,
    stream: HubStream,
    generation: u64,
}

impl HubClient {
    /// Connect (with retry) and complete the `Hello` handshake.
    pub fn connect(opts: HubOptions) -> Result<HubClient> {
        let stream = dial(&opts, opts.connect_retries)?;
        Ok(HubClient { opts, stream, generation: 0 })
    }

    /// Options this client was built with.
    pub fn options(&self) -> &HubOptions {
        &self.opts
    }

    /// Connection generation: bumped every time the client had to redial
    /// after a dead stream. A change signals the broker may have
    /// restarted (and, unless persistent, lost its map) — callers
    /// caching per-entry versions must drop that cache and
    /// resynchronize.
    pub fn generation(&self) -> u64 {
        self.generation
    }

    /// Fetch the broker's full tuned map.
    pub fn pull_all(&mut self) -> Result<Vec<HubEntry>> {
        match self.request(&Frame::PullAll)? {
            Frame::Update { entries } => Ok(entries),
            other => Err(proto_err(format!("expected update, got {other:?}"))),
        }
    }

    /// Publish one winner; returns the broker's merge acknowledgement.
    pub fn publish(&mut self, entry: &HubEntry) -> Result<PublishAck> {
        match self.request(&Frame::Publish { entry: entry.clone() })? {
            Frame::Ack { version, conflict } => Ok(PublishAck { version, conflict }),
            other => Err(proto_err(format!("expected ack, got {other:?}"))),
        }
    }

    /// One request/reply round-trip. A dead stream (broker restarted,
    /// socket dropped) gets one transparent redial before the error
    /// surfaces — a *single* immediate attempt, not the full startup
    /// retry budget: requests run on the coordinator's leader thread,
    /// and a down broker must degrade serving to a warning, not stall
    /// every queued call behind a retry sleep loop. A *timeout* is not
    /// redialed at all: the broker is wedged, not gone, and a redial
    /// would both double the stall (another `io_timeout` on the
    /// handshake) and re-send a request that may already have applied.
    fn request(&mut self, frame: &Frame) -> Result<Frame> {
        match round_trip(&mut self.stream, frame) {
            Ok(reply) => Ok(reply),
            Err(e) if is_timeout(&e) => {
                // the reply may still arrive late and would desynchronize
                // the stream (the next request would read *this* one's
                // answer): kill the stream so the next request starts
                // from a clean redial instead of a stale frame
                self.stream.shutdown();
                Err(e)
            }
            Err(first) => {
                log::debug!("hub: request failed ({first}); redialing");
                self.stream = dial(&self.opts, 0)?;
                self.generation = self.generation.wrapping_add(1);
                round_trip(&mut self.stream, frame)
            }
        }
    }

    /// Test hook: kill the live stream to exercise the redial path.
    #[cfg(test)]
    pub(crate) fn shutdown_stream_for_test(&mut self) {
        self.stream.shutdown();
    }
}

/// A push-subscribed hub connection: the broker pushes every accepted
/// publish as an `Update` frame. Built for a dedicated notifier thread
/// — [`HubSubscriber::next`] polls with a bounded wait so the thread
/// can check its stop flag between frames, and partial frames survive
/// across calls (a timeout mid-frame resumes cleanly).
pub struct HubSubscriber {
    stream: HubStream,
    pending: Vec<u8>,
    initial: Vec<HubEntry>,
}

impl HubSubscriber {
    /// Connect (with retry), shake hands, and subscribe. The broker
    /// replies with its full map, retrievable once via
    /// [`HubSubscriber::take_initial`].
    pub fn connect(opts: &HubOptions) -> Result<HubSubscriber> {
        let mut stream = dial(opts, opts.connect_retries)?;
        write_frame(&mut stream, &Frame::Subscribe { peer: opts.peer.clone() })?;
        // the broker registers the push channel before replying, so an
        // Update can legitimately overtake the Subscribed frame
        let mut early: Vec<HubEntry> = Vec::new();
        let mut initial = loop {
            match read_frame(&mut stream)? {
                Frame::Subscribed { entries } => break entries,
                Frame::Update { entries } => early.extend(entries),
                other => return Err(proto_err(format!("expected subscribed, got {other:?}"))),
            }
        };
        initial.extend(early);
        Ok(HubSubscriber { stream, pending: Vec::new(), initial })
    }

    /// The broker's map as of subscription (plus any update that raced
    /// the handshake). Empties on first call.
    pub fn take_initial(&mut self) -> Vec<HubEntry> {
        std::mem::take(&mut self.initial)
    }

    /// Wait up to `wait` for one pushed update. `Ok(None)` is a clean
    /// timeout (check your stop flag and call again); an error means
    /// the push channel is gone and the subscriber must reconnect.
    pub fn next(&mut self, wait: Duration) -> Result<Option<Vec<HubEntry>>> {
        self.stream
            .set_read_timeout(Some(wait.max(Duration::from_millis(1))))
            .map_err(|e| proto_err(format!("subscriber timeout: {e}")))?;
        let mut chunk = [0u8; 4096];
        loop {
            if let Some(frame) = self.decode_buffered()? {
                return match frame {
                    Frame::Update { entries } => Ok(Some(entries)),
                    other => Err(proto_err(format!("unexpected push frame {other:?}"))),
                };
            }
            match std::io::Read::read(&mut self.stream, &mut chunk) {
                Ok(0) => return Err(proto_err("push channel closed by broker")),
                Ok(n) => self.pending.extend_from_slice(&chunk[..n]),
                Err(e)
                    if matches!(
                        e.kind(),
                        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                    ) =>
                {
                    return Ok(None)
                }
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(e) => return Err(crate::Error::io("hub push channel".into(), e)),
            }
        }
    }

    /// Decode one frame out of the partial-read buffer, if complete.
    fn decode_buffered(&mut self) -> Result<Option<Frame>> {
        if self.pending.len() < 4 {
            return Ok(None);
        }
        let len =
            u32::from_be_bytes([self.pending[0], self.pending[1], self.pending[2], self.pending[3]])
                as usize;
        if len == 0 || len > MAX_FRAME_BYTES {
            return Err(proto_err(format!("bad push frame length {len}")));
        }
        if self.pending.len() < 4 + len {
            return Ok(None);
        }
        let frame = {
            let mut slice = &self.pending[..4 + len];
            read_frame(&mut slice)?
        };
        self.pending.drain(..4 + len);
        Ok(Some(frame))
    }
}

fn round_trip(stream: &mut HubStream, frame: &Frame) -> Result<Frame> {
    write_frame(stream, frame)?;
    read_frame(stream)
}

/// Whether a request failure was the io-timeout set on the stream
/// (`SO_RCVTIMEO`/`SO_SNDTIMEO` surface as `WouldBlock` or `TimedOut`).
fn is_timeout(e: &crate::Error) -> bool {
    use std::io::ErrorKind;
    matches!(e, crate::Error::Io { source, .. }
        if matches!(source.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut))
}

/// Connect (with up to `retries` re-attempts) and shake hands.
fn dial(opts: &HubOptions, retries: u32) -> Result<HubStream> {
    let mut last: Option<std::io::Error> = None;
    for attempt in 0..=retries {
        if attempt > 0 {
            std::thread::sleep(opts.retry_delay);
        }
        match HubStream::connect(&opts.addr) {
            Ok(mut stream) => {
                stream
                    .set_timeouts(Some(opts.io_timeout))
                    .map_err(|e| proto_err(format!("set timeout: {e}")))?;
                let hello = Frame::Hello { protocol: PROTOCOL_VERSION, peer: opts.peer.clone() };
                match round_trip(&mut stream, &hello)? {
                    Frame::HelloAck { protocol, entries } => {
                        if protocol != PROTOCOL_VERSION {
                            return Err(proto_err(format!(
                                "protocol mismatch: broker v{protocol}, client v{PROTOCOL_VERSION}"
                            )));
                        }
                        log::debug!("hub: connected to {} ({entries} entries held)", opts.addr);
                        return Ok(stream);
                    }
                    other => return Err(proto_err(format!("expected hello_ack, got {other:?}"))),
                }
            }
            Err(e) => last = Some(e),
        }
    }
    Err(proto_err(format!(
        "cannot reach broker at {} after {} attempt(s): {}",
        opts.addr,
        retries + 1,
        last.map(|e| e.to_string()).unwrap_or_else(|| "no attempt made".into()),
    )))
}
