//! Broker persistence: an append-only entry log plus periodic snapshot
//! compaction, so a restarted broker comes back with the fleet's
//! winners instead of an empty map.
//!
//! # Durability model
//!
//! The persist directory holds two files:
//!
//! * `snapshot.json` — the full tuned map as a JSON array of entries
//!   (the same shape `save_state` writes), rewritten atomically via
//!   [`crate::util::atomic_write`] (tmp sibling + fsync file *and*
//!   parent directory + rename).
//! * `entries.log` — one record per accepted publish, appended and
//!   `fdatasync`ed **before** the broker acks, so an acked publish is
//!   on disk. A record is `[u32 BE body-len][u32 BE crc32(body)][body]`
//!   where the body is the entry's JSON.
//!
//! Replay on [`HubLog::open`] loads the snapshot, then folds every log
//! record through [`merge_entry`] — the same last-writer-wins rule the
//! live broker applies, so replay is idempotent and order-tolerant. A
//! torn tail record (crash mid-append: short header, short body, crc
//! mismatch, or unparseable JSON) is detected, logged, and truncated
//! away; everything before it is kept. Once the log grows past
//! `compact_every` records, the map is snapshotted and the log reset —
//! a crash between those two steps only re-replays records the
//! snapshot already holds, which LWW merging absorbs.

use std::collections::BTreeMap;
use std::fs::{File, OpenOptions};
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};

use crate::error::{Error, Result};
use crate::util::atomic_write;
use crate::util::json::Value;

use super::protocol::{merge_entry, EntryKey, HubEntry, MAX_FRAME_BYTES};

/// Snapshot file name inside the persist directory.
pub const SNAPSHOT_FILE: &str = "snapshot.json";
/// Append-only log file name inside the persist directory.
pub const LOG_FILE: &str = "entries.log";

/// Bytes of record framing ahead of each body: length + checksum.
const RECORD_HEADER: usize = 8;

/// Persistence configuration for a broker.
#[derive(Debug, Clone)]
pub struct PersistOptions {
    /// Directory holding `snapshot.json` + `entries.log` (created on
    /// open).
    pub dir: PathBuf,
    /// Snapshot-compact the log every N appended records; 0 disables
    /// compaction (the log grows unboundedly — tests only).
    pub compact_every: u64,
}

impl PersistOptions {
    /// Defaults for a persist directory: compact every 256 records.
    pub fn at(dir: impl AsRef<Path>) -> PersistOptions {
        PersistOptions { dir: dir.as_ref().to_path_buf(), compact_every: 256 }
    }
}

/// What replay found when opening a persist directory.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ReplayReport {
    /// Entries restored from the snapshot.
    pub snapshot_entries: usize,
    /// Valid log records folded in after the snapshot.
    pub log_records: usize,
    /// Bytes of torn/corrupt tail discarded (0 on a clean log).
    pub truncated_bytes: u64,
}

/// An open broker log: owns the append handle and the compaction
/// counter. The in-memory map itself lives with the caller (the broker
/// holds it under its own lock).
pub struct HubLog {
    dir: PathBuf,
    file: File,
    compact_every: u64,
    records_since_snapshot: u64,
}

impl HubLog {
    /// Open (creating if needed) a persist directory: load the
    /// snapshot, replay the log — truncating a torn tail — and return
    /// the restored map plus a replay report.
    pub fn open(opts: &PersistOptions) -> Result<(HubLog, BTreeMap<EntryKey, HubEntry>, ReplayReport)> {
        std::fs::create_dir_all(&opts.dir)
            .map_err(|e| Error::io(opts.dir.display().to_string(), e))?;
        let mut map = BTreeMap::new();
        let mut report = ReplayReport::default();

        let snap_path = opts.dir.join(SNAPSHOT_FILE);
        if snap_path.exists() {
            let text = std::fs::read_to_string(&snap_path)
                .map_err(|e| Error::io(snap_path.display().to_string(), e))?;
            let parsed = crate::util::json::parse(&text)?;
            let Value::Arr(items) = &parsed else {
                return Err(Error::Coordinator(format!(
                    "hub snapshot {} is not a JSON array",
                    snap_path.display()
                )));
            };
            for item in items {
                merge_entry(&mut map, HubEntry::from_json(item)?);
            }
            report.snapshot_entries = map.len();
        }

        let log_path = opts.dir.join(LOG_FILE);
        let mut file = OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .truncate(false)
            .open(&log_path)
            .map_err(|e| Error::io(log_path.display().to_string(), e))?;
        let mut buf = Vec::new();
        file.read_to_end(&mut buf).map_err(|e| Error::io(log_path.display().to_string(), e))?;

        let mut offset = 0usize;
        while offset < buf.len() {
            let Some(body) = read_record(&buf[offset..]) else { break };
            match body.and_then(parse_entry) {
                Some(entry) => {
                    let len = entry_len(&buf[offset..]);
                    merge_entry(&mut map, entry);
                    report.log_records += 1;
                    offset += len;
                }
                None => break, // corrupt record: treat as torn tail
            }
        }
        if offset < buf.len() {
            report.truncated_bytes = (buf.len() - offset) as u64;
            log::warn!(
                "hub: {} torn/corrupt byte(s) at log tail of {} (crash mid-append); \
                 truncating and continuing with {} replayed record(s)",
                report.truncated_bytes,
                log_path.display(),
                report.log_records
            );
            file.set_len(offset as u64).map_err(|e| Error::io(log_path.display().to_string(), e))?;
            file.sync_all().map_err(|e| Error::io(log_path.display().to_string(), e))?;
        }
        file.seek(SeekFrom::Start(offset as u64))
            .map_err(|e| Error::io(log_path.display().to_string(), e))?;

        let log = HubLog {
            dir: opts.dir.clone(),
            file,
            compact_every: opts.compact_every,
            records_since_snapshot: report.log_records as u64,
        };
        Ok((log, map, report))
    }

    /// Append one entry record and `fdatasync` it — callers ack the
    /// publish only after this returns.
    pub fn append(&mut self, entry: &HubEntry) -> Result<()> {
        let body = entry.to_json().to_json();
        let bytes = body.as_bytes();
        if bytes.len() > MAX_FRAME_BYTES {
            return Err(Error::Coordinator(format!(
                "hub: log record too large ({} bytes)",
                bytes.len()
            )));
        }
        let log_path = self.dir.join(LOG_FILE);
        let io = |e: std::io::Error| Error::io(log_path.display().to_string(), e);
        self.file.write_all(&(bytes.len() as u32).to_be_bytes()).map_err(io)?;
        self.file.write_all(&crc32(bytes).to_be_bytes()).map_err(io)?;
        self.file.write_all(bytes).map_err(io)?;
        self.file.sync_data().map_err(io)?;
        self.records_since_snapshot += 1;
        Ok(())
    }

    /// Whether the log has grown enough to warrant a snapshot compact.
    pub fn should_compact(&self) -> bool {
        self.compact_every > 0 && self.records_since_snapshot >= self.compact_every
    }

    /// Snapshot `entries` and reset the log. Crash-ordering: the
    /// snapshot lands atomically first; only then is the log truncated,
    /// so a crash in between merely re-replays records the snapshot
    /// already contains (idempotent under LWW merge).
    pub fn compact(&mut self, entries: &BTreeMap<EntryKey, HubEntry>) -> Result<()> {
        let snap = Value::Arr(entries.values().map(HubEntry::to_json).collect()).to_json();
        atomic_write(&self.dir.join(SNAPSHOT_FILE), &snap)?;
        let log_path = self.dir.join(LOG_FILE);
        let io = |e: std::io::Error| Error::io(log_path.display().to_string(), e);
        self.file.set_len(0).map_err(io)?;
        self.file.seek(SeekFrom::Start(0)).map_err(io)?;
        self.file.sync_all().map_err(io)?;
        self.records_since_snapshot = 0;
        log::debug!("hub: compacted log into snapshot ({} entries)", entries.len());
        Ok(())
    }

    /// Records appended since the last snapshot (diagnostics/tests).
    pub fn records_since_snapshot(&self) -> u64 {
        self.records_since_snapshot
    }
}

/// Slice one record's body out of `buf` (which starts at a record
/// boundary). `None` means the bytes end mid-record; `Some(None)` means
/// a structurally complete but corrupt record (bad length or checksum).
#[allow(clippy::option_option)]
fn read_record(buf: &[u8]) -> Option<Option<&[u8]>> {
    if buf.len() < RECORD_HEADER {
        return None;
    }
    let len = u32::from_be_bytes([buf[0], buf[1], buf[2], buf[3]]) as usize;
    if len == 0 || len > MAX_FRAME_BYTES {
        return Some(None);
    }
    if buf.len() < RECORD_HEADER + len {
        return None;
    }
    let crc = u32::from_be_bytes([buf[4], buf[5], buf[6], buf[7]]);
    let body = &buf[RECORD_HEADER..RECORD_HEADER + len];
    if crc32(body) != crc {
        return Some(None);
    }
    Some(Some(body))
}

/// Total on-disk length of the (valid) record at the head of `buf`.
fn entry_len(buf: &[u8]) -> usize {
    RECORD_HEADER + u32::from_be_bytes([buf[0], buf[1], buf[2], buf[3]]) as usize
}

fn parse_entry(body: &[u8]) -> Option<HubEntry> {
    let text = std::str::from_utf8(body).ok()?;
    let value = crate::util::json::parse(text).ok()?;
    HubEntry::from_json(&value).ok()
}

/// CRC-32 (IEEE 802.3, reflected) — the log's torn-write detector.
pub fn crc32(bytes: &[u8]) -> u32 {
    const TABLE: [u32; 256] = crc32_table();
    let mut crc = !0u32;
    for &b in bytes {
        crc = (crc >> 8) ^ TABLE[((crc ^ b as u32) & 0xFF) as usize];
    }
    !crc
}

const fn crc32_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut bit = 0;
        while bit < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            bit += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(kernel: &str, winner: i64, version: u64) -> HubEntry {
        HubEntry {
            kernel: kernel.into(),
            param: "p".into(),
            signature: "f32[8,8]".into(),
            values: vec![0, 1],
            winner_value: winner,
            version,
        }
    }

    fn temp_dir(tag: &str) -> PathBuf {
        let dir = crate::testutil::temp_path(&format!("hub-persist-{tag}"), "d");
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn crc32_matches_known_vectors() {
        // canonical IEEE test vector
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn append_then_reopen_restores_entries() {
        let dir = temp_dir("roundtrip");
        let opts = PersistOptions::at(&dir);
        {
            let (mut log, map, report) = HubLog::open(&opts).unwrap();
            assert!(map.is_empty());
            assert_eq!(report, ReplayReport::default());
            log.append(&entry("a", 1, 1)).unwrap();
            log.append(&entry("b", 0, 3)).unwrap();
            log.append(&entry("a", 0, 2)).unwrap(); // newer version of `a`
        }
        let (_log, map, report) = HubLog::open(&opts).unwrap();
        assert_eq!(report.log_records, 3);
        assert_eq!(report.truncated_bytes, 0);
        assert_eq!(map.len(), 2);
        let a = map.values().find(|e| e.kernel == "a").unwrap();
        assert_eq!((a.winner_value, a.version), (0, 2), "replay is LWW");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn torn_tail_is_truncated_and_log_stays_appendable() {
        let dir = temp_dir("torn");
        let opts = PersistOptions::at(&dir);
        {
            let (mut log, _, _) = HubLog::open(&opts).unwrap();
            log.append(&entry("a", 1, 1)).unwrap();
            log.append(&entry("b", 0, 1)).unwrap();
        }
        // crash mid-append: a partial record (length prefix promising
        // more bytes than exist) lands at the tail
        let log_path = dir.join(LOG_FILE);
        let clean_len = std::fs::metadata(&log_path).unwrap().len();
        let mut f = OpenOptions::new().append(true).open(&log_path).unwrap();
        f.write_all(&200u32.to_be_bytes()).unwrap();
        f.write_all(&[0xAB; 10]).unwrap();
        drop(f);

        let (mut log, map, report) = HubLog::open(&opts).unwrap();
        assert_eq!(report.log_records, 2, "records before the tear survive");
        assert_eq!(report.truncated_bytes, 14);
        assert_eq!(map.len(), 2);
        assert_eq!(std::fs::metadata(&log_path).unwrap().len(), clean_len, "tail truncated");
        // the log keeps working after recovery
        log.append(&entry("c", 1, 1)).unwrap();
        drop(log);
        let (_log, map, report) = HubLog::open(&opts).unwrap();
        assert_eq!((map.len(), report.log_records, report.truncated_bytes), (3, 3, 0));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_checksum_tail_is_detected() {
        let dir = temp_dir("crc");
        let opts = PersistOptions::at(&dir);
        {
            let (mut log, _, _) = HubLog::open(&opts).unwrap();
            log.append(&entry("a", 1, 1)).unwrap();
            log.append(&entry("b", 0, 1)).unwrap();
        }
        // flip one byte inside the *last* record's body: the length
        // still reads fine, only the checksum catches it
        let log_path = dir.join(LOG_FILE);
        let mut bytes = std::fs::read(&log_path).unwrap();
        let last = bytes.len() - 3;
        bytes[last] ^= 0x40;
        std::fs::write(&log_path, &bytes).unwrap();

        let (_log, map, report) = HubLog::open(&opts).unwrap();
        assert_eq!(report.log_records, 1, "only the intact prefix replays");
        assert!(report.truncated_bytes > 0);
        assert_eq!(map.len(), 1);
        assert_eq!(map.values().next().unwrap().kernel, "a");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn compaction_snapshots_and_resets_the_log() {
        let dir = temp_dir("compact");
        let opts = PersistOptions { dir: dir.clone(), compact_every: 3 };
        let (mut log, mut map, _) = HubLog::open(&opts).unwrap();
        for v in 1..=3u64 {
            let e = entry("a", v as i64 % 2, v);
            merge_entry(&mut map, e.clone());
            log.append(&e).unwrap();
        }
        assert!(log.should_compact());
        log.compact(&map).unwrap();
        assert!(!log.should_compact());
        assert_eq!(std::fs::metadata(dir.join(LOG_FILE)).unwrap().len(), 0);

        // post-compaction appends land in the fresh log; reopen sees
        // snapshot + new records
        let e = entry("b", 1, 1);
        merge_entry(&mut map, e.clone());
        log.append(&e).unwrap();
        drop(log);
        let (_log, restored, report) = HubLog::open(&opts).unwrap();
        assert_eq!(report.snapshot_entries, 1);
        assert_eq!(report.log_records, 1);
        assert_eq!(restored, map);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn zero_compact_every_never_compacts() {
        let dir = temp_dir("nocompact");
        let opts = PersistOptions { dir: dir.clone(), compact_every: 0 };
        let (mut log, _, _) = HubLog::open(&opts).unwrap();
        for v in 1..=10u64 {
            log.append(&entry("a", 0, v)).unwrap();
            assert!(!log.should_compact());
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
}
