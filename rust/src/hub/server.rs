//! The hub broker: a Unix-domain-socket server holding the fleet's
//! tuned map.
//!
//! Deliberately boring: one accept loop, one thread per connection
//! (fleets are tens of processes, not thousands), state behind a mutex.
//! The broker is manifest-agnostic — it stores whatever entries clients
//! publish and lets *pullers* validate against their own manifest, so
//! one hub can serve heterogeneous binaries.

use std::collections::BTreeMap;
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::error::Result;
use crate::sync::TrackedMutex;

use super::protocol::{
    merge_entry, proto_err, read_frame, write_frame, EntryKey, Frame, HubEntry, Merge,
    PROTOCOL_VERSION,
};

/// Broker state shared across connection threads.
struct Shared {
    entries: TrackedMutex<BTreeMap<EntryKey, HubEntry>>,
    publishes: AtomicU64, // relaxed-counter: stats-only tally
    pulls: AtomicU64,     // relaxed-counter: stats-only tally
    conflicts: AtomicU64, // relaxed-counter: stats-only tally
}

/// The tuned-state hub broker.
pub struct HubServer {
    listener: UnixListener,
    path: PathBuf,
    shared: Arc<Shared>,
}

impl HubServer {
    /// Bind the broker socket, replacing a stale socket file from a
    /// previous run. A path where a broker is still *answering* is
    /// refused — unlinking a live broker's socket would silently split
    /// the fleet across two inconsistent in-memory maps. Bind is
    /// attempted *first* (no probe-then-unlink window for a racing
    /// broker to fall into): only an `AddrInUse` failure probes the
    /// existing socket, and only a socket nobody answers is removed.
    pub fn bind(path: impl AsRef<Path>) -> Result<HubServer> {
        let path = path.as_ref().to_path_buf();
        let bind_once = |path: &Path| UnixListener::bind(path);
        let listener = match bind_once(&path) {
            Ok(l) => l,
            Err(e) if e.kind() == std::io::ErrorKind::AddrInUse => {
                if UnixStream::connect(&path).is_ok() {
                    return Err(proto_err(format!(
                        "a broker is already serving on {}",
                        path.display()
                    )));
                }
                std::fs::remove_file(&path).map_err(|e| {
                    proto_err(format!("remove stale socket {}: {e}", path.display()))
                })?;
                // a concurrent bind in this window surfaces as an error
                // here — never a silent hijack
                bind_once(&path)
                    .map_err(|e| proto_err(format!("bind {}: {e}", path.display())))?
            }
            Err(e) => return Err(proto_err(format!("bind {}: {e}", path.display()))),
        };
        let shared = Arc::new(Shared {
            entries: TrackedMutex::new("hub.entries", BTreeMap::new()),
            publishes: AtomicU64::new(0),
            pulls: AtomicU64::new(0),
            conflicts: AtomicU64::new(0),
        });
        Ok(HubServer { listener, path, shared })
    }

    /// Socket path this broker listens on.
    pub fn socket_path(&self) -> &Path {
        &self.path
    }

    /// Number of entries currently held.
    pub fn entries(&self) -> usize {
        self.shared.entries.lock().len()
    }

    /// (publishes, pulls, merge conflicts) counters.
    pub fn counters(&self) -> (u64, u64, u64) {
        (
            self.shared.publishes.load(Ordering::Relaxed),
            self.shared.pulls.load(Ordering::Relaxed),
            self.shared.conflicts.load(Ordering::Relaxed),
        )
    }

    /// Serve until the process exits: accept connections and spawn one
    /// handler thread each. Accept errors are logged and survived.
    pub fn serve_forever(&self) -> Result<()> {
        log::info!("hub: listening on {}", self.path.display());
        for stream in self.listener.incoming() {
            match stream {
                Ok(stream) => {
                    let shared = Arc::clone(&self.shared);
                    // a failed handler spawn (thread exhaustion at peak
                    // fleet size) drops one connection, never the broker
                    if let Err(e) = std::thread::Builder::new()
                        .name("jitune-hub-conn".into())
                        .spawn(move || handle_conn(stream, &shared))
                    {
                        log::warn!("hub: could not spawn handler: {e}");
                    }
                }
                Err(e) => log::warn!("hub: accept failed: {e}"),
            }
        }
        Ok(())
    }

    /// Run the broker on a background thread (examples and tests; the
    /// thread serves until process exit).
    pub fn spawn(self) -> std::thread::JoinHandle<()> {
        std::thread::Builder::new()
            .name("jitune-hub".into())
            .spawn(move || {
                if let Err(e) = self.serve_forever() {
                    log::warn!("hub: server stopped: {e}");
                }
            })
            // jitune-lint: allow(L005): spawn failure at broker startup is unrecoverable
            .expect("spawn hub server thread")
    }
}

/// Serve one client connection until it disconnects.
fn handle_conn(mut stream: UnixStream, shared: &Shared) {
    loop {
        let frame = match read_frame(&mut stream) {
            Ok(f) => f,
            Err(_) => return, // EOF or a broken peer: drop the connection
        };
        let reply = match frame {
            Frame::Hello { protocol, peer } => {
                if protocol != PROTOCOL_VERSION {
                    log::warn!("hub: peer {peer} speaks v{protocol}, want v{PROTOCOL_VERSION}");
                }
                let entries = shared.entries.lock().len() as i64;
                Frame::HelloAck { protocol: PROTOCOL_VERSION, entries }
            }
            Frame::PullAll => {
                shared.pulls.fetch_add(1, Ordering::Relaxed);
                let entries: Vec<HubEntry> =
                    shared.entries.lock().values().cloned().collect();
                Frame::Update { entries }
            }
            Frame::Publish { entry } => {
                shared.publishes.fetch_add(1, Ordering::Relaxed);
                let label = entry.problem_key();
                let key = entry.entry_key();
                let proposed = entry.version;
                let mut map = shared.entries.lock();
                let merge = merge_entry(&mut map, entry);
                // jitune-lint: allow(L005): merge_entry always leaves `key` present in the map
                let stored = map.get(&key).expect("merged entry present").version;
                drop(map);
                let conflict = matches!(merge, Merge::Conflict { .. } | Merge::Outdated);
                if conflict {
                    shared.conflicts.fetch_add(1, Ordering::Relaxed);
                    log::warn!("hub: conflict on {label} (proposed v{proposed}, stored v{stored})");
                } else {
                    log::debug!("hub: publish {label} → v{stored} ({merge:?})");
                }
                Frame::Ack { version: stored, conflict }
            }
            other => {
                // a server-bound stream must never carry server frames
                log::warn!("hub: unexpected frame from client: {other:?}");
                return;
            }
        };
        if write_frame(&mut stream, &reply).is_err() {
            return;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hub::client::{HubClient, HubOptions};

    fn temp_socket(tag: &str) -> PathBuf {
        crate::testutil::temp_path(&format!("hub-test-{tag}"), "sock")
    }

    fn entry(kernel: &str, winner: i64, version: u64) -> HubEntry {
        HubEntry {
            kernel: kernel.into(),
            param: "p".into(),
            signature: "f32[8,8]".into(),
            values: vec![0, 1],
            winner_value: winner,
            version,
        }
    }

    #[test]
    fn publish_pull_roundtrip_across_clients() {
        let path = temp_socket("roundtrip");
        let server = HubServer::bind(&path).unwrap();
        server.spawn();

        let mut a = HubClient::connect(HubOptions::at(&path)).unwrap();
        let mut b = HubClient::connect(HubOptions::at(&path)).unwrap();
        assert!(a.pull_all().unwrap().is_empty());

        let ack = a.publish(&entry("k", 1, 1)).unwrap();
        assert_eq!((ack.version, ack.conflict), (1, false));
        let pulled = b.pull_all().unwrap();
        assert_eq!(pulled.len(), 1);
        assert_eq!(pulled[0].winner_value, 1);

        // a retune publishes a newer version; the other client sees it
        let ack = a.publish(&entry("k", 0, 2)).unwrap();
        assert_eq!((ack.version, ack.conflict), (2, false));
        let pulled = b.pull_all().unwrap();
        assert_eq!((pulled[0].winner_value, pulled[0].version), (0, 2));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn concurrent_publishers_conflict_is_last_writer_wins() {
        let path = temp_socket("conflict");
        HubServer::bind(&path).unwrap().spawn();
        let mut a = HubClient::connect(HubOptions::at(&path)).unwrap();
        let mut b = HubClient::connect(HubOptions::at(&path)).unwrap();

        // both processes tuned from scratch and propose version 1
        let ack_a = a.publish(&entry("k", 0, 1)).unwrap();
        assert!(!ack_a.conflict);
        let ack_b = b.publish(&entry("k", 1, 1)).unwrap();
        assert!(ack_b.conflict, "same version, different winner");
        assert_eq!(ack_b.version, 2, "conflict re-versions above the stored entry");

        // the later writer's entry is what the fleet now pulls
        let pulled = a.pull_all().unwrap();
        assert_eq!((pulled[0].winner_value, pulled[0].version), (1, 2));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn bind_replaces_stale_socket_file() {
        let path = temp_socket("stale");
        std::fs::write(&path, b"stale").unwrap();
        let server = HubServer::bind(&path).unwrap();
        assert_eq!(server.entries(), 0);
        assert_eq!(server.socket_path(), path.as_path());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn bind_refuses_to_hijack_a_live_broker() {
        let path = temp_socket("hijack");
        let server = HubServer::bind(&path).unwrap();
        // keep the first broker accepting, then try to bind again
        server.spawn();
        let err = HubServer::bind(&path).err().expect("second bind must fail");
        assert!(err.to_string().contains("already serving"), "{err}");
        // the live broker is untouched: clients still reach it
        let mut c = HubClient::connect(HubOptions::at(&path)).unwrap();
        assert!(c.pull_all().unwrap().is_empty());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn client_reconnects_after_a_dropped_stream() {
        let path = temp_socket("reconnect");
        HubServer::bind(&path).unwrap().spawn();
        let mut c = HubClient::connect(HubOptions::at(&path)).unwrap();
        c.publish(&entry("k", 1, 1)).unwrap();
        // sabotage the live stream: the next request must transparently
        // redial instead of failing
        c.shutdown_stream_for_test();
        let pulled = c.pull_all().unwrap();
        assert_eq!(pulled.len(), 1);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn connect_fails_fast_without_a_server() {
        let path = temp_socket("nobody");
        let opts = HubOptions {
            connect_retries: 2,
            retry_delay: std::time::Duration::from_millis(1),
            ..HubOptions::at(&path)
        };
        assert!(HubClient::connect(opts).is_err());
    }
}
