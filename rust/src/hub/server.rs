//! The hub broker: the server holding the fleet's tuned map, over a
//! Unix-domain socket (same host), TCP (cross-host fleets), or both.
//!
//! Deliberately boring: one accept loop, one thread per connection
//! (fleets are tens of processes, not thousands), state behind a mutex.
//! The broker is manifest-agnostic — it stores whatever entries clients
//! publish and lets *pullers* validate against their own manifest, so
//! one hub can serve heterogeneous binaries.
//!
//! With [`BrokerOptions::persist`] set, every accepted publish is
//! appended (and fsynced) to an on-disk log *before* it is acked, and
//! [`HubServer::bind_with`] replays log + snapshot — a restarted broker
//! comes back with the fleet's winners. See [`super::persist`] for the
//! durability model.
//!
//! Clients that [`Frame::Subscribe`] get every accepted publish pushed
//! to them as an [`Frame::Update`] — propagation is push-first, with
//! periodic pulls as the fallback.

use std::collections::BTreeMap;
use std::net::TcpListener;
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::error::Result;
use crate::sync::TrackedMutex;

use super::persist::{HubLog, PersistOptions, ReplayReport};
use super::protocol::{
    merge_entry, proto_err, read_frame, write_frame, EntryKey, Frame, HubEntry, Merge,
    PROTOCOL_VERSION,
};
use super::transport::HubStream;

/// Broker configuration: which transports to listen on and whether the
/// tuned map is durable.
#[derive(Debug, Clone, Default)]
pub struct BrokerOptions {
    /// Unix-domain socket path to listen on.
    pub socket: Option<PathBuf>,
    /// TCP listen address (`host:port`; port 0 picks a free port —
    /// read it back via [`HubServer::tcp_addr`]).
    pub tcp: Option<String>,
    /// Persist directory — `None` keeps the map in memory only.
    pub persist: Option<PersistOptions>,
}

impl BrokerOptions {
    /// Listen on a Unix socket only (the pre-TCP default).
    pub fn unix(path: impl AsRef<Path>) -> BrokerOptions {
        BrokerOptions { socket: Some(path.as_ref().to_path_buf()), ..Default::default() }
    }

    /// Add a TCP listener.
    pub fn with_tcp(mut self, addr: impl Into<String>) -> BrokerOptions {
        self.tcp = Some(addr.into());
        self
    }

    /// Make the tuned map durable under `persist`.
    pub fn with_persist(mut self, persist: PersistOptions) -> BrokerOptions {
        self.persist = Some(persist);
        self
    }
}

/// One push-subscribed client connection.
struct Subscriber {
    id: u64,
    peer: String,
    /// Pushed-to socket clone; the lock serializes writers (the
    /// `Subscribed` reply and every publisher thread's push).
    stream: Arc<TrackedMutex<HubStream>>,
}

/// Broker state shared across connection threads.
struct Shared {
    entries: TrackedMutex<BTreeMap<EntryKey, HubEntry>>,
    /// Durable log; publishes append+fsync here before they are acked.
    log: Option<TrackedMutex<HubLog>>,
    subscribers: TrackedMutex<Vec<Subscriber>>,
    next_subscriber: AtomicU64, // relaxed-counter: id allocator, never synchronizes
    publishes: AtomicU64,       // relaxed-counter: stats-only tally
    pulls: AtomicU64,           // relaxed-counter: stats-only tally
    conflicts: AtomicU64,       // relaxed-counter: stats-only tally
    notifies: AtomicU64,        // relaxed-counter: stats-only tally
    /// Injection hook: the next accepted connection's handler spawn
    /// "fails" (per-broker so parallel tests cannot interfere).
    #[cfg(test)]
    fail_next_spawn: AtomicBool,
}

/// Signals a serving broker to wind down (accept loop exits, listeners
/// close, subscriber push channels shut). Cloneable; obtained from
/// [`HubServer::stop_handle`] before [`HubServer::spawn`] consumes the
/// server.
#[derive(Clone)]
pub struct HubStopHandle {
    stop: Arc<AtomicBool>,
}

impl HubStopHandle {
    /// Request shutdown; the serve loop notices within its poll tick.
    pub fn stop(&self) {
        self.stop.store(true, Ordering::Release);
    }
}

/// The tuned-state hub broker.
pub struct HubServer {
    unix: Option<UnixListener>,
    tcp: Option<TcpListener>,
    path: Option<PathBuf>,
    tcp_local: Option<std::net::SocketAddr>,
    replay: ReplayReport,
    shared: Arc<Shared>,
    stop: Arc<AtomicBool>,
}

impl HubServer {
    /// Bind a Unix-socket-only, in-memory broker (the original shape;
    /// see [`HubServer::bind_with`] for TCP and persistence).
    pub fn bind(path: impl AsRef<Path>) -> Result<HubServer> {
        HubServer::bind_with(BrokerOptions::unix(path))
    }

    /// Bind the configured listeners and, when persistence is enabled,
    /// replay the on-disk log/snapshot so the broker comes back with
    /// the fleet's winners.
    ///
    /// For the Unix socket, a stale socket file from a previous run is
    /// replaced — but a path where a broker is still *answering* is
    /// refused (unlinking a live broker's socket would silently split
    /// the fleet across two inconsistent maps). Bind is attempted
    /// *first* (no probe-then-unlink window for a racing broker to fall
    /// into): only an `AddrInUse` failure probes the existing socket,
    /// and only a socket nobody answers is removed.
    pub fn bind_with(opts: BrokerOptions) -> Result<HubServer> {
        if opts.socket.is_none() && opts.tcp.is_none() {
            return Err(proto_err("broker needs at least one listener (socket or tcp)"));
        }
        let unix = match &opts.socket {
            None => None,
            Some(path) => Some(bind_unix(path)?),
        };
        let tcp = match &opts.tcp {
            None => None,
            Some(addr) => Some(
                TcpListener::bind(addr).map_err(|e| proto_err(format!("bind tcp {addr}: {e}")))?,
            ),
        };
        let tcp_local = match &tcp {
            Some(l) => {
                Some(l.local_addr().map_err(|e| proto_err(format!("tcp local addr: {e}")))?)
            }
            None => None,
        };
        let (log, entries, replay) = match &opts.persist {
            None => (None, BTreeMap::new(), ReplayReport::default()),
            Some(popts) => {
                let (log, entries, replay) = HubLog::open(popts)?;
                if replay.snapshot_entries + replay.log_records > 0 {
                    log::info!(
                        "hub: restored {} entr{} from {} (snapshot {}, log records {})",
                        entries.len(),
                        if entries.len() == 1 { "y" } else { "ies" },
                        popts.dir.display(),
                        replay.snapshot_entries,
                        replay.log_records
                    );
                }
                (Some(TrackedMutex::new("hub.log", log)), entries, replay)
            }
        };
        let shared = Arc::new(Shared {
            entries: TrackedMutex::new("hub.entries", entries),
            log,
            subscribers: TrackedMutex::new("hub.subscribers", Vec::new()),
            next_subscriber: AtomicU64::new(0),
            publishes: AtomicU64::new(0),
            pulls: AtomicU64::new(0),
            conflicts: AtomicU64::new(0),
            notifies: AtomicU64::new(0),
            #[cfg(test)]
            fail_next_spawn: AtomicBool::new(false),
        });
        Ok(HubServer {
            unix,
            tcp,
            path: opts.socket,
            tcp_local,
            replay,
            shared,
            stop: Arc::new(AtomicBool::new(false)),
        })
    }

    /// Unix socket path this broker listens on, if any.
    pub fn socket_path(&self) -> Option<&Path> {
        self.path.as_deref()
    }

    /// Resolved TCP listen address, if any (port 0 specs resolve to the
    /// actual port here).
    pub fn tcp_addr(&self) -> Option<std::net::SocketAddr> {
        self.tcp_local
    }

    /// What replay restored at bind time (zeros without persistence).
    pub fn replay_report(&self) -> ReplayReport {
        self.replay
    }

    /// Number of entries currently held.
    pub fn entries(&self) -> usize {
        self.shared.entries.lock().len()
    }

    /// (publishes, pulls, merge conflicts) counters.
    pub fn counters(&self) -> (u64, u64, u64) {
        (
            self.shared.publishes.load(Ordering::Relaxed),
            self.shared.pulls.load(Ordering::Relaxed),
            self.shared.conflicts.load(Ordering::Relaxed),
        )
    }

    /// Update pushes delivered to subscribers.
    pub fn notifies(&self) -> u64 {
        self.shared.notifies.load(Ordering::Relaxed)
    }

    /// Handle that stops a serving broker (see [`HubStopHandle`]).
    pub fn stop_handle(&self) -> HubStopHandle {
        HubStopHandle { stop: Arc::clone(&self.stop) }
    }

    /// Serve until stopped (or forever): accept connections on every
    /// listener and spawn one handler thread each. Accept errors are
    /// logged and survived; so is a failed handler spawn (thread
    /// exhaustion at peak fleet size drops one connection, never the
    /// broker). On stop, listeners close, the Unix socket file is
    /// unlinked, and subscriber push channels are shut so their handler
    /// threads unblock.
    pub fn serve_forever(&self) -> Result<()> {
        match (&self.path, &self.tcp_local) {
            (Some(p), Some(t)) => log::info!("hub: listening on {} and tcp {t}", p.display()),
            (Some(p), None) => log::info!("hub: listening on {}", p.display()),
            (None, Some(t)) => log::info!("hub: listening on tcp {t}"),
            (None, None) => {}
        }
        // Nonblocking accept + poll: the loop wakes every tick to check
        // the stop flag, so no sentinel wake-connection is needed (and
        // the listeners close promptly on stop).
        if let Some(l) = &self.unix {
            l.set_nonblocking(true).map_err(|e| proto_err(format!("unix nonblocking: {e}")))?;
        }
        if let Some(l) = &self.tcp {
            l.set_nonblocking(true).map_err(|e| proto_err(format!("tcp nonblocking: {e}")))?;
        }
        loop {
            if self.stop.load(Ordering::Acquire) {
                break;
            }
            let mut accepted = false;
            if let Some(l) = &self.unix {
                match l.accept() {
                    Ok((stream, _)) => {
                        accepted = true;
                        spawn_handler(HubStream::Unix(stream), &self.shared);
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {}
                    Err(e) => log::warn!("hub: unix accept failed: {e}"),
                }
            }
            if let Some(l) = &self.tcp {
                match l.accept() {
                    Ok((stream, _)) => {
                        accepted = true;
                        let _ = stream.set_nodelay(true);
                        spawn_handler(HubStream::Tcp(stream), &self.shared);
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {}
                    Err(e) => log::warn!("hub: tcp accept failed: {e}"),
                }
            }
            if !accepted {
                std::thread::sleep(Duration::from_millis(5));
            }
        }
        // unblock subscriber handler threads parked in read
        for sub in self.shared.subscribers.lock().drain(..) {
            sub.stream.lock().shutdown();
        }
        if let Some(p) = &self.path {
            let _ = std::fs::remove_file(p);
        }
        log::info!("hub: stopped");
        Ok(())
    }

    /// Run the broker on a background thread (examples, tests, and
    /// `jitune hub serve`; the thread serves until stopped via
    /// [`HubServer::stop_handle`] or process exit).
    pub fn spawn(self) -> std::thread::JoinHandle<()> {
        std::thread::Builder::new()
            .name("jitune-hub".into())
            .spawn(move || {
                if let Err(e) = self.serve_forever() {
                    log::warn!("hub: server stopped: {e}");
                }
            })
            // jitune-lint: allow(L005): spawn failure at broker startup is unrecoverable
            .expect("spawn hub server thread")
    }
}

/// Bind the Unix listener, replacing a stale socket file (see
/// [`HubServer::bind_with`] for the race discipline).
fn bind_unix(path: &Path) -> Result<UnixListener> {
    let bind_once = |path: &Path| UnixListener::bind(path);
    match bind_once(path) {
        Ok(l) => Ok(l),
        Err(e) if e.kind() == std::io::ErrorKind::AddrInUse => {
            if UnixStream::connect(path).is_ok() {
                return Err(proto_err(format!("a broker is already serving on {}", path.display())));
            }
            std::fs::remove_file(path)
                .map_err(|e| proto_err(format!("remove stale socket {}: {e}", path.display())))?;
            // a concurrent bind in this window surfaces as an error
            // here — never a silent hijack
            bind_once(path).map_err(|e| proto_err(format!("bind {}: {e}", path.display())))
        }
        Err(e) => Err(proto_err(format!("bind {}: {e}", path.display()))),
    }
}

/// Spawn one connection-handler thread. A failed spawn (thread/fd
/// exhaustion at peak fleet size) logs and drops that one connection —
/// it must never take the broker down.
fn spawn_handler(stream: HubStream, shared: &Arc<Shared>) {
    #[cfg(test)]
    if shared.fail_next_spawn.swap(false, Ordering::SeqCst) {
        log::warn!("hub: could not spawn handler: injected failure (connection dropped)");
        return;
    }
    let shared = Arc::clone(shared);
    if let Err(e) = std::thread::Builder::new()
        .name("jitune-hub-conn".into())
        .spawn(move || handle_conn(stream, &shared))
    {
        log::warn!("hub: could not spawn handler: {e} (connection dropped)");
    }
}

/// Serve one client connection until it disconnects. A connection that
/// subscribes turns into a push channel: the handler thread keeps
/// draining reads (to notice the disconnect) while publisher threads
/// push updates through the registered socket clone.
fn handle_conn(mut stream: HubStream, shared: &Shared) {
    let mut subscriber_id: Option<u64> = None;
    loop {
        let frame = match read_frame(&mut stream) {
            Ok(f) => f,
            Err(_) => break, // EOF or a broken peer: drop the connection
        };
        let reply = match frame {
            Frame::Hello { protocol, peer } => {
                if protocol != PROTOCOL_VERSION {
                    log::warn!("hub: peer {peer} speaks v{protocol}, want v{PROTOCOL_VERSION}");
                }
                let entries = shared.entries.lock().len() as i64;
                Frame::HelloAck { protocol: PROTOCOL_VERSION, entries }
            }
            Frame::PullAll => {
                shared.pulls.fetch_add(1, Ordering::Relaxed);
                let entries: Vec<HubEntry> = shared.entries.lock().values().cloned().collect();
                Frame::Update { entries }
            }
            Frame::Publish { entry } => apply_publish(shared, entry),
            Frame::Subscribe { peer } => {
                match register_subscriber(shared, &stream, peer) {
                    Ok((id, snapshot, writer)) => {
                        subscriber_id = Some(id);
                        // the Subscribed reply goes through the shared
                        // writer so it serializes against concurrent
                        // pushes (which may legitimately overtake it —
                        // the client tolerates either order)
                        let ok = {
                            let mut w = writer.lock();
                            write_frame(&mut *w, &Frame::Subscribed { entries: snapshot }).is_ok()
                        };
                        if !ok {
                            break;
                        }
                        continue; // stay in the read loop to notice EOF
                    }
                    Err(e) => {
                        log::warn!("hub: subscribe failed: {e}");
                        break;
                    }
                }
            }
            other => {
                // a server-bound stream must never carry server frames
                log::warn!("hub: unexpected frame from client: {other:?}");
                break;
            }
        };
        if write_frame(&mut stream, &reply).is_err() {
            break;
        }
    }
    if let Some(id) = subscriber_id {
        shared.subscribers.lock().retain(|s| s.id != id);
    }
}

/// Merge one published entry, persist it (fsync before ack), and push
/// it to subscribers. Returns the ack frame.
fn apply_publish(shared: &Shared, entry: HubEntry) -> Frame {
    shared.publishes.fetch_add(1, Ordering::Relaxed);
    let label = entry.problem_key();
    let key = entry.entry_key();
    let proposed = entry.version;
    let mut map = shared.entries.lock();
    let merge = merge_entry(&mut map, entry);
    // jitune-lint: allow(L005): merge_entry always leaves `key` present in the map
    let stored = map.get(&key).expect("merged entry present").clone();
    drop(map);
    let conflict = matches!(merge, Merge::Conflict { .. } | Merge::Outdated);
    if conflict {
        shared.conflicts.fetch_add(1, Ordering::Relaxed);
        log::warn!("hub: conflict on {label} (proposed v{proposed}, stored v{})", stored.version);
    } else {
        log::debug!("hub: publish {label} → v{} ({merge:?})", stored.version);
    }
    if matches!(merge, Merge::Inserted | Merge::Replaced | Merge::Conflict { .. }) {
        persist_entry(shared, &stored);
        notify_subscribers(shared, &stored);
    }
    Frame::Ack { version: stored.version, conflict }
}

/// Append one accepted entry to the durable log (when persistence is
/// on) and compact when due. Lock order: `hub.log` → `hub.entries`
/// (compaction snapshots the map while holding the log); no path locks
/// them in the opposite order.
fn persist_entry(shared: &Shared, stored: &HubEntry) {
    let Some(log) = &shared.log else { return };
    let mut lg = log.lock();
    if let Err(e) = lg.append(stored) {
        // keep serving from memory — durability degrades, the fleet
        // does not
        log::error!("hub: persist append failed: {e} — entry survives in memory only");
        return;
    }
    if lg.should_compact() {
        let snapshot = shared.entries.lock().clone();
        if let Err(e) = lg.compact(&snapshot) {
            log::warn!("hub: snapshot compaction failed: {e}");
        }
    }
}

/// Register a push subscriber: snapshot the map and add the socket
/// clone to the subscriber list *atomically with respect to publishes*
/// (both under `hub.entries`), so no accepted publish can fall between
/// the snapshot and the registration.
#[allow(clippy::type_complexity)]
fn register_subscriber(
    shared: &Shared,
    stream: &HubStream,
    peer: String,
) -> Result<(u64, Vec<HubEntry>, Arc<TrackedMutex<HubStream>>)> {
    let clone = stream.try_clone().map_err(|e| proto_err(format!("clone subscriber: {e}")))?;
    // a wedged subscriber must stall pushes for at most this long
    // before being dropped from the list
    clone
        .set_timeouts(Some(Duration::from_secs(5)))
        .map_err(|e| proto_err(format!("subscriber timeouts: {e}")))?;
    let writer = Arc::new(TrackedMutex::new("hub.sub.stream", clone));
    let id = shared.next_subscriber.fetch_add(1, Ordering::Relaxed);
    let map = shared.entries.lock();
    let snapshot: Vec<HubEntry> = map.values().cloned().collect();
    shared.subscribers.lock().push(Subscriber {
        id,
        peer: peer.clone(),
        stream: Arc::clone(&writer),
    });
    drop(map);
    log::debug!("hub: subscriber {peer} registered (#{id})");
    Ok((id, snapshot, writer))
}

/// Push one accepted entry to every subscriber; unreachable subscribers
/// are dropped from the list. Streams are pushed outside the subscriber
/// list lock (each has its own writer lock), so one slow subscriber
/// delays the others but cannot deadlock registration.
fn notify_subscribers(shared: &Shared, stored: &HubEntry) {
    let targets: Vec<(u64, String, Arc<TrackedMutex<HubStream>>)> = shared
        .subscribers
        .lock()
        .iter()
        .map(|s| (s.id, s.peer.clone(), Arc::clone(&s.stream)))
        .collect();
    if targets.is_empty() {
        return;
    }
    let update = Frame::Update { entries: vec![stored.clone()] };
    let mut dead = Vec::new();
    for (id, peer, stream) in targets {
        let mut w = stream.lock();
        if let Err(e) = write_frame(&mut *w, &update) {
            log::debug!("hub: dropping subscriber {peer} (#{id}): {e}");
            w.shutdown();
            dead.push(id);
        } else {
            shared.notifies.fetch_add(1, Ordering::Relaxed);
        }
    }
    if !dead.is_empty() {
        shared.subscribers.lock().retain(|s| !dead.contains(&s.id));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hub::client::{HubClient, HubOptions, HubSubscriber};

    fn temp_socket(tag: &str) -> PathBuf {
        crate::testutil::temp_path(&format!("hub-test-{tag}"), "sock")
    }

    fn entry(kernel: &str, winner: i64, version: u64) -> HubEntry {
        HubEntry {
            kernel: kernel.into(),
            param: "p".into(),
            signature: "f32[8,8]".into(),
            values: vec![0, 1],
            winner_value: winner,
            version,
        }
    }

    #[test]
    fn publish_pull_roundtrip_across_clients() {
        let path = temp_socket("roundtrip");
        let server = HubServer::bind(&path).unwrap();
        server.spawn();

        let mut a = HubClient::connect(HubOptions::at(&path)).unwrap();
        let mut b = HubClient::connect(HubOptions::at(&path)).unwrap();
        assert!(a.pull_all().unwrap().is_empty());

        let ack = a.publish(&entry("k", 1, 1)).unwrap();
        assert_eq!((ack.version, ack.conflict), (1, false));
        let pulled = b.pull_all().unwrap();
        assert_eq!(pulled.len(), 1);
        assert_eq!(pulled[0].winner_value, 1);

        // a retune publishes a newer version; the other client sees it
        let ack = a.publish(&entry("k", 0, 2)).unwrap();
        assert_eq!((ack.version, ack.conflict), (2, false));
        let pulled = b.pull_all().unwrap();
        assert_eq!((pulled[0].winner_value, pulled[0].version), (0, 2));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn tcp_transport_serves_the_same_protocol() {
        let server =
            HubServer::bind_with(BrokerOptions::default().with_tcp("127.0.0.1:0")).unwrap();
        let addr = server.tcp_addr().unwrap();
        server.spawn();

        let mut a = HubClient::connect(HubOptions::tcp(addr.to_string())).unwrap();
        let mut b = HubClient::connect(HubOptions::tcp(addr.to_string())).unwrap();
        let ack = a.publish(&entry("k", 1, 1)).unwrap();
        assert_eq!((ack.version, ack.conflict), (1, false));
        let pulled = b.pull_all().unwrap();
        assert_eq!(pulled.len(), 1);
        assert_eq!(pulled[0].winner_value, 1);
    }

    #[test]
    fn dual_transport_brokers_share_one_map() {
        let path = temp_socket("dual");
        let server =
            HubServer::bind_with(BrokerOptions::unix(&path).with_tcp("127.0.0.1:0")).unwrap();
        let addr = server.tcp_addr().unwrap();
        server.spawn();

        let mut unix = HubClient::connect(HubOptions::at(&path)).unwrap();
        let mut tcp = HubClient::connect(HubOptions::tcp(addr.to_string())).unwrap();
        unix.publish(&entry("k", 1, 1)).unwrap();
        let pulled = tcp.pull_all().unwrap();
        assert_eq!(pulled.len(), 1, "tcp client sees the unix client's publish");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn concurrent_publishers_conflict_is_last_writer_wins() {
        let path = temp_socket("conflict");
        HubServer::bind(&path).unwrap().spawn();
        let mut a = HubClient::connect(HubOptions::at(&path)).unwrap();
        let mut b = HubClient::connect(HubOptions::at(&path)).unwrap();

        // both processes tuned from scratch and propose version 1
        let ack_a = a.publish(&entry("k", 0, 1)).unwrap();
        assert!(!ack_a.conflict);
        let ack_b = b.publish(&entry("k", 1, 1)).unwrap();
        assert!(ack_b.conflict, "same version, different winner");
        assert_eq!(ack_b.version, 2, "conflict re-versions above the stored entry");

        // the later writer's entry is what the fleet now pulls
        let pulled = a.pull_all().unwrap();
        assert_eq!((pulled[0].winner_value, pulled[0].version), (1, 2));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn bind_replaces_stale_socket_file() {
        let path = temp_socket("stale");
        std::fs::write(&path, b"stale").unwrap();
        let server = HubServer::bind(&path).unwrap();
        assert_eq!(server.entries(), 0);
        assert_eq!(server.socket_path(), Some(path.as_path()));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn bind_refuses_to_hijack_a_live_broker() {
        let path = temp_socket("hijack");
        let server = HubServer::bind(&path).unwrap();
        // keep the first broker accepting, then try to bind again
        server.spawn();
        let err = HubServer::bind(&path).err().expect("second bind must fail");
        assert!(err.to_string().contains("already serving"), "{err}");
        // the live broker is untouched: clients still reach it
        let mut c = HubClient::connect(HubOptions::at(&path)).unwrap();
        assert!(c.pull_all().unwrap().is_empty());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn client_reconnects_after_a_dropped_stream() {
        let path = temp_socket("reconnect");
        HubServer::bind(&path).unwrap().spawn();
        let mut c = HubClient::connect(HubOptions::at(&path)).unwrap();
        c.publish(&entry("k", 1, 1)).unwrap();
        // sabotage the live stream: the next request must transparently
        // redial instead of failing
        c.shutdown_stream_for_test();
        let pulled = c.pull_all().unwrap();
        assert_eq!(pulled.len(), 1);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn connect_fails_fast_without_a_server() {
        let path = temp_socket("nobody");
        let opts = HubOptions {
            connect_retries: 2,
            retry_delay: std::time::Duration::from_millis(1),
            ..HubOptions::at(&path)
        };
        assert!(HubClient::connect(opts).is_err());
    }

    #[test]
    fn handler_spawn_failure_drops_one_connection_not_the_broker() {
        let path = temp_socket("spawnfail");
        let server = HubServer::bind(&path).unwrap();
        let shared = Arc::clone(&server.shared);
        server.spawn();
        // warm up: the broker answers before the injection
        let mut ok = HubClient::connect(HubOptions::at(&path)).unwrap();
        ok.publish(&entry("k", 1, 1)).unwrap();

        shared.fail_next_spawn.store(true, Ordering::SeqCst);
        let victim = HubClient::connect(HubOptions {
            connect_retries: 0,
            ..HubOptions::at(&path)
        });
        assert!(victim.is_err(), "the injected connection is dropped");
        assert!(!shared.fail_next_spawn.load(Ordering::SeqCst), "injection consumed");

        // the broker survived: existing and new clients still work
        assert_eq!(ok.pull_all().unwrap().len(), 1);
        let mut fresh = HubClient::connect(HubOptions::at(&path)).unwrap();
        assert_eq!(fresh.pull_all().unwrap().len(), 1);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn subscribers_get_publishes_pushed() {
        let path = temp_socket("push");
        let server = HubServer::bind(&path).unwrap();
        let shared = Arc::clone(&server.shared);
        server.spawn();

        let mut sub = HubSubscriber::connect(&HubOptions::at(&path)).unwrap();
        assert!(sub.take_initial().is_empty());

        let mut publisher = HubClient::connect(HubOptions::at(&path)).unwrap();
        publisher.publish(&entry("k", 1, 1)).unwrap();

        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        let mut got = Vec::new();
        while got.is_empty() && std::time::Instant::now() < deadline {
            if let Some(entries) = sub.next(Duration::from_millis(50)).unwrap() {
                got = entries;
            }
        }
        assert_eq!(got.len(), 1, "publish pushed to subscriber without polling");
        assert_eq!(got[0].winner_value, 1);
        assert!(shared.notifies.load(Ordering::Relaxed) >= 1);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn subscriber_snapshot_covers_pre_subscription_entries() {
        let path = temp_socket("snapshot");
        HubServer::bind(&path).unwrap().spawn();
        let mut publisher = HubClient::connect(HubOptions::at(&path)).unwrap();
        publisher.publish(&entry("k", 1, 3)).unwrap();

        let mut sub = HubSubscriber::connect(&HubOptions::at(&path)).unwrap();
        let initial = sub.take_initial();
        assert_eq!(initial.len(), 1);
        assert_eq!((initial[0].winner_value, initial[0].version), (1, 3));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn stop_handle_winds_the_broker_down() {
        let path = temp_socket("stop");
        let server = HubServer::bind(&path).unwrap();
        let stop = server.stop_handle();
        let join = server.spawn();
        let mut c = HubClient::connect(HubOptions::at(&path)).unwrap();
        c.publish(&entry("k", 1, 1)).unwrap();
        drop(c);
        stop.stop();
        join.join().unwrap();
        assert!(!path.exists(), "socket unlinked on stop");
        // a new broker can bind the same path immediately
        let server = HubServer::bind(&path).unwrap();
        drop(server);
        let _ = std::fs::remove_file(&path);
    }
}
