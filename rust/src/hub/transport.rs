//! Hub transport abstraction: one broker address / stream type over
//! both Unix-domain sockets (same-host fleets) and TCP (cross-host
//! fleets).
//!
//! The wire protocol ([`super::protocol`]) is transport-agnostic — a
//! frame is a frame over any byte stream — so the only transport-aware
//! pieces are connecting, cloning, timeouts and shutdown, all folded
//! into [`HubStream`]. Addresses parse from operator-facing strings:
//! `unix:/path/to.sock`, `tcp:host:port`, or a bare path (treated as a
//! Unix socket for backward compatibility).

use std::io::{Read, Write};
use std::net::TcpStream;
use std::os::unix::net::UnixStream;
use std::path::{Path, PathBuf};
use std::time::Duration;

use crate::error::Result;

use super::protocol::proto_err;

/// Where a hub broker lives.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum HubAddr {
    /// Unix-domain socket path (same host).
    Unix(PathBuf),
    /// TCP `host:port` (cross-host fleets).
    Tcp(String),
}

impl HubAddr {
    /// Parse an operator-facing address spec: `unix:<path>`,
    /// `tcp:<host:port>`, or a bare path (Unix socket).
    pub fn parse(spec: &str) -> Result<HubAddr> {
        if let Some(rest) = spec.strip_prefix("unix:") {
            if rest.is_empty() {
                return Err(proto_err("empty unix socket path in hub address"));
            }
            return Ok(HubAddr::Unix(PathBuf::from(rest)));
        }
        if let Some(rest) = spec.strip_prefix("tcp:") {
            if !rest.contains(':') {
                return Err(proto_err(format!("tcp hub address `{rest}` needs host:port")));
            }
            return Ok(HubAddr::Tcp(rest.to_string()));
        }
        if spec.is_empty() {
            return Err(proto_err("empty hub address"));
        }
        Ok(HubAddr::Unix(PathBuf::from(spec)))
    }

    /// Unix socket path, when this is a Unix address.
    pub fn unix_path(&self) -> Option<&Path> {
        match self {
            HubAddr::Unix(p) => Some(p),
            HubAddr::Tcp(_) => None,
        }
    }
}

impl std::fmt::Display for HubAddr {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            HubAddr::Unix(p) => write!(f, "unix:{}", p.display()),
            HubAddr::Tcp(a) => write!(f, "tcp:{a}"),
        }
    }
}

/// A connected broker stream over either transport. Implements
/// `Read`/`Write` so the frame codec never sees which one.
#[derive(Debug)]
pub enum HubStream {
    /// Unix-domain stream.
    Unix(UnixStream),
    /// TCP stream (`TCP_NODELAY` set: frames are small request/reply
    /// and push payloads, Nagle would only add latency).
    Tcp(TcpStream),
}

impl HubStream {
    /// Connect to `addr` (one attempt; retry policy lives in the
    /// client's dial loop).
    pub fn connect(addr: &HubAddr) -> std::io::Result<HubStream> {
        match addr {
            HubAddr::Unix(path) => UnixStream::connect(path).map(HubStream::Unix),
            HubAddr::Tcp(spec) => {
                let s = TcpStream::connect(spec)?;
                s.set_nodelay(true)?;
                Ok(HubStream::Tcp(s))
            }
        }
    }

    /// Set both read and write timeouts (`None` blocks forever).
    pub fn set_timeouts(&self, timeout: Option<Duration>) -> std::io::Result<()> {
        match self {
            HubStream::Unix(s) => {
                s.set_read_timeout(timeout)?;
                s.set_write_timeout(timeout)
            }
            HubStream::Tcp(s) => {
                s.set_read_timeout(timeout)?;
                s.set_write_timeout(timeout)
            }
        }
    }

    /// Set only the read timeout (subscriber streams poll reads but
    /// must not time out pushes).
    pub fn set_read_timeout(&self, timeout: Option<Duration>) -> std::io::Result<()> {
        match self {
            HubStream::Unix(s) => s.set_read_timeout(timeout),
            HubStream::Tcp(s) => s.set_read_timeout(timeout),
        }
    }

    /// Clone the underlying socket handle (used to push to a subscriber
    /// from publisher threads while its own thread blocks in read).
    pub fn try_clone(&self) -> std::io::Result<HubStream> {
        match self {
            HubStream::Unix(s) => s.try_clone().map(HubStream::Unix),
            HubStream::Tcp(s) => s.try_clone().map(HubStream::Tcp),
        }
    }

    /// Shut down both directions, unblocking any reader.
    pub fn shutdown(&self) {
        match self {
            HubStream::Unix(s) => {
                let _ = s.shutdown(std::net::Shutdown::Both);
            }
            HubStream::Tcp(s) => {
                let _ = s.shutdown(std::net::Shutdown::Both);
            }
        }
    }
}

impl Read for HubStream {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        match self {
            HubStream::Unix(s) => s.read(buf),
            HubStream::Tcp(s) => s.read(buf),
        }
    }
}

impl Write for HubStream {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        match self {
            HubStream::Unix(s) => s.write(buf),
            HubStream::Tcp(s) => s.write(buf),
        }
    }

    fn flush(&mut self) -> std::io::Result<()> {
        match self {
            HubStream::Unix(s) => s.flush(),
            HubStream::Tcp(s) => s.flush(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn addr_parses_all_spellings() {
        assert_eq!(
            HubAddr::parse("unix:/tmp/hub.sock").unwrap(),
            HubAddr::Unix(PathBuf::from("/tmp/hub.sock"))
        );
        assert_eq!(
            HubAddr::parse("tcp:127.0.0.1:7878").unwrap(),
            HubAddr::Tcp("127.0.0.1:7878".into())
        );
        // bare path stays a unix socket (backward compatibility)
        assert_eq!(
            HubAddr::parse("/tmp/hub.sock").unwrap(),
            HubAddr::Unix(PathBuf::from("/tmp/hub.sock"))
        );
        assert!(HubAddr::parse("").is_err());
        assert!(HubAddr::parse("unix:").is_err());
        assert!(HubAddr::parse("tcp:no-port").is_err());
    }

    #[test]
    fn addr_displays_roundtrip() {
        for spec in ["unix:/tmp/x.sock", "tcp:10.0.0.1:9000"] {
            let addr = HubAddr::parse(spec).unwrap();
            assert_eq!(addr.to_string(), spec);
            assert_eq!(HubAddr::parse(&addr.to_string()).unwrap(), addr);
        }
    }
}
