//! Tuned-state hub: a fleet-wide warm-start service.
//!
//! The paper's payoff is that "the programmer can obtain the optimal
//! parameters to use them for other kernels" — but without help that
//! knowledge dies with the process. `save_state`/`load_state` bridges
//! runs through files; the hub bridges *processes*: a tiny std-only
//! broker holding the fleet's tuned map, so any number of serving
//! processes warm-start from whichever process tuned first and adopt
//! retuned winners as they happen.
//!
//! # Pieces
//!
//! * [`protocol`] — the wire format: length-prefixed JSON frames
//!   ([`Frame`]: `Hello`/`HelloAck`/`PullAll`/`Update`/`Publish`/`Ack`)
//!   over any byte stream, carrying [`HubEntry`] records (the same
//!   kernel/param/signature/values/winner_value shape `save_state`
//!   writes, plus a per-entry monotonic `version`). The merge rule is
//!   last-writer-wins-by-version ([`merge_entry`]), shared by the broker
//!   and the `jitune state merge` CLI.
//! * [`server`] — [`HubServer`]: a Unix-domain-socket broker, one thread
//!   per connection, state under a mutex. Run it with
//!   `jitune hub serve --socket <path>` (or in-process via
//!   [`HubServer::spawn`] for examples/tests).
//! * [`client`] — [`HubClient`]: connect-with-retry, one reconnect per
//!   request, `pull_all` + `publish`. Configured by [`HubOptions`]
//!   (socket path, retry budget, optional periodic pull interval).
//!
//! # How the coordinator uses it
//!
//! With `ServerOptions { hub: Some(HubOptions::at(path)) }` the leader
//! connects at spawn, pulls the full tuned map and warm-starts every
//! matching problem (zero explore iterations — only the winner's final
//! compilation remains, as with `load_state`). Every finalization —
//! first tune, manual retune, drift-triggered retune — publishes the
//! winner back; other processes adopt it on their next pull (periodic
//! via `HubOptions::pull_interval`, or explicit via
//! `CoordinatorHandle::hub_pull`). `stats_json()` reports pushes, pulls,
//! adoptions and merge conflicts under `"hub"`.
//!
//! Everything is `std`-only: `std::os::unix::net` sockets and
//! [`crate::util::json`] for the frames — no new dependencies.

pub mod client;
pub mod protocol;
pub mod server;

pub use client::{HubClient, HubOptions, PublishAck};
pub use protocol::{merge_entry, read_frame, write_frame, EntryKey, Frame, HubEntry, Merge};
pub use server::HubServer;
