//! Tuned-state hub: a fleet-wide warm-start service that survives
//! broker restarts, spans hosts, and ships its cache.
//!
//! The paper's payoff is that "the programmer can obtain the optimal
//! parameters to use them for other kernels" — but without help that
//! knowledge dies with the process. `save_state`/`load_state` bridges
//! runs through files; the hub bridges *processes and machines*: a tiny
//! std-only broker holding the fleet's tuned map, so any number of
//! serving processes warm-start from whichever process tuned first and
//! adopt retuned winners as they happen.
//!
//! # Pieces
//!
//! * [`protocol`] — the wire format: length-prefixed JSON frames
//!   ([`Frame`]: `Hello`/`HelloAck`/`PullAll`/`Update`/`Publish`/`Ack`/
//!   `Subscribe`/`Subscribed`) over any byte stream, carrying
//!   [`HubEntry`] records (the same kernel/param/signature/values/
//!   winner_value shape `save_state` writes, plus a per-entry monotonic
//!   `version`). The merge rule is last-writer-wins-by-version
//!   ([`merge_entry`]), shared by the broker, replay, and the
//!   `jitune state merge` CLI.
//! * [`transport`] — [`HubAddr`]/[`HubStream`]: one address/stream type
//!   over Unix-domain sockets (same host) and TCP (cross-host fleets);
//!   the protocol never sees which.
//! * [`persist`] — [`HubLog`]: the broker's durability layer. Every
//!   accepted publish is appended to `entries.log` (`[len][crc32]
//!   [json]` records, fsynced **before** the ack), and the log is
//!   periodically compacted into `snapshot.json` (written via
//!   `util::atomic_write`, which fsyncs file *and* directory). Replay
//!   on bind folds snapshot + log through [`merge_entry`], so it is
//!   idempotent; a torn tail record from a crash mid-append is
//!   detected by length+checksum, logged, and truncated away.
//! * [`server`] — [`HubServer`]: the broker. One thread per connection,
//!   state under a mutex, configured by [`BrokerOptions`] (Unix socket
//!   and/or TCP listener, optional [`PersistOptions`]). Run it with
//!   `jitune hub serve --socket <path> [--listen <host:port>]
//!   [--persist <dir>]` (or in-process via [`HubServer::spawn`];
//!   [`HubServer::stop_handle`] winds it down cleanly). Subscribed
//!   clients get every accepted publish *pushed* as an `Update`.
//! * [`client`] — [`HubClient`]: connect-with-retry, one reconnect per
//!   request, `pull_all` + `publish`; and [`HubSubscriber`]: the push
//!   channel. Configured by [`HubOptions`] (address, retry budget,
//!   optional periodic pull interval, `subscribe`).
//!
//! # Durability model
//!
//! What survives a broker crash or restart: every publish that was
//! **acked** (the ack happens after the log append is fsynced) plus
//! everything in the last snapshot. What does not: nothing — an unacked
//! publish is re-asserted by its publisher anyway (`hub_publish`
//! re-publishes known winners on reconnect, and the coordinator's
//! resync path re-seeds a broker that did come back empty).
//!
//! # How the coordinator uses it
//!
//! With `ServerOptions { hub: Some(HubOptions::at(path)) }` the leader
//! connects at spawn, pulls the full tuned map and warm-starts every
//! matching problem (zero explore iterations — only the winner's final
//! compilation remains, as with `load_state`; with
//! `ServerOptions { prewarm: true }` even that compilation happens at
//! spawn, so the first call is already tuned). Every finalization —
//! first tune, manual retune, drift-triggered retune — publishes the
//! winner back. Propagation to other processes is push-first: with
//! `HubOptions { subscribe: true }` a notifier thread receives broker
//! pushes and triggers an immediate pull; `pull_interval` remains as
//! the fallback. `stats_json()` reports pushes, pulls, adoptions and
//! merge conflicts under `"hub"`.
//!
//! # Shipping the cache
//!
//! `jitune state export --hub <addr> <file>` captures the broker's map
//! as a single versioned artifact; `jitune state import --hub <addr>
//! <file>` publishes it into any other broker (LWW-merged), and
//! `jitune run --state-file <file>` boots a process straight from it —
//! tuned configurations as deployment artifacts.
//!
//! Everything is `std`-only: `std::os::unix::net` / `std::net` sockets
//! and [`crate::util::json`] for the frames — no new dependencies.

pub mod client;
pub mod persist;
pub mod protocol;
pub mod server;
pub mod transport;

pub use client::{HubClient, HubOptions, HubSubscriber, PublishAck};
pub use persist::{HubLog, PersistOptions, ReplayReport};
pub use protocol::{
    artifact_json, merge_entry, read_frame, state_entry_values, write_frame, EntryKey, Frame,
    HubEntry, Merge,
};
pub use server::{BrokerOptions, HubServer, HubStopHandle};
pub use transport::{HubAddr, HubStream};
